"""Shared constants for the Hybrid-LLM reproduction compile path.

Everything here must stay in sync with the rust side, which learns these
values from ``artifacts/manifest.txt`` (written by ``aot.py``) rather than
hard-coding them.

Vocabulary (64 tokens)
----------------------
0 PAD, 1 BOS, 2 EOS, 3 SEP, 4..29 letters a..z, 30..39 digits 0..9,
40..49 task keywords (COPY, DOUBLE, REV, SORT, DEDUP, SUCC, ADD, COUNT,
EXTR, ROT), 50 COLON marker, 51..63 reserved.
"""

from dataclasses import dataclass

VOCAB = 64
S_CTX = 64  # total context (prompt + generated answer)
S_PROMPT = 40  # max prompt length (incl BOS .. SEP)
A_MAX = 24  # max answer length (incl EOS)

PAD, BOS, EOS, SEP = 0, 1, 2, 3
LETTER0 = 4  # 'a'
DIGIT0 = 30  # '0'
TASK0 = 40  # first task keyword token
COLON = 50

GEN_B = 16  # batch for generation (prefill/decode) artifacts
TRAIN_B = 32  # batch for LM / router train-step artifacts
SCORE_B = 32  # batch for scorer artifacts

# Block-paged KV cache geometry (manifest v4). The pool holds KV_POOL
# blocks of KV_BLOCK tokens each per layer; block 0 is the reserved null
# block (free decode lanes and not-yet-allocated table entries point at
# it, so their writes land harmlessly and their garbage keys are masked
# out before softmax). KV_POOL = 1 null + GEN_B * (S_CTX // KV_BLOCK)
# for live slots + 2 * (S_CTX // KV_BLOCK) spare for cached prefixes.
KV_BLOCK = 8  # tokens per KV block
KV_MAXBLK = S_CTX // KV_BLOCK  # blocks per request table
KV_POOL = 1 + GEN_B * KV_MAXBLK + 2 * KV_MAXBLK  # pool blocks per layer

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01
GRAD_CLIP = 1.0


@dataclass(frozen=True)
class ModelCfg:
    """Transformer dims for one roster entry."""

    name: str
    d: int
    layers: int
    heads: int
    ff: int

    @property
    def head_dim(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads


# The LM roster mirrors the paper's model line-up (DESIGN.md §3):
#   nano   ~ FLAN-t5 (800m)     micro ~ FLAN-t5 (11b)
#   small  ~ Llama-2 (7b)       medium ~ Llama-2 (13b)
#   large  ~ GPT-3.5-turbo
# plus the BART-analogue scorer and the DeBERTa-analogue router encoder.
LM_SIZES = ("nano", "micro", "small", "medium", "large")

CFGS = {
    "nano": ModelCfg("nano", d=32, layers=1, heads=2, ff=64),
    "micro": ModelCfg("micro", d=48, layers=2, heads=3, ff=96),
    "small": ModelCfg("small", d=64, layers=3, heads=4, ff=128),
    "medium": ModelCfg("medium", d=96, layers=4, heads=4, ff=192),
    "large": ModelCfg("large", d=128, layers=6, heads=8, ff=256),
    "scorer": ModelCfg("scorer", d=96, layers=4, heads=4, ff=192),
    "router": ModelCfg("router", d=64, layers=2, heads=4, ff=128),
}
