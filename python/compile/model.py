"""L2: JAX transformer models for the Hybrid-LLM reproduction.

Defines, for every roster entry (DESIGN.md §3):

* ``init_params``     — seeded parameter initialization,
* ``prefill``         — prompt ingestion: fills the KV cache and samples
                        the first answer token (Pallas flash attention),
* ``decode_step``     — one autoregressive step against the KV cache
                        (Pallas decode attention) with in-graph sampling,
* ``score``           — BART-score analogue: mean per-token log-prob of a
                        response region under the scorer LM,
* ``router_forward``  — DeBERTa-analogue encoder score in [0, 1],
* ``lm_train_step`` / ``router_train_step`` — fused fwd+bwd+AdamW updates
                        (gradients flow through the jnp reference
                        attention; the Pallas kernels define no VJP).

All functions operate on *flat parameter lists* in the order of
``param_names(cfg)`` so that the AOT artifacts' HLO parameter numbering is
deterministic and recorded in the manifest for the rust side.
"""

import functools

import jax
import jax.numpy as jnp

from .common import (
    ADAM_B1,
    ADAM_B2,
    ADAM_EPS,
    GRAD_CLIP,
    S_CTX,
    VOCAB,
    WEIGHT_DECAY,
    ModelCfg,
)
from .kernels import decode_attention, flash_attention, paged_decode_attention, ref_attention

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelCfg, head: bool = False):
    """Ordered ``[(name, shape)]`` for a roster entry.

    ``head=True`` adds the router's pooled MLP head. The order of this
    list *is* the HLO parameter order of every artifact (manifest
    contract with rust).
    """
    d, ff = cfg.d, cfg.ff
    shapes = [("emb", (VOCAB, d)), ("pos", (S_CTX, d))]
    for l in range(cfg.layers):
        p = f"l{l:02d}."
        shapes += [
            (p + "ln1g", (d,)),
            (p + "ln1b", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2g", (d,)),
            (p + "ln2b", (d,)),
            (p + "w1", (d, ff)),
            (p + "b1", (ff,)),
            (p + "w2", (ff, d)),
            (p + "b2", (d,)),
        ]
    shapes += [("lnfg", (d,)), ("lnfb", (d,))]
    if head:
        shapes += [
            ("head.w1", (d, d)),
            ("head.b1", (d,)),
            ("head.w2", (d, 1)),
            ("head.b2", (1,)),
        ]
    return shapes


def param_names(cfg: ModelCfg, head: bool = False):
    return [n for n, _ in param_shapes(cfg, head)]


def init_params(cfg: ModelCfg, seed, head: bool = False):
    """Seeded init; returns the flat param list (manifest order).

    Residual-output projections (``wo``, ``w2``) are scaled by
    ``1/sqrt(2*layers)`` (GPT-2-style) so depth does not blow up the
    residual stream; gains start at 1, biases at 0.
    """
    key = jax.random.PRNGKey(seed)
    out = []
    resid_scale = 1.0 / jnp.sqrt(jnp.float32(2 * cfg.layers))
    for i, (name, shape) in enumerate(param_shapes(cfg, head)):
        k = jax.random.fold_in(key, i)
        base = name.split(".")[-1]
        if base in ("ln1g", "ln2g", "lnfg"):
            w = jnp.ones(shape, jnp.float32)
        elif base in ("ln1b", "ln2b", "lnfb", "b1", "b2"):
            w = jnp.zeros(shape, jnp.float32)
        else:
            w = jax.random.normal(k, shape, jnp.float32) * 0.02
            if base in ("wo", "w2") and name.startswith("l"):
                w = w * resid_scale
        out.append(w)
    return out


def as_dict(cfg: ModelCfg, flat, head: bool = False):
    names = param_names(cfg, head)
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _ln(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attn_full(cfg, p, l, x, lens, causal, use_pallas):
    """Full-sequence attention sub-block; x: [B,S,d] -> [B,S,d]."""
    B, S, d = x.shape
    H, Dh = cfg.heads, cfg.head_dim
    pre = f"l{l:02d}."
    h = _ln(x, p[pre + "ln1g"], p[pre + "ln1b"])
    q = (h @ p[pre + "wq"]).reshape(B, S, H, Dh)
    k = (h @ p[pre + "wk"]).reshape(B, S, H, Dh)
    v = (h @ p[pre + "wv"]).reshape(B, S, H, Dh)
    attn = flash_attention(q, k, v, lens, causal) if use_pallas else ref_attention(q, k, v, lens, causal)
    return x + attn.reshape(B, S, d) @ p[pre + "wo"], k, v


def _mlp(cfg, p, l, x):
    pre = f"l{l:02d}."
    h = _ln(x, p[pre + "ln2g"], p[pre + "ln2b"])
    return x + (jax.nn.gelu(h @ p[pre + "w1"] + p[pre + "b1"])) @ p[pre + "w2"] + p[pre + "b2"]


def lm_logits(cfg, p, tokens, lens, causal=True, use_pallas=True):
    """Teacher-forced logits over a full sequence; tokens: [B,S] -> [B,S,V]."""
    B, S = tokens.shape
    x = p["emb"][tokens] + p["pos"][:S][None, :, :]
    for l in range(cfg.layers):
        x, _, _ = _attn_full(cfg, p, l, x, lens, causal, use_pallas)
        x = _mlp(cfg, p, l, x)
    x = _ln(x, p["lnfg"], p["lnfb"])
    return x @ p["emb"].T


def _sample(logits, seeds, step, temp):
    """In-graph sampling: per-example threefry keys, temperature, greedy at 0.

    Returns (token [B] int32, logprob [B] f32 of the sampled token).
    """
    B = logits.shape[0]
    base = jax.random.PRNGKey(0)

    def one(seed, s, lg):
        k = jax.random.fold_in(jax.random.fold_in(base, seed), s)
        return jax.random.categorical(k, lg / jnp.maximum(temp, 1e-6))

    sampled = jax.vmap(one, in_axes=(0, None, 0))(seeds, step, logits)
    greedy = jnp.argmax(logits, axis=-1)
    tok = jnp.where(temp > 1e-6, sampled, greedy).astype(jnp.int32)
    lp = jax.nn.log_softmax(logits, axis=-1)[jnp.arange(B), tok]
    return tok, lp


# ---------------------------------------------------------------------------
# Serving graphs (AOT-lowered; Pallas kernels on the hot path)
# ---------------------------------------------------------------------------


def prefill(cfg, flat, prompt, lens, seeds, temp, use_pallas=True):
    """Ingest right-padded prompts, fill the KV cache, sample 1st token.

    Args:
      flat: params (manifest order).
      prompt: [B, Sp] int32 right-padded with PAD.
      lens: [B] int32 true prompt lengths (>= 1).
      seeds: [B] uint32 per-slot sampling seeds.
      temp: scalar f32 (0 => greedy).

    Returns:
      (first_tok [B] i32, logprob [B] f32,
       kcache [L,B,S_CTX,H,Dh] f32, vcache [L,B,S_CTX,H,Dh] f32)

    Cache layout is *compacted*: the answer continues at position
    ``lens[b]``, overwriting the pad region, so decode masks ``j <= pos``
    never see stale prompt padding (DESIGN.md §4).
    """
    p = as_dict(cfg, flat)
    B, Sp = prompt.shape
    H, Dh, L = cfg.heads, cfg.head_dim, cfg.layers
    x = p["emb"][prompt] + p["pos"][:Sp][None, :, :]
    ks, vs = [], []
    for l in range(L):
        x, k, v = _attn_full(cfg, p, l, x, lens, True, use_pallas)
        x = _mlp(cfg, p, l, x)
        pad = ((0, 0), (0, S_CTX - Sp), (0, 0), (0, 0))
        ks.append(jnp.pad(k, pad))
        vs.append(jnp.pad(v, pad))
    x = _ln(x, p["lnfg"], p["lnfb"])
    logits_all = x @ p["emb"].T  # [B, Sp, V]
    last = jnp.clip(lens - 1, 0, Sp - 1)
    logits = logits_all[jnp.arange(B), last]  # [B, V]
    tok, lp = _sample(logits, seeds, jnp.zeros((), jnp.int32), temp)
    kcache = jnp.stack(ks)  # [L,B,S_CTX,H,Dh]
    vcache = jnp.stack(vs)
    return tok, lp, kcache, vcache


def decode_step(cfg, flat, kcache, vcache, tok, pos, step, seeds, temp, use_pallas=True):
    """One autoregressive step for all B slots.

    Args:
      tok: [B] i32 current input token (the previously sampled one).
      pos: [B] i32 its position (K/V written there; attends j <= pos).
      step: scalar i32 decode step counter (folded into sampling keys).
      seeds, temp: as in ``prefill``.

    Returns: (next_tok [B], logprob [B], kcache', vcache').
    """
    p = as_dict(cfg, flat)
    B = tok.shape[0]
    H, Dh, L = cfg.heads, cfg.head_dim, cfg.layers
    x = p["emb"][tok] + p["pos"][pos]  # [B, d]
    for l in range(L):
        pre = f"l{l:02d}."
        h = _ln(x, p[pre + "ln1g"], p[pre + "ln1b"])
        q = (h @ p[pre + "wq"]).reshape(B, H, Dh)
        k = (h @ p[pre + "wk"]).reshape(B, H, Dh)
        v = (h @ p[pre + "wv"]).reshape(B, H, Dh)

        def write(cache_b, new_b, pb):
            return jax.lax.dynamic_update_slice(cache_b, new_b[None], (pb, 0, 0))

        kc_l = jax.vmap(write)(kcache[l], k, pos)  # [B,S,H,Dh]
        vc_l = jax.vmap(write)(vcache[l], v, pos)
        kcache = kcache.at[l].set(kc_l)
        vcache = vcache.at[l].set(vc_l)
        if use_pallas:
            attn = decode_attention(q, kc_l, vc_l, pos)
        else:
            from .kernels import ref_decode_attention

            attn = ref_decode_attention(q, kc_l, vc_l, pos)
        x = x + attn.reshape(B, cfg.d) @ p[pre + "wo"]
        x = _mlp(cfg, p, l, x[:, None, :])[:, 0, :]
    x = _ln(x, p["lnfg"], p["lnfb"])
    logits = x @ p["emb"].T
    tok2, lp = _sample(logits, seeds, step, temp)
    return tok2, lp, kcache, vcache


def kv_install(kcache, vcache, src_k, src_v, slots, count):
    """Device-side admission scatter (manifest v3, DESIGN.md §8).

    Writes the first ``count`` batch slots of a bucketed-prefill KV cache
    into a persistent full-batch cache at caller-chosen slot indices,
    without the cache ever crossing the host boundary — the only host
    inputs are ``slots``/``count`` (O(B) bytes). Entries ``b >= count``
    are padding (the bucket is the smallest power of two >= the number
    of admitted requests): their writes are masked out by re-installing
    the destination slot's current contents, so a padding entry can
    never clobber live state whatever index it carries.

    Args:
      kcache, vcache: [L, B_full, S, H, Dh] persistent worker cache.
      src_k, src_v:   [L, B_bucket, S, H, Dh] bucketed prefill outputs.
      slots: [B_bucket] int32 destination slot indices in the full cache.
      count: scalar int32 number of valid entries (<= B_bucket).

    Returns: (kcache', vcache').
    """
    bucket = src_k.shape[1]
    # B_bucket is a compile-time constant (one artifact per bucket), so
    # the scatter unrolls into `bucket` dynamic-update-slices.
    for b in range(bucket):
        idx = slots[b]
        valid = jnp.int32(b) < count
        new_k = src_k[:, b : b + 1]
        new_v = src_v[:, b : b + 1]
        cur_k = jax.lax.dynamic_slice_in_dim(kcache, idx, 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(vcache, idx, 1, axis=1)
        kcache = jax.lax.dynamic_update_slice_in_dim(
            kcache, jnp.where(valid, new_k, cur_k), idx, axis=1
        )
        vcache = jax.lax.dynamic_update_slice_in_dim(
            vcache, jnp.where(valid, new_v, cur_v), idx, axis=1
        )
    return kcache, vcache


def _paged_token(cfg, p, kpool, vpool, tables, tok, pos, use_pallas):
    """One token through all layers against the paged pool.

    The shared body of ``paged_decode_step`` and ``verify_step``: writes
    this position's K/V through the block table, runs paged attention +
    MLP per layer, and returns the pre-sampling logits. Keeping the op
    sequence identical between the two callers is what makes the K-token
    verify step bitwise-equal to K single-token decode steps.

    Returns: (logits [B, V], kpool', vpool').
    """
    B = tok.shape[0]
    H, Dh, L = cfg.heads, cfg.head_dim, cfg.layers
    BLOCK = kpool.shape[2]
    x = p["emb"][tok] + p["pos"][pos]  # [B, d]
    for l in range(L):
        pre = f"l{l:02d}."
        h = _ln(x, p[pre + "ln1g"], p[pre + "ln1b"])
        q = (h @ p[pre + "wq"]).reshape(B, H, Dh)
        k = (h @ p[pre + "wk"]).reshape(B, H, Dh)
        v = (h @ p[pre + "wv"]).reshape(B, H, Dh)
        kp_l, vp_l = kpool[l], vpool[l]  # [NBLK, BLOCK, H, Dh]
        # B is a compile-time constant, so the table-indirected write
        # unrolls into B dynamic-update-slices per pool (same idiom as
        # the dense decode write, one indirection deeper).
        for b in range(B):
            tid = tables[b, pos[b] // BLOCK]
            off = pos[b] % BLOCK
            kp_l = jax.lax.dynamic_update_slice(kp_l, k[b][None, None], (tid, off, 0, 0))
            vp_l = jax.lax.dynamic_update_slice(vp_l, v[b][None, None], (tid, off, 0, 0))
        kpool = kpool.at[l].set(kp_l)
        vpool = vpool.at[l].set(vp_l)
        if use_pallas:
            attn = paged_decode_attention(q, kp_l, vp_l, tables, pos)
        else:
            from .kernels import ref_paged_decode_attention

            attn = ref_paged_decode_attention(q, kp_l, vp_l, tables, pos)
        x = x + attn.reshape(B, cfg.d) @ p[pre + "wo"]
        x = _mlp(cfg, p, l, x[:, None, :])[:, 0, :]
    x = _ln(x, p["lnfg"], p["lnfb"])
    return x @ p["emb"].T, kpool, vpool


def paged_decode_step(cfg, flat, kpool, vpool, tables, tok, pos, step, seeds, temp, use_pallas=True):
    """One autoregressive step against the block-paged KV pool (manifest v4).

    The paged sibling of ``decode_step``: K/V for this step are written
    through the block table — lane ``b``'s position ``pos[b]`` lives at
    offset ``pos[b] % BLOCK`` of pool block ``tables[b, pos[b]//BLOCK]``
    — and attention gathers the lane's blocks back into position order.
    Free/padding lanes carry an all-zero table row, so their writes land
    in the reserved null block 0 and never touch live state.

    Args:
      kpool, vpool: [L, NBLK, BLOCK, H, Dh] per-layer block pools.
      tables: [B, MAXBLK] i32 pool block ids (0 = unallocated/null).
      tok, pos, step, seeds, temp: as in ``decode_step``.

    Returns: (next_tok [B], logprob [B], kpool', vpool').
    """
    p = as_dict(cfg, flat)
    logits, kpool, vpool = _paged_token(cfg, p, kpool, vpool, tables, tok, pos, use_pallas)
    tok2, lp = _sample(logits, seeds, step, temp)
    return tok2, lp, kpool, vpool


def verify_step(cfg, flat, kpool, vpool, tables, toks, pos, step, seeds, temp, use_pallas=True):
    """K-token verify step for speculative draft–verify (manifest v5).

    The multi-token generalization of ``paged_decode_step``: lane ``b``
    appends K draft tokens ``toks[b, 0..K-1]`` at positions
    ``pos[b]..pos[b]+K-1`` of its paged KV state and gets back the
    model's own next-token choice *at every appended position*. Token
    ``i`` is processed with all earlier draft tokens already resident
    (causal within the appended block), so ``next[b, i]`` is exactly what
    single-token decoding would have produced after consuming
    ``toks[b, :i+1]`` — the longest-prefix acceptance rule on the rust
    side compares ``next[b, i]`` against ``toks[b, i+1]`` and takes
    ``next[b, m]`` as the correction token at the first mismatch, which
    pins hybrid greedy output byte-identical to large-only decoding.

    Implemented as K unrolled single-token bodies (``_paged_token``) in
    one graph, so results are bitwise-equal to K sequential
    ``paged_decode_step`` calls (pinned by ``test_model.py``); one
    artifact is lowered per draft-length bucket K.

    Args:
      kpool, vpool: [L, NBLK, BLOCK, H, Dh] per-layer block pools.
      tables: [B, MAXBLK] i32 pool block ids (0 = unallocated/null).
      toks: [B, K] i32 draft tokens; idle/padding lanes carry PAD with an
        all-zero table row (writes land in null block 0).
      pos: [B] i32 position of ``toks[:, 0]``; caller guarantees
        ``pos[b] + K <= S_CTX`` for live lanes.
      step, seeds, temp: as in ``decode_step``; sampling at position i
        folds ``step + i`` so stochastic mode decorrelates positions
        (greedy temp=0 is pure argmax either way).

    Returns: (next [B, K], logprob [B, K], kpool', vpool').
    """
    p = as_dict(cfg, flat)
    K = toks.shape[1]
    nexts, lps = [], []
    for i in range(K):
        logits, kpool, vpool = _paged_token(
            cfg, p, kpool, vpool, tables, toks[:, i], pos + i, use_pallas
        )
        t, lp = _sample(logits, seeds, step + i, temp)
        nexts.append(t)
        lps.append(lp)
    return jnp.stack(nexts, axis=1), jnp.stack(lps, axis=1), kpool, vpool


def kv_install_paged(kpool, vpool, src_k, src_v, dst_tables):
    """Device-side paged admission scatter (manifest v4).

    Splits each lane of a bucketed dense prefill cache into BLOCK-token
    chunks and writes chunk ``j`` of lane ``b`` into pool block
    ``dst_tables[b, j]``. Entry 0 means *skip*: it covers both bucket
    padding lanes (all-zero rows) and prefix-cache hits, where the
    leading blocks are already resident and shared — the skipped writes
    re-install the null block's own contents, so nothing live is
    touched. The only host input is the O(B·MAXBLK) table.

    Args:
      kpool, vpool: [L, NBLK, BLOCK, H, Dh] persistent block pools.
      src_k, src_v: [L, B_bucket, S_CTX, H, Dh] bucketed prefill outputs.
      dst_tables: [B_bucket, MAXBLK] int32 destination pool block ids.

    Returns: (kpool', vpool').
    """
    bucket = src_k.shape[1]
    BLOCK = kpool.shape[2]
    maxblk = dst_tables.shape[1]
    # bucket and MAXBLK are compile-time constants (one artifact per
    # bucket), so the scatter unrolls into bucket*MAXBLK masked
    # dynamic-update-slices — same no-clobber masking as ``kv_install``.
    for b in range(bucket):
        for j in range(maxblk):
            idx = dst_tables[b, j]
            valid = idx != 0
            new_k = src_k[:, b : b + 1, j * BLOCK : (j + 1) * BLOCK]  # [L,1,BLOCK,H,Dh]
            new_v = src_v[:, b : b + 1, j * BLOCK : (j + 1) * BLOCK]
            cur_k = jax.lax.dynamic_slice_in_dim(kpool, idx, 1, axis=1)
            cur_v = jax.lax.dynamic_slice_in_dim(vpool, idx, 1, axis=1)
            kpool = jax.lax.dynamic_update_slice_in_dim(
                kpool, jnp.where(valid, new_k, cur_k), idx, axis=1
            )
            vpool = jax.lax.dynamic_update_slice_in_dim(
                vpool, jnp.where(valid, new_v, cur_v), idx, axis=1
            )
    return kpool, vpool


def kv_block_copy(kpool, vpool, src, dst, count):
    """Pool-internal block copies (copy-on-extend, manifest v4).

    Copies pool block ``src[i]`` over pool block ``dst[i]`` for the first
    ``count`` entries; entries with ``dst[i] == 0`` are also skipped (0
    is the null block — never a copy target). Used at admission when a
    request extends a shared prefix whose tail block is partially full:
    the shared tail is copied into a private block before the request's
    own tokens land in it. O(B) host bytes (the two index vectors).

    Args:
      kpool, vpool: [L, NBLK, BLOCK, H, Dh] persistent block pools.
      src, dst: [C] int32 pool block ids (C fixed at lowering time).
      count: scalar int32 number of valid pairs (<= C).

    Returns: (kpool', vpool').
    """
    C = src.shape[0]
    for i in range(C):
        valid = jnp.logical_and(jnp.int32(i) < count, dst[i] != 0)
        new_k = jax.lax.dynamic_slice_in_dim(kpool, src[i], 1, axis=1)
        new_v = jax.lax.dynamic_slice_in_dim(vpool, src[i], 1, axis=1)
        cur_k = jax.lax.dynamic_slice_in_dim(kpool, dst[i], 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(vpool, dst[i], 1, axis=1)
        kpool = jax.lax.dynamic_update_slice_in_dim(
            kpool, jnp.where(valid, new_k, cur_k), dst[i], axis=1
        )
        vpool = jax.lax.dynamic_update_slice_in_dim(
            vpool, jnp.where(valid, new_v, cur_v), dst[i], axis=1
        )
    return kpool, vpool


def score(cfg, flat, tokens, resp_mask, use_pallas=True):
    """BART-score analogue: mean next-token log-prob over the response.

    tokens: [B,S] full teacher-forced sequence (BOS prompt SEP answer EOS
    PAD*); resp_mask: [B,S] f32, 1.0 on positions whose *token* belongs to
    the response (incl EOS). Score of example b =
    mean_{t: mask[t]=1} log p(tokens[t] | tokens[<t]).
    """
    p = as_dict(cfg, flat)
    B, S = tokens.shape
    lens = jnp.sum((tokens != 0).astype(jnp.int32), axis=1)
    logits = lm_logits(cfg, p, tokens, lens, causal=True, use_pallas=use_pallas)
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)  # predicts tokens[:,1:]
    tgt = tokens[:, 1:]
    tok_lp = jnp.take_along_axis(lp, tgt[:, :, None], axis=-1)[:, :, 0]  # [B,S-1]
    m = resp_mask[:, 1:]
    denom = jnp.maximum(jnp.sum(m, axis=1), 1.0)
    return jnp.sum(tok_lp * m, axis=1) / denom


def router_forward(cfg, flat, tokens, lens, use_pallas=True):
    """Router score p_w(x) in [0,1]; single bidirectional encoder pass."""
    p = as_dict(cfg, flat, head=True)
    B, S = tokens.shape
    x = p["emb"][tokens] + p["pos"][:S][None, :, :]
    for l in range(cfg.layers):
        x, _, _ = _attn_full(cfg, p, l, x, lens, False, use_pallas)
        x = _mlp(cfg, p, l, x)
    x = _ln(x, p["lnfg"], p["lnfb"])
    mask = (jnp.arange(S)[None, :] < lens[:, None]).astype(jnp.float32)
    pooled = jnp.sum(x * mask[:, :, None], axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0
    )
    h = jnp.tanh(pooled @ p["head.w1"] + p["head.b1"])
    logit = (h @ p["head.w2"] + p["head.b2"])[:, 0]
    return jax.nn.sigmoid(logit)


# ---------------------------------------------------------------------------
# Training graphs (fused fwd+bwd+AdamW; jnp reference attention for VJP)
# ---------------------------------------------------------------------------


def _adamw(flat, m, v, grads, lr, step):
    """AdamW with global-norm clipping; returns (flat', m', v')."""
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, GRAD_CLIP / gnorm)
    t = step.astype(jnp.float32)
    b1c = 1.0 - ADAM_B1 ** t
    b2c = 1.0 - ADAM_B2 ** t
    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(flat, m, v, grads):
        g = gi * scale
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / b1c
        vhat = vi / b2c
        upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS) + WEIGHT_DECAY * pi
        new_p.append(pi - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def _lm_loss(cfg, flat, tokens, loss_mask):
    p = as_dict(cfg, flat)
    lens = jnp.sum((tokens != 0).astype(jnp.int32), axis=1)
    logits = lm_logits(cfg, p, tokens, lens, causal=True, use_pallas=False)
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    tok_lp = jnp.take_along_axis(lp, tgt[:, :, None], axis=-1)[:, :, 0]
    m = loss_mask[:, 1:]
    return -jnp.sum(tok_lp * m) / jnp.maximum(jnp.sum(m), 1.0)


def lm_train_step(cfg, flat, m, v, tokens, loss_mask, lr, step):
    """One AdamW step of next-token CE on the answer region.

    Returns (flat', m', v', loss)."""
    loss, grads = jax.value_and_grad(lambda f: _lm_loss(cfg, f, tokens, loss_mask))(list(flat))
    new_p, new_m, new_v = _adamw(flat, m, v, grads, lr, step)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)


def _router_loss(cfg, flat, tokens, lens, labels):
    s = router_forward(cfg, flat, tokens, lens, use_pallas=False)
    s = jnp.clip(s, 1e-6, 1.0 - 1e-6)
    return -jnp.mean(labels * jnp.log(s) + (1.0 - labels) * jnp.log(1.0 - s))


def router_train_step(cfg, flat, m, v, tokens, lens, labels, lr, step):
    """One AdamW step of (soft-label) BCE — Eqs. (1), (2), (4) of the paper
    share this graph; the label *values* decide which router is trained.

    Returns (flat', m', v', loss)."""
    loss, grads = jax.value_and_grad(lambda f: _router_loss(cfg, f, tokens, lens, labels))(
        list(flat)
    )
    new_p, new_m, new_v = _adamw(flat, m, v, grads, lr, step)
    return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)
