"""Build-time compile path (L1 kernels + L2 models + AOT lowering).

Never imported at runtime: the rust coordinator consumes only the HLO-text
artifacts and the manifest that ``compile.aot`` writes.
"""
