"""Pure-jnp oracles for the Pallas kernels.

These are the *correctness contract* for ``attention.py`` and
``decode_attention.py``: pytest (with hypothesis sweeps over shapes,
lengths and dtypes) asserts allclose between the kernels and these
references. They are also used directly inside the *training* graphs,
where gradients must flow (the Pallas kernels define no VJP; serving is
the hot path, see DESIGN.md §5).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, lens, causal=True):
    """Multi-head attention over a full sequence.

    Args:
      q, k, v: ``[B, S, H, Dh]``.
      lens: ``[B]`` int32 — valid prefix length per example; key/value
        positions ``>= lens[b]`` are masked out.
      causal: if True, query position ``i`` attends only to ``j <= i``.

    Returns:
      ``[B, S, H, Dh]`` attention output (same dtype as ``q``).
    """
    B, S, H, Dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.array(Dh, jnp.float32))
    # [B, H, S, S]
    s = jnp.einsum("bihd,bjhd->bhij", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    ii = jnp.arange(S)[:, None]
    jj = jnp.arange(S)[None, :]
    mask = jj[None, :, :] < lens[:, None, None]  # [B, S, S] key validity
    if causal:
        mask = jnp.logical_and(mask, (jj <= ii)[None, :, :])
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhij,bjhd->bihd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_paged_decode_attention(q, kpool, vpool, tables, pos):
    """Single-step attention over a block-paged KV pool.

    Args:
      q: ``[B, H, Dh]`` — query for the token at position ``pos[b]``.
      kpool, vpool: ``[NBLK, BLOCK, H, Dh]`` — per-layer block pool;
        block 0 is the reserved null block.
      tables: ``[B, MAXBLK]`` int32 — pool block ids in position order;
        0 means unallocated (those positions are ``> pos[b]``).
      pos: ``[B]`` int32 — attends to ``j <= pos[b]``.

    Returns:
      ``[B, H, Dh]``.
    """
    NBLK, BLOCK, H, Dh = kpool.shape
    B, MAXBLK = tables.shape
    # gather to the dense [B, S, H, Dh] view, then defer to the dense oracle
    kcache = kpool[tables].reshape(B, MAXBLK * BLOCK, H, Dh)
    vcache = vpool[tables].reshape(B, MAXBLK * BLOCK, H, Dh)
    return ref_decode_attention(q, kcache, vcache, pos)


def ref_decode_attention(q, kcache, vcache, pos):
    """Single-step attention of one new query against a KV cache.

    Args:
      q: ``[B, H, Dh]`` — query for the token at position ``pos[b]``.
      kcache, vcache: ``[B, S, H, Dh]`` — positions ``> pos[b]`` may hold
        garbage and must not contribute.
      pos: ``[B]`` int32 — current position (attends to ``j <= pos[b]``,
        i.e. the cache is expected to already contain this step's K/V).

    Returns:
      ``[B, H, Dh]``.
    """
    B, S, H, Dh = kcache.shape
    scale = 1.0 / jnp.sqrt(jnp.array(Dh, jnp.float32))
    s = jnp.einsum("bhd,bjhd->bhj", q.astype(jnp.float32), kcache.astype(jnp.float32)) * scale
    jj = jnp.arange(S)[None, None, :]
    mask = jj <= pos[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhj,bjhd->bhd", p, vcache.astype(jnp.float32))
    return out.astype(q.dtype)
