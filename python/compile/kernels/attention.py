"""Pallas flash-style prefill attention (L1 hot-spot kernel).

TPU adaptation of the paper's GPU serving hot path (DESIGN.md §5): instead
of a threadblock-per-tile CUDA schedule with shared-memory staging, the
HBM→VMEM schedule is expressed with ``BlockSpec``s — the kernel walks the
KV sequence in ``BLOCK_KV``-sized tiles with an *online softmax* (running
max / running sum), exactly the flash-attention recurrence.

Grid = (heads,): each program instance holds one head's Q/K/V for the
*whole batch* in VMEM and computes all B rows of the recurrence at once.
As with the decode kernel, batch is kept inside the block rather than on
the grid because grid instances execute sequentially in interpret mode
(and on a single TPU core) — moving B off the grid measured ~3–4× faster
per query at B=16 (EXPERIMENTS.md §Perf L1). VMEM per instance at B=16,
S=64, Dh≤32: Q+O `[B,S,Dh]` ×2 + one KV tile ×2 + running stats ≈ 560 KB
— still ~3% of a 16 MB VMEM; at production dims you would tile Q across
the grid as well.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops and validated
against ``ref.py``; real-TPU performance is estimated analytically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BLOCK_KV = 16  # KV tile width walked by the online-softmax loop


def _attention_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, causal: bool, s: int, dh: int):
    """One (head,) program instance over the full batch.

    Block shapes: ``q_ref/k_ref/v_ref/o_ref: [B, S, 1, Dh]``,
    ``lens_ref: [B]``.
    """
    q = q_ref[:, :, 0, :].astype(jnp.float32)  # [B, S, Dh]
    b = q.shape[0]
    length = lens_ref[...]  # [B]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    m_i = jnp.full((b, s), NEG_INF, jnp.float32)  # running row max
    l_i = jnp.zeros((b, s), jnp.float32)  # running row sum
    acc = jnp.zeros((b, s, dh), jnp.float32)  # running output accumulator

    rows = jax.lax.iota(jnp.int32, s)
    n_blocks = pl.cdiv(s, BLOCK_KV)
    for blk in range(n_blocks):  # static unroll: the flash KV walk
        bw = min(BLOCK_KV, s - blk * BLOCK_KV)  # ragged last tile
        k_blk = k_ref[:, pl.dslice(blk * BLOCK_KV, bw), 0, :].astype(jnp.float32)
        v_blk = v_ref[:, pl.dslice(blk * BLOCK_KV, bw), 0, :].astype(jnp.float32)
        sc = jnp.einsum("bqd,bkd->bqk", q, k_blk) * scale  # [B, S, bw]
        cols = blk * BLOCK_KV + jax.lax.iota(jnp.int32, bw)
        ok = cols[None, None, :] < length[:, None, None]  # [B, 1->S, bw]
        if causal:
            ok = jnp.logical_and(ok, (cols[None, :] <= rows[:, None])[None, :, :])
        sc = jnp.where(ok, sc, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[:, :, None])
        alpha = jnp.exp(m_i - m_new)
        l_i = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, :, None] + jnp.einsum("bqk,bkd->bqd", p, v_blk)
        m_i = m_new

    out = acc / jnp.maximum(l_i, 1e-30)[:, :, None]
    o_ref[:, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, lens, causal=True):
    """Flash-style attention; drop-in for ``ref.ref_attention``.

    Args:
      q, k, v: ``[B, S, H, Dh]``.
      lens: ``[B]`` int32 valid key prefix per example.
      causal: static — causal (LM) vs bidirectional (router encoder).
    """
    B, S, H, Dh = q.shape
    kernel = functools.partial(_attention_kernel, causal=causal, s=S, dh=Dh)
    qkv_spec = pl.BlockSpec((B, S, 1, Dh), lambda h: (0, 0, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((B,), lambda h: (0,)),  # lens
            qkv_spec,
            qkv_spec,
            qkv_spec,
        ],
        out_specs=qkv_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, H, Dh), q.dtype),
        interpret=True,
    )(lens, q, k, v)
