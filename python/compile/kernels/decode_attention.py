"""Pallas single-step decode attention (the serving hot path).

One new query token per sequence attends to the KV cache; this is the
kernel executed once per generated token per layer, i.e. the innermost
loop of the whole serving system.

Grid = (heads,): each program instance holds one head's cache slice for
the *whole batch* (`[B, S, Dh]` in VMEM) and computes all B rows at
once. The batch dimension is deliberately kept inside the block rather
than on the grid: interpret-mode Pallas (and a single TPU core) executes
grid instances *sequentially*, so a (B, H) grid serializes over batch —
measured 3–4× slower per query at B=16 on this substrate (see
EXPERIMENTS.md §Perf L1). VMEM per instance at B=16, S=64, Dh≤32 is
2·B·S·Dh·4 ≈ 256 KB — comfortably inside a real core's budget too. No
online softmax is needed: with query length 1 the full score row is a
single `[S]` vector (the flash recurrence degenerates).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, s: int, dh: int):
    """Block shapes: ``q_ref/o_ref: [B, 1, Dh]``, ``k_ref/v_ref: [B, S, 1, Dh]``,
    ``pos_ref: [B]`` (full batch per (head,) program instance)."""
    q = q_ref[:, 0, :].astype(jnp.float32)  # [B, Dh]
    k = k_ref[:, :, 0, :].astype(jnp.float32)  # [B, S, Dh]
    v = v_ref[:, :, 0, :].astype(jnp.float32)  # [B, S, Dh]
    pos = pos_ref[...]  # [B]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    sc = jnp.einsum("bd,bsd->bs", q, k) * scale  # [B, S]
    jj = jax.lax.iota(jnp.int32, s)[None, :]
    sc = jnp.where(jj <= pos[:, None], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o_ref[:, 0, :] = jnp.einsum("bs,bsd->bd", p, v).astype(o_ref.dtype)


@jax.jit
def decode_attention(q, kcache, vcache, pos):
    """Single-query cached attention; drop-in for ``ref.ref_decode_attention``.

    Args:
      q: ``[B, H, Dh]`` query at position ``pos[b]``.
      kcache, vcache: ``[B, S, H, Dh]``; entries ``> pos[b]`` are garbage.
      pos: ``[B]`` int32; attends to ``j <= pos[b]``.
    """
    B, S, H, Dh = kcache.shape
    kernel = functools.partial(_decode_kernel, s=S, dh=Dh)
    cache_spec = pl.BlockSpec((B, S, 1, Dh), lambda h: (0, 0, h, 0))
    q_spec = pl.BlockSpec((B, 1, Dh), lambda h: (0, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((B,), lambda h: (0,)),  # pos
            q_spec,
            cache_spec,
            cache_spec,
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=True,
    )(pos, q, kcache, vcache)
