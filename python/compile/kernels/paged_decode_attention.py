"""Pallas single-step decode attention over a block-paged KV cache.

The paged sibling of ``decode_attention.py``: instead of a dense
``[B, S, H, Dh]`` cache per request, K/V live in a per-layer block pool
``[NBLK, BLOCK, H, Dh]`` and each request owns a small table of pool
block indices. The kernel gathers a request's blocks by table index,
reassembles the ``[B, S, Dh]`` view in VMEM, and from there the math is
*identical* to the dense kernel — same einsums, same mask, same softmax
normalization — which is what makes paged decode byte-for-byte equal to
the dense path under greedy sampling (the rust equivalence test pins
this).

Grid = (heads,), batch kept inside the block, exactly like the dense
kernel (see its header for the measured rationale). The gather adds
``B·MAXBLK`` index loads per instance; VMEM grows by the pool slice
``NBLK·BLOCK·Dh·4`` per K and V, which at NBLK=145, BLOCK=8, Dh≤32
is ≈ 150 KB — still inside budget.

Block 0 is the reserved *null block*: table entries that are 0 are
unallocated (or padding lanes). Whatever garbage the null block holds is
finite and sits at positions ``> pos[b]``, so the causal mask replaces
its scores with NEG_INF before softmax — zero contribution, bitwise.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_decode_kernel(pos_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref, *, block: int, maxblk: int, dh: int):
    """Block shapes: ``q_ref/o_ref: [B, 1, Dh]``, ``k_ref/v_ref:
    [NBLK, BLOCK, 1, Dh]`` (one head's pool slice), ``tbl_ref: [B, MAXBLK]``,
    ``pos_ref: [B]`` (full batch per (head,) program instance)."""
    q = q_ref[:, 0, :].astype(jnp.float32)  # [B, Dh]
    kpool = k_ref[:, :, 0, :].astype(jnp.float32)  # [NBLK, BLOCK, Dh]
    vpool = v_ref[:, :, 0, :].astype(jnp.float32)  # [NBLK, BLOCK, Dh]
    tbl = tbl_ref[...]  # [B, MAXBLK]
    pos = pos_ref[...]  # [B]
    s = maxblk * block
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    # gather each lane's blocks back into position order: [B, MAXBLK,
    # BLOCK, Dh] -> [B, S, Dh]. Unallocated entries gather null block 0;
    # those positions are > pos[b] and get masked below.
    k = kpool[tbl].reshape(tbl.shape[0], s, dh)
    v = vpool[tbl].reshape(tbl.shape[0], s, dh)

    sc = jnp.einsum("bd,bsd->bs", q, k) * scale  # [B, S]
    jj = jax.lax.iota(jnp.int32, s)[None, :]
    sc = jnp.where(jj <= pos[:, None], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o_ref[:, 0, :] = jnp.einsum("bs,bsd->bd", p, v).astype(o_ref.dtype)


@jax.jit
def paged_decode_attention(q, kpool, vpool, tables, pos):
    """Single-query cached attention over a paged pool; drop-in for
    ``ref.ref_paged_decode_attention``.

    Args:
      q: ``[B, H, Dh]`` query at position ``pos[b]``.
      kpool, vpool: ``[NBLK, BLOCK, H, Dh]`` block pool for one layer.
      tables: ``[B, MAXBLK]`` int32 pool block ids; entry ``j`` holds
        positions ``[j*BLOCK, (j+1)*BLOCK)``; 0 = unallocated (null).
      pos: ``[B]`` int32; attends to ``j <= pos[b]``.
    """
    NBLK, BLOCK, H, Dh = kpool.shape
    B, MAXBLK = tables.shape
    kernel = functools.partial(_paged_decode_kernel, block=BLOCK, maxblk=MAXBLK, dh=Dh)
    pool_spec = pl.BlockSpec((NBLK, BLOCK, 1, Dh), lambda h: (0, 0, h, 0))
    q_spec = pl.BlockSpec((B, 1, Dh), lambda h: (0, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((B,), lambda h: (0,)),  # pos
            pl.BlockSpec((B, MAXBLK), lambda h: (0, 0)),  # tables
            q_spec,
            pool_spec,
            pool_spec,
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=True,
    )(pos, tables, q, kpool, vpool)
