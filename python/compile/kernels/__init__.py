"""L1 Pallas kernels + pure-jnp oracle."""

from .attention import flash_attention
from .decode_attention import decode_attention
from .paged_decode_attention import paged_decode_attention
from .ref import ref_attention, ref_decode_attention, ref_paged_decode_attention

__all__ = [
    "flash_attention",
    "decode_attention",
    "paged_decode_attention",
    "ref_attention",
    "ref_decode_attention",
    "ref_paged_decode_attention",
]
