"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts + manifest.

Run once at build time (``make artifacts``); the rust coordinator then
loads ``artifacts/*.hlo.txt`` through the PJRT C API and never touches
python again.

HLO text — not ``HloModuleProto.serialize()`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

The manifest (``artifacts/manifest.txt``) is the *contract* with rust: a
plain line-oriented file recording global dims, per-model configs, and for
every artifact the exact HLO parameter order/shapes/dtypes and output
structure. Rust refuses to run against a manifest whose version it does
not know.

Manifest v2: artifacts are lowered with ``return_tuple=False`` so every
output is its own PJRT buffer (no fused tuple), and each ``out`` line
carries a residency class — ``state`` outputs (KV caches) stay
device-resident across decode iterations in the rust runtime
(``Exec::run_resident``), which is what removes the O(KV-size) host
round-trip per generated token.

Manifest v3 moves *admission* onto the device too:

* **Bucketed prefill** — ``<model>.prefill@B`` for every power-of-two
  bucket ``B`` up to ``GEN_B``, so admitting ``n`` requests runs prefill
  at the smallest bucket ``>= n`` instead of always padding to the full
  generation batch (``<model>.prefill`` / ``<model>.prefill1`` remain as
  aliases of the ``@GEN_B`` / ``@1`` buckets — same HLO file, second
  manifest entry).
* **KV slot install** — ``<model>.kv_install@B``: a dynamic-update-slice
  scatter (``model.kv_install``) that writes the bucketed prefill's KV
  slots into the persistent ``[L, GEN_B, S_CTX, H, Dh]`` worker cache
  entirely on device; the only host inputs are the O(B) slot indices and
  the valid count. This ends the full-cache download/upload the rust
  serving layer previously paid for host-side slot surgery on every
  admission (host surgery remains the fallback for v1/v2 artifacts).

Manifest v4 pages the KV cache (block pool + per-request block tables,
geometry on the ``global`` line as ``kvblock``/``kvpool``):

* **Paged decode** — ``<model>.decode_paged``: ``model.paged_decode_step``
  over ``[L, KV_POOL, KV_BLOCK, H, Dh]`` pools, with a ``[GEN_B,
  KV_MAXBLK]`` block table as the only extra host input per step. Block 0
  is the reserved null block (free lanes / unallocated entries).
* **Paged install** — ``<model>.kv_install_paged@B``: splits a bucketed
  dense prefill cache into blocks and scatters them at table-chosen pool
  ids; 0-entries are skipped, which is how prefix-cache hits avoid
  re-installing blocks that are already resident and shared.
* **Block copy** — ``<model>.kv_block_copy``: pool-internal block moves
  for copy-on-extend of shared prefix tails.

The dense v3 artifacts are still lowered and registered, so the rust
side can A/B the two paths (``ServeConfig::force_dense_kv``) and fall
back when paged artifacts are absent.

Manifest v5 adds the speculative draft–verify family:

* **Multi-token verify** — ``<model>.verify@K`` for every power-of-two
  draft length ``K`` up to ``KV_BLOCK``: ``model.verify_step`` appends K
  draft tokens per lane through the paged block tables (non-empty KV
  prefix — the bucketed-``prefill@B`` idea generalized to mid-stream)
  and emits the model's own next-token choice at *every* appended
  position, which is what the rust hybrid decoder's longest-prefix
  acceptance consumes. Bitwise-equal to K sequential ``decode_paged``
  steps (pinned in ``python/tests/test_model.py``), so hybrid greedy
  output stays byte-identical to large-only greedy decoding.
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .common import (
    A_MAX,
    CFGS,
    GEN_B,
    KV_BLOCK,
    KV_MAXBLK,
    KV_POOL,
    LM_SIZES,
    SCORE_B,
    S_CTX,
    S_PROMPT,
    TRAIN_B,
    VOCAB,
)

MANIFEST_VERSION = 5

F32 = jnp.float32
S32 = jnp.int32
U32 = jnp.uint32

_DTYPE_NAMES = {jnp.dtype("float32"): "f32", jnp.dtype("int32"): "s32", jnp.dtype("uint32"): "u32"}


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_str(shape):
    return "scalar" if len(shape) == 0 else "x".join(str(d) for d in shape)


def prefill_buckets(genb):
    """Admission bucket sizes: powers of two up to (and including) genb.

    Mirrored by ``Manifest::prefill_buckets`` on the rust side, which
    discovers the buckets from the artifact names rather than recomputing
    this sequence.
    """
    out = []
    b = 1
    while b < genb:
        out.append(b)
        b *= 2
    out.append(genb)
    return out


def verify_buckets(kvblock):
    """Draft-length buckets for the v5 ``verify@K`` family: powers of two
    up to one KV block. A draft block never spans more than one page, so
    the rust side can bound the rejected-suffix release to a single
    block-table entry; rust discovers the lowered K set from artifact
    names (``Manifest::verify_buckets``) rather than recomputing this."""
    return prefill_buckets(kvblock)


def _out_class(name):
    """Residency class of an output (manifest v2): ``state`` outputs stay
    device-resident in the rust runtime; everything else is downloaded."""
    if name in ("kcache", "vcache"):
        return "state"
    if name.startswith("p."):
        return "param"
    if name.startswith(("m.", "v.")):
        return "opt"
    return "data"


def to_hlo_text(lowered) -> str:
    # return_tuple=False: multi-output artifacts come back from PJRT as
    # one buffer per output instead of a single fused tuple buffer, which
    # is what lets the rust runtime keep `state` outputs (KV caches)
    # device-resident between decode calls.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


class ManifestWriter:
    def __init__(self):
        self.lines = [
            f"version {MANIFEST_VERSION}",
            f"global vocab {VOCAB} sctx {S_CTX} sprompt {S_PROMPT} amax {A_MAX} "
            f"genb {GEN_B} trainb {TRAIN_B} scoreb {SCORE_B} "
            f"kvblock {KV_BLOCK} kvpool {KV_POOL}",
        ]

    def model(self, cfg, head=False):
        n = len(M.param_names(cfg, head))
        self.lines.append(
            f"model {cfg.name} d {cfg.d} layers {cfg.layers} heads {cfg.heads} "
            f"ff {cfg.ff} headdim {cfg.head_dim} nparams {n} head {int(head)}"
        )

    def artifact(self, name, fname, ins, outs):
        self.lines.append(f"artifact {name} file {fname}")
        for nm, spec, cls in ins:
            self.lines.append(f"in {nm} {_DTYPE_NAMES[jnp.dtype(spec.dtype)]} {_shape_str(spec.shape)} {cls}")
        for nm, spec in outs:
            self.lines.append(
                f"out {nm} {_DTYPE_NAMES[jnp.dtype(spec.dtype)]} {_shape_str(spec.shape)} {_out_class(nm)}"
            )

    def write(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\nend\n")


def lower_one(out_dir, mw, name, fn, ins, out_names):
    """Lower ``fn`` over ``ins`` ([(name, spec, class)]) and register it.

    Returns ``(fname, ins, outs)`` so callers can register the same HLO
    file under an alias name (e.g. ``prefill`` -> ``prefill@GEN_B``)
    without lowering it twice.
    """
    t0 = time.time()
    specs = [spec for _, spec, _ in ins]
    lowered = jax.jit(fn).lower(*specs)
    out_specs = jax.eval_shape(fn, *specs)
    if not isinstance(out_specs, (tuple, list)):
        out_specs = (out_specs,)
    assert len(out_names) == len(out_specs), (name, len(out_names), len(out_specs))
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = list(zip(out_names, out_specs))
    mw.artifact(name, fname, ins, outs)
    print(f"  {name:<22} {len(text):>9} chars  {time.time() - t0:5.1f}s", flush=True)
    return fname, ins, outs


def param_ins(cfg, head=False, cls="param", prefix="p."):
    return [
        (prefix + n, _spec(s, F32), cls) for n, s in M.param_shapes(cfg, head)
    ]


def lm_artifacts(out_dir, mw, cfg):
    """init / prefill / decode (+ B=1 variants) / train for one LM size."""
    L, H, Dh = cfg.layers, cfg.heads, cfg.head_dim
    n = len(M.param_names(cfg))
    pnames = M.param_names(cfg)

    # --- init ------------------------------------------------------------
    def init_fn(seed):
        return tuple(M.init_params(cfg, seed))

    lower_one(
        out_dir, mw, f"{cfg.name}.init", init_fn,
        [("seed", _spec((), U32), "data")],
        [f"p.{nm}" for nm in pnames],
    )

    # --- bucketed prefill (manifest v3) -----------------------------------
    # one artifact per power-of-two admission bucket; `prefill` and
    # `prefill1` are manifest aliases of the @GEN_B / @1 buckets (same
    # HLO file) so pre-v3 call sites keep resolving
    prefill_reg = {}
    for b in prefill_buckets(GEN_B):

        def prefill_fn(*flat):
            params, rest = flat[:n], flat[n:]
            prompt, lens, seeds, temp = rest
            return M.prefill(cfg, list(params), prompt, lens, seeds, temp)

        prefill_reg[b] = lower_one(
            out_dir, mw, f"{cfg.name}.prefill@{b}", prefill_fn,
            param_ins(cfg)
            + [
                ("prompt", _spec((b, S_PROMPT), S32), "data"),
                ("lens", _spec((b,), S32), "data"),
                ("seeds", _spec((b,), U32), "data"),
                ("temp", _spec((), F32), "data"),
            ],
            ["next", "logp", "kcache", "vcache"],
        )
    mw.artifact(f"{cfg.name}.prefill", *prefill_reg[GEN_B])
    mw.artifact(f"{cfg.name}.prefill1", *prefill_reg[1])

    # --- kv_install: device-side admission scatter (manifest v3) ---------
    full_cache = _spec((L, GEN_B, S_CTX, H, Dh), F32)
    for b in prefill_buckets(GEN_B):

        def install_fn(kcache, vcache, src_k, src_v, slots, count):
            return M.kv_install(kcache, vcache, src_k, src_v, slots, count)

        lower_one(
            out_dir, mw, f"{cfg.name}.kv_install@{b}", install_fn,
            [
                ("kcache", full_cache, "state"),
                ("vcache", full_cache, "state"),
                ("src_k", _spec((L, b, S_CTX, H, Dh), F32), "state"),
                ("src_v", _spec((L, b, S_CTX, H, Dh), F32), "state"),
                ("slots", _spec((b,), S32), "data"),
                ("count", _spec((), S32), "data"),
            ],
            ["kcache", "vcache"],
        )

    # --- decode at generation and latency batch sizes ---------------------
    for b, tag in ((GEN_B, ""), (1, "1")):
        cache = _spec((L, b, S_CTX, H, Dh), F32)

        def decode_fn(*flat):
            params, rest = flat[:n], flat[n:]
            kc, vc, tok, pos, step, seeds, temp = rest
            return M.decode_step(cfg, list(params), kc, vc, tok, pos, step, seeds, temp)

        lower_one(
            out_dir, mw, f"{cfg.name}.decode{tag}", decode_fn,
            param_ins(cfg)
            + [
                ("kcache", cache, "state"),
                ("vcache", cache, "state"),
                ("tok", _spec((b,), S32), "data"),
                ("pos", _spec((b,), S32), "data"),
                ("step", _spec((), S32), "data"),
                ("seeds", _spec((b,), U32), "data"),
                ("temp", _spec((), F32), "data"),
            ],
            ["next", "logp", "kcache", "vcache"],
        )

    # --- block-paged KV cache (manifest v4) -------------------------------
    # pool + table decode, paged admission install per bucket, and the
    # copy-on-extend block mover; the dense artifacts above stay
    # registered for A/B and fallback
    pool = _spec((L, KV_POOL, KV_BLOCK, H, Dh), F32)

    def decode_paged_fn(*flat):
        params, rest = flat[:n], flat[n:]
        kp, vp, tables, tok, pos, step, seeds, temp = rest
        return M.paged_decode_step(
            cfg, list(params), kp, vp, tables, tok, pos, step, seeds, temp
        )

    lower_one(
        out_dir, mw, f"{cfg.name}.decode_paged", decode_paged_fn,
        param_ins(cfg)
        + [
            ("kcache", pool, "state"),
            ("vcache", pool, "state"),
            ("tables", _spec((GEN_B, KV_MAXBLK), S32), "data"),
            ("tok", _spec((GEN_B,), S32), "data"),
            ("pos", _spec((GEN_B,), S32), "data"),
            ("step", _spec((), S32), "data"),
            ("seeds", _spec((GEN_B,), U32), "data"),
            ("temp", _spec((), F32), "data"),
        ],
        ["next", "logp", "kcache", "vcache"],
    )

    for b in prefill_buckets(GEN_B):

        def install_paged_fn(kpool, vpool, src_k, src_v, dst_tables):
            return M.kv_install_paged(kpool, vpool, src_k, src_v, dst_tables)

        lower_one(
            out_dir, mw, f"{cfg.name}.kv_install_paged@{b}", install_paged_fn,
            [
                ("kcache", pool, "state"),
                ("vcache", pool, "state"),
                ("src_k", _spec((L, b, S_CTX, H, Dh), F32), "state"),
                ("src_v", _spec((L, b, S_CTX, H, Dh), F32), "state"),
                ("dst_tables", _spec((b, KV_MAXBLK), S32), "data"),
            ],
            ["kcache", "vcache"],
        )

    def block_copy_fn(kpool, vpool, src, dst, count):
        return M.kv_block_copy(kpool, vpool, src, dst, count)

    lower_one(
        out_dir, mw, f"{cfg.name}.kv_block_copy", block_copy_fn,
        [
            ("kcache", pool, "state"),
            ("vcache", pool, "state"),
            ("src", _spec((GEN_B,), S32), "data"),
            ("dst", _spec((GEN_B,), S32), "data"),
            ("count", _spec((), S32), "data"),
        ],
        ["kcache", "vcache"],
    )

    # --- speculative verify (manifest v5) ---------------------------------
    # one artifact per draft-length bucket K; same host-input discipline
    # as decode_paged (tables + O(B·K) tokens per call)
    for kb in verify_buckets(KV_BLOCK):

        def verify_fn(*flat, _k=kb):
            params, rest = flat[:n], flat[n:]
            kp, vp, tables, toks, pos, step, seeds, temp = rest
            return M.verify_step(
                cfg, list(params), kp, vp, tables, toks, pos, step, seeds, temp
            )

        lower_one(
            out_dir, mw, f"{cfg.name}.verify@{kb}", verify_fn,
            param_ins(cfg)
            + [
                ("kcache", pool, "state"),
                ("vcache", pool, "state"),
                ("tables", _spec((GEN_B, KV_MAXBLK), S32), "data"),
                ("toks", _spec((GEN_B, kb), S32), "data"),
                ("pos", _spec((GEN_B,), S32), "data"),
                ("step", _spec((), S32), "data"),
                ("seeds", _spec((GEN_B,), U32), "data"),
                ("temp", _spec((), F32), "data"),
            ],
            ["next", "logp", "kcache", "vcache"],
        )

    # --- train ------------------------------------------------------------
    def train_fn(*flat):
        params, m, v = flat[:n], flat[n : 2 * n], flat[2 * n : 3 * n]
        tokens, loss_mask, lr, step = flat[3 * n :]
        return M.lm_train_step(cfg, list(params), list(m), list(v), tokens, loss_mask, lr, step)

    lower_one(
        out_dir, mw, f"{cfg.name}.train", train_fn,
        param_ins(cfg)
        + param_ins(cfg, cls="opt", prefix="m.")
        + param_ins(cfg, cls="opt", prefix="v.")
        + [
            ("tokens", _spec((TRAIN_B, S_CTX), S32), "data"),
            ("loss_mask", _spec((TRAIN_B, S_CTX), F32), "data"),
            ("lr", _spec((), F32), "data"),
            ("step", _spec((), S32), "data"),
        ],
        [f"p.{nm}" for nm in pnames]
        + [f"m.{nm}" for nm in pnames]
        + [f"v.{nm}" for nm in pnames]
        + ["loss"],
    )


def scorer_artifacts(out_dir, mw, cfg):
    n = len(M.param_names(cfg))
    pnames = M.param_names(cfg)

    def init_fn(seed):
        return tuple(M.init_params(cfg, seed))

    lower_one(
        out_dir, mw, f"{cfg.name}.init", init_fn,
        [("seed", _spec((), U32), "data")],
        [f"p.{nm}" for nm in pnames],
    )

    def train_fn(*flat):
        params, m, v = flat[:n], flat[n : 2 * n], flat[2 * n : 3 * n]
        tokens, loss_mask, lr, step = flat[3 * n :]
        return M.lm_train_step(cfg, list(params), list(m), list(v), tokens, loss_mask, lr, step)

    lower_one(
        out_dir, mw, f"{cfg.name}.train", train_fn,
        param_ins(cfg)
        + param_ins(cfg, cls="opt", prefix="m.")
        + param_ins(cfg, cls="opt", prefix="v.")
        + [
            ("tokens", _spec((TRAIN_B, S_CTX), S32), "data"),
            ("loss_mask", _spec((TRAIN_B, S_CTX), F32), "data"),
            ("lr", _spec((), F32), "data"),
            ("step", _spec((), S32), "data"),
        ],
        [f"p.{nm}" for nm in pnames]
        + [f"m.{nm}" for nm in pnames]
        + [f"v.{nm}" for nm in pnames]
        + ["loss"],
    )

    for b, tag in ((SCORE_B, ""), (1, "1")):

        def score_fn(*flat):
            params, rest = flat[:n], flat[n:]
            tokens, resp_mask = rest
            return (M.score(cfg, list(params), tokens, resp_mask),)

        lower_one(
            out_dir, mw, f"{cfg.name}.score{tag}", score_fn,
            param_ins(cfg)
            + [
                ("tokens", _spec((b, S_CTX), S32), "data"),
                ("resp_mask", _spec((b, S_CTX), F32), "data"),
            ],
            ["q"],
        )


def router_artifacts(out_dir, mw, cfg):
    n = len(M.param_names(cfg, head=True))
    pnames = M.param_names(cfg, head=True)

    def init_fn(seed):
        return tuple(M.init_params(cfg, seed, head=True))

    lower_one(
        out_dir, mw, "router.init", init_fn,
        [("seed", _spec((), U32), "data")],
        [f"p.{nm}" for nm in pnames],
    )

    for b, tag in ((TRAIN_B, ""), (1, "1")):

        def fwd_fn(*flat):
            params, rest = flat[:n], flat[n:]
            tokens, lens = rest
            return (M.router_forward(cfg, list(params), tokens, lens),)

        lower_one(
            out_dir, mw, f"router.fwd{tag}", fwd_fn,
            param_ins(cfg, head=True)
            + [
                ("tokens", _spec((b, S_PROMPT), S32), "data"),
                ("lens", _spec((b,), S32), "data"),
            ],
            ["score"],
        )

    def train_fn(*flat):
        params, m, v = flat[:n], flat[n : 2 * n], flat[2 * n : 3 * n]
        tokens, lens, labels, lr, step = flat[3 * n :]
        return M.router_train_step(
            cfg, list(params), list(m), list(v), tokens, lens, labels, lr, step
        )

    lower_one(
        out_dir, mw, "router.train", train_fn,
        param_ins(cfg, head=True)
        + param_ins(cfg, head=True, cls="opt", prefix="m.")
        + param_ins(cfg, head=True, cls="opt", prefix="v.")
        + [
            ("tokens", _spec((TRAIN_B, S_PROMPT), S32), "data"),
            ("lens", _spec((TRAIN_B,), S32), "data"),
            ("labels", _spec((TRAIN_B,), F32), "data"),
            ("lr", _spec((), F32), "data"),
            ("step", _spec((), S32), "data"),
        ],
        [f"p.{nm}" for nm in pnames]
        + [f"m.{nm}" for nm in pnames]
        + [f"v.{nm}" for nm in pnames]
        + ["loss"],
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--only",
        default="",
        help="comma-separated subset of model names to lower (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    t0 = time.time()
    mw = ManifestWriter()
    for name in LM_SIZES:
        mw.model(CFGS[name])
    mw.model(CFGS["scorer"])
    mw.model(CFGS["router"], head=True)

    for name in LM_SIZES:
        if only and name not in only:
            continue
        print(f"[aot] lowering LM '{name}'", flush=True)
        lm_artifacts(args.out, mw, CFGS[name])
    if not only or "scorer" in only:
        print("[aot] lowering scorer", flush=True)
        scorer_artifacts(args.out, mw, CFGS["scorer"])
    if not only or "router" in only:
        print("[aot] lowering router", flush=True)
        router_artifacts(args.out, mw, CFGS["router"])

    mw.write(os.path.join(args.out, "manifest.txt"))
    print(f"[aot] done in {time.time() - t0:.1f}s -> {args.out}/manifest.txt", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
