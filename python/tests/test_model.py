"""L2 correctness: model graphs — shapes, invariances, and the key
consistency property: prefill + decode_step chain reproduces the
teacher-forced forward pass (same logits path, same cache semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.common import CFGS, EOS, S_CTX, S_PROMPT, VOCAB


CFG = CFGS["nano"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 7)


def test_param_shapes_match_init(params):
    shapes = M.param_shapes(CFG)
    assert len(shapes) == len(params)
    for (name, shape), arr in zip(shapes, params):
        assert arr.shape == shape, name
        assert arr.dtype == jnp.float32


def test_init_is_seed_deterministic():
    a = M.init_params(CFG, 3)
    b = M.init_params(CFG, 3)
    c = M.init_params(CFG, 4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(not np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, c))


def test_router_score_in_unit_interval():
    cfg = CFGS["router"]
    p = M.init_params(cfg, 0, head=True)
    tokens = jnp.zeros((4, S_PROMPT), jnp.int32).at[:, 0].set(1)
    lens = jnp.array([1, 5, 10, S_PROMPT], jnp.int32)
    s = M.router_forward(cfg, p, tokens, lens)
    assert s.shape == (4,)
    assert bool(jnp.all((s > 0) & (s < 1)))


def test_router_padding_invariance():
    """Tokens beyond lens must not change the score."""
    cfg = CFGS["router"]
    p = M.init_params(cfg, 0, head=True)
    base = jnp.zeros((1, S_PROMPT), jnp.int32).at[0, :6].set(
        jnp.array([1, 40, 50, 9, 9, 3])
    )
    lens = jnp.array([6], jnp.int32)
    poisoned = base.at[0, 6:].set(17)
    s0 = M.router_forward(cfg, p, base, lens)
    s1 = M.router_forward(cfg, p, poisoned, lens)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-5, atol=1e-6)


def test_prefill_then_decode_matches_teacher_forcing(params):
    """Greedy generation via prefill+decode must equal argmax of the
    teacher-forced logits over the same (gold) context at every step."""
    B = 2
    prompt = jnp.zeros((B, S_PROMPT), jnp.int32)
    seq0 = [1, 40, 50, 9, 10, 3]
    seq1 = [1, 41, 50, 4, 3]
    prompt = prompt.at[0, : len(seq0)].set(jnp.array(seq0))
    prompt = prompt.at[1, : len(seq1)].set(jnp.array(seq1))
    lens = jnp.array([len(seq0), len(seq1)], jnp.int32)
    seeds = jnp.array([0, 0], jnp.uint32)
    temp = jnp.float32(0.0)  # greedy

    tok, lp, kc, vc = M.prefill(CFG, params, prompt, lens, seeds, temp)
    gen = [[int(tok[0])], [int(tok[1])]]
    pos = lens  # position of the token just sampled
    cur = tok
    steps = 4
    for t in range(steps):
        cur, lp, kc, vc = M.decode_step(
            CFG, params, kc, vc, cur, pos, jnp.int32(t), seeds, temp
        )
        pos = pos + 1
        gen[0].append(int(cur[0]))
        gen[1].append(int(cur[1]))

    # teacher-forced check: feed [prompt, generated...] through lm_logits
    p = M.as_dict(CFG, params)
    for b, seq in enumerate((seq0, seq1)):
        ctx = list(seq) + gen[b][:-1]
        tokens = jnp.zeros((1, S_CTX), jnp.int32).at[0, : len(ctx)].set(jnp.array(ctx))
        tlens = jnp.array([len(ctx)], jnp.int32)
        logits = M.lm_logits(CFG, p, tokens, tlens, causal=True, use_pallas=True)
        for i, want_pos in enumerate(range(len(seq) - 1, len(ctx))):
            pred = int(jnp.argmax(logits[0, want_pos]))
            assert pred == gen[b][i], (b, i)


def test_sampling_temperature_zero_is_greedy(params):
    B = 2
    prompt = jnp.zeros((B, S_PROMPT), jnp.int32).at[:, 0].set(1)
    lens = jnp.ones((B,), jnp.int32)
    t1, *_ = M.prefill(CFG, params, prompt, lens, jnp.array([1, 2], jnp.uint32), jnp.float32(0.0))
    t2, *_ = M.prefill(CFG, params, prompt, lens, jnp.array([9, 8], jnp.uint32), jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


def test_sampling_seeds_vary_output(params):
    """At high temperature different seeds should (eventually) differ."""
    B = 8
    prompt = jnp.zeros((B, S_PROMPT), jnp.int32).at[:, 0].set(1)
    lens = jnp.ones((B,), jnp.int32)
    seeds = jnp.arange(B, dtype=jnp.uint32)
    t1, *_ = M.prefill(CFG, params, prompt, lens, seeds, jnp.float32(2.0))
    assert len(set(np.asarray(t1).tolist())) > 1


@pytest.mark.parametrize("k", [1, 2, 4])
def test_verify_step_matches_sequential_paged_decode(params, k):
    """The v5 verify@K contract: one K-token verify step is *bitwise*
    equal to K sequential paged_decode_step calls — next tokens, per-token
    logprobs, and the updated pools. This is what lets the rust hybrid
    decoder accept a drafted prefix and keep output byte-identical to
    large-only decoding."""
    cfg = CFG
    L, H, Dh = cfg.layers, cfg.heads, cfg.head_dim
    B, NBLK, BLOCK, MAXBLK = 2, 9, 4, 4
    key = jax.random.PRNGKey(3)
    kpool = jax.random.normal(key, (L, NBLK, BLOCK, H, Dh), jnp.float32)
    vpool = jax.random.normal(jax.random.fold_in(key, 1), (L, NBLK, BLOCK, H, Dh), jnp.float32)
    # two live lanes with disjoint nonzero blocks; lane 0 starts mid-block
    tables = jnp.array([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    pos = jnp.array([5, 2], jnp.int32)
    toks = jax.random.randint(jax.random.fold_in(key, 2), (B, k), 4, VOCAB).astype(jnp.int32)
    seeds = jnp.array([11, 12], jnp.uint32)
    step = jnp.int32(7)
    for temp in (jnp.float32(0.0), jnp.float32(0.9)):
        got_n, got_lp, got_kp, got_vp = M.verify_step(
            cfg, params, kpool, vpool, tables, toks, pos, step, seeds, temp
        )
        kp, vp = kpool, vpool
        want_n, want_lp = [], []
        for i in range(k):
            t, lp, kp, vp = M.paged_decode_step(
                cfg, params, kp, vp, tables, toks[:, i], pos + i, step + i, seeds, temp
            )
            want_n.append(t)
            want_lp.append(lp)
        np.testing.assert_array_equal(np.asarray(got_n), np.stack([np.asarray(t) for t in want_n], 1))
        np.testing.assert_array_equal(np.asarray(got_lp), np.stack([np.asarray(t) for t in want_lp], 1))
        np.testing.assert_array_equal(np.asarray(got_kp), np.asarray(kp))
        np.testing.assert_array_equal(np.asarray(got_vp), np.asarray(vp))


def test_score_is_mean_logprob(params):
    """Hand-check the scorer math on the nano config."""
    cfg = CFG
    tokens = jnp.zeros((1, S_CTX), jnp.int32)
    seq = [1, 40, 50, 9, 3, 10, 11, EOS]
    tokens = tokens.at[0, : len(seq)].set(jnp.array(seq))
    mask = jnp.zeros((1, S_CTX), jnp.float32).at[0, 5:8].set(1.0)  # answer region
    got = M.score(cfg, params, tokens, mask)
    p = M.as_dict(cfg, params)
    lens = jnp.array([len(seq)], jnp.int32)
    logits = M.lm_logits(cfg, p, tokens, lens, use_pallas=True)
    lp = jax.nn.log_softmax(logits[0, :-1])
    want = float(np.mean([float(lp[t - 1, seq[t]]) for t in (5, 6, 7)]))
    np.testing.assert_allclose(float(got[0]), want, rtol=1e-5)


def test_lm_train_step_reduces_loss(params):
    """A few steps on a single repeated batch must reduce the CE loss."""
    cfg = CFG
    m = [jnp.zeros_like(w) for w in params]
    v = [jnp.zeros_like(w) for w in params]
    flat = list(params)
    tokens = np.zeros((32, S_CTX), np.int32)
    rng = np.random.RandomState(0)
    for b in range(32):
        seq = [1, 40, 50] + rng.randint(4, 30, size=5).tolist() + [3, 9, 9, EOS]
        tokens[b, : len(seq)] = seq
    mask = np.zeros((32, S_CTX), np.float32)
    mask[:, 9:12] = 1.0
    tokens = jnp.array(tokens)
    mask = jnp.array(mask)
    losses = []
    for step in range(1, 9):
        out = M.lm_train_step(cfg, flat, m, v, tokens, mask, jnp.float32(3e-3), jnp.int32(step))
        n = len(flat)
        flat = list(out[:n])
        m = list(out[n : 2 * n])
        v = list(out[2 * n : 3 * n])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_router_train_step_reduces_bce():
    cfg = CFGS["router"]
    flat = M.init_params(cfg, 0, head=True)
    m = [jnp.zeros_like(w) for w in flat]
    v = [jnp.zeros_like(w) for w in flat]
    rng = np.random.RandomState(0)
    tokens = np.zeros((32, S_PROMPT), np.int32)
    labels = np.zeros((32,), np.float32)
    for b in range(32):
        task = 40 if b % 2 == 0 else 44
        labels[b] = 1.0 if b % 2 == 0 else 0.0
        seq = [1, task, 50] + rng.randint(4, 30, size=6).tolist() + [3]
        tokens[b, : len(seq)] = seq
    lens = jnp.full((32,), 10, jnp.int32)
    tokens = jnp.array(tokens)
    labels = jnp.array(labels)
    losses = []
    for step in range(1, 13):
        out = M.router_train_step(
            cfg, flat, m, v, tokens, lens, labels, jnp.float32(1e-3), jnp.int32(step)
        )
        n = len(flat)
        flat, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_vocab_constant():
    assert VOCAB == 64 and S_CTX == 64 and S_PROMPT == 40
