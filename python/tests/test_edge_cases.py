"""Edge-case tests for the L1/L2 graphs: boundary lengths, empty masks,
prompt-length extremes, and cross-size consistency of the generation
chain — behaviours the rust coordinator relies on implicitly."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.common import A_MAX, CFGS, EOS, LM_SIZES, PAD, S_CTX, S_PROMPT


@pytest.mark.parametrize("size", list(LM_SIZES))
def test_prefill_shapes_all_sizes(size):
    cfg = CFGS[size]
    p = M.init_params(cfg, 0)
    B = 2
    prompt = jnp.zeros((B, S_PROMPT), jnp.int32).at[:, 0].set(1)
    lens = jnp.ones((B,), jnp.int32)
    seeds = jnp.zeros((B,), jnp.uint32)
    tok, lp, kc, vc = M.prefill(cfg, p, prompt, lens, seeds, jnp.float32(0.0))
    assert tok.shape == (B,)
    assert lp.shape == (B,)
    assert kc.shape == (cfg.layers, B, S_CTX, cfg.heads, cfg.head_dim)
    assert vc.shape == kc.shape


def test_prefill_max_length_prompt():
    cfg = CFGS["nano"]
    p = M.init_params(cfg, 0)
    prompt = jnp.full((1, S_PROMPT), 9, jnp.int32).at[0, 0].set(1)
    lens = jnp.array([S_PROMPT], jnp.int32)
    tok, lp, kc, vc = M.prefill(cfg, p, prompt, lens, jnp.zeros((1,), jnp.uint32), jnp.float32(0.0))
    assert int(tok[0]) >= 0
    assert np.isfinite(float(lp[0]))


def test_decode_at_last_position():
    """Writing K/V at the final cache slot must not error or overflow."""
    cfg = CFGS["nano"]
    p = M.init_params(cfg, 0)
    B = 1
    kc = jnp.zeros((cfg.layers, B, S_CTX, cfg.heads, cfg.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    tok = jnp.array([5], jnp.int32)
    pos = jnp.array([S_CTX - 1], jnp.int32)
    seeds = jnp.zeros((B,), jnp.uint32)
    nxt, lp, kc2, vc2 = M.decode_step(
        cfg, p, kc, vc, tok, pos, jnp.int32(0), seeds, jnp.float32(0.0)
    )
    assert nxt.shape == (B,)
    assert np.isfinite(float(lp[0]))
    # the write landed in the last slot
    assert not np.allclose(np.asarray(kc2[:, 0, -1]), 0.0)


def test_score_empty_response_region_is_finite():
    cfg = CFGS["scorer"]
    p = M.init_params(cfg, 0)
    tokens = jnp.zeros((1, S_CTX), jnp.int32).at[0, 0].set(1)
    mask = jnp.zeros((1, S_CTX), jnp.float32)  # nothing to score
    q = M.score(cfg, p, tokens, mask)
    assert np.isfinite(float(q[0]))
    assert float(q[0]) == 0.0  # sum 0 / max(denom,1)


def test_score_is_length_normalized():
    """Doubling the scored region must not double the score magnitude."""
    cfg = CFGS["scorer"]
    p = M.init_params(cfg, 0)
    seq = [1, 40, 50, 9, 3] + [7] * 8 + [EOS]
    tokens = jnp.zeros((1, S_CTX), jnp.int32).at[0, : len(seq)].set(jnp.array(seq))
    m_short = jnp.zeros((1, S_CTX), jnp.float32).at[0, 5:9].set(1.0)
    m_long = jnp.zeros((1, S_CTX), jnp.float32).at[0, 5:13].set(1.0)
    q_short = float(M.score(cfg, p, tokens, m_short)[0])
    q_long = float(M.score(cfg, p, tokens, m_long)[0])
    # both are means over their regions: same order of magnitude
    assert abs(q_long) < 2.5 * abs(q_short) + 1.0


def test_decode_seeds_decorrelate_slots():
    """Same token/pos in different slots with different seeds must sample
    different continuations at high temperature (slot independence)."""
    cfg = CFGS["nano"]
    p = M.init_params(cfg, 0)
    B = 8
    kc = jnp.zeros((cfg.layers, B, S_CTX, cfg.heads, cfg.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    tok = jnp.full((B,), 9, jnp.int32)
    pos = jnp.full((B,), 3, jnp.int32)
    seeds = jnp.arange(B, dtype=jnp.uint32)
    nxt, *_ = M.decode_step(cfg, p, kc, vc, tok, pos, jnp.int32(0), seeds, jnp.float32(3.0))
    assert len(set(np.asarray(nxt).tolist())) > 1


def test_amax_budget_consistent_with_sctx():
    assert S_PROMPT + A_MAX <= S_CTX


def test_pad_token_is_zero():
    # rust relies on PAD == 0 for zeroed buffers
    assert PAD == 0
