"""AOT/manifest contract tests: the manifest must exactly describe the
lowered artifacts (file presence, parameter counts, HLO parameter order),
because the rust runtime trusts it blindly."""

import os

import pytest

from compile import model as M
from compile.common import CFGS, LM_SIZES

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.txt")),
    reason="artifacts not built (run `make artifacts`)",
)


def parse_manifest(path):
    arts, models, globals_ = {}, {}, {}
    cur = None
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            if parts[0] == "global":
                globals_ = dict(zip(parts[1::2], parts[2::2]))
            elif parts[0] == "model":
                models[parts[1]] = dict(zip(parts[2::2], parts[3::2]))
            elif parts[0] == "artifact":
                cur = {"file": parts[3], "ins": [], "outs": []}
                arts[parts[1]] = cur
            elif parts[0] == "in":
                cur["ins"].append((parts[1], parts[2], parts[3], parts[4]))
            elif parts[0] == "out":
                # v2 appends a residency class; v1 lines have none
                cls = parts[4] if len(parts) > 4 else "data"
                cur["outs"].append((parts[1], parts[2], parts[3], cls))
    return globals_, models, arts


@needs_artifacts
def test_manifest_files_exist():
    _, _, arts = parse_manifest(os.path.join(ART, "manifest.txt"))
    assert len(arts) == 38
    for name, a in arts.items():
        p = os.path.join(ART, a["file"])
        assert os.path.exists(p), name
        assert os.path.getsize(p) > 1000, name


@needs_artifacts
def test_manifest_artifact_set_complete():
    _, _, arts = parse_manifest(os.path.join(ART, "manifest.txt"))
    for s in LM_SIZES:
        for kind in ("init", "prefill", "decode", "prefill1", "decode1", "train"):
            assert f"{s}.{kind}" in arts, (s, kind)
    for kind in ("init", "train", "score", "score1"):
        assert f"scorer.{kind}" in arts
    for kind in ("init", "fwd", "fwd1", "train"):
        assert f"router.{kind}" in arts


@needs_artifacts
def test_manifest_param_order_matches_model():
    """The in-lines of each artifact must list params in param_names order
    (that order is the HLO parameter numbering rust relies on)."""
    _, models, arts = parse_manifest(os.path.join(ART, "manifest.txt"))
    for s in LM_SIZES + ("scorer",):
        names = M.param_names(CFGS[s])
        ins = arts[f"{s}.train"]["ins"]
        got_p = [n[2:] for n, _, _, c in ins if c == "param"]
        assert got_p == names, s
        got_m = [n[2:] for n, _, _, c in ins if c == "opt" and n.startswith("m.")]
        assert got_m == names, s
    names = M.param_names(CFGS["router"], head=True)
    ins = arts["router.fwd"]["ins"]
    got = [n[2:] for n, _, _, c in ins if c == "param"]
    assert got == names


@needs_artifacts
def test_manifest_hlo_param_count_matches():
    """HLO text must declare exactly as many parameters as manifest ins."""
    import re

    _, _, arts = parse_manifest(os.path.join(ART, "manifest.txt"))
    for name in ("nano.decode", "router.fwd", "scorer.score", "nano.init"):
        a = arts[name]
        text = open(os.path.join(ART, a["file"])).read()
        # count distinct parameter(k) declarations in the ENTRY computation
        entry = text.split("ENTRY")[1]
        params = set(re.findall(r"parameter\((\d+)\)", entry))
        assert len(params) == len(a["ins"]), (name, len(params), len(a["ins"]))


@needs_artifacts
def test_manifest_output_residency_classes():
    """v2 manifests mark KV-cache outputs `state` (device-resident in the
    rust runtime) and sampled-token outputs `data` (downloaded)."""
    _, _, arts = parse_manifest(os.path.join(ART, "manifest.txt"))
    for s in LM_SIZES:
        for kind in ("prefill", "decode", "prefill1", "decode1"):
            outs = {n: c for n, _, _, c in arts[f"{s}.{kind}"]["outs"]}
            assert outs["kcache"] == "state", (s, kind)
            assert outs["vcache"] == "state", (s, kind)
            assert outs["next"] == "data", (s, kind)
            assert outs["logp"] == "data", (s, kind)
    # scalar-score artifacts stay plain data
    outs = {n: c for n, _, _, c in arts["router.fwd"]["outs"]}
    assert outs["score"] == "data"


@needs_artifacts
def test_manifest_model_dims():
    _, models, _ = parse_manifest(os.path.join(ART, "manifest.txt"))
    for s in LM_SIZES:
        cfg = CFGS[s]
        m = models[s]
        assert int(m["d"]) == cfg.d
        assert int(m["layers"]) == cfg.layers
        assert int(m["heads"]) == cfg.heads
        assert int(m["nparams"]) == len(M.param_names(cfg))
