"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes, lengths and causality; these tests are
the core numerical contract for everything the rust side executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import (
    decode_attention,
    flash_attention,
    ref_attention,
    ref_decode_attention,
)

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@st.composite
def attn_case(draw):
    b = draw(st.integers(1, 4))
    s = draw(st.integers(1, 70))
    h = draw(st.integers(1, 4))
    dh = draw(st.sampled_from([4, 8, 16, 24, 32]))
    causal = draw(st.booleans())
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    lens = draw(st.lists(st.integers(1, s), min_size=b, max_size=b))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, s, h, dh, causal, dtype, lens, seed


@given(attn_case())
def test_flash_attention_matches_ref(case):
    b, s, h, dh, causal, dtype, lens, seed = case
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, s, h, dh), dtype)
    k = _rand(kk, (b, s, h, dh), dtype)
    v = _rand(kv, (b, s, h, dh), dtype)
    lens = jnp.array(lens, jnp.int32)
    got = flash_attention(q, k, v, lens, causal)
    want = ref_attention(q, k, v, lens, causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@st.composite
def decode_case(draw):
    b = draw(st.integers(1, 4))
    s = draw(st.integers(1, 70))
    h = draw(st.integers(1, 4))
    dh = draw(st.sampled_from([4, 8, 16, 32]))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    pos = draw(st.lists(st.integers(0, s - 1), min_size=b, max_size=b))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, s, h, dh, dtype, pos, seed


@given(decode_case())
def test_decode_attention_matches_ref(case):
    b, s, h, dh, dtype, pos, seed = case
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, dh), dtype)
    kc = _rand(kk, (b, s, h, dh), dtype)
    vc = _rand(kv, (b, s, h, dh), dtype)
    pos = jnp.array(pos, jnp.int32)
    got = decode_attention(q, kc, vc, pos)
    want = ref_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_decode_ignores_garbage_beyond_pos():
    """Cache positions > pos must not affect the output at all."""
    key = jax.random.PRNGKey(0)
    b, s, h, dh = 2, 16, 2, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, dh), jnp.float32)
    kc = _rand(kk, (b, s, h, dh), jnp.float32)
    vc = _rand(kv, (b, s, h, dh), jnp.float32)
    pos = jnp.array([3, 9], jnp.int32)
    base = decode_attention(q, kc, vc, pos)
    kc2 = kc.at[0, 4:].set(1e6).at[1, 10:].set(-1e6)
    vc2 = vc.at[0, 4:].set(1e6).at[1, 10:].set(-1e6)
    poisoned = decode_attention(q, kc2, vc2, pos)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned), rtol=1e-6, atol=1e-6)


def test_flash_attention_respects_lens():
    """Keys beyond lens[b] must not affect the output."""
    key = jax.random.PRNGKey(1)
    b, s, h, dh = 2, 12, 2, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, s, h, dh), jnp.float32)
    k = _rand(kk, (b, s, h, dh), jnp.float32)
    v = _rand(kv, (b, s, h, dh), jnp.float32)
    lens = jnp.array([5, 12], jnp.int32)
    base = flash_attention(q, k, v, lens, causal=False)
    k2 = k.at[0, 5:].set(1e6)
    v2 = v.at[0, 5:].set(-1e6)
    poisoned = flash_attention(q, k2, v2, lens, causal=False)
    np.testing.assert_allclose(
        np.asarray(base[:, :5]), np.asarray(poisoned[:, :5]), rtol=1e-6, atol=1e-6
    )
    # example 1 (full length) identical everywhere
    np.testing.assert_allclose(np.asarray(base[1]), np.asarray(poisoned[1]), rtol=1e-6, atol=1e-6)


def test_causal_first_position_is_value_passthrough():
    """At i=0 with causal masking, output must equal v[:, 0] exactly-ish."""
    key = jax.random.PRNGKey(2)
    b, s, h, dh = 3, 8, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, s, h, dh), jnp.float32)
    k = _rand(kk, (b, s, h, dh), jnp.float32)
    v = _rand(kv, (b, s, h, dh), jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    out = flash_attention(q, k, v, lens, causal=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("s", [1, 15, 16, 17, 40, 64])
def test_flash_attention_ragged_tiles(s):
    """Sequence lengths straddling BLOCK_KV boundaries."""
    key = jax.random.PRNGKey(3)
    b, h, dh = 2, 2, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, s, h, dh), jnp.float32)
    k = _rand(kk, (b, s, h, dh), jnp.float32)
    v = _rand(kv, (b, s, h, dh), jnp.float32)
    lens = jnp.array([s, max(1, s // 2)], jnp.int32)
    got = flash_attention(q, k, v, lens, causal=True)
    want = ref_attention(q, k, v, lens, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
