//! Device–edge–cloud routing scenario (companion to `edge_cloud.rs`,
//! generalized to the 3-tier fleet): a tiny on-device model (`nano`), a
//! mid-size edge model (`medium`), and a strong cloud model (`large`).
//! A single router score is partitioned into three bands by the ladder
//! policy; the sweep prints the per-tier traffic split, cost-weighted
//! cost advantage, and quality drop, then calibrates a §4.5-style
//! ladder operating point on the validation split. When the AOT
//! artifacts and trained params are present, the same ladder is also
//! exercised live through a 3-tier `Server`.
//!
//! Requires a completed pipeline run (default `runs/smoke`):
//! `cargo run --release --example device_edge_cloud [RUN_DIR]`

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};
use hybrid_llm::batching::BatchMode;
use hybrid_llm::calibrate::{
    calibrate_ladder, calibrate_quality_ladders, evaluate_ladder, ladder_from_pivot,
};
use hybrid_llm::corpus::{Scale, Split};
use hybrid_llm::pipeline::{ladder_specs, model_cost, pair_id, subset, Pipeline};
use hybrid_llm::policy::{self, TierPolicy};
use hybrid_llm::router::RouterKind;
use hybrid_llm::runtime::Runtime;
use hybrid_llm::serve::{ReplicaSelect, Request, ServeConfig, Server, DEFAULT_QUEUE_CAP};
use hybrid_llm::stats;

const FLEET: [&str; 3] = ["nano", "medium", "large"];

fn main() -> Result<()> {
    let run_dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "runs/smoke".into()),
    );
    let artifacts = Runtime::default_dir();
    let rt = Runtime::load(&artifacts)?;
    let pl = Pipeline::new(rt, &run_dir, Scale::Smoke);
    let corpus = pl.ensure_corpus()?;
    let costs: Vec<f64> = FLEET.iter().map(|m| model_cost(m)).collect();
    // one router score for the whole ladder: the medium/large r_trans
    let pair = pair_id("medium", "large");
    let all_scores = pl
        .load_router_scores(&pair, RouterKind::Trans)
        .context("run the pipeline first")?;

    let test = hybrid_llm::corpus::split_ids(&corpus, Split::Test);
    let val = hybrid_llm::corpus::split_ids(&corpus, Split::Val);
    let scores: Vec<f32> = test.iter().map(|&i| all_scores[i]).collect();
    // one tensor load per model, subset for both splits
    let mut quals: Vec<Vec<f64>> = Vec::new();
    let mut quals_v: Vec<Vec<f64>> = Vec::new();
    for m in FLEET {
        let q = pl.load_quality(m, &corpus)?;
        quals.push(subset(&q, &test).mean());
        quals_v.push(subset(&q, &val).mean());
    }

    println!("== device–edge–cloud: {} ==\n", FLEET.join(" -> "));
    for (m, (q, c)) in FLEET.iter().zip(quals.iter().zip(&costs)) {
        println!("  {m:<8} mean quality {:+.3}   relative cost {c:.2}", stats::mean(q));
    }
    println!("\npivot  frac_device  frac_edge  frac_cloud  cost_adv%  quality_drop%");
    for k in 0..=10 {
        let pivot = k as f32 / 10.0;
        let thresholds = ladder_from_pivot(pivot, FLEET.len());
        let assign = TierPolicy::Ladder { thresholds }.assign(&scores);
        let frac = policy::tier_fractions(&assign, FLEET.len());
        let ca = policy::cost_advantage_tiers(&assign, &costs);
        let q = policy::achieved_quality_tiers(&assign, &quals);
        let drop = hybrid_llm::metrics::quality_drop_pct(stats::mean(&quals[2]), q);
        println!(
            "  {pivot:.1}      {:5.2}       {:5.2}      {:5.2}     {:6.1}      {drop:+7.2}",
            frac[0], frac[1], frac[2], ca * 100.0
        );
    }

    // §4.5 generalized: calibrate the ladder pivot on val for <=1% drop
    let scores_v: Vec<f32> = val.iter().map(|&i| all_scores[i]).collect();
    let cal = calibrate_ladder(&scores_v, &quals_v, &costs, 1.0);
    let on_test = evaluate_ladder(&cal.thresholds, &scores, &quals, &costs);
    println!(
        "\ncalibrated ladder {:?}: saves {:.1}% of cloud-equivalent spend at {:+.2}% drop on test",
        cal.thresholds,
        on_test.cost_advantage * 100.0,
        on_test.drop_pct
    );

    // live 3-tier serving, when the fleet's params are trained
    let have_params = FLEET
        .iter()
        .all(|m| pl.paths.params(m).join("p.emb.tz").exists());
    if !have_params {
        println!("\n(skipping live serving: fleet params not trained — run the pipeline)");
        return Ok(());
    }
    println!("\n== live 3-tier serving (ladder {:?}) ==", cal.thresholds);
    // the quality-indexed family: the same validation data, calibrated
    // at every quality level so each *request* picks its own tradeoff
    let family = calibrate_quality_ladders(&scores_v, &quals_v, &costs, 8)?;
    let cfg = ServeConfig {
        artifacts_dir: artifacts,
        run_dir: run_dir.clone(),
        tiers: ladder_specs(&FLEET),
        router: format!("{pair}_trans"),
        policy: TierPolicy::Ladder { thresholds: cal.thresholds.clone() },
        select: ReplicaSelect::ShortestQueue,
        temp: 0.0,
        mode: BatchMode::Continuous,
        batch_window: Duration::from_millis(5),
        queue_cap: DEFAULT_QUEUE_CAP,
        quality_ladders: Some(family),
        force_host_admission: false,
    };
    let server = Server::start(cfg)?;
    let reqs: Vec<_> = corpus
        .iter()
        .filter(|q| q.split == Split::Test)
        .take(24)
        .collect();
    // interleave per-request quality targets: the same traffic served
    // cost-first (0.1), calibrated-default (no target), quality-first (0.9)
    let targets = [Some(0.1f32), None, Some(0.9)];
    let handles = reqs
        .iter()
        .enumerate()
        .map(|(i, q)| {
            let mut req = Request::new(q.prompt.clone());
            if let Some(t) = targets[i % targets.len()] {
                req = req.quality(t);
            }
            server.submit(req).context("submit")
        })
        .collect::<Result<Vec<_>>>()?;
    let mut tier_by_target = [[0usize; 3]; 3];
    for (i, h) in handles.into_iter().enumerate() {
        let c = h.wait().context("completion dropped")?;
        tier_by_target[i % targets.len()][c.tier.min(2)] += 1;
    }
    let live = server.shutdown()?;
    let total = live.routing.total().max(1);
    for (ts, tr) in live.tiers.iter().zip(&live.routing.tiers) {
        println!(
            "tier {:<8} routed {:>3} ({:>5.1}%)   e2e p50 {:>6.0} ms",
            ts.name,
            tr.routed,
            tr.routed as f64 / total as f64 * 100.0,
            ts.latency.p50_ms
        );
    }
    for (t, counts) in targets.iter().zip(&tier_by_target) {
        let label = t.map_or("default".to_string(), |q| format!("q={q:.1}"));
        println!(
            "target {label:<8} device {:>2}  edge {:>2}  cloud {:>2}",
            counts[0], counts[1], counts[2]
        );
    }
    println!(
        "live cost advantage {:.1}%   e2e p95 {:.0} ms",
        live.routing.cost_advantage * 100.0,
        live.e2e_latency.p95_ms
    );
    Ok(())
}
