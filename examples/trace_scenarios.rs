//! Trace scenarios — generate synthetic traffic traces, round-trip one
//! through the on-disk `# hybrid-trace v1` text format, then (when the
//! AOT artifacts are built) replay a burst trace against a live two-tier
//! fleet and check the serving invariants.
//!
//! ```sh
//! cargo run --release --example trace_scenarios            # traces only
//! make artifacts && cargo run --release --example trace_scenarios
//! ```
//!
//! The full seven-scenario sweep (overload, cancel storms, ...) is the
//! CLI's job: `cargo run --release -- kick-tires [--smoke]`.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;
use hybrid_llm::batching::BatchMode;
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::{Manifest, Runtime};
use hybrid_llm::scenario::{
    self, check_invariants, GenShape, ReplayOpts, Trace, TransferBounds,
};
use hybrid_llm::serve::{ServeConfig, Server};

fn main() -> Result<()> {
    println!("== trace scenarios ==\n");

    // 1. generate: every built-in scenario is a seeded pure function of
    // (seed, n, shape) — same inputs, same trace, any machine
    let shape = GenShape { sprompt: 40, amax: 24 };
    for sc in scenario::builtin_suite() {
        let trace = (sc.make)(7, 32, shape);
        println!(
            "{:<14} {:>3} events over {:>7.1?}  ({})",
            trace.name,
            trace.events.len(),
            trace.span(),
            sc.about
        );
    }

    // 2. round-trip: traces persist as plain text so recorded production
    // traffic can be replayed later (lengths and timing only — replays
    // fabricate token payloads, so no user data lands on disk)
    let trace = scenario::gen_poisson_burst(7, 32, shape);
    let path = std::env::temp_dir().join("hybrid_trace_example.txt");
    trace.save(&path)?;
    let loaded = Trace::load(&path)?;
    assert_eq!(trace, loaded, "trace text round-trip must be lossless");
    println!("\nsaved + reloaded {:?} ({} bytes)", path, std::fs::metadata(&path)?.len());
    let _ = std::fs::remove_file(&path);

    // 3. replay against a live fleet (needs artifacts)
    let artifacts = Runtime::default_dir();
    if !artifacts.join("manifest.txt").exists() {
        println!("\nartifacts not built — skipping the live replay (run `make artifacts`)");
        return Ok(());
    }
    let manifest = Manifest::load(&artifacts.join("manifest.txt"))?;
    let g = &manifest.globals;
    let shape = GenShape { sprompt: g.sprompt, amax: g.amax };

    // seed a temp run dir with init weights (replay latency and the
    // invariants are weight-independent)
    let run_dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "runs/trace_scenarios".into()),
    );
    {
        let rt = Runtime::load(&artifacts)?;
        for model in ["small", "medium"] {
            let dir = run_dir.join("params").join(model);
            if !dir.join("p.emb.tz").exists() {
                LmEngine::init(rt.clone(), model, 3)?.save(&dir)?;
            }
        }
    }
    let mut cfg = ServeConfig::two_tier(
        artifacts.clone(),
        run_dir.clone(),
        "small",
        "medium",
        String::new(), // random router — no trained run required
        0.5,
    );
    cfg.temp = 0.8;
    cfg.mode = BatchMode::Continuous;
    cfg.batch_window = Duration::from_millis(2);
    let server = Server::start(cfg)?;

    let trace = scenario::gen_poisson_burst(7, 32, shape);
    println!("\nreplaying {:?}: {} requests...", trace.name, trace.events.len());
    let out = scenario::replay(&server, &trace, &ReplayOpts::default())?;
    let queue_cap = server.queue_cap();
    let stats = server.shutdown()?;

    println!(
        "accepted {}  done {}  failed {}  cancelled {}  p50 {:.0} ms  p95 {:.0} ms",
        out.accepted,
        out.done,
        out.failed,
        out.cancelled,
        out.e2e_p50_ms(),
        out.e2e_p95_ms(),
    );
    let bounds: TransferBounds = scenario::transfer_bounds(&manifest, &["small", "medium"])?;
    let violations = check_invariants(&out, &stats, queue_cap, &bounds);
    if violations.is_empty() {
        println!("invariants OK: exactly-one-terminal, balanced counters, bounded transfers");
    } else {
        for v in &violations {
            println!("VIOLATION: {v}");
        }
        anyhow::bail!("{} invariant violation(s)", violations.len());
    }
    Ok(())
}
