//! Serving benchmark — load trained models and serve batched requests,
//! reporting latency and throughput under four configurations:
//! {learned router, random router} × {continuous batching,
//! run-to-completion}. This is the "load a small real model and serve
//! batched requests" end-to-end validation driver.
//!
//! `cargo run --release --example serve_bench [RUN_DIR] [N_REQUESTS]`

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};
use hybrid_llm::batching::BatchMode;
use hybrid_llm::corpus::{Scale, Split};
use hybrid_llm::pipeline::{pair_id, Pipeline};
use hybrid_llm::runtime::Runtime;
use hybrid_llm::serve::{Request, ServeConfig, Server};

fn main() -> Result<()> {
    let run_dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "runs/smoke".into()),
    );
    let n: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(64);
    let artifacts = Runtime::default_dir();
    let rt = Runtime::load(&artifacts)?;
    let pl = Pipeline::new(rt, &run_dir, Scale::Smoke);
    let corpus = pl.ensure_corpus()?;
    let prompts: Vec<Vec<i32>> = corpus
        .iter()
        .filter(|q| q.split == Split::Test)
        .take(n)
        .map(|q| q.prompt.clone())
        .collect();
    anyhow::ensure!(!prompts.is_empty());

    let (small, large) = ("medium", "large");
    println!("== serve_bench: {} requests, {small} vs {large} ==\n", prompts.len());
    println!(
        "{:<28} {:>9} {:>10} {:>9} {:>9} {:>9}",
        "config", "wall s", "req/s", "p50 ms", "p95 ms", "cost adv"
    );

    for (router, mode, label) in [
        (format!("{}_trans", pair_id(small, large)), BatchMode::Continuous, "r_trans + continuous"),
        (format!("{}_trans", pair_id(small, large)), BatchMode::RunToCompletion, "r_trans + run-to-completion"),
        (String::new(), BatchMode::Continuous, "random + continuous"),
        (String::new(), BatchMode::RunToCompletion, "random + run-to-completion"),
    ] {
        let mut cfg =
            ServeConfig::two_tier(artifacts.clone(), run_dir.clone(), small, large, router, 0.5);
        cfg.mode = mode;
        cfg.batch_window = Duration::from_millis(5);
        // the bench submits its whole workload upfront — size the
        // admission window to it so large N_REQUESTS measures serving,
        // not Busy backpressure
        cfg.queue_cap = cfg.queue_cap.max(prompts.len());
        let server = Server::start(cfg)?;
        let t0 = std::time::Instant::now();
        let handles = prompts
            .iter()
            .map(|p| server.submit(Request::new(p.clone())).context("submit"))
            .collect::<Result<Vec<_>>>()?;
        for h in handles {
            h.wait().context("completion dropped")?;
        }
        let wall = t0.elapsed();
        let stats = server.shutdown()?;
        println!(
            "{:<28} {:>9.2} {:>10.1} {:>9.0} {:>9.0} {:>8.1}%",
            label,
            wall.as_secs_f64(),
            prompts.len() as f64 / wall.as_secs_f64(),
            stats.e2e_latency.p50_ms,
            stats.e2e_latency.p95_ms,
            stats.routing.cost_advantage * 100.0,
        );
    }
    Ok(())
}
