//! Quickstart — the end-to-end driver (DESIGN.md e2e mandate).
//!
//! Runs the entire reproduction at smoke scale against the real AOT
//! artifacts: trains the LM roster + scorer + routers **from rust**,
//! then serves a batch of live requests through the router + two
//! continuous-batching workers and reports latency, throughput, cost
//! advantage and response quality.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//! Re-runs reuse `runs/quickstart` (every stage is resumable).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};
use hybrid_llm::batching::BatchMode;
use hybrid_llm::corpus::{Scale, Split};
use hybrid_llm::eval::Eval;
use hybrid_llm::pipeline::{pair_id, Pipeline};
use hybrid_llm::runtime::Runtime;
use hybrid_llm::scorer::ScorerEngine;
use hybrid_llm::serve::{Request, ServeConfig, Server};

fn main() -> Result<()> {
    let artifacts = Runtime::default_dir();
    let run_dir = PathBuf::from(
        std::env::args()
            .nth(1)
            .unwrap_or_else(|| "runs/quickstart".into()),
    );
    println!("== hybrid-llm quickstart ==");
    println!("artifacts: {artifacts:?}   run: {run_dir:?}\n");

    // 1. full pipeline at smoke scale (resumable)
    let rt = Runtime::load(&artifacts).context("run `make artifacts` first")?;
    let pl = Pipeline::new(rt.clone(), &run_dir, Scale::Smoke);
    pl.run_all()?;
    let corpus = pl.ensure_corpus()?;

    // 2. headline numbers (Fig 1 analogue)
    let ev = Eval::new(&pl, &corpus);
    println!("{}", ev.run("fig1")?);

    // 3. live serving demo: medium (small/edge) vs large (cloud)
    let (small, large) = ("medium", "large");
    let mut cfg = ServeConfig::two_tier(
        artifacts,
        run_dir.clone(),
        small,
        large,
        format!("{}_trans", pair_id(small, large)),
        0.5,
    );
    cfg.mode = BatchMode::Continuous;
    cfg.batch_window = Duration::from_millis(5);
    println!("== live serving: {small} vs {large}, r_trans ==");
    let server = Server::start(cfg)?;
    let test: Vec<_> = corpus
        .iter()
        .filter(|q| q.split == Split::Test)
        .take(48)
        .collect();
    let t0 = std::time::Instant::now();
    let handles = test
        .iter()
        .map(|q| server.submit(Request::new(q.prompt.clone())).context("submit"))
        .collect::<Result<Vec<_>>>()?;
    let completions: Vec<_> = handles
        .into_iter()
        .map(|h| h.wait().context("completion"))
        .collect::<Result<_>>()?;
    let wall = t0.elapsed();
    let stats = server.shutdown()?;

    // 4. score the live responses with the quality scorer
    let scorer = ScorerEngine::load(rt, &pl.paths.params("scorer"))?;
    let pairs: Vec<(&[i32], &[i32])> = test
        .iter()
        .zip(&completions)
        .map(|(q, c)| (q.prompt.as_slice(), c.tokens.as_slice()))
        .collect();
    let quals = scorer.score(&pairs)?;
    let mean_q: f64 = quals.iter().map(|&x| x as f64).sum::<f64>() / quals.len() as f64;

    println!("\n== serving report ==");
    println!(
        "requests {}   wall {:.2}s   throughput {:.1} req/s",
        completions.len(),
        wall.as_secs_f64(),
        completions.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "cost advantage {:.1}%   mean quality {:.3}   e2e p50 {:.0} ms  p95 {:.0} ms",
        stats.routing.cost_advantage * 100.0,
        mean_q,
        stats.e2e_latency.p50_ms,
        stats.e2e_latency.p95_ms
    );
    println!("done. Full tables/figures: `repro eval all --run {run_dir:?} --scale smoke`");
    Ok(())
}
