//! Edge–cloud routing scenario (paper Fig 2): a weak on-device model
//! (`nano` ~ FLAN-t5 800m on a phone) backed by a strong cloud model
//! (`medium` ~ Llama-2 13b behind an API). Sweeps the router threshold
//! and prints the achievable cost-advantage / quality-drop frontier —
//! the consumer's "how many API calls can I skip" view.
//!
//! Requires a completed pipeline run (default `runs/smoke`):
//! `cargo run --release --example edge_cloud [RUN_DIR]`

use std::path::PathBuf;

use anyhow::{Context, Result};
use hybrid_llm::corpus::{Scale, Split};
use hybrid_llm::pipeline::{pair_id, subset, Pipeline};
use hybrid_llm::policy;
use hybrid_llm::router::RouterKind;
use hybrid_llm::runtime::Runtime;
use hybrid_llm::stats;

fn main() -> Result<()> {
    let run_dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "runs/smoke".into()),
    );
    let rt = Runtime::load(&Runtime::default_dir())?;
    let pl = Pipeline::new(rt, &run_dir, Scale::Smoke);
    let corpus = pl.ensure_corpus()?;
    let (edge, cloud) = ("nano", "medium");
    let pair = pair_id(edge, cloud);

    let test = hybrid_llm::corpus::split_ids(&corpus, Split::Test);
    let qs = subset(&pl.load_quality(edge, &corpus).context("run the pipeline first")?, &test).mean();
    let ql = subset(&pl.load_quality(cloud, &corpus)?, &test).mean();
    let all_scores = pl.load_router_scores(&pair, RouterKind::Trans)?;
    let scores: Vec<f32> = test.iter().map(|&i| all_scores[i]).collect();

    println!("== edge–cloud routing: {edge} (edge) vs {cloud} (cloud API) ==\n");
    println!(
        "all-at-cloud quality {:.3} | all-at-edge quality {:.3}\n",
        stats::mean(&ql),
        stats::mean(&qs)
    );
    println!("threshold  api_calls_saved%  quality_drop%");
    for k in 0..=10 {
        let thr = k as f32 / 10.0;
        let assign = policy::Policy::Threshold { threshold: thr }.assign(&scores);
        let ca = policy::cost_advantage(&assign);
        let q = policy::achieved_quality(&assign, &qs, &ql);
        let drop = hybrid_llm::metrics::quality_drop_pct(stats::mean(&ql), q);
        println!("   {thr:.1}        {:6.1}        {drop:+7.2}", ca * 100.0);
    }

    // the §4.5 operating point: calibrate on val for <=1% drop
    let val = hybrid_llm::corpus::split_ids(&corpus, Split::Val);
    let qs_v = subset(&pl.load_quality(edge, &corpus)?, &val).mean();
    let ql_v = subset(&pl.load_quality(cloud, &corpus)?, &val).mean();
    let scores_v: Vec<f32> = val.iter().map(|&i| all_scores[i]).collect();
    let cal = hybrid_llm::calibrate::calibrate(&scores_v, &qs_v, &ql_v, 1.0);
    let on_test = hybrid_llm::calibrate::evaluate_threshold(cal.threshold, &scores, &qs, &ql);
    println!(
        "\ncalibrated threshold {:.3}: saves {:.1}% of cloud calls at {:+.2}% drop on test",
        cal.threshold,
        on_test.cost_advantage * 100.0,
        on_test.drop_pct
    );
    Ok(())
}
