//! §4.5 walkthrough: choose router thresholds on a 500-sample validation
//! subset under a performance-drop budget, then verify generalization on
//! the test split (the Table 3 protocol), for all routers × main pairs.
//!
//! `cargo run --release --example threshold_calibration [RUN_DIR] [MAX_DROP_PCT]`

use std::path::PathBuf;

use anyhow::{Context, Result};
use hybrid_llm::calibrate;
use hybrid_llm::corpus::{Scale, Split};
use hybrid_llm::pipeline::{pair_id, subset, Pipeline, MAIN_PAIRS};
use hybrid_llm::router::ALL_ROUTERS;
use hybrid_llm::runtime::Runtime;

fn main() -> Result<()> {
    let run_dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "runs/smoke".into()),
    );
    let max_drop: f64 = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1.0);
    let rt = Runtime::load(&Runtime::default_dir())?;
    let pl = Pipeline::new(rt, &run_dir, Scale::Smoke);
    let corpus = pl.ensure_corpus()?;
    let val = hybrid_llm::corpus::split_ids(&corpus, Split::Val);
    let test = hybrid_llm::corpus::split_ids(&corpus, Split::Test);

    println!("== threshold calibration (<= {max_drop}% drop on 500 val samples) ==\n");
    println!(
        "{:<8} {:<16} {:>9} {:>12} {:>9} {:>12}",
        "router", "pair", "val drop", "val cost adv", "test drop", "test cost adv"
    );
    for kind in ALL_ROUTERS {
        for (small, large, _) in MAIN_PAIRS {
            let pair = pair_id(small, large);
            let scores_all = pl
                .load_router_scores(&pair, kind)
                .context("run the pipeline first")?;
            let sub = calibrate::subsample(val.len(), 500, 0xCAFE);
            let val_ids: Vec<usize> = sub.iter().map(|&i| val[i]).collect();
            let qs_v = subset(&pl.load_quality(small, &corpus)?, &val_ids).mean();
            let ql_v = subset(&pl.load_quality(large, &corpus)?, &val_ids).mean();
            let scores_v: Vec<f32> = val_ids.iter().map(|&i| scores_all[i]).collect();
            let cal = calibrate::calibrate(&scores_v, &qs_v, &ql_v, max_drop);

            let qs_t = subset(&pl.load_quality(small, &corpus)?, &test).mean();
            let ql_t = subset(&pl.load_quality(large, &corpus)?, &test).mean();
            let scores_t: Vec<f32> = test.iter().map(|&i| scores_all[i]).collect();
            let on_test = calibrate::evaluate_threshold(cal.threshold, &scores_t, &qs_t, &ql_t);
            println!(
                "r_{:<6} {:<16} {:>8.2}% {:>11.1}% {:>8.2}% {:>11.1}%",
                kind.name(),
                pair,
                cal.drop_pct,
                cal.cost_advantage * 100.0,
                on_test.drop_pct,
                on_test.cost_advantage * 100.0
            );
        }
    }
    Ok(())
}
