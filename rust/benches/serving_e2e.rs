//! Bench: end-to-end serving — requests flow through the router thread
//! and the two continuous-batching workers. Reports request throughput,
//! latency percentiles, decoded tokens/sec, and host-transfer bytes per
//! decode step (the device-resident-KV headline) at several offered
//! loads. Uses seeded-init weights written to a temp run dir (latency is
//! weight-independent), so it runs without a pipeline run; the router is
//! random at threshold 0.5 giving a ~50% routing split. The largest-load
//! point is appended to `BENCH_serving.json` as the perf trajectory.

use std::path::Path;
use std::time::{Duration, Instant};

use hybrid_llm::batching::BatchMode;
use hybrid_llm::bench::merge_bench_json;
use hybrid_llm::corpus::{generate, Scale};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::Runtime;
use hybrid_llm::serve::{ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let artifacts = Runtime::default_dir();
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    // seed a temp run dir with init weights
    let run_dir = std::env::temp_dir().join(format!("hybrid_bench_run_{}", std::process::id()));
    {
        let rt = Runtime::load(&artifacts)?;
        for model in ["small", "medium"] {
            let eng = LmEngine::init(rt.clone(), model, 3)?;
            eng.save(&run_dir.join("params").join(model))?;
        }
    }
    let corpus = generate(11, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(96).map(|q| q.prompt.clone()).collect();

    println!("== serving_e2e: small/medium pair, random router ==");
    println!(
        "{:>9} {:>9} {:>10} {:>9} {:>9} {:>10} {:>10} {:>12} {:>12}",
        "requests", "wall s", "req/s", "p50 ms", "p95 ms", "slot eff", "tok/s", "d2h B/step", "h2d B/step"
    );
    let mut json: Vec<(String, f64)> = Vec::new();
    for n in [16, 48, 96] {
        let mut cfg = ServeConfig::two_tier(
            artifacts.clone(),
            run_dir.clone(),
            "small",
            "medium",
            String::new(), // random routing
            0.5,
        );
        cfg.temp = 0.8;
        cfg.mode = BatchMode::Continuous;
        cfg.batch_window = Duration::from_millis(2);
        let server = Server::start(cfg)?;
        let t0 = Instant::now();
        let rxs: Vec<_> = prompts[..n].iter().map(|p| server.submit(p.clone())).collect();
        let mut tokens = 0usize;
        for rx in rxs {
            tokens += rx.recv()?.tokens.len();
        }
        let wall = t0.elapsed();
        let stats = server.shutdown()?;
        let eff = if stats.decode_steps > 0 {
            stats.decode_slot_steps as f64 / (stats.decode_steps as f64 * 16.0)
        } else {
            0.0
        };
        let tok_s = tokens as f64 / wall.as_secs_f64();
        println!(
            "{:>9} {:>9.2} {:>10.1} {:>9.0} {:>9.0} {:>10.2} {:>10.1} {:>12.0} {:>12.0}",
            n,
            wall.as_secs_f64(),
            n as f64 / wall.as_secs_f64(),
            stats.e2e_latency.p50_ms,
            stats.e2e_latency.p95_ms,
            eff,
            tok_s,
            stats.d2h_bytes_per_step(),
            stats.h2d_bytes_per_step(),
        );
        if n == 96 {
            json.push(("serving.req_per_sec".to_string(), n as f64 / wall.as_secs_f64()));
            json.push(("serving.tokens_per_sec".to_string(), tok_s));
            json.push(("serving.e2e_p50_ms".to_string(), stats.e2e_latency.p50_ms));
            json.push(("serving.e2e_p95_ms".to_string(), stats.e2e_latency.p95_ms));
            json.push(("serving.slot_efficiency".to_string(), eff));
            json.push(("serving.d2h_bytes_per_step".to_string(), stats.d2h_bytes_per_step()));
            json.push(("serving.h2d_bytes_per_step".to_string(), stats.h2d_bytes_per_step()));
        }
    }
    let json_path = Path::new("BENCH_serving.json");
    merge_bench_json(json_path, &json)?;
    println!("\nwrote {} metrics to {}", json.len(), json_path.display());
    let _ = std::fs::remove_dir_all(&run_dir);
    Ok(())
}
