//! Bench: end-to-end serving — requests flow through the router thread
//! and the two continuous-batching workers. Reports request throughput,
//! latency percentiles, streamed tokens/sec (counted from `Event::Token`s
//! — the streaming path, not the final completions), and host-transfer
//! bytes per decode step (the device-resident-KV headline) at several
//! offered loads, then probes cancel latency (cancel() → terminal
//! `Cancelled`). Uses seeded-init weights written to a temp run dir
//! (latency is weight-independent), so it runs without a pipeline run;
//! the router is random at threshold 0.5 giving a ~50% routing split.
//! The largest-load point and the cancel probe are appended to
//! `BENCH_serving.json` as the perf trajectory, including admission
//! latency and host bytes per admitted request. On manifest-v3
//! artifacts this bench is also the CI gate for device-side admission:
//! it **fails** when admission bytes scale with the KV cache (i.e. with
//! `sctx`) instead of the O(B·sprompt) prompt window.
//!
//! After the load points it runs the smoke scenario sweep
//! (`scenario::kick_tires`): trace-replayed bursts, diurnal swings,
//! long tails, mixed quality targets, overload, and cancel storms, each
//! gated on the serving invariants — and fails on any violation.
//!
//! On manifest-v4 artifacts it then replays the prefix-heavy `sessions`
//! trace twice — prefix cache on vs off — and **fails** unless sharing
//! engages (hit rate > 0) and actually removes prefill work
//! (`prefill_tokens` drops). The paged-KV utilization and hit rate join
//! `BENCH_serving.json` as `serving.kv_blocks_utilization` /
//! `serving.prefix_hit_rate`.

use std::path::Path;
use std::time::{Duration, Instant};

use hybrid_llm::batching::BatchMode;
use hybrid_llm::bench::merge_bench_json;
use hybrid_llm::corpus::{generate, Scale};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::{Manifest, Runtime};
use hybrid_llm::serve::{Event, Request, RequestError, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let artifacts = Runtime::default_dir();
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    // seed a temp run dir with init weights
    let run_dir = std::env::temp_dir().join(format!("hybrid_bench_run_{}", std::process::id()));
    {
        let rt = Runtime::load(&artifacts)?;
        for model in ["small", "medium"] {
            let eng = LmEngine::init(rt.clone(), model, 3)?;
            eng.save(&run_dir.join("params").join(model))?;
        }
    }
    let corpus = generate(11, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(96).map(|q| q.prompt.clone()).collect();

    println!("== serving_e2e: small/medium pair, random router ==");
    println!(
        "{:>9} {:>9} {:>10} {:>9} {:>9} {:>10} {:>10} {:>12} {:>12}",
        "requests", "wall s", "req/s", "p50 ms", "p95 ms", "slot eff", "tok/s", "d2h B/step", "h2d B/step"
    );
    let mut json: Vec<(String, f64)> = Vec::new();
    for n in [16, 48, 96] {
        let mut cfg = ServeConfig::two_tier(
            artifacts.clone(),
            run_dir.clone(),
            "small",
            "medium",
            String::new(), // random routing
            0.5,
        );
        cfg.temp = 0.8;
        cfg.mode = BatchMode::Continuous;
        cfg.batch_window = Duration::from_millis(2);
        let server = Server::start(cfg)?;
        let t0 = Instant::now();
        let handles = prompts[..n]
            .iter()
            .map(|p| server.submit(Request::new(p.clone())))
            .collect::<Result<Vec<_>, _>>()?;
        // consume the event streams live (round-robin try_recv, so Token
        // arrival times are real): count streamed tokens per handle, pin
        // them against the completion's token count, and time the
        // first-token → last-token window for the streaming rate
        let mut tokens = 0usize;
        let mut streamed = vec![0usize; handles.len()];
        let mut finished = vec![false; handles.len()];
        let mut n_done = 0usize;
        let mut first_tok: Option<Instant> = None;
        let mut last_tok = t0;
        while n_done < handles.len() {
            let mut progressed = false;
            for (i, h) in handles.iter().enumerate() {
                if finished[i] {
                    continue;
                }
                loop {
                    match h.events().try_recv() {
                        Ok(Event::Token { .. }) => {
                            let now = Instant::now();
                            first_tok.get_or_insert(now);
                            last_tok = now;
                            streamed[i] += 1;
                            tokens += 1;
                            progressed = true;
                        }
                        Ok(Event::Done(c)) => {
                            assert_eq!(
                                streamed[i],
                                c.tokens.len(),
                                "stream diverged from completion"
                            );
                            finished[i] = true;
                            n_done += 1;
                            progressed = true;
                            break;
                        }
                        Ok(Event::Routed { .. }) => progressed = true,
                        Ok(ev) => anyhow::bail!("unexpected terminal event: {ev:?}"),
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            anyhow::bail!("event stream closed without a terminal event")
                        }
                    }
                }
            }
            if !progressed {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        let stream_window = first_tok.map(|f| last_tok.duration_since(f).as_secs_f64());
        let wall = t0.elapsed();
        // snapshot the load-phase stats *before* the cancel probe so the
        // trajectory metrics (slot efficiency, e2e percentiles, transfer
        // bytes) measure the offered load, not the probe's 8 sequential
        // single-slot decodes
        let stats = server.stats();

        // cancel-latency probe (server idle): submit, wait until routed,
        // cancel, time to the terminal event
        let mut cancel_lat: Option<f64> = None;
        if n == 96 {
            for p in prompts.iter().take(8) {
                let h = server.submit(Request::new(p.clone()).max_new_tokens(64))?;
                // the first event is the routing decision — wait for it
                // so the cancel lands on an in-flight request
                let _ = h.events().recv();
                let c0 = Instant::now();
                h.cancel();
                match h.wait_timeout(Duration::from_secs(30)) {
                    Err(RequestError::Cancelled) => {
                        let ms = c0.elapsed().as_secs_f64() * 1e3;
                        cancel_lat = Some(cancel_lat.map_or(ms, |m: f64| m.min(ms)));
                    }
                    // the request can win the race by completing first
                    Ok(_) => {}
                    Err(e) => anyhow::bail!("cancel probe: {e}"),
                }
            }
            if let Some(ms) = cancel_lat {
                json.push(("serving.cancel_latency_ms".to_string(), ms));
            }
        }
        server.shutdown()?;
        let eff = if stats.decode_steps > 0 {
            stats.decode_slot_steps as f64 / (stats.decode_steps as f64 * 16.0)
        } else {
            0.0
        };
        let tok_s = tokens as f64 / wall.as_secs_f64();
        println!(
            "{:>9} {:>9.2} {:>10.1} {:>9.0} {:>9.0} {:>10.2} {:>10.1} {:>12.0} {:>12.0}",
            n,
            wall.as_secs_f64(),
            n as f64 / wall.as_secs_f64(),
            stats.e2e_latency.p50_ms,
            stats.e2e_latency.p95_ms,
            eff,
            tok_s,
            stats.d2h_bytes_per_step(),
            stats.h2d_bytes_per_step(),
        );
        if n == 96 {
            json.push(("serving.req_per_sec".to_string(), n as f64 / wall.as_secs_f64()));
            json.push(("serving.tokens_per_sec".to_string(), tok_s));
            json.push(("serving.admit_latency_ms".to_string(), stats.admit_latency.p50_ms));
            json.push(("serving.admit_bytes_per_req".to_string(), stats.admit_bytes_per_req()));
            // CI gate: on v3 artifacts admission must move O(B·sprompt)
            // host bytes per request — a number that scales with sctx
            // means the KV cache is round-tripping through the host
            let manifest = Manifest::load(&artifacts.join("manifest.txt"))?;
            if manifest.version >= 3 {
                let kv_pair_bytes =
                    hybrid_llm::serve::min_kv_pair_bytes(&manifest, &["small", "medium"])?;
                let per_req = stats.admit_bytes_per_req();
                let o_b_sprompt = hybrid_llm::serve::admission_byte_bound(&manifest.globals);
                anyhow::ensure!(
                    per_req > 0.0 && per_req < o_b_sprompt.min(kv_pair_bytes / 4.0),
                    "admission moved {per_req:.0} B/request — scaling with sctx \
                     (O(B·sprompt) bound {o_b_sprompt:.0} B, KV pair {kv_pair_bytes:.0} B); \
                     device-side kv_install is not engaging"
                );
                println!(
                    "admission gate OK: {per_req:.0} B/request (O(B·sprompt) bound {o_b_sprompt:.0} B)"
                );
            }
            // streaming-mode rate over the first-token → last-token
            // arrival window — excludes the submit/routing head and
            // measures the event stream itself, so it can diverge from
            // the completion-based tokens_per_sec above
            if let Some(w) = stream_window {
                if w > 0.0 {
                    json.push((
                        "serving.stream_tokens_per_sec".to_string(),
                        tokens as f64 / w,
                    ));
                }
            }
            json.push(("serving.e2e_p50_ms".to_string(), stats.e2e_latency.p50_ms));
            json.push(("serving.e2e_p95_ms".to_string(), stats.e2e_latency.p95_ms));
            // submit→dispatch sojourn — the brownout controller's delay
            // sensor, tracked whether or not the controller is armed
            json.push(("serving.queue_delay_p50_ms".to_string(), stats.queue_delay.p50_ms));
            json.push(("serving.queue_delay_p99_ms".to_string(), stats.queue_delay.p99_ms));
            json.push(("serving.slot_efficiency".to_string(), eff));
            json.push(("serving.d2h_bytes_per_step".to_string(), stats.d2h_bytes_per_step()));
            json.push(("serving.h2d_bytes_per_step".to_string(), stats.h2d_bytes_per_step()));
        }
    }
    let json_path = Path::new("BENCH_serving.json");
    merge_bench_json(json_path, &json)?;
    println!("\nwrote {} metrics to {}", json.len(), json_path.display());

    // scenario sweep (smoke): replay the built-in traffic scenarios —
    // Poisson bursts, diurnal swings, long tails, mixed quality,
    // overload, cancel storms — against the same fleet and gate each on
    // the serving invariants (exactly-one-terminal, counter balance,
    // bounded queue, O(B) transfer bounds). Per-scenario latency/shed/
    // cancel/cost-advantage metrics join the trajectory file.
    println!("\n== serving_e2e: scenario sweep (smoke + chaos + overload) ==");
    let mut opts = hybrid_llm::scenario::KickTiresOpts::new(artifacts.clone(), run_dir.clone());
    opts.smoke = true;
    // fault-injection suite rides along: crash/stall/tier-outage chaos
    // metrics (failovers, degraded, retries, lost) join the trajectory
    opts.chaos = true;
    // overload-brownout suite too: 3x sustained load against the armed
    // controller, gated on zero lost, the interactive goodput floor,
    // strict priority ordering, and level-0 recovery after the drain
    opts.overload = true;
    opts.bench_json = Some(json_path.to_path_buf());
    let report = hybrid_llm::scenario::kick_tires(&opts)?;
    print!("{}", report.render());
    anyhow::ensure!(
        report.total_violations() == 0,
        "{} serving-invariant violation(s) in the scenario sweep",
        report.total_violations()
    );
    println!("scenario gate OK: all scenarios passed their invariants");

    // prefix-cache A/B gate (manifest v4): replay the sessions trace —
    // multi-turn conversations re-sending a shared system prompt — with
    // cross-request sharing on and off. With the trie engaged, shared
    // blocks skip prefill install, so the prefill token count must drop.
    let manifest = Manifest::load(&artifacts.join("manifest.txt"))?;
    if manifest.version >= 4 {
        use hybrid_llm::scenario::{gen_sessions, replay, GenShape, ReplayOpts};
        println!("\n== serving_e2e: prefix-cache A/B (sessions trace) ==");
        let shape = GenShape {
            sprompt: manifest.globals.sprompt,
            amax: manifest.globals.amax,
        };
        let trace = gen_sessions(23, 48, shape);
        let run_sessions = |disable: bool| -> anyhow::Result<hybrid_llm::serve::ServerStats> {
            let mut cfg = ServeConfig::two_tier(
                artifacts.clone(),
                run_dir.clone(),
                "small",
                "medium",
                String::new(),
                0.5,
            );
            // greedy: exact full-prompt re-sends can replay their cached
            // first token and skip prefill entirely
            cfg.temp = 0.0;
            cfg.mode = BatchMode::Continuous;
            cfg.batch_window = Duration::from_millis(2);
            cfg.disable_prefix_cache = disable;
            let server = Server::start(cfg)?;
            replay(&server, &trace, &ReplayOpts::default())?;
            server.shutdown()
        };
        let off = run_sessions(true)?;
        let on = run_sessions(false)?;
        println!(
            "prefill tokens: {} (cache off) -> {} (cache on)   hit rate {:.0}%   \
             block utilization {:.0}%",
            off.prefill_tokens,
            on.prefill_tokens,
            on.prefix_hit_rate * 100.0,
            on.kv_blocks_utilization * 100.0
        );
        anyhow::ensure!(
            on.prefix_hit_rate > 0.0,
            "prefix cache never hit on the sessions trace (lookups found no shared blocks)"
        );
        anyhow::ensure!(
            on.prefill_tokens < off.prefill_tokens,
            "prefix cache did not reduce prefill work on the sessions trace \
             ({} tokens with sharing vs {} without)",
            on.prefill_tokens,
            off.prefill_tokens
        );
        println!("prefix gate OK: prefill work dropped with sharing enabled");
        merge_bench_json(
            json_path,
            &[
                ("serving.prefix_hit_rate".to_string(), on.prefix_hit_rate),
                (
                    "serving.kv_blocks_utilization".to_string(),
                    on.kv_blocks_utilization,
                ),
                (
                    "serving.sessions_prefill_tokens".to_string(),
                    on.prefill_tokens as f64,
                ),
                (
                    "serving.sessions_prefill_tokens_nocache".to_string(),
                    off.prefill_tokens as f64,
                ),
            ],
        )?;
    }

    // hybrid draft–verify A/B (manifest v5 `verify@K`): same prompts at
    // temperature 0, three passes —
    //   (a) routed with every request pinned to the large tier: the
    //       baseline, exactly one large forward pass per emitted token;
    //   (b) hybrid small→medium at quality 1.0 (always verify): must be
    //       **byte-identical** to (a), and its acceptance / large-call /
    //       throughput metrics join the trajectory. Reported, not gated
    //       on savings: seeded-init weights share no greedy agreement,
    //       so the cross-pair acceptance floor is ~1/vocab;
    //   (c) hybrid medium→medium (a perfectly-agreeing draft): the
    //       protocol-efficiency CI gate — `large_call_fraction`
    //       (verify calls per emitted token) must be ≤ 0.7, i.e. ≥ 30%
    //       fewer large forward passes than routed decoding pays by
    //       construction, with speculation the only possible source of
    //       the saving.
    if manifest.has_verify("medium") && manifest.has_paged_kv("small") {
        use hybrid_llm::policy::TierPolicy;
        use hybrid_llm::serve::DecodeMode;
        println!("\n== serving_e2e: hybrid draft–verify A/B ==");
        let ab_prompts = &prompts[..48.min(prompts.len())];
        type PassOut = (hybrid_llm::serve::ServerStats, Vec<Vec<i32>>, f64);
        let run_pass = |draft: &str, hybrid: bool| -> anyhow::Result<PassOut> {
            let mut cfg = ServeConfig::two_tier(
                artifacts.clone(),
                run_dir.clone(),
                draft,
                "medium",
                String::new(),
                0.5,
            );
            cfg.temp = 0.0; // the byte-identity claim is greedy-only
            cfg.mode = BatchMode::Continuous;
            cfg.batch_window = Duration::from_millis(2);
            if hybrid {
                cfg.decode = DecodeMode::Hybrid;
            }
            let server = Server::start(cfg)?;
            let t0 = Instant::now();
            let handles = ab_prompts
                .iter()
                .map(|p| {
                    let req = Request::new(p.clone());
                    let req = if hybrid {
                        req.quality(1.0)
                    } else {
                        req.policy(TierPolicy::Fixed { tier: 1 })
                    };
                    server.submit(req)
                })
                .collect::<Result<Vec<_>, _>>()?;
            let streams = handles
                .into_iter()
                .map(|h| {
                    h.wait_timeout(Duration::from_secs(120))
                        .map(|c| c.tokens)
                        .map_err(|e| anyhow::anyhow!("hybrid A/B completion: {e}"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let wall = t0.elapsed().as_secs_f64();
            Ok((server.shutdown()?, streams, wall))
        };
        let (routed, reference, _) = run_pass("small", false)?;
        let (cross, cross_streams, cross_wall) = run_pass("small", true)?;
        anyhow::ensure!(
            cross_streams == reference,
            "hybrid decode diverged from large-only greedy — the draft–verify pin is broken"
        );
        let emitted: usize = reference.iter().map(|t| t.len().saturating_sub(1)).sum();
        let routed_per_tok = routed.large_slot_steps as f64 / emitted.max(1) as f64;
        let cross_tokens: usize = cross_streams.iter().map(Vec::len).sum();
        let cross_tok_s = cross_tokens as f64 / cross_wall.max(1e-9);
        println!(
            "cross-pair (small drafts medium): byte-identical to large-only; accept rate \
             {:.0}%   large-call fraction {:.2} (routed baseline {:.2})   {:.1} tok/s",
            cross.draft_accept_rate * 100.0,
            cross.large_call_fraction,
            routed_per_tok,
            cross_tok_s
        );
        let (agree, _, _) = run_pass("medium", true)?;
        anyhow::ensure!(
            agree.hybrid_requests > 0 && agree.hybrid_emitted > 0,
            "hybrid self-pair pass produced no hybrid traffic"
        );
        println!(
            "self-pair (medium drafts medium): accept rate {:.0}%   large-call fraction {:.2}",
            agree.draft_accept_rate * 100.0,
            agree.large_call_fraction
        );
        anyhow::ensure!(
            agree.large_call_fraction <= 0.7,
            "speculation gate failed: {:.2} large forward passes per emitted hybrid token \
             with a perfectly-agreeing draft (routed decoding pays 1.0; gate requires <= 0.7)",
            agree.large_call_fraction
        );
        println!("hybrid gate OK: >= 30% fewer large-tier forward passes than routed decoding");
        merge_bench_json(
            json_path,
            &[
                ("serving.draft_accept_rate".to_string(), cross.draft_accept_rate),
                ("serving.large_call_fraction".to_string(), cross.large_call_fraction),
                ("serving.hybrid_tokens_per_sec".to_string(), cross_tok_s),
                ("serving.routed_large_passes_per_token".to_string(), routed_per_tok),
                (
                    "serving.hybrid_selfpair_large_call_fraction".to_string(),
                    agree.large_call_fraction,
                ),
            ],
        )?;
    }

    let _ = std::fs::remove_dir_all(&run_dir);
    Ok(())
}
