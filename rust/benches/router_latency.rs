//! Bench: router scoring latency — the paper's claim that the router adds
//! negligible overhead (Table 2 row 1, §4.4). Measures the single-query
//! path (B=1 artifact) and the batched path (B=32), plus the pure
//! manifest-validation overhead. Uses seeded-init router params (latency
//! is weight-independent), so this runs without a pipeline run.

use hybrid_llm::bench::{report, Bencher};
use hybrid_llm::corpus::{generate, Scale};
use hybrid_llm::router::RouterEngine;
use hybrid_llm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::load(&dir)?;
    let router = RouterEngine::init(rt.clone(), 0)?;
    let corpus = generate(7, Scale::Smoke);
    let prompts: Vec<&[i32]> = corpus.iter().take(32).map(|q| q.prompt.as_slice()).collect();

    // warm the executable cache
    router.score_one(prompts[0])?;
    router.scores(&prompts)?;

    let b = Bencher::default();
    let mut results = Vec::new();
    results.push(b.bench("router.score_one (B=1)", || {
        router.score_one(prompts[0]).unwrap();
    }));
    results.push(b.bench_items("router.scores (B=32)", 32.0, &mut || {
        router.scores(&prompts).unwrap();
    }));
    report("router_latency", &results);

    let one = results[0].mean.as_secs_f64();
    let batched = results[1].mean.as_secs_f64() / 32.0;
    println!(
        "\nper-query: single {:.3} ms, batched {:.3} ms ({:.1}x amortization)",
        one * 1e3,
        batched * 1e3,
        one / batched.max(1e-12)
    );
    Ok(())
}
