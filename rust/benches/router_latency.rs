//! Bench: router scoring latency — the paper's claim that the router adds
//! negligible overhead (Table 2 row 1, §4.4). Measures the single-query
//! path (B=1 artifact) and the batched path (B=32), plus the pure
//! manifest-validation overhead. Uses seeded-init router params (latency
//! is weight-independent), so this runs without a pipeline run.
//!
//! Also reports the fleet's **tier-dispatch overhead** — threshold-ladder
//! assignment plus replica selection — at 2, 3, and 5 tiers, so the
//! N-tier refactor's hot-path cost stays visible in the bench
//! trajectory. The dispatch section is pure CPU and runs even without
//! artifacts.

use std::sync::atomic::{AtomicU64, Ordering};

use hybrid_llm::bench::{report, Bencher};
use hybrid_llm::corpus::{generate, Scale};
use hybrid_llm::policy::TierPolicy;
use hybrid_llm::router::RouterEngine;
use hybrid_llm::runtime::Runtime;

const DISPATCH_BATCH: usize = 1024;

/// Ladder assignment + shortest-queue replica pick over a simulated
/// fleet — the router thread's per-batch dispatch work, minus the
/// channels. Policy and depth counters are built once by the caller,
/// as the real router thread does at startup.
fn dispatch_overhead(policy: &TierPolicy, depths: &[Vec<AtomicU64>], scores: &[f32]) -> u64 {
    let assigns = policy.assign(scores);
    let mut picked = 0u64;
    for &tier in &assigns {
        let tier = tier.min(depths.len() - 1);
        let rep = depths[tier]
            .iter()
            .enumerate()
            .min_by_key(|(_, q)| q.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        depths[tier][rep].fetch_add(1, Ordering::Relaxed);
        picked += rep as u64 + tier as u64;
    }
    picked
}

fn main() -> anyhow::Result<()> {
    // --- tier dispatch overhead (artifact-free, pure CPU) -------------
    let mut rng = hybrid_llm::rng::Rng::new(42);
    let scores: Vec<f32> = (0..DISPATCH_BATCH).map(|_| rng.next_f32()).collect();
    let b = Bencher::quick();
    let mut results = Vec::new();
    for k in [2usize, 3, 5] {
        let policy = TierPolicy::even_ladder(k);
        let depths: Vec<Vec<AtomicU64>> = (0..k)
            .map(|_| (0..2).map(|_| AtomicU64::new(0)).collect())
            .collect();
        results.push(b.bench_items(
            &format!("tier dispatch (K={k}, B={DISPATCH_BATCH})"),
            DISPATCH_BATCH as f64,
            &mut || {
                std::hint::black_box(dispatch_overhead(
                    &policy,
                    &depths,
                    std::hint::black_box(&scores),
                ));
            },
        ));
    }
    report("tier_dispatch", &results);
    let two = results[0].mean.as_secs_f64();
    let five = results[2].mean.as_secs_f64();
    println!(
        "\nper-query dispatch: K=2 {:.1} ns, K=5 {:.1} ns ({:.2}x)",
        two / DISPATCH_BATCH as f64 * 1e9,
        five / DISPATCH_BATCH as f64 * 1e9,
        five / two.max(1e-12)
    );

    // --- router scoring (needs artifacts) -----------------------------
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping router scoring bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::load(&dir)?;
    let router = RouterEngine::init(rt.clone(), 0)?;
    let corpus = generate(7, Scale::Smoke);
    let prompts: Vec<&[i32]> = corpus.iter().take(32).map(|q| q.prompt.as_slice()).collect();

    // warm the executable cache
    router.score_one(prompts[0])?;
    router.scores(&prompts)?;

    let b = Bencher::default();
    let mut results = Vec::new();
    results.push(b.bench("router.score_one (B=1)", || {
        router.score_one(prompts[0]).unwrap();
    }));
    results.push(b.bench_items("router.scores (B=32)", 32.0, &mut || {
        router.scores(&prompts).unwrap();
    }));
    report("router_latency", &results);

    let one = results[0].mean.as_secs_f64();
    let batched = results[1].mean.as_secs_f64() / 32.0;
    println!(
        "\nper-query: single {:.3} ms, batched {:.3} ms ({:.1}x amortization)",
        one * 1e3,
        batched * 1e3,
        one / batched.max(1e-12)
    );
    Ok(())
}
