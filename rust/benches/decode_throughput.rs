//! Bench: decode-step throughput per roster model — the serving hot path
//! (one fused HLO call per generated token for B slots). Reports
//! tokens/sec at full batch for each model size plus the B=1 latency
//! path, quantifying the batching win and the model-size cost gradient
//! that motivates routing in the first place.

use hybrid_llm::bench::{report, Bencher};
use hybrid_llm::corpus::{generate, Scale};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::load(&dir)?;
    let g = rt.manifest.globals;
    let corpus = generate(7, Scale::Smoke);
    let prompts: Vec<&[i32]> = corpus
        .iter()
        .take(g.genb)
        .map(|q| q.prompt.as_slice())
        .collect();
    let seeds: Vec<u32> = (0..g.genb as u32).collect();

    let b = Bencher::default();
    let mut results = Vec::new();
    for model in hybrid_llm::pipeline::ROSTER {
        let eng = LmEngine::init(rt.clone(), model, 1)?;
        // warm compile; untrained weights rarely emit EOS so every wave
        // decodes to the full answer budget — worst-case throughput.
        eng.generate(&prompts, &seeds, 0.8)?;
        let tokens_per_wave = (g.genb * (hybrid_llm::corpus::A_MAX - 1)) as f64;
        results.push(b.bench_items(
            &format!("{model}.generate wave (B={})", g.genb),
            tokens_per_wave,
            &mut || {
                eng.generate(&prompts, &seeds, 0.8).unwrap();
            },
        ));
        // B=1 latency path on the largest + smallest only (slow)
        if model == "nano" || model == "large" {
            eng.generate_one(prompts[0], 0, 0.8)?;
            results.push(b.bench(&format!("{model}.generate_one (B=1)"), || {
                eng.generate_one(prompts[0], 0, 0.8).unwrap();
            }));
        }
    }
    report("decode_throughput (tokens/s where listed)", &results);

    // ---- perf before/after: params re-uploaded per call (naive literal
    // path) vs device-resident params (execute_b). This is the L3
    // optimization recorded in EXPERIMENTS.md §Perf.
    let eng = LmEngine::init(rt.clone(), "large", 1)?;
    let exec = rt.exec("large.decode")?;
    let meta = *rt.manifest.model("large")?;
    let n = eng.params.len();
    let cache_dims = vec![meta.layers, g.genb, g.sctx, meta.heads, meta.headdim];
    let cache_len: usize = cache_dims.iter().product();
    let kc = hybrid_llm::io::Tensor::f32(cache_dims.clone(), vec![0.0; cache_len]);
    let vc = kc.clone();
    let tok = hybrid_llm::io::Tensor::i32(vec![g.genb], vec![5; g.genb]);
    let pos = hybrid_llm::io::Tensor::i32(vec![g.genb], vec![8; g.genb]);
    let step = hybrid_llm::io::Tensor::i32(vec![], vec![1]);
    let seeds_t = hybrid_llm::io::Tensor::u32(vec![g.genb], vec![0; g.genb]);
    let temp = hybrid_llm::io::Tensor::f32(vec![], vec![0.8]);

    let mut ins: Vec<&hybrid_llm::io::Tensor> = eng.params.host.iter().collect();
    ins.extend([&kc, &vc, &tok, &pos, &step, &seeds_t, &temp]);
    exec.run(&ins)?; // warm
    let resident: std::collections::HashMap<usize, std::sync::Arc<xla::PjRtBuffer>> =
        eng.params.device.iter().cloned().enumerate().collect();
    let host: Vec<(usize, &hybrid_llm::io::Tensor)> = vec![
        (n, &kc),
        (n + 1, &vc),
        (n + 2, &tok),
        (n + 3, &pos),
        (n + 4, &step),
        (n + 5, &seeds_t),
        (n + 6, &temp),
    ];
    exec.run_with_resident(&resident, &host)?; // warm

    let mut results = Vec::new();
    results.push(b.bench("large.decode literal path (re-upload params)", || {
        exec.run(&ins).unwrap();
    }));
    results.push(b.bench("large.decode resident params (execute_b)", || {
        exec.run_with_resident(&resident, &host).unwrap();
    }));
    report("decode step: naive vs resident params", &results);
    let speedup = results[0].mean.as_secs_f64() / results[1].mean.as_secs_f64().max(1e-12);
    println!("\nresident-params speedup on large.decode: {speedup:.2}x");
    Ok(())
}
