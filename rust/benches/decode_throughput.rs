//! Bench: decode-step throughput per roster model — the serving hot path
//! (one fused HLO call per generated token for B slots). Reports
//! tokens/sec at full batch for each model size plus the B=1 latency
//! path, and the host-transfer bytes per decode step — the number the
//! device-resident KV-cache path drives to O(B) (the pre-residency
//! runtime paid the full `[L, B, S, H, Dh]` KV pair both ways per step).
//! Results land in `BENCH_serving.json` (flat key → value, merged with
//! the serving bench) as the perf trajectory.

use std::path::Path;

use hybrid_llm::bench::{merge_bench_json, report, Bencher};
use hybrid_llm::corpus::{generate, Scale, A_MAX};
use hybrid_llm::io::Tensor;
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let rt = Runtime::load(&dir)?;
    let g = rt.manifest.globals;
    let corpus = generate(7, Scale::Smoke);
    let prompts: Vec<&[i32]> = corpus
        .iter()
        .take(g.genb)
        .map(|q| q.prompt.as_slice())
        .collect();
    let seeds: Vec<u32> = (0..g.genb as u32).collect();
    let json_path = Path::new("BENCH_serving.json");
    let mut json: Vec<(String, f64)> = Vec::new();

    let b = Bencher::default();
    let mut results = Vec::new();
    for model in hybrid_llm::pipeline::ROSTER {
        let eng = LmEngine::init(rt.clone(), model, 1)?;
        // warm compile; untrained weights rarely emit EOS so every wave
        // decodes to the full answer budget — worst-case throughput.
        eng.generate(&prompts, &seeds, 0.8)?;
        let tokens_per_wave = (g.genb * (A_MAX - 1)) as f64;
        let r = b.bench_items(
            &format!("{model}.generate wave (B={})", g.genb),
            tokens_per_wave,
            &mut || {
                eng.generate(&prompts, &seeds, 0.8).unwrap();
            },
        );
        json.push((format!("decode.{model}.tokens_per_sec"), r.throughput_per_s()));
        results.push(r);

        // host traffic per decode step over one measured wave (steady
        // state decodes A_MAX-1 iterations; the prefill + first-step KV
        // upload amortize across them)
        let before = rt.transfers();
        eng.generate(&prompts, &seeds, 0.8)?;
        let moved = before.delta(rt.transfers());
        let steps = (A_MAX - 1) as f64;
        println!(
            "{model}: host transfer per decode step  d2h {:>10.0} B  h2d {:>10.0} B",
            moved.d2h_bytes as f64 / steps,
            moved.h2d_bytes as f64 / steps
        );
        json.push((
            format!("decode.{model}.d2h_bytes_per_step"),
            moved.d2h_bytes as f64 / steps,
        ));
        json.push((
            format!("decode.{model}.h2d_bytes_per_step"),
            moved.h2d_bytes as f64 / steps,
        ));

        // B=1 latency path on the largest + smallest only (slow)
        if model == "nano" || model == "large" {
            eng.generate_one(prompts[0], 0, 0.8)?;
            results.push(b.bench(&format!("{model}.generate_one (B=1)"), || {
                eng.generate_one(prompts[0], 0, 0.8).unwrap();
            }));
        }
    }
    report("decode_throughput (tokens/s where listed)", &results);

    // ---- perf trajectory: one decode step under the three residency
    // regimes. (1) naive literal path re-uploads params + KV and
    // downloads everything; (2) resident params still round-trip the KV
    // pair through the host; (3) device-resident KV moves only O(B)
    // tokens/logprobs — the tentpole optimization.
    let eng = LmEngine::init(rt.clone(), "large", 1)?;
    let exec = rt.exec("large.decode")?;
    let meta = *rt.manifest.model("large")?;
    let n = eng.params.len();
    let cache_dims = vec![meta.layers, g.genb, g.sctx, meta.heads, meta.headdim];
    let cache_len: usize = cache_dims.iter().product();
    let kc = Tensor::f32(cache_dims.clone(), vec![0.0; cache_len]);
    let vc = kc.clone();
    let tok = Tensor::i32(vec![g.genb], vec![5; g.genb]);
    let pos = Tensor::i32(vec![g.genb], vec![8; g.genb]);
    let step = Tensor::i32(vec![], vec![1]);
    let seeds_t = Tensor::u32(vec![g.genb], vec![0; g.genb]);
    let temp = Tensor::f32(vec![], vec![0.8]);

    let mut ins: Vec<&Tensor> = eng.params.host.iter().collect();
    ins.extend([&kc, &vc, &tok, &pos, &step, &seeds_t, &temp]);
    exec.run(&ins)?; // warm
    let resident = eng.params.resident_map();
    let host_full: Vec<(usize, &Tensor)> = vec![
        (n, &kc),
        (n + 1, &vc),
        (n + 2, &tok),
        (n + 3, &pos),
        (n + 4, &step),
        (n + 5, &seeds_t),
        (n + 6, &temp),
    ];
    exec.run_with_resident(&resident, &host_full)?; // warm

    let mut results = Vec::new();
    results.push(b.bench("large.decode literal path (re-upload all)", || {
        exec.run(&ins).unwrap();
    }));
    results.push(b.bench("large.decode resident params, host KV", || {
        exec.run_with_resident(&resident, &host_full).unwrap();
    }));

    // seed the device-resident caches from one run, then keep feeding the
    // returned buffers back in — the serving steady state
    let mut outs = exec.run_resident(&resident, &host_full)?;
    let vdev = outs.pop().unwrap();
    let kdev = outs.pop().unwrap();
    let device_capable = kdev.is_device() && vdev.is_device();
    if device_capable {
        let host_small: Vec<(usize, &Tensor)> = vec![
            (n + 2, &tok),
            (n + 3, &pos),
            (n + 4, &step),
            (n + 5, &seeds_t),
            (n + 6, &temp),
        ];
        let mut res_dev = resident.clone();
        res_dev.insert(n, kdev.device().unwrap().clone());
        res_dev.insert(n + 1, vdev.device().unwrap().clone());
        let before = rt.transfers();
        let mut steps = 0u64;
        results.push(b.bench("large.decode device-resident KV", || {
            let mut outs = exec.run_resident(&res_dev, &host_small).unwrap();
            let vc = outs.pop().unwrap();
            let kc = outs.pop().unwrap();
            res_dev.insert(n, kc.device().unwrap().clone());
            res_dev.insert(n + 1, vc.device().unwrap().clone());
            steps += 1;
        }));
        let moved = before.delta(rt.transfers());
        let steps = steps.max(1) as f64;
        println!(
            "device-resident steady state: d2h {:.0} B/step, h2d {:.0} B/step \
             (full KV pair would be {} B)",
            moved.d2h_bytes as f64 / steps,
            moved.h2d_bytes as f64 / steps,
            2 * cache_len * 4,
        );
        json.push((
            "decode.large.resident_d2h_bytes_per_step".to_string(),
            moved.d2h_bytes as f64 / steps,
        ));
        json.push((
            "decode.large.resident_h2d_bytes_per_step".to_string(),
            moved.h2d_bytes as f64 / steps,
        ));
    } else {
        println!(
            "device-resident KV unavailable (pre-v2 fused-tuple artifacts, \
             manifest v{}); host fallback exercised instead",
            rt.manifest.version
        );
    }
    report("decode step residency ladder", &results);
    if results.len() >= 2 {
        let speedup = results[0].mean.as_secs_f64() / results[1].mean.as_secs_f64().max(1e-12);
        println!("\nresident-params speedup on large.decode: {speedup:.2}x");
        json.push(("decode.large.resident_params_speedup".to_string(), speedup));
    }
    if device_capable && results.len() >= 3 {
        let speedup = results[1].mean.as_secs_f64() / results[2].mean.as_secs_f64().max(1e-12);
        println!("device-resident-KV speedup over host KV round-trip: {speedup:.2}x");
        json.push(("decode.large.resident_kv_speedup".to_string(), speedup));
    }

    merge_bench_json(json_path, &json)?;
    println!("\nwrote {} metrics to {}", json.len(), json_path.display());
    Ok(())
}
