//! Bench: batching-policy ablation — continuous batching (iteration-level
//! admission, vLLM/Orca-style) vs run-to-completion (static batches).
//! The DESIGN.md §8 L3 target: continuous batching should win wall-clock
//! on mixed-length workloads because finished slots are refilled instead
//! of idling until the batch drains.

use std::time::{Duration, Instant};

use hybrid_llm::batching::BatchMode;
use hybrid_llm::corpus::{generate, Scale};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::runtime::Runtime;
use hybrid_llm::serve::{Request, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let artifacts = Runtime::default_dir();
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("skipping bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let run_dir = std::env::temp_dir().join(format!("hybrid_ablation_{}", std::process::id()));
    {
        let rt = Runtime::load(&artifacts)?;
        for model in ["small", "medium"] {
            let eng = LmEngine::init(rt.clone(), model, 3)?;
            eng.save(&run_dir.join("params").join(model))?;
        }
    }
    let corpus = generate(23, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(64).map(|q| q.prompt.clone()).collect();

    println!("== batching ablation: 64 requests, small/medium ==");
    println!(
        "{:<22} {:>9} {:>10} {:>9} {:>9} {:>10} {:>12}",
        "mode", "wall s", "req/s", "p50 ms", "p95 ms", "slot eff", "decode iters"
    );
    let mut walls = Vec::new();
    for (mode, label) in [
        (BatchMode::Continuous, "continuous"),
        (BatchMode::RunToCompletion, "run-to-completion"),
    ] {
        let mut cfg = ServeConfig::two_tier(
            artifacts.clone(),
            run_dir.clone(),
            "small",
            "medium",
            String::new(),
            0.5,
        );
        cfg.temp = 0.8;
        cfg.mode = mode;
        cfg.batch_window = Duration::from_millis(2);
        let server = Server::start(cfg)?;
        let t0 = Instant::now();
        // staggered arrivals: 4 waves to exercise admission policy
        let mut handles = Vec::new();
        for chunk in prompts.chunks(16) {
            for p in chunk {
                handles.push(server.submit(Request::new(p.clone()))?);
            }
            std::thread::sleep(Duration::from_millis(120));
        }
        for h in handles {
            h.wait()?;
        }
        let wall = t0.elapsed();
        let stats = server.shutdown()?;
        let eff = if stats.decode_steps > 0 {
            stats.decode_slot_steps as f64 / (stats.decode_steps as f64 * 16.0)
        } else {
            0.0
        };
        println!(
            "{:<22} {:>9.2} {:>10.1} {:>9.0} {:>9.0} {:>10.2} {:>12}",
            label,
            wall.as_secs_f64(),
            prompts.len() as f64 / wall.as_secs_f64(),
            stats.e2e_latency.p50_ms,
            stats.e2e_latency.p95_ms,
            eff,
            stats.decode_steps
        );
        walls.push(wall.as_secs_f64());
    }
    println!(
        "\ncontinuous vs run-to-completion speedup: {:.2}x",
        walls[1] / walls[0].max(1e-9)
    );
    let _ = std::fs::remove_dir_all(&run_dir);
    Ok(())
}
