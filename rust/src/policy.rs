//! Routing policies and tradeoff evaluation (§2.2, §4.1 baselines).
//!
//! A policy decides, per query, small (`true`) vs large (`false`). The
//! learned policies threshold the router score; the baselines are
//! `all-at-small`, `all-at-large`, and `random`. [`tradeoff_curve`]
//! sweeps cost advantage and reports the quality drop w.r.t.
//! all-at-large — the Fig. 5 series and Table 1 cells.

use crate::metrics::quality_drop_pct;
use crate::rng::Rng;
use crate::stats;

/// A routing decision source.
#[derive(Debug, Clone)]
pub enum Policy {
    AllSmall,
    AllLarge,
    /// Route to small with probability `p_small` (seeded).
    Random { p_small: f64, seed: u64 },
    /// Route to small when the router score >= `threshold`.
    Threshold { threshold: f32 },
}

impl Policy {
    /// Per-query assignments; `scores[i]` is the router score (ignored by
    /// the baselines).
    pub fn assign(&self, scores: &[f32]) -> Vec<bool> {
        match self {
            Policy::AllSmall => vec![true; scores.len()],
            Policy::AllLarge => vec![false; scores.len()],
            Policy::Random { p_small, seed } => {
                let mut rng = Rng::new(*seed);
                scores.iter().map(|_| rng.next_f64() < *p_small).collect()
            }
            Policy::Threshold { threshold } => scores.iter().map(|&s| s >= *threshold).collect(),
        }
    }
}

/// Threshold achieving (approximately) a target cost advantage: route the
/// top `target` fraction of scores to the small model.
pub fn threshold_for_cost_advantage(scores: &[f32], target: f64) -> f32 {
    assert!(!scores.is_empty());
    let xs: Vec<f64> = scores.iter().map(|&s| s as f64).collect();
    // scores >= thr go to small; thr = (1-target) quantile
    stats::percentile(&xs, (1.0 - target.clamp(0.0, 1.0)) * 100.0) as f32
}

/// Achieved cost advantage of an assignment.
pub fn cost_advantage(assign: &[bool]) -> f64 {
    if assign.is_empty() {
        return 0.0;
    }
    assign.iter().filter(|&&s| s).count() as f64 / assign.len() as f64
}

/// Mean achieved quality under an assignment, given per-query expected
/// qualities of each model's response.
pub fn achieved_quality(assign: &[bool], q_small: &[f64], q_large: &[f64]) -> f64 {
    assert_eq!(assign.len(), q_small.len());
    assert_eq!(assign.len(), q_large.len());
    if assign.is_empty() {
        return 0.0;
    }
    let total: f64 = assign
        .iter()
        .enumerate()
        .map(|(i, &s)| if s { q_small[i] } else { q_large[i] })
        .sum();
    total / assign.len() as f64
}

/// One point on an error–cost curve.
#[derive(Debug, Clone, PartialEq)]
pub struct TradeoffPoint {
    pub target_cost_advantage: f64,
    pub achieved_cost_advantage: f64,
    pub quality: f64,
    /// % drop w.r.t. all-at-large (negative = better than baseline).
    pub drop_pct: f64,
}

/// Sweep cost advantages `0..=1` in `steps` increments for a score-based
/// policy (Fig. 5 series).
pub fn tradeoff_curve(
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
    steps: usize,
) -> Vec<TradeoffPoint> {
    let base = stats::mean(q_large);
    (0..=steps)
        .map(|k| {
            let target = k as f64 / steps as f64;
            let point = tradeoff_at(scores, q_small, q_large, target);
            TradeoffPoint { target_cost_advantage: target, ..point }
        })
        .map(|mut p| {
            p.drop_pct = quality_drop_pct(base, p.quality);
            p
        })
        .collect()
}

/// Single tradeoff point at a target cost advantage.
pub fn tradeoff_at(
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
    target: f64,
) -> TradeoffPoint {
    // exact target: route the top ceil(target*n) scores to small (ties
    // broken by index) — avoids quantile-threshold granularity noise
    let n = scores.len();
    let k = ((target * n as f64).round() as usize).min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let mut assign = vec![false; n];
    for &i in idx.iter().take(k) {
        assign[i] = true;
    }
    let quality = achieved_quality(&assign, q_small, q_large);
    TradeoffPoint {
        target_cost_advantage: target,
        achieved_cost_advantage: cost_advantage(&assign),
        quality,
        drop_pct: quality_drop_pct(stats::mean(q_large), quality),
    }
}

/// Random-baseline curve (expected values via seeded assignment).
pub fn random_curve(
    n: usize,
    q_small: &[f64],
    q_large: &[f64],
    steps: usize,
    seed: u64,
) -> Vec<TradeoffPoint> {
    let base = stats::mean(q_large);
    (0..=steps)
        .map(|k| {
            let target = k as f64 / steps as f64;
            let assign = Policy::Random { p_small: target, seed: seed ^ k as u64 }
                .assign(&vec![0.0; n]);
            let quality = achieved_quality(&assign, q_small, q_large);
            TradeoffPoint {
                target_cost_advantage: target,
                achieved_cost_advantage: cost_advantage(&assign),
                quality,
                drop_pct: quality_drop_pct(base, quality),
            }
        })
        .collect()
}

/// §5 extension (2): N-model routing. Given scores from one router per
/// *adjacent pair* in a quality-ordered roster and per-model per-query
/// qualities, assign each query to the cheapest model whose pair-router
/// deems it "easy enough" all the way down. Models are ordered cheapest
/// first; `pair_scores[m]` is the router score of "model m can replace
/// model m+1".
pub fn nmodel_assign(pair_scores: &[Vec<f32>], thresholds: &[f32], n_queries: usize) -> Vec<usize> {
    let m = pair_scores.len(); // m pair-routers => m+1 models
    assert_eq!(thresholds.len(), m);
    (0..n_queries)
        .map(|i| {
            // walk from the most expensive model downwards while the
            // pair-router keeps saying "the cheaper one matches"
            let mut choice = m; // most expensive
            for level in (0..m).rev() {
                if pair_scores[level][i] >= thresholds[level] {
                    choice = level;
                } else {
                    break;
                }
            }
            choice
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baselines() {
        let scores = vec![0.1, 0.9, 0.5];
        assert_eq!(Policy::AllSmall.assign(&scores), vec![true; 3]);
        assert_eq!(Policy::AllLarge.assign(&scores), vec![false; 3]);
        let r = Policy::Random { p_small: 1.0, seed: 1 }.assign(&scores);
        assert_eq!(r, vec![true; 3]);
        let r = Policy::Random { p_small: 0.0, seed: 1 }.assign(&scores);
        assert_eq!(r, vec![false; 3]);
    }

    #[test]
    fn threshold_policy_routes_high_scores_to_small() {
        let scores = vec![0.2, 0.8, 0.5];
        let a = Policy::Threshold { threshold: 0.5 }.assign(&scores);
        assert_eq!(a, vec![false, true, true]);
    }

    #[test]
    fn threshold_for_target() {
        let scores: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let thr = threshold_for_cost_advantage(&scores, 0.2);
        let a = Policy::Threshold { threshold: thr }.assign(&scores);
        let ca = cost_advantage(&a);
        assert!((ca - 0.2).abs() < 0.03, "{ca}");
    }

    #[test]
    fn tradeoff_at_exact_fraction() {
        let scores = vec![0.9, 0.1, 0.5, 0.7];
        let qs = vec![-2.0, -2.0, -2.0, -2.0];
        let ql = vec![-1.0, -1.0, -1.0, -1.0];
        let p = tradeoff_at(&scores, &qs, &ql, 0.5);
        assert_eq!(p.achieved_cost_advantage, 0.5);
        // top-2 scores (0.9, 0.7) go small => quality = (-2-2-1-1)/4
        assert!((p.quality + 1.5).abs() < 1e-12);
        // drop = (-1 - (-1.5))/1 = 50%
        assert!((p.drop_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn perfect_router_has_no_drop_when_small_matches() {
        // small matches large on half the queries; a perfect router
        // achieves 50% cost advantage with zero drop
        let n = 100;
        let mut scores = vec![0.0f32; n];
        let mut qs = vec![0.0f64; n];
        let mut ql = vec![-1.0f64; n];
        for i in 0..n {
            if i % 2 == 0 {
                scores[i] = 0.9; // easy
                qs[i] = -1.0;
            } else {
                scores[i] = 0.1; // hard
                qs[i] = -3.0;
            }
            ql[i] = -1.0;
        }
        let p = tradeoff_at(&scores, &qs, &ql, 0.5);
        assert!((p.quality + 1.0).abs() < 1e-12);
        assert!(p.drop_pct.abs() < 1e-9);
    }

    #[test]
    fn curve_monotone_cost() {
        let scores: Vec<f32> = (0..50).map(|i| (i as f32) / 50.0).collect();
        let qs: Vec<f64> = (0..50).map(|i| -2.0 - i as f64 * 0.01).collect();
        let ql = vec![-1.0; 50];
        let c = tradeoff_curve(&scores, &qs, &ql, 10);
        assert_eq!(c.len(), 11);
        assert_eq!(c[0].achieved_cost_advantage, 0.0);
        assert_eq!(c[10].achieved_cost_advantage, 1.0);
        // at 0 cost advantage drop is 0
        assert!(c[0].drop_pct.abs() < 1e-9);
        // drop grows along the curve for a weak small model
        assert!(c[10].drop_pct > c[5].drop_pct);
    }

    #[test]
    fn nmodel_walks_down_while_easy() {
        // 3 models, 2 pair-routers
        let pair_scores = vec![
            vec![0.9, 0.1, 0.9, 0.1], // model0 replaces model1
            vec![0.9, 0.9, 0.1, 0.1], // model1 replaces model2
        ];
        let thr = vec![0.5, 0.5];
        let a = nmodel_assign(&pair_scores, &thr, 4);
        // q0: both easy -> model0; q1: level1 easy but level0 hard -> model1
        // q2: level1 hard -> stop at model2 even though level0 says easy
        // q3: both hard -> model2
        assert_eq!(a, vec![0, 1, 2, 2]);
    }

    #[test]
    fn random_curve_cost_tracks_target() {
        let qs = vec![-2.0; 1000];
        let ql = vec![-1.0; 1000];
        let c = random_curve(1000, &qs, &ql, 4, 42);
        for p in &c {
            assert!((p.achieved_cost_advantage - p.target_cost_advantage).abs() < 0.06);
        }
    }
}
