//! Routing policies and tradeoff evaluation (§2.2, §4.1 baselines),
//! generalized to an N-tier model fleet.
//!
//! The paper's policy decides, per query, small (`true`) vs large
//! (`false`); [`Policy`] keeps that two-model API. [`TierPolicy`] is the
//! N-tier generalization used by the serving fleet: assignments are tier
//! indices (`Vec<usize>`, tier 0 = cheapest), the two-tier threshold
//! policy is the `K == 2` special case of the multi-threshold
//! [`TierPolicy::Ladder`], and [`cost_argmax_assign`] is the cost-aware
//! argmax policy over per-tier quality estimates. [`tradeoff_curve`]
//! sweeps cost advantage and reports the quality drop w.r.t.
//! all-at-large — the Fig. 5 series and Table 1 cells;
//! [`cost_advantage_tiers`] / [`achieved_quality_tiers`] /
//! [`ladder_tradeoff_at`] are the per-tier-cost-weighted counterparts.

use crate::metrics::quality_drop_pct;
use crate::rng::Rng;
use crate::stats;

/// A routing decision source.
#[derive(Debug, Clone)]
pub enum Policy {
    AllSmall,
    AllLarge,
    /// Route to small with probability `p_small` (seeded).
    Random { p_small: f64, seed: u64 },
    /// Route to small when the router score >= `threshold`.
    Threshold { threshold: f32 },
}

impl Policy {
    /// Per-query assignments; `scores[i]` is the router score (ignored by
    /// the baselines).
    pub fn assign(&self, scores: &[f32]) -> Vec<bool> {
        match self {
            Policy::AllSmall => vec![true; scores.len()],
            Policy::AllLarge => vec![false; scores.len()],
            Policy::Random { p_small, seed } => {
                let mut rng = Rng::new(*seed);
                scores.iter().map(|_| rng.next_f64() < *p_small).collect()
            }
            Policy::Threshold { threshold } => scores.iter().map(|&s| s >= *threshold).collect(),
        }
    }
}

/// An N-tier routing decision source; assignments are tier indices with
/// tier 0 the cheapest and the last tier the most capable. The two-model
/// [`Policy`] maps onto `K == 2` with `small == tier 0`.
#[derive(Debug, Clone, PartialEq)]
pub enum TierPolicy {
    /// Every query to one fixed tier.
    Fixed { tier: usize },
    /// Seeded random assignment with (unnormalized) per-tier weights.
    /// An offline baseline: each `assign` call replays the same stream.
    Random { weights: Vec<f64>, seed: u64 },
    /// Multi-threshold ladder: `thresholds[i]` is the minimum router
    /// score for tier `i`, descending; a query lands in the first tier
    /// whose threshold it clears, else the last (most capable) tier.
    /// `K` tiers take `K - 1` thresholds, and `K == 2` reproduces
    /// [`Policy::Threshold`] bit for bit (same `>=` comparison, so NaN
    /// scores fall through to the last tier either way).
    Ladder { thresholds: Vec<f32> },
}

impl TierPolicy {
    /// Number of tiers this policy distinguishes (`None` for `Fixed`,
    /// which works with any fleet that has its tier).
    pub fn n_tiers(&self) -> Option<usize> {
        match self {
            TierPolicy::Fixed { .. } => None,
            TierPolicy::Random { weights, .. } => Some(weights.len()),
            TierPolicy::Ladder { thresholds } => Some(thresholds.len() + 1),
        }
    }

    /// Evenly spaced descending ladder over `[0, 1]` score space for `k`
    /// tiers: thresholds `(k-1)/k, …, 1/k`. `k == 2` gives `[0.5]`, the
    /// seed default threshold.
    pub fn even_ladder(k: usize) -> TierPolicy {
        let k = k.max(1);
        TierPolicy::Ladder {
            thresholds: (1..k).map(|i| (k - i) as f32 / k as f32).collect(),
        }
    }

    /// Per-query tier assignments; `scores[i]` is the router score
    /// (ignored by `Fixed` and `Random`).
    pub fn assign(&self, scores: &[f32]) -> Vec<usize> {
        match self {
            TierPolicy::Fixed { tier } => vec![*tier; scores.len()],
            TierPolicy::Random { weights, seed } => {
                let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
                let last = weights.len().saturating_sub(1);
                let mut rng = Rng::new(*seed);
                scores
                    .iter()
                    .map(|_| {
                        if total <= 0.0 {
                            return last;
                        }
                        let mut u = rng.next_f64() * total;
                        for (i, &w) in weights.iter().enumerate() {
                            if w.is_finite() && w > 0.0 {
                                u -= w;
                                if u < 0.0 {
                                    return i;
                                }
                            }
                        }
                        last
                    })
                    .collect()
            }
            TierPolicy::Ladder { thresholds } => {
                scores.iter().map(|&s| ladder_tier(thresholds, s)).collect()
            }
        }
    }
}

/// First tier whose threshold the score clears (thresholds descending),
/// else the last tier. NaN scores clear nothing and land in the last
/// (most capable) tier — same fall-through as [`Policy::Threshold`].
pub fn ladder_tier(thresholds: &[f32], score: f32) -> usize {
    for (i, &t) in thresholds.iter().enumerate() {
        if score >= t {
            return i;
        }
    }
    thresholds.len()
}

/// A quality-indexed family of threshold ladders: resolves a per-request
/// quality target in `[0, 1]` to a K-tier ladder at routing time, so two
/// requests in the same batch window can route under different targets.
///
/// A family is a set of **rungs** `(quality level, thresholds)` ascending
/// in quality. Lookup rounds *up*: a target picks the lowest rung whose
/// level covers it, so the achieved quality meets or exceeds the target
/// (grid density controls the slack). The constructor sorts rungs and
/// enforces pointwise non-decreasing thresholds along the quality axis,
/// which makes tier assignment monotone: for a fixed router score,
/// raising the quality target can never route to a *cheaper* tier
/// (property-tested in `tests/property_suite.rs`).
///
/// Build a calibrated family from validation data with
/// [`crate::calibrate::calibrate_quality_ladders`], or an uncalibrated
/// placeholder with [`LadderFamily::synthetic`].
#[derive(Debug, Clone, PartialEq)]
pub struct LadderFamily {
    /// `(quality level, thresholds)`, ascending in quality, thresholds
    /// pointwise non-decreasing across rungs.
    rungs: Vec<(f32, Vec<f32>)>,
}

impl LadderFamily {
    /// Validate and normalize rungs: levels must be finite in `[0, 1]`,
    /// thresholds non-NaN (`±inf` is meaningful: all-cheapest /
    /// all-most-capable) and all the same length. Rungs are sorted by
    /// level and thresholds are made pointwise non-decreasing along the
    /// quality axis by a running max — the monotonicity invariant the
    /// quality knob relies on.
    pub fn new(mut rungs: Vec<(f32, Vec<f32>)>) -> anyhow::Result<LadderFamily> {
        anyhow::ensure!(!rungs.is_empty(), "ladder family needs at least one rung");
        let width = rungs[0].1.len();
        for (q, t) in &rungs {
            anyhow::ensure!(
                q.is_finite() && (0.0..=1.0).contains(q),
                "rung quality level {q} outside [0, 1]"
            );
            anyhow::ensure!(
                t.len() == width,
                "rung threshold counts disagree ({} vs {width})",
                t.len()
            );
            anyhow::ensure!(t.iter().all(|x| !x.is_nan()), "NaN rung threshold");
        }
        rungs.sort_by(|a, b| a.0.total_cmp(&b.0));
        for r in 1..rungs.len() {
            for i in 0..width {
                let floor = rungs[r - 1].1[i];
                if rungs[r].1[i] < floor {
                    rungs[r].1[i] = floor;
                }
            }
        }
        Ok(LadderFamily { rungs })
    }

    /// Number of tiers the family routes across.
    pub fn n_tiers(&self) -> usize {
        self.rungs[0].1.len() + 1
    }

    /// Uncalibrated placeholder family for a `k`-tier fleet, `levels + 1`
    /// rungs: rung `j` (quality `j / levels`) is the proportional ladder
    /// with pivot `q / (1 - q)` — quality 0 routes everything to the
    /// cheapest tier, quality 1 (infinite pivot) everything to the most
    /// capable. Use [`crate::calibrate::calibrate_quality_ladders`] when
    /// validation data is available.
    pub fn synthetic(k: usize, levels: usize) -> LadderFamily {
        let levels = levels.max(1);
        let rungs = (0..=levels)
            .map(|j| {
                let q = j as f32 / levels as f32;
                let pivot = if q >= 1.0 { f32::INFINITY } else { q / (1.0 - q) };
                (q, crate::calibrate::ladder_from_pivot(pivot, k.max(1)))
            })
            .collect();
        LadderFamily::new(rungs).expect("synthetic rungs are valid by construction")
    }

    /// Thresholds for a quality target: the lowest rung whose level
    /// covers the (clamped) target, else the top rung. Non-finite
    /// targets route conservatively through the top (most capable) rung.
    pub fn thresholds_for(&self, quality: f32) -> &[f32] {
        let q = if quality.is_finite() { quality.clamp(0.0, 1.0) } else { 1.0 };
        self.rungs
            .iter()
            .find(|(level, _)| *level >= q)
            .or_else(|| self.rungs.last())
            .map(|(_, t)| t.as_slice())
            .unwrap()
    }

    /// Tier for one `(quality target, router score)` pair.
    pub fn assign_one(&self, quality: f32, score: f32) -> usize {
        ladder_tier(self.thresholds_for(quality), score)
    }
}

/// Threshold achieving (approximately) a target cost advantage: route the
/// top `target` fraction of scores to the small model. Non-finite scores
/// are ignored; if no usable score remains, the all-at-large threshold
/// (`f32::INFINITY`, cost advantage 0) is returned instead of panicking.
pub fn threshold_for_cost_advantage(scores: &[f32], target: f64) -> f32 {
    let xs: Vec<f64> = scores
        .iter()
        .filter(|s| s.is_finite())
        .map(|&s| s as f64)
        .collect();
    if xs.is_empty() {
        return f32::INFINITY;
    }
    // scores >= thr go to small; thr = (1-target) quantile
    stats::percentile(&xs, (1.0 - target.clamp(0.0, 1.0)) * 100.0) as f32
}

/// Achieved cost advantage of an assignment.
pub fn cost_advantage(assign: &[bool]) -> f64 {
    if assign.is_empty() {
        return 0.0;
    }
    assign.iter().filter(|&&s| s).count() as f64 / assign.len() as f64
}

/// Mean achieved quality under an assignment, given per-query expected
/// qualities of each model's response. Instead of panicking on
/// mismatched lengths, evaluates over the common prefix of the three
/// slices; empty input yields 0.0.
pub fn achieved_quality(assign: &[bool], q_small: &[f64], q_large: &[f64]) -> f64 {
    let n = assign.len().min(q_small.len()).min(q_large.len());
    if n == 0 {
        return 0.0;
    }
    let total: f64 = (0..n)
        .map(|i| if assign[i] { q_small[i] } else { q_large[i] })
        .sum();
    total / n as f64
}

/// Fraction of queries assigned to each of `k` tiers (out-of-range
/// assignments clamp to the last tier).
pub fn tier_fractions(assign: &[usize], k: usize) -> Vec<f64> {
    let mut frac = vec![0.0f64; k];
    if assign.is_empty() || k == 0 {
        return frac;
    }
    for &a in assign {
        frac[a.min(k - 1)] += 1.0;
    }
    for f in &mut frac {
        *f /= assign.len() as f64;
    }
    frac
}

/// Cost advantage of an N-tier assignment under per-tier cost weights:
/// `1 - mean(costs[a_i]) / max(costs)` — the relative spend saved
/// against all-at-most-expensive. With costs `[0, 1]` this reduces to
/// the paper's fraction-routed-small. Empty or degenerate (no positive
/// cost) inputs yield 0.0.
pub fn cost_advantage_tiers(assign: &[usize], costs: &[f64]) -> f64 {
    if assign.is_empty() || costs.is_empty() {
        return 0.0;
    }
    let cmax = costs.iter().cloned().fold(f64::MIN, f64::max);
    if !(cmax > 0.0) {
        return 0.0;
    }
    let spent: f64 = assign.iter().map(|&a| costs[a.min(costs.len() - 1)]).sum();
    1.0 - spent / (assign.len() as f64 * cmax)
}

/// Mean achieved quality of an N-tier assignment; `q[t][i]` is query
/// `i`'s expected quality when served by tier `t`. Out-of-range tiers
/// clamp to the last row; mismatched lengths evaluate over the common
/// prefix of `assign` and every quality row (fabricating 0.0 for a
/// missing query would read as *perfect* on the negative log-prob
/// scale); empty inputs yield 0.0. No panics.
pub fn achieved_quality_tiers(assign: &[usize], q: &[Vec<f64>]) -> f64 {
    if q.is_empty() {
        return 0.0;
    }
    let n = q
        .iter()
        .map(|row| row.len())
        .min()
        .unwrap_or(0)
        .min(assign.len());
    if n == 0 {
        return 0.0;
    }
    let total: f64 = assign[..n]
        .iter()
        .enumerate()
        .map(|(i, &a)| q[a.min(q.len() - 1)][i])
        .sum();
    total / n as f64
}

/// Cost-aware argmax policy: assign each query to the tier maximizing
/// `q[t][i] - lambda * costs[t]`. `lambda` prices cost in quality units
/// (`0` → pure quality argmax; large → always the cheapest tier wins on
/// any quality tie). Ties break toward the lower-index (cheaper) tier.
pub fn cost_argmax_assign(q: &[Vec<f64>], costs: &[f64], lambda: f64) -> Vec<usize> {
    let k = q.len().min(costs.len());
    if k == 0 {
        return Vec::new();
    }
    let n = q[..k].iter().map(|row| row.len()).min().unwrap_or(0);
    (0..n)
        .map(|i| {
            let mut best = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            for t in 0..k {
                let v = q[t][i] - lambda * costs[t];
                if v > best_v {
                    best_v = v;
                    best = t;
                }
            }
            best
        })
        .collect()
}

/// One point on an error–cost curve.
#[derive(Debug, Clone, PartialEq)]
pub struct TradeoffPoint {
    pub target_cost_advantage: f64,
    pub achieved_cost_advantage: f64,
    pub quality: f64,
    /// % drop w.r.t. all-at-large (negative = better than baseline).
    pub drop_pct: f64,
}

/// Sweep cost advantages `0..=1` in `steps` increments for a score-based
/// policy (Fig. 5 series).
pub fn tradeoff_curve(
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
    steps: usize,
) -> Vec<TradeoffPoint> {
    let base = stats::mean(q_large);
    (0..=steps)
        .map(|k| {
            let target = k as f64 / steps as f64;
            let point = tradeoff_at(scores, q_small, q_large, target);
            TradeoffPoint { target_cost_advantage: target, ..point }
        })
        .map(|mut p| {
            p.drop_pct = quality_drop_pct(base, p.quality);
            p
        })
        .collect()
}

/// Single tradeoff point at a target cost advantage.
pub fn tradeoff_at(
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
    target: f64,
) -> TradeoffPoint {
    // exact target: route the top ceil(target*n) scores to small (ties
    // broken by index) — avoids quantile-threshold granularity noise.
    // total_cmp, not partial_cmp: router scores can be NaN (an untrained
    // or diverged router) and a sort comparator that panics takes the
    // whole eval driver down with it. Under total order, +NaN sorts
    // above +inf (routed small first) and -NaN below -inf.
    let n = scores.len();
    let k = ((target * n as f64).round() as usize).min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    let mut assign = vec![false; n];
    for &i in idx.iter().take(k) {
        assign[i] = true;
    }
    let quality = achieved_quality(&assign, q_small, q_large);
    TradeoffPoint {
        target_cost_advantage: target,
        achieved_cost_advantage: cost_advantage(&assign),
        quality,
        drop_pct: quality_drop_pct(stats::mean(q_large), quality),
    }
}

/// One tradeoff point of a threshold ladder over an N-tier fleet:
/// evaluate the full ladder against per-tier qualities `q[t][i]` and
/// cost weights, with the drop measured vs all-at-most-expensive (the
/// last tier). `target_cost_advantage` is set to the achieved value —
/// a ladder is parameterized by thresholds, not a target fraction.
pub fn ladder_tradeoff_at(
    scores: &[f32],
    q: &[Vec<f64>],
    costs: &[f64],
    thresholds: &[f32],
) -> TradeoffPoint {
    let assign = TierPolicy::Ladder { thresholds: thresholds.to_vec() }.assign(scores);
    let base = q.last().map(|row| stats::mean(row)).unwrap_or(0.0);
    let quality = achieved_quality_tiers(&assign, q);
    let ca = cost_advantage_tiers(&assign, costs);
    TradeoffPoint {
        target_cost_advantage: ca,
        achieved_cost_advantage: ca,
        quality,
        drop_pct: quality_drop_pct(base, quality),
    }
}

/// Random-baseline curve (expected values via seeded assignment).
pub fn random_curve(
    n: usize,
    q_small: &[f64],
    q_large: &[f64],
    steps: usize,
    seed: u64,
) -> Vec<TradeoffPoint> {
    let base = stats::mean(q_large);
    (0..=steps)
        .map(|k| {
            let target = k as f64 / steps as f64;
            let assign = Policy::Random { p_small: target, seed: seed ^ k as u64 }
                .assign(&vec![0.0; n]);
            let quality = achieved_quality(&assign, q_small, q_large);
            TradeoffPoint {
                target_cost_advantage: target,
                achieved_cost_advantage: cost_advantage(&assign),
                quality,
                drop_pct: quality_drop_pct(base, quality),
            }
        })
        .collect()
}

/// §5 extension (2): N-model routing. Given scores from one router per
/// *adjacent pair* in a quality-ordered roster and per-model per-query
/// qualities, assign each query to the cheapest model whose pair-router
/// deems it "easy enough" all the way down. Models are ordered cheapest
/// first; `pair_scores[m]` is the router score of "model m can replace
/// model m+1".
pub fn nmodel_assign(pair_scores: &[Vec<f32>], thresholds: &[f32], n_queries: usize) -> Vec<usize> {
    // m pair-routers => m+1 models; extra thresholds are ignored, and a
    // level with no threshold is treated as "never route down past it"
    // (queries stay at the expensive end) — conservative, not a panic
    let m = pair_scores.len();
    (0..n_queries)
        .map(|i| {
            // walk from the most expensive model downwards while the
            // pair-router keeps saying "the cheaper one matches"
            let mut choice = m; // most expensive
            for level in (0..m).rev() {
                let Some(&thr) = thresholds.get(level) else { break };
                if pair_scores[level][i] >= thr {
                    choice = level;
                } else {
                    break;
                }
            }
            choice
        })
        .collect()
}

/// Quality targets at or above this verify every drafted block — the
/// regime in which hybrid decoding is byte-identical to large-only
/// greedy decoding (every emitted token is the large tier's choice).
pub const ALWAYS_VERIFY_QUALITY: f32 = 0.75;

/// Draft-confidence floor of the escalation ladder: at quality target 0
/// only blocks whose weakest draft logprob falls below this get a
/// verify call.
const ESCALATION_LO: f32 = -8.0;

/// Upper end of the linear ramp, just below certainty — at targets
/// approaching [`ALWAYS_VERIFY_QUALITY`] essentially every block
/// escalates.
const ESCALATION_HI: f32 = -0.05;

/// Token-level escalation threshold for hybrid draft–verify decoding
/// (DESIGN.md §12): a drafted block whose weakest per-token draft
/// logprob falls below `escalation_threshold(quality)` is sent to the
/// large tier for verification; a block clearing it is accepted locally
/// (streamed small-tier tokens, no large forward pass).
///
/// Monotone nondecreasing in the quality target: a higher target never
/// yields a lower threshold, so it never verifies *less* (property-
/// tested). Non-finite targets and targets at or above
/// [`ALWAYS_VERIFY_QUALITY`] pin the threshold to `+∞` — every block
/// verifies, which is what makes the high-quality regime byte-identical
/// to large-only decoding.
pub fn escalation_threshold(quality: f32) -> f32 {
    if !quality.is_finite() {
        return f32::INFINITY;
    }
    let q = quality.clamp(0.0, 1.0);
    if q >= ALWAYS_VERIFY_QUALITY {
        return f32::INFINITY;
    }
    // linear ramp over [0, ALWAYS_VERIFY_QUALITY): LO at 0, HI as the
    // always-verify regime is approached
    ESCALATION_LO + (ESCALATION_HI - ESCALATION_LO) * (q / ALWAYS_VERIFY_QUALITY)
}

/// Should a drafted block with weakest draft logprob `conf` be verified
/// by the large tier under quality target `quality`? Total-order
/// comparison ([`f32::total_cmp`]) plus an explicit non-finite guard:
/// a NaN confidence always verifies — corrupted confidence must never
/// silently skip the large tier.
pub fn should_verify(quality: f32, conf: f32) -> bool {
    if !conf.is_finite() {
        return true;
    }
    conf.total_cmp(&escalation_threshold(quality)) == std::cmp::Ordering::Less
}

/// Request priority class for admission and shedding under overload
/// (DESIGN.md §13). Declaration order is shedding order — under
/// brownout pressure `BestEffort` sheds first and `Interactive` last —
/// and the derived `Ord` agrees: `BestEffort < Batch < Interactive`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Shed first: opportunistic work with no latency contract.
    BestEffort,
    /// Shed second: throughput-oriented offline work.
    Batch,
    /// Shed last: latency-sensitive user-facing traffic (the default).
    #[default]
    Interactive,
}

/// Number of priority classes ([`Priority::index`] is dense in
/// `0..PRIORITY_CLASSES`).
pub const PRIORITY_CLASSES: usize = 3;

impl Priority {
    /// Dense per-class counter index in shedding order:
    /// 0 = `BestEffort`, 1 = `Batch`, 2 = `Interactive`.
    pub fn index(self) -> usize {
        self as usize
    }

    /// All classes, ascending (shedding order).
    pub fn all() -> [Priority; PRIORITY_CLASSES] {
        [Priority::BestEffort, Priority::Batch, Priority::Interactive]
    }

    /// Stable lowercase name for reports and trace files.
    pub fn name(self) -> &'static str {
        match self {
            Priority::BestEffort => "best-effort",
            Priority::Batch => "batch",
            Priority::Interactive => "interactive",
        }
    }
}

/// Highest brownout level the controller will actuate. Levels:
/// 0 = normal, 1 = cap effective quality targets (route cheaper),
/// 2 = additionally relax hybrid escalation and shrink draft blocks,
/// 3 = additionally apply priority-weighted admission.
pub const BROWNOUT_MAX_LEVEL: u8 = 3;

/// Consecutive hot ticks before the level ramps up one step (the
/// additive-increase half of AIMD, gated so a single noisy sample
/// cannot trip a level).
const BROWNOUT_TRIP_TICKS: u32 = 3;

/// Consecutive calm ticks before the level steps back down. Larger
/// than [`BROWNOUT_TRIP_TICKS`]: recovery is deliberately slower than
/// ramp-up (hysteresis), so the controller cannot oscillate on load
/// hovering near the target.
const BROWNOUT_RECOVER_TICKS: u32 = 6;

/// EWMA smoothing factor for the queue-delay sensor.
const BROWNOUT_EWMA_ALPHA: f64 = 0.2;

/// Pressure at or below this fraction of the trip point counts as a
/// calm tick; the band between calm and hot holds the level steady.
const BROWNOUT_CALM_FRACTION: f64 = 0.5;

/// Queue depth (as a fraction of `queue_cap`) that alone saturates the
/// pressure signal: a queue this full is overloaded even if delay has
/// not caught up yet.
const BROWNOUT_DEPTH_TRIP_FRACTION: f64 = 0.85;

/// Load-adaptive brownout controller (DESIGN.md §13): senses sustained
/// queue pressure and actuates a small integer brownout level with
/// AIMD ramp-up and hysteretic recovery.
///
/// Sensors (all pushed in by the caller — the controller owns no clock
/// and no server state, which is what makes it property-testable):
/// an EWMA of submit→dispatch queue delay against a CoDel-style target
/// sojourn, instantaneous queue depth as a fraction of `queue_cap`,
/// and the shed count delta since the last tick. The pressure signal
/// is the max of the three normalized sensors; a tick is *hot* at
/// pressure ≥ 1, *calm* at pressure ≤ [`BROWNOUT_CALM_FRACTION`], and
/// the band between holds the level (hysteresis).
///
/// Dynamics, property-tested in `tests/property_suite.rs`:
/// the level is monotone under constant pressure (never changes
/// direction on steady input, so it cannot oscillate), ramps only
/// after [`BROWNOUT_TRIP_TICKS`] consecutive hot ticks, recovers only
/// after [`BROWNOUT_RECOVER_TICKS`] consecutive calm ticks, and always
/// walks back to level 0 when load recedes.
#[derive(Debug, Clone)]
pub struct BrownoutController {
    target_ms: f64,
    ewma_ms: f64,
    level: u8,
    hot: u32,
    calm: u32,
}

impl BrownoutController {
    /// Controller targeting a queue sojourn of `target_ms` (CoDel-style
    /// target delay). Non-finite or non-positive targets clamp to 1ms
    /// rather than disabling the delay sensor.
    pub fn new(target_ms: f64) -> BrownoutController {
        let target_ms = if target_ms.is_finite() { target_ms.max(1e-3) } else { 1.0 };
        BrownoutController { target_ms, ewma_ms: 0.0, level: 0, hot: 0, calm: 0 }
    }

    /// Current brownout level in `0..=BROWNOUT_MAX_LEVEL`.
    pub fn level(&self) -> u8 {
        self.level
    }

    /// Fold one observed submit→dispatch queue delay into the EWMA.
    /// NaN and negative samples are dropped, not folded.
    pub fn observe_delay_ms(&mut self, ms: f64) {
        if ms.is_finite() && ms >= 0.0 {
            self.ewma_ms += BROWNOUT_EWMA_ALPHA * (ms - self.ewma_ms);
        }
    }

    /// Normalized pressure for the given instantaneous sensors plus the
    /// internal delay EWMA: ≥ 1 means overloaded. Non-finite or
    /// negative sensor values are treated as zero pressure from that
    /// sensor, never as a trip.
    pub fn pressure(&self, depth_fraction: f64, shed_delta: u64) -> f64 {
        let delay = self.ewma_ms / self.target_ms;
        let depth = if depth_fraction.is_finite() && depth_fraction > 0.0 {
            depth_fraction / BROWNOUT_DEPTH_TRIP_FRACTION
        } else {
            0.0
        };
        let shed = if shed_delta > 0 { 1.0 } else { 0.0 };
        delay.max(depth).max(shed)
    }

    /// One control tick: classify the pressure as hot / calm / in-band,
    /// update the streak counters, and (de)actuate the level. Returns
    /// the level in force after the tick. Call at a steady cadence; the
    /// caller owns the clock.
    pub fn tick(&mut self, depth_fraction: f64, shed_delta: u64) -> u8 {
        // an empty queue has zero sojourn by definition: fold a zero
        // delay sample so an EWMA left high by the last burst cannot
        // pin the pressure signal after the queue drains — recovery
        // must not depend on fresh dispatches that never come
        if depth_fraction == 0.0 {
            self.observe_delay_ms(0.0);
        }
        let p = self.pressure(depth_fraction, shed_delta);
        if p >= 1.0 {
            self.calm = 0;
            self.hot += 1;
            if self.hot >= BROWNOUT_TRIP_TICKS {
                self.hot = 0;
                self.level = (self.level + 1).min(BROWNOUT_MAX_LEVEL);
            }
        } else if p <= BROWNOUT_CALM_FRACTION {
            self.hot = 0;
            self.calm += 1;
            if self.calm >= BROWNOUT_RECOVER_TICKS {
                self.calm = 0;
                self.level = self.level.saturating_sub(1);
            }
        } else {
            // hysteresis band: hold the level, restart both streaks
            self.hot = 0;
            self.calm = 0;
        }
        self.level
    }
}

/// Effective per-request quality-target ceiling at a brownout level —
/// the L1 actuator. Level 0 never caps (byte-identical routing to a
/// server without the controller); deeper levels bias the
/// [`LadderFamily`] resolution toward cheaper tiers. Monotone
/// non-increasing in the level.
pub fn brownout_quality_cap(level: u8) -> f32 {
    match level {
        0 => 1.0,
        1 => 0.7,
        2 => 0.5,
        _ => 0.3,
    }
}

/// Effective quality target used for *routing* under brownout: the
/// request's own target capped by [`brownout_quality_cap`]. Level 0 is
/// the identity.
pub fn brownout_effective_quality(level: u8, quality: f32) -> f32 {
    if level == 0 { quality } else { quality.min(brownout_quality_cap(level)) }
}

/// Effective quality target used for *hybrid escalation*
/// ([`should_verify`]) under brownout — the L2 actuator. Only levels
/// ≥ 2 relax escalation (L1 touches routing, not verification), which
/// thins out the large tier's verify passes first.
pub fn brownout_escalation_quality(level: u8, quality: f32) -> f32 {
    if level >= 2 { quality.min(brownout_quality_cap(level)) } else { quality }
}

/// Draft-block size under brownout — the other half of the L2
/// actuator: at levels ≥ 2 the speculative draft block γ halves
/// (min 1), shrinking the work a failed verify throws away. Never
/// grows γ and maps 0 to 0.
pub fn brownout_gamma(level: u8, gamma: usize) -> usize {
    if level < 2 || gamma <= 1 { gamma } else { (gamma / 2).max(1) }
}

/// Fraction of `queue_cap` a priority class may occupy at a brownout
/// level — the L3 actuator. Below [`BROWNOUT_MAX_LEVEL`] every class
/// gets the full queue; at L3 admission is priority-weighted. Monotone
/// non-decreasing in priority at every level, which is what makes
/// shedding strictly lowest-class-first: at any occupancy where a
/// lower class is admitted, every higher class is admitted too
/// (property-tested in `tests/property_suite.rs`).
pub fn admission_fraction(level: u8, prio: Priority) -> f64 {
    if level < BROWNOUT_MAX_LEVEL {
        return 1.0;
    }
    match prio {
        Priority::Interactive => 1.0,
        Priority::Batch => 0.6,
        Priority::BestEffort => 0.25,
    }
}

/// In-flight cap for a priority class: `queue_cap` scaled by
/// [`admission_fraction`], floored at 1 so `Interactive` (fraction
/// 1.0) always retains at least the full cap and no class cap rounds
/// to a hard lockout at tiny queue sizes.
pub fn class_queue_cap(level: u8, prio: Priority, queue_cap: usize) -> usize {
    let f = admission_fraction(level, prio);
    ((queue_cap as f64 * f).floor() as usize).max(1).min(queue_cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baselines() {
        let scores = vec![0.1, 0.9, 0.5];
        assert_eq!(Policy::AllSmall.assign(&scores), vec![true; 3]);
        assert_eq!(Policy::AllLarge.assign(&scores), vec![false; 3]);
        let r = Policy::Random { p_small: 1.0, seed: 1 }.assign(&scores);
        assert_eq!(r, vec![true; 3]);
        let r = Policy::Random { p_small: 0.0, seed: 1 }.assign(&scores);
        assert_eq!(r, vec![false; 3]);
    }

    #[test]
    fn escalation_threshold_is_monotone_and_pins_high_quality() {
        // coarse sweep; the exhaustive sweep lives in the property suite
        let mut prev = f32::NEG_INFINITY;
        for i in 0..=100 {
            let q = i as f32 / 100.0;
            let t = escalation_threshold(q);
            assert!(t >= prev, "threshold dipped at q={q}: {t} < {prev}");
            prev = t;
        }
        assert_eq!(escalation_threshold(ALWAYS_VERIFY_QUALITY), f32::INFINITY);
        assert_eq!(escalation_threshold(1.0), f32::INFINITY);
        assert_eq!(escalation_threshold(f32::NAN), f32::INFINITY);
        assert_eq!(escalation_threshold(f32::INFINITY), f32::INFINITY);
        // below the pin the ramp is finite and anchored at LO
        assert_eq!(escalation_threshold(0.0), ESCALATION_LO);
        assert!(escalation_threshold(0.5).is_finite());
        // out-of-range targets clamp instead of extrapolating
        assert_eq!(escalation_threshold(-3.0), escalation_threshold(0.0));
        assert_eq!(escalation_threshold(7.0), f32::INFINITY);
    }

    #[test]
    fn should_verify_gates_on_confidence_and_is_nan_safe() {
        // high quality: everything verifies, even a perfect confidence
        assert!(should_verify(1.0, 0.0));
        assert!(should_verify(0.9, -0.001));
        // low quality: confident blocks skip the large tier …
        assert!(!should_verify(0.0, -0.5));
        // … but hopeless drafts still escalate
        assert!(should_verify(0.0, -20.0));
        // corrupted confidence never silently skips verification
        assert!(should_verify(0.0, f32::NAN));
        assert!(should_verify(0.0, f32::INFINITY));
        assert!(should_verify(0.0, f32::NEG_INFINITY));
    }

    #[test]
    fn threshold_policy_routes_high_scores_to_small() {
        let scores = vec![0.2, 0.8, 0.5];
        let a = Policy::Threshold { threshold: 0.5 }.assign(&scores);
        assert_eq!(a, vec![false, true, true]);
    }

    #[test]
    fn threshold_for_target() {
        let scores: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let thr = threshold_for_cost_advantage(&scores, 0.2);
        let a = Policy::Threshold { threshold: thr }.assign(&scores);
        let ca = cost_advantage(&a);
        assert!((ca - 0.2).abs() < 0.03, "{ca}");
    }

    #[test]
    fn tradeoff_at_exact_fraction() {
        let scores = vec![0.9, 0.1, 0.5, 0.7];
        let qs = vec![-2.0, -2.0, -2.0, -2.0];
        let ql = vec![-1.0, -1.0, -1.0, -1.0];
        let p = tradeoff_at(&scores, &qs, &ql, 0.5);
        assert_eq!(p.achieved_cost_advantage, 0.5);
        // top-2 scores (0.9, 0.7) go small => quality = (-2-2-1-1)/4
        assert!((p.quality + 1.5).abs() < 1e-12);
        // drop = (-1 - (-1.5))/1 = 50%
        assert!((p.drop_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn perfect_router_has_no_drop_when_small_matches() {
        // small matches large on half the queries; a perfect router
        // achieves 50% cost advantage with zero drop
        let n = 100;
        let mut scores = vec![0.0f32; n];
        let mut qs = vec![0.0f64; n];
        let mut ql = vec![-1.0f64; n];
        for i in 0..n {
            if i % 2 == 0 {
                scores[i] = 0.9; // easy
                qs[i] = -1.0;
            } else {
                scores[i] = 0.1; // hard
                qs[i] = -3.0;
            }
            ql[i] = -1.0;
        }
        let p = tradeoff_at(&scores, &qs, &ql, 0.5);
        assert!((p.quality + 1.0).abs() < 1e-12);
        assert!(p.drop_pct.abs() < 1e-9);
    }

    #[test]
    fn curve_monotone_cost() {
        let scores: Vec<f32> = (0..50).map(|i| (i as f32) / 50.0).collect();
        let qs: Vec<f64> = (0..50).map(|i| -2.0 - i as f64 * 0.01).collect();
        let ql = vec![-1.0; 50];
        let c = tradeoff_curve(&scores, &qs, &ql, 10);
        assert_eq!(c.len(), 11);
        assert_eq!(c[0].achieved_cost_advantage, 0.0);
        assert_eq!(c[10].achieved_cost_advantage, 1.0);
        // at 0 cost advantage drop is 0
        assert!(c[0].drop_pct.abs() < 1e-9);
        // drop grows along the curve for a weak small model
        assert!(c[10].drop_pct > c[5].drop_pct);
    }

    #[test]
    fn nmodel_walks_down_while_easy() {
        // 3 models, 2 pair-routers
        let pair_scores = vec![
            vec![0.9, 0.1, 0.9, 0.1], // model0 replaces model1
            vec![0.9, 0.9, 0.1, 0.1], // model1 replaces model2
        ];
        let thr = vec![0.5, 0.5];
        let a = nmodel_assign(&pair_scores, &thr, 4);
        // q0: both easy -> model0; q1: level1 easy but level0 hard -> model1
        // q2: level1 hard -> stop at model2 even though level0 says easy
        // q3: both hard -> model2
        assert_eq!(a, vec![0, 1, 2, 2]);
        // missing thresholds never shrink the model universe: with no
        // threshold for the top level the walk stops immediately and
        // everything stays at the most expensive model
        let a = nmodel_assign(&pair_scores, &[0.5], 4);
        assert_eq!(a, vec![2, 2, 2, 2]);
        // extra thresholds are ignored
        let a = nmodel_assign(&pair_scores, &[0.5, 0.5, 0.1], 4);
        assert_eq!(a, vec![0, 1, 2, 2]);
    }

    #[test]
    fn threshold_for_cost_advantage_degenerate_inputs() {
        // empty => all-at-large fallback instead of a panic
        let thr = threshold_for_cost_advantage(&[], 0.5);
        assert_eq!(thr, f32::INFINITY);
        assert_eq!(Policy::Threshold { threshold: thr }.assign(&[0.3, 0.9]), vec![false, false]);
        // all-NaN => same fallback
        let thr = threshold_for_cost_advantage(&[f32::NAN, f32::NAN], 0.5);
        assert_eq!(thr, f32::INFINITY);
        // non-finite scores are ignored, finite ones still calibrate
        let mut scores: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        scores.push(f32::NAN);
        scores.push(f32::INFINITY);
        let thr = threshold_for_cost_advantage(&scores, 0.2);
        assert!(thr.is_finite());
        assert!((0.7..=0.9).contains(&thr), "{thr}");
    }

    #[test]
    fn achieved_quality_degenerate_inputs() {
        // empty => 0.0, not a panic
        assert_eq!(achieved_quality(&[], &[], &[]), 0.0);
        // mismatched lengths => common prefix, not a panic
        let q = achieved_quality(&[true, false, true], &[-1.0, -1.0], &[-2.0, -2.0, -2.0]);
        assert!((q - (-1.0 - 2.0) / 2.0).abs() < 1e-12, "{q}");
    }

    #[test]
    fn ladder_bands_partition_scores() {
        // 3 tiers, thresholds [0.6, 0.3]
        let p = TierPolicy::Ladder { thresholds: vec![0.6, 0.3] };
        assert_eq!(p.n_tiers(), Some(3));
        let a = p.assign(&[0.9, 0.6, 0.5, 0.3, 0.1, f32::NAN]);
        assert_eq!(a, vec![0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn even_ladder_matches_seed_default() {
        assert_eq!(TierPolicy::even_ladder(2), TierPolicy::Ladder { thresholds: vec![0.5] });
        let TierPolicy::Ladder { thresholds } = TierPolicy::even_ladder(4) else {
            unreachable!()
        };
        assert_eq!(thresholds.len(), 3);
        for w in thresholds.windows(2) {
            assert!(w[0] > w[1], "ladder must descend: {thresholds:?}");
        }
    }

    #[test]
    fn tier_policy_fixed_and_random() {
        let scores = vec![0.1, 0.9, 0.5];
        assert_eq!(TierPolicy::Fixed { tier: 2 }.assign(&scores), vec![2; 3]);
        // all weight on one tier => deterministic
        let p = TierPolicy::Random { weights: vec![0.0, 1.0, 0.0], seed: 9 };
        assert_eq!(p.assign(&scores), vec![1; 3]);
        // degenerate weights => last tier fallback
        let p = TierPolicy::Random { weights: vec![0.0, 0.0], seed: 9 };
        assert_eq!(p.assign(&scores), vec![1; 3]);
        // weights roughly respected over a long stream
        let p = TierPolicy::Random { weights: vec![3.0, 1.0], seed: 4 };
        let a = p.assign(&vec![0.0; 4000]);
        let frac = tier_fractions(&a, 2);
        assert!((frac[0] - 0.75).abs() < 0.05, "{frac:?}");
    }

    #[test]
    fn tier_cost_advantage_reduces_to_two_tier() {
        // costs [0, 1]: cost advantage == fraction at tier 0
        let assign = vec![0, 1, 0, 0];
        let ca = cost_advantage_tiers(&assign, &[0.0, 1.0]);
        assert!((ca - 0.75).abs() < 1e-12);
        let two: Vec<bool> = assign.iter().map(|&a| a == 0).collect();
        assert!((ca - cost_advantage(&two)).abs() < 1e-12);
        // degenerate: empty or non-positive costs
        assert_eq!(cost_advantage_tiers(&[], &[0.0, 1.0]), 0.0);
        assert_eq!(cost_advantage_tiers(&assign, &[0.0, 0.0]), 0.0);
    }

    #[test]
    fn tier_quality_matches_manual_sum() {
        let q = vec![vec![-3.0, -3.0, -3.0], vec![-2.0, -2.0, -2.0], vec![-1.0, -1.0, -1.0]];
        let a = vec![0, 2, 1];
        let got = achieved_quality_tiers(&a, &q);
        assert!((got - (-3.0 - 1.0 - 2.0) / 3.0).abs() < 1e-12);
        // out-of-range tier clamps to the last row
        let got = achieved_quality_tiers(&[9, 9, 9], &q);
        assert!((got + 1.0).abs() < 1e-12);
        assert_eq!(achieved_quality_tiers(&[], &q), 0.0);
    }

    #[test]
    fn cost_argmax_prices_quality_against_cost() {
        // tier 1 is slightly better but 10x the cost
        let q = vec![vec![-1.1, -3.0], vec![-1.0, -1.0]];
        let costs = vec![0.1, 1.0];
        // lambda 0: pure quality argmax
        assert_eq!(cost_argmax_assign(&q, &costs, 0.0), vec![1, 1]);
        // moderate lambda: the near-tie flips cheap, the big gap stays
        assert_eq!(cost_argmax_assign(&q, &costs, 0.5), vec![0, 1]);
        // huge lambda: everything at the cheapest tier
        assert_eq!(cost_argmax_assign(&q, &costs, 100.0), vec![0, 0]);
        assert_eq!(cost_argmax_assign(&[], &costs, 1.0), Vec::<usize>::new());
    }

    #[test]
    fn ladder_tradeoff_extremes_equal_baselines() {
        let scores = vec![0.9, 0.1, 0.5, 0.7];
        let q = vec![vec![-3.0; 4], vec![-2.0; 4], vec![-1.0; 4]];
        let costs = vec![0.0, 0.5, 1.0];
        // impossible thresholds: everything at the last tier
        let p = ladder_tradeoff_at(&scores, &q, &costs, &[2.0, 1.5]);
        assert_eq!(p.achieved_cost_advantage, 0.0);
        assert!(p.drop_pct.abs() < 1e-9);
        // free thresholds: everything at tier 0
        let p = ladder_tradeoff_at(&scores, &q, &costs, &[0.0, 0.0]);
        assert!((p.achieved_cost_advantage - 1.0).abs() < 1e-12);
        assert!((p.quality + 3.0).abs() < 1e-12);
    }

    #[test]
    fn tradeoff_at_survives_nan_scores() {
        // regression: the score sort used partial_cmp().unwrap() and
        // panicked on NaN router scores
        let scores = vec![f32::NAN, 0.9, 0.1, f32::NAN];
        let qs = vec![-2.0; 4];
        let ql = vec![-1.0; 4];
        for k in 0..=4 {
            let p = tradeoff_at(&scores, &qs, &ql, k as f64 / 4.0);
            assert!((p.achieved_cost_advantage - k as f64 / 4.0).abs() < 1e-9);
        }
        // finite scores still dominate the ordering among themselves:
        // at target 0.25 exactly one query routes small, and +NaN sorts
        // first under the total order, so the pick is deterministic
        let p = tradeoff_at(&scores, &qs, &ql, 0.25);
        assert!((p.achieved_cost_advantage - 0.25).abs() < 1e-9);
    }

    #[test]
    fn ladder_family_rounds_up_and_clamps() {
        let fam = LadderFamily::new(vec![
            (0.0, vec![f32::NEG_INFINITY]),
            (0.5, vec![0.5]),
            (1.0, vec![f32::INFINITY]),
        ])
        .unwrap();
        assert_eq!(fam.n_tiers(), 2);
        // exact levels hit their rung
        assert_eq!(fam.assign_one(0.0, 0.2), 0);
        assert_eq!(fam.assign_one(0.5, 0.7), 0);
        assert_eq!(fam.assign_one(0.5, 0.3), 1);
        // between rungs rounds up to the more conservative ladder
        assert_eq!(fam.assign_one(0.2, 0.7), 0);
        assert_eq!(fam.assign_one(0.6, 0.99), 1);
        // out-of-range and non-finite targets clamp / go conservative
        assert_eq!(fam.assign_one(-3.0, 0.1), 0);
        assert_eq!(fam.assign_one(7.0, 0.99), 1);
        assert_eq!(fam.assign_one(f32::NAN, 0.99), 1);
    }

    #[test]
    fn ladder_family_enforces_pointwise_monotonicity() {
        // rung 0.8's threshold dips below rung 0.2's: the constructor
        // must raise it so a higher target can never route cheaper
        let fam = LadderFamily::new(vec![(0.8, vec![0.3, 0.1]), (0.2, vec![0.6, 0.2])]).unwrap();
        assert_eq!(fam.thresholds_for(0.2), &[0.6, 0.2]);
        assert_eq!(fam.thresholds_for(0.8), &[0.6, 0.2]);
        let score = 0.4;
        let mut last = 0;
        for j in 0..=10 {
            let t = fam.assign_one(j as f32 / 10.0, score);
            assert!(t >= last, "quality knob routed cheaper: {t} < {last}");
            last = t;
        }
    }

    #[test]
    fn ladder_family_rejects_malformed_rungs() {
        assert!(LadderFamily::new(vec![]).is_err());
        assert!(LadderFamily::new(vec![(f32::NAN, vec![0.5])]).is_err());
        assert!(LadderFamily::new(vec![(1.5, vec![0.5])]).is_err());
        assert!(LadderFamily::new(vec![(0.5, vec![f32::NAN])]).is_err());
        assert!(LadderFamily::new(vec![(0.1, vec![0.5]), (0.9, vec![0.5, 0.4])]).is_err());
    }

    #[test]
    fn synthetic_family_extremes_match_baselines() {
        let fam = LadderFamily::synthetic(3, 8);
        assert_eq!(fam.n_tiers(), 3);
        for score in [0.0, 0.25, 0.5, 0.99] {
            // quality 0: everything at the cheapest tier (zero pivot)
            assert_eq!(fam.assign_one(0.0, score), 0);
            // quality 1: everything at the most capable tier
            assert_eq!(fam.assign_one(1.0, score), 2);
        }
    }

    #[test]
    fn random_curve_cost_tracks_target() {
        let qs = vec![-2.0; 1000];
        let ql = vec![-1.0; 1000];
        let c = random_curve(1000, &qs, &ql, 4, 42);
        for p in &c {
            assert!((p.achieved_cost_advantage - p.target_cost_advantage).abs() < 0.06);
        }
    }

    #[test]
    fn priority_orders_and_indexes_in_shedding_order() {
        assert!(Priority::BestEffort < Priority::Batch);
        assert!(Priority::Batch < Priority::Interactive);
        assert_eq!(Priority::default(), Priority::Interactive);
        for (i, p) in Priority::all().iter().enumerate() {
            assert_eq!(p.index(), i);
        }
        assert_eq!(Priority::all().len(), PRIORITY_CLASSES);
        assert_eq!(Priority::Interactive.name(), "interactive");
        assert_eq!(Priority::Batch.name(), "batch");
        assert_eq!(Priority::BestEffort.name(), "best-effort");
    }

    #[test]
    fn brownout_trips_after_sustained_pressure_only() {
        let mut c = BrownoutController::new(10.0);
        assert_eq!(c.level(), 0);
        // two hot ticks are not enough …
        for _ in 0..2 {
            assert_eq!(c.tick(1.0, 0), 0);
        }
        // … a calm tick resets the streak …
        assert_eq!(c.tick(0.0, 0), 0);
        // … and only TRIP_TICKS consecutive hot ticks ramp the level
        for _ in 0..3 {
            c.tick(1.0, 0);
        }
        assert_eq!(c.level(), 1);
        // sustained overload saturates at the max level
        for _ in 0..100 {
            c.tick(1.0, 0);
        }
        assert_eq!(c.level(), BROWNOUT_MAX_LEVEL);
    }

    #[test]
    fn brownout_recovery_is_hysteretic_and_reaches_zero() {
        let mut c = BrownoutController::new(10.0);
        for _ in 0..100 {
            c.tick(1.0, 0);
        }
        assert_eq!(c.level(), BROWNOUT_MAX_LEVEL);
        // in-band pressure holds the level instead of recovering
        for _ in 0..50 {
            assert_eq!(c.tick(0.7, 0), BROWNOUT_MAX_LEVEL);
        }
        // calm ticks walk the level back down one step per
        // RECOVER_TICKS, monotonically, all the way to zero
        let mut prev = c.level();
        let mut ticks = 0u32;
        while c.level() > 0 {
            let l = c.tick(0.0, 0);
            assert!(l <= prev, "recovery went back up: {l} > {prev}");
            prev = l;
            ticks += 1;
            assert!(ticks < 1000, "recovery never reached level 0");
        }
        assert!(ticks >= 6, "recovery was not hysteretic: {ticks} ticks");
        // and it stays at zero under continued calm
        for _ in 0..20 {
            assert_eq!(c.tick(0.0, 0), 0);
        }
    }

    #[test]
    fn brownout_sensors_are_nan_safe_and_shed_trips() {
        let mut c = BrownoutController::new(10.0);
        // corrupted sensors are zero pressure, not a trip
        c.observe_delay_ms(f64::NAN);
        c.observe_delay_ms(-5.0);
        for _ in 0..10 {
            assert_eq!(c.tick(f64::NAN, 0), 0);
            assert_eq!(c.tick(-1.0, 0), 0);
        }
        // a nonzero shed delta alone saturates pressure
        assert!(c.pressure(0.0, 1) >= 1.0);
        // delay EWMA over target saturates pressure
        for _ in 0..50 {
            c.observe_delay_ms(100.0);
        }
        assert!(c.pressure(0.0, 0) >= 1.0);
        // … but empty-queue ticks decay the stale EWMA: recovery never
        // depends on fresh dispatches arriving to pull the EWMA down
        let mut ticks = 0u32;
        while c.pressure(0.0, 0) > 0.5 {
            c.tick(0.0, 0);
            ticks += 1;
            assert!(ticks < 1000, "stale delay EWMA never decayed");
        }
        for _ in 0..200 {
            c.tick(0.0, 0);
        }
        assert_eq!(c.level(), 0, "drained controller must return to level 0");
    }

    #[test]
    fn brownout_actuators_are_monotone_and_identity_at_level_zero() {
        // L1: quality cap non-increasing in level, identity at 0
        let mut prev = f32::INFINITY;
        for l in 0..=BROWNOUT_MAX_LEVEL {
            let cap = brownout_quality_cap(l);
            assert!(cap <= prev);
            prev = cap;
        }
        assert_eq!(brownout_effective_quality(0, 0.9), 0.9);
        assert_eq!(brownout_effective_quality(1, 0.9), 0.7);
        assert_eq!(brownout_effective_quality(1, 0.2), 0.2);
        // L2: escalation only relaxes at level >= 2
        assert_eq!(brownout_escalation_quality(1, 0.9), 0.9);
        assert_eq!(brownout_escalation_quality(2, 0.9), 0.5);
        // gamma never grows, never hits 0 from a positive input
        for l in 0..=BROWNOUT_MAX_LEVEL {
            for g in 0..16 {
                let s = brownout_gamma(l, g);
                assert!(s <= g);
                assert!(g == 0 || s >= 1);
            }
        }
        assert_eq!(brownout_gamma(2, 8), 4);
        assert_eq!(brownout_gamma(1, 8), 8);
        // L3: admission fraction monotone in priority, full below max
        for l in 0..BROWNOUT_MAX_LEVEL {
            for p in Priority::all() {
                assert_eq!(admission_fraction(l, p), 1.0);
            }
        }
        let f = Priority::all().map(|p| admission_fraction(BROWNOUT_MAX_LEVEL, p));
        assert!(f[0] < f[1] && f[1] < f[2]);
        assert_eq!(f[2], 1.0);
        // class caps respect the fraction, floor at 1, ceil at cap
        assert_eq!(class_queue_cap(BROWNOUT_MAX_LEVEL, Priority::Interactive, 64), 64);
        assert_eq!(class_queue_cap(BROWNOUT_MAX_LEVEL, Priority::BestEffort, 64), 16);
        assert_eq!(class_queue_cap(BROWNOUT_MAX_LEVEL, Priority::BestEffort, 1), 1);
        assert_eq!(class_queue_cap(0, Priority::BestEffort, 64), 64);
    }
}
