//! Statistics toolbox for the experiment drivers: means, percentiles,
//! Pearson/Spearman correlation (Figs. 7–8), histograms (Figs. 1/3/4),
//! and a small ASCII renderer used by the report generators.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation (0.0 if n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn std_err(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `p` in [0, 100]. Input need not be sorted.
///
/// Returns the 0.0 sentinel for empty input: latency windows with no
/// completions yet (snapshot before the first request finishes, all-shed
/// windows) are a normal serving condition, not a caller bug. NaN samples
/// sort last (`total_cmp`), so a stray NaN skews the top percentiles but
/// never aborts the snapshot.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over already-sorted data (0.0 for empty input, see
/// [`percentile`]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Pearson correlation coefficient (NaN-free: returns 0.0 on degenerate
/// inputs, matching how the paper's figures treat uncorrelated data).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Fractional ranks with ties averaged (the standard Spearman convention).
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // 1-based average rank
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Fixed-bin histogram over `[lo, hi]`; values outside are clamped to the
/// edge bins (the paper's distribution plots do the same visually).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub n: u64,
}

impl Histogram {
    pub fn build(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Self {
        assert!(bins > 0 && hi > lo);
        let mut counts = vec![0u64; bins];
        for &x in xs {
            let t = ((x - lo) / (hi - lo) * bins as f64).floor();
            let b = (t.max(0.0) as usize).min(bins - 1);
            counts[b] += 1;
        }
        Histogram { lo, hi, counts, n: xs.len() as u64 }
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }

    /// Normalized densities (sum to 1).
    pub fn density(&self) -> Vec<f64> {
        let n = self.n.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / n).collect()
    }

    /// Simple ASCII bar rendering for reports.
    pub fn ascii(&self, width: usize) -> String {
        let maxc = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let centers = self.centers();
        let mut s = String::new();
        for (c, &cnt) in centers.iter().zip(&self.counts) {
            let bar = "#".repeat((cnt as usize * width).div_ceil(maxc as usize).min(width));
            s.push_str(&format!("{c:>9.3} | {bar} {cnt}\n"));
        }
        s
    }
}

/// Online mean/min/max/std accumulator (used by the metrics module).
#[derive(Debug, Clone, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
    pub sumsq: f64,
    pub min: f64,
    pub max: f64,
}

impl Accum {
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sumsq / self.n as f64 - m * m).max(0.0) * self.n as f64 / (self.n - 1) as f64)
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_window_is_zero() {
        // Snapshot before the first completion: no samples, no panic.
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile_sorted(&[], 99.0), 0.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // NaN sorts last under total_cmp: low/mid percentiles stay finite.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn ranks_survive_nan_samples() {
        // NaN ranks last; the finite entries keep their usual ordering.
        let r = ranks(&[2.0, f64::NAN, 1.0]);
        assert_eq!(r[2], 1.0);
        assert_eq!(r[0], 2.0);
        assert_eq!(r[1], 3.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate_is_zero() {
        let xs = [1.0, 1.0, 1.0];
        let ys = [2.0, 3.0, 4.0];
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_average_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let h = Histogram::build(&[-10.0, 0.1, 0.2, 0.9, 10.0], 0.0, 1.0, 2);
        assert_eq!(h.counts, vec![3, 2]);
        assert_eq!(h.n, 5);
    }

    #[test]
    fn accum_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut a = Accum::default();
        for &x in &xs {
            a.add(x);
        }
        assert!((a.mean() - mean(&xs)).abs() < 1e-12);
        assert!((a.std() - std_dev(&xs)).abs() < 1e-9);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 9.0);
    }
}
