//! LM engine: everything the coordinator does with one roster LM —
//! seeded init, AdamW pre-training (driving the fused `*.train` artifact),
//! and batched autoregressive generation (prefill + decode artifacts with
//! the Pallas attention kernels inside).
//!
//! Training happens *from rust*: python only lowered the train-step graph;
//! the data loop, LR schedule, and checkpointing live here.

use std::cell::RefCell;
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::batching::KvCache;
use crate::corpus::{Query, A_MAX};
use crate::io::Tensor;
use crate::rng::Rng;
use crate::runtime::{bucket_for, Exec, ModelMeta, OutValue, ParamSet, Runtime};
use crate::tokenizer as tok;

/// A generated response: answer tokens (EOS stripped) + mean sampled
/// token log-prob (generation-time confidence, not the quality score).
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub tokens: Vec<i32>,
    pub mean_logprob: f32,
}

/// Build the teacher-forced training / scoring sequence for (query, answer):
/// `[prompt..., answer..., EOS, PAD...]` of length `sctx`, plus the f32
/// mask marking answer+EOS token positions (the loss / score region).
pub fn build_sequence(
    sctx: usize,
    prompt: &[i32],
    answer: &[i32],
) -> Result<(Vec<i32>, Vec<f32>)> {
    let total = prompt.len() + answer.len() + 1;
    ensure!(total <= sctx, "sequence too long: {total} > {sctx}");
    let mut seq = vec![tok::PAD; sctx];
    let mut mask = vec![0.0f32; sctx];
    seq[..prompt.len()].copy_from_slice(prompt);
    seq[prompt.len()..prompt.len() + answer.len()].copy_from_slice(answer);
    seq[prompt.len() + answer.len()] = tok::EOS;
    for m in mask.iter_mut().skip(prompt.len()).take(answer.len() + 1) {
        *m = 1.0;
    }
    Ok((seq, mask))
}

/// Linear-warmup + cosine-decay learning-rate schedule.
pub fn lr_schedule(base: f32, step: usize, total: usize, warmup: usize) -> f32 {
    let warmup = warmup.max(1);
    if step < warmup {
        return base * (step as f32 + 1.0) / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
    let min_ratio = 0.1;
    base * (min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + (std::f32::consts::PI * t).cos()))
}

/// Exec handles + pool geometry for the manifest-v4 block-paged KV path
/// (DESIGN.md §10). Built once per worker by
/// [`LmEngine::paged_artifacts`]; `None` on pre-v4 manifests, which keep
/// the dense path.
pub struct PagedArtifacts {
    /// `<name>.decode_paged` — one decode step gathering KV blocks
    /// through per-lane block tables.
    pub decode: Arc<Exec>,
    /// `(bucket, <name>.kv_install_paged@B)` pairs, ascending by bucket.
    pub installs: Vec<(usize, Arc<Exec>)>,
    /// `<name>.kv_block_copy` — batched block-granular pool copy
    /// (copy-on-extend for shared prefix tails).
    pub block_copy: Arc<Exec>,
    /// Tokens per block (`kvblock`).
    pub block: usize,
    /// Pool blocks per layer including the null block (`kvpool`).
    pub nblk: usize,
    /// Block-table entries per request (`sctx / kvblock`).
    pub maxblk: usize,
}

impl PagedArtifacts {
    /// The smallest install bucket that fits `nb` freshly admitted
    /// requests, mirroring [`bucket_for`] on the dense admission path.
    pub fn install_for(&self, nb: usize) -> Option<(usize, Arc<Exec>)> {
        self.installs
            .iter()
            .find(|(b, _)| *b >= nb)
            .map(|(b, e)| (*b, e.clone()))
    }
}

/// Exec handles for the manifest-v5 speculative `verify@K` family
/// (DESIGN.md §12): multi-token paged decode steps the hybrid decoder
/// uses to batch-verify a drafted block on the large tier. Built by
/// [`LmEngine::verify_artifacts`]; `None` on pre-v5 manifests, which
/// keep per-request routing.
pub struct VerifyArtifacts {
    /// `(K, <name>.verify@K)` pairs, ascending by draft length `K`.
    pub execs: Vec<(usize, Arc<Exec>)>,
}

impl VerifyArtifacts {
    /// The smallest lowered draft-length bucket that fits `k` appended
    /// tokens (first-fit, like the admission buckets). Callers pad the
    /// token block with PAD up to the bucket; padded positions attend
    /// through the same causal mask and their outputs are ignored.
    pub fn bucket_for(&self, k: usize) -> Option<(usize, Arc<Exec>)> {
        self.execs
            .iter()
            .find(|(b, _)| *b >= k)
            .map(|(b, e)| (*b, e.clone()))
    }

    /// Largest lowered draft length — the cap on how many unverified
    /// tokens a hybrid lane may hold before a verify pass is forced.
    pub fn max_k(&self) -> usize {
        self.execs.last().map_or(0, |(b, _)| *b)
    }
}

/// One roster LM bound to the runtime.
pub struct LmEngine {
    rt: Arc<Runtime>,
    pub name: String,
    pub meta: ModelMeta,
    pub params: ParamSet,
    /// Zeroed `[L, genb, sctx, H, Dh]` device cache pair (keyed by the
    /// dims it was built with), uploaded once and shared by every
    /// bucketed-prefill wave (`kv_install` never mutates its inputs, so
    /// the zeros stay pristine). `None` until the first partial wave on
    /// v3 artifacts needs it.
    #[allow(clippy::type_complexity)]
    zero_cache: RefCell<Option<(Vec<usize>, Arc<xla::PjRtBuffer>, Arc<xla::PjRtBuffer>)>>,
}

impl LmEngine {
    /// Fresh seeded parameters via the `<name>.init` artifact.
    pub fn init(rt: Arc<Runtime>, name: &str, seed: u32) -> Result<LmEngine> {
        let meta = *rt.manifest.model(name)?;
        let init = rt.exec(&format!("{name}.init"))?;
        let host = init.run(&[&Tensor::u32(vec![], vec![seed])])?;
        let names: Vec<String> = init.spec.outs.iter().map(|o| o.name.clone()).collect();
        let params = ParamSet::from_host(&rt, names, host)?;
        Ok(LmEngine {
            rt,
            name: name.to_string(),
            meta,
            params,
            zero_cache: RefCell::new(None),
        })
    }

    /// Load previously-trained parameters from `<dir>` (saved by [`Self::save`]).
    pub fn load(rt: Arc<Runtime>, name: &str, dir: &Path) -> Result<LmEngine> {
        let meta = *rt.manifest.model(name)?;
        let init = rt.exec(&format!("{name}.init"))?;
        let names: Vec<String> = init.spec.outs.iter().map(|o| o.name.clone()).collect();
        let params = ParamSet::load(&rt, dir, names)
            .with_context(|| format!("load params for {name} from {dir:?}"))?;
        Ok(LmEngine {
            rt,
            name: name.to_string(),
            meta,
            params,
            zero_cache: RefCell::new(None),
        })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        self.params.save(dir)
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// Pre-train on the MixSynth corpus: `steps` AdamW steps of batch
    /// `trainb`, batches drawn uniformly from `queries` with seeded RNG.
    /// Returns the per-step losses.
    pub fn train(
        &mut self,
        queries: &[&Query],
        steps: usize,
        base_lr: f32,
        seed: u64,
        mut progress: impl FnMut(usize, f32),
    ) -> Result<Vec<f32>> {
        ensure!(!queries.is_empty());
        let g = self.rt.manifest.globals;
        let train = self.rt.exec(&format!("{}.train", self.name))?;
        let n = self.params.len();
        // optimizer state lives host-side between steps
        let mut m: Vec<Tensor> = self
            .params
            .host
            .iter()
            .map(|t| Tensor::f32(t.dims().to_vec(), vec![0.0; t.len()]))
            .collect();
        let mut v = m.clone();
        let mut rng = Rng::new(seed);
        let mut losses = Vec::with_capacity(steps);

        for step in 0..steps {
            let mut toks = vec![tok::PAD; g.trainb * g.sctx];
            let mut mask = vec![0.0f32; g.trainb * g.sctx];
            for b in 0..g.trainb {
                let q = queries[rng.below(queries.len())];
                let (s, mk) = build_sequence(g.sctx, &q.prompt, &q.reference)?;
                toks[b * g.sctx..(b + 1) * g.sctx].copy_from_slice(&s);
                mask[b * g.sctx..(b + 1) * g.sctx].copy_from_slice(&mk);
            }
            let toks = Tensor::i32(vec![g.trainb, g.sctx], toks);
            let mask = Tensor::f32(vec![g.trainb, g.sctx], mask);
            let lr = Tensor::f32(vec![], vec![lr_schedule(base_lr, step, steps, steps / 20 + 1)]);
            let stept = Tensor::i32(vec![], vec![step as i32 + 1]);

            let mut ins: Vec<&Tensor> = Vec::with_capacity(3 * n + 4);
            ins.extend(self.params.host.iter());
            ins.extend(m.iter());
            ins.extend(v.iter());
            ins.extend([&toks, &mask, &lr, &stept]);
            let mut out = train.run(&ins)?;

            let loss = out.pop().context("train: missing loss")?;
            let loss = loss.as_f32()?[0];
            losses.push(loss);
            let new_v: Vec<Tensor> = out.drain(2 * n..).collect();
            let new_m: Vec<Tensor> = out.drain(n..).collect();
            let new_p = out;
            m = new_m;
            v = new_v;
            self.params.update(&self.rt, new_p)?;
            progress(step, loss);
        }
        Ok(losses)
    }

    /// Generate one response per prompt with the *batched* (B = `genb`)
    /// prefill/decode artifacts. `seeds[i]` individualizes sampling per
    /// sequence; `temp = 0` is greedy. Prompts beyond `genb` are processed
    /// in successive waves (run-to-completion batching; the serving layer
    /// does continuous batching instead). KV caches stay device-resident
    /// across decode iterations (v2 artifacts), and a partial final wave
    /// prefills at the smallest v3 bucket that fits (`prefill@B` +
    /// on-device `kv_install`) instead of padding to `genb`.
    pub fn generate(&self, prompts: &[&[i32]], seeds: &[u32], temp: f32) -> Result<Vec<Response>> {
        self.generate_with(prompts, seeds, temp, false)
    }

    /// [`Self::generate`] with an explicit residency override:
    /// `force_host_kv = true` pulls the KV caches back to the host after
    /// every call (the seed's round-trip behavior) — kept for the
    /// residency-equivalence test and for A/B benchmarking; both paths
    /// must produce identical tokens for identical seeds.
    pub fn generate_with(
        &self,
        prompts: &[&[i32]],
        seeds: &[u32],
        temp: f32,
        force_host_kv: bool,
    ) -> Result<Vec<Response>> {
        self.generate_observed(prompts, seeds, temp, force_host_kv, &mut |_, _, _| {})
    }

    /// Streaming generation: `on_token(i, token, logprob)` fires for
    /// prompt `i`'s tokens in decode order, as each wave samples them —
    /// the same stream the serving layer forwards as
    /// `serve::Event::Token`s. Concatenating prompt `i`'s callbacks
    /// reproduces `Response::tokens` exactly (pinned by the integration
    /// suite's streaming-equivalence test).
    pub fn generate_streaming(
        &self,
        prompts: &[&[i32]],
        seeds: &[u32],
        temp: f32,
        on_token: &mut dyn FnMut(usize, i32, f32),
    ) -> Result<Vec<Response>> {
        self.generate_observed(prompts, seeds, temp, false, on_token)
    }

    fn generate_observed(
        &self,
        prompts: &[&[i32]],
        seeds: &[u32],
        temp: f32,
        force_host_kv: bool,
        on_token: &mut dyn FnMut(usize, i32, f32),
    ) -> Result<Vec<Response>> {
        ensure!(prompts.len() == seeds.len());
        let g = self.rt.manifest.globals;
        let bsz = g.genb;
        let mut out = Vec::with_capacity(prompts.len());
        for (wave, (chunk_p, chunk_s)) in
            prompts.chunks(bsz).zip(seeds.chunks(bsz)).enumerate()
        {
            let base = wave * bsz;
            let mut observe = |b: usize, t: i32, lp: f32| on_token(base + b, t, lp);
            out.extend(self.generate_wave(chunk_p, chunk_s, temp, bsz, force_host_kv, &mut observe)?);
        }
        Ok(out)
    }

    /// The admission bucket for a partial wave of `nb` prompts: the
    /// smallest v3 `prefill@B` strictly under the full batch whose
    /// matching `kv_install@B` exists. `None` runs the full-batch
    /// prefill (pre-v3 manifests, or the wave already fills the batch).
    fn wave_bucket(&self, nb: usize, full: usize) -> Result<Option<(usize, Arc<Exec>, Arc<Exec>)>> {
        let buckets = self.rt.manifest.prefill_buckets(&self.name);
        let Some(b) = bucket_for(&buckets, nb) else {
            return Ok(None);
        };
        if b >= full || !self.rt.manifest.has_artifact(&format!("{}.kv_install@{b}", self.name)) {
            return Ok(None);
        }
        Ok(Some((
            b,
            self.rt.exec(&format!("{}.prefill@{b}", self.name))?,
            self.rt.exec(&format!("{}.kv_install@{b}", self.name))?,
        )))
    }

    /// The shared zeroed device cache bucketed waves install into
    /// (uploaded on first use, then reused — `kv_install` copies rather
    /// than donates, so the zeros are never clobbered). The cache is
    /// keyed by its dims: a caller asking for a different shape than the
    /// one cached is a bug, surfaced here instead of as a shape mismatch
    /// inside the install exec.
    fn zero_gen_cache(
        &self,
        dims: &[usize],
    ) -> Result<(Arc<xla::PjRtBuffer>, Arc<xla::PjRtBuffer>)> {
        if let Some((cached_dims, k, v)) = self.zero_cache.borrow().as_ref() {
            ensure!(
                cached_dims == dims,
                "zero cache built for dims {cached_dims:?}, requested {dims:?}"
            );
            return Ok((k.clone(), v.clone()));
        }
        let z = Tensor::f32(dims.to_vec(), vec![0.0; dims.iter().product()]);
        let pair = (self.rt.upload(&z)?, self.rt.upload(&z)?);
        *self.zero_cache.borrow_mut() = Some((dims.to_vec(), pair.0.clone(), pair.1.clone()));
        Ok(pair)
    }

    fn generate_wave(
        &self,
        prompts: &[&[i32]],
        seeds: &[u32],
        temp: f32,
        bsz: usize,
        force_host_kv: bool,
        on_token: &mut dyn FnMut(usize, i32, f32),
    ) -> Result<Vec<Response>> {
        let g = self.rt.manifest.globals;
        let nb = prompts.len();
        ensure!(nb <= bsz && nb > 0);
        let decode = self.rt.exec(&format!("{}.decode", self.name))?;
        let n = self.params.len();
        let mut resident = self.params.resident_map();
        let cache_dims =
            vec![self.meta.layers, bsz, g.sctx, self.meta.heads, self.meta.headdim];

        // partial waves prefill at the smallest v3 bucket that fits and
        // install into the shared zeroed device cache; `force_host_kv`
        // keeps the seed's full-batch path so the A/B stays exact
        let bucket = if force_host_kv { None } else { self.wave_bucket(nb, bsz)? };
        let (pf_b, prefill) = match &bucket {
            Some((b, pf, _)) => (*b, pf.clone()),
            None => (bsz, self.rt.exec(&format!("{}.prefill", self.name))?),
        };

        // right-pad prompts into [pf_b, sprompt]
        let mut ptoks = vec![tok::PAD; pf_b * g.sprompt];
        let mut lens = vec![1i32; bsz];
        let mut pf_lens = vec![1i32; pf_b];
        for (b, p) in prompts.iter().enumerate() {
            ensure!(p.len() <= g.sprompt, "prompt too long");
            ptoks[b * g.sprompt..b * g.sprompt + p.len()].copy_from_slice(p);
            lens[b] = p.len() as i32;
            pf_lens[b] = p.len() as i32;
        }
        let ptoks = Tensor::i32(vec![pf_b, g.sprompt], ptoks);
        let lens_t = Tensor::i32(vec![pf_b], pf_lens);
        let mut seedv = vec![0u32; bsz];
        seedv[..nb].copy_from_slice(seeds);
        let pf_seeds = Tensor::u32(vec![pf_b], seedv[..pf_b].to_vec());
        let seeds_t = Tensor::u32(vec![bsz], seedv);
        let temp_t = Tensor::f32(vec![], vec![temp]);

        let host: Vec<(usize, &Tensor)> = vec![
            (n, &ptoks),
            (n + 1, &lens_t),
            (n + 2, &pf_seeds),
            (n + 3, &temp_t),
        ];
        let mut outs = prefill.run_resident(&resident, &host)?;
        let vc = outs.pop().context("prefill: vcache")?;
        let kc = outs.pop().context("prefill: kcache")?;
        let logp = outs.pop().context("prefill: logp")?.into_tensor()?;
        let first = outs.pop().context("prefill: next")?.into_tensor()?;
        // the caches never leave the device between iterations unless the
        // caller forces the host round-trip
        let mut kv = match &bucket {
            Some((_, _, install)) => {
                let (Some(kb), Some(vb)) = (kc.device().cloned(), vc.device().cloned()) else {
                    anyhow::bail!(
                        "{}: bucketed prefill returned host outputs (untupled v3 expected)",
                        self.name
                    );
                };
                let (zk, zv) = self.zero_gen_cache(&cache_dims)?;
                let mut kv = KvCache::from_outputs(
                    OutValue::Device(zk),
                    OutValue::Device(zv),
                    &cache_dims,
                )?;
                let slots: Vec<usize> = (0..nb).collect();
                kv.install_slots_device(&self.rt, install, &kb, &vb, &slots)?;
                kv
            }
            None => KvCache::from_outputs(kc, vc, &cache_dims)?,
        };
        if force_host_kv {
            kv.to_host(&self.rt)?;
        }

        let mut answers: Vec<Vec<i32>> = vec![Vec::new(); nb];
        let mut lps: Vec<Vec<f32>> = vec![Vec::new(); nb];
        let mut done = vec![false; nb];
        // first/logp are [pf_b]; the decode loop always runs at the full
        // batch, so pad `cur` back out (padding lanes decode PAD tokens,
        // exactly like the serving layer's free slots)
        let first = first.as_i32()?;
        let mut cur = vec![tok::PAD; bsz];
        cur[..first.len()].copy_from_slice(first);
        let logp0 = logp.as_f32()?;
        for b in 0..nb {
            if cur[b] == tok::EOS {
                done[b] = true;
            } else {
                answers[b].push(cur[b]);
                lps[b].push(logp0[b]);
                on_token(b, cur[b], logp0[b]);
            }
        }
        let mut pos: Vec<i32> = lens.clone();

        // decode until every live slot hit EOS or the answer budget
        for step in 0..A_MAX - 1 {
            if done.iter().take(nb).all(|&d| d) {
                break;
            }
            if pos.iter().any(|&p| p as usize >= g.sctx - 1) {
                break;
            }
            let cur_t = Tensor::i32(vec![bsz], cur.clone());
            let pos_t = Tensor::i32(vec![bsz], pos.clone());
            let step_t = Tensor::i32(vec![], vec![step as i32 + 1]);
            let mut host: Vec<(usize, &Tensor)> = vec![
                (n + 2, &cur_t),
                (n + 3, &pos_t),
                (n + 4, &step_t),
                (n + 5, &seeds_t),
                (n + 6, &temp_t),
            ];
            kv.bind(n, n + 1, &mut resident, &mut host);
            let mut outs = decode.run_resident(&resident, &host)?;
            let vc = outs.pop().context("decode: vcache")?;
            let kc = outs.pop().context("decode: kcache")?;
            let logp = outs.pop().context("decode: logp")?.into_tensor()?;
            let next = outs.pop().context("decode: next")?.into_tensor()?;
            kv.update(kc, vc)?;
            if force_host_kv {
                kv.to_host(&self.rt)?;
            }
            let next = next.as_i32()?;
            let logp = logp.as_f32()?;
            for b in 0..bsz {
                pos[b] += 1;
                if b >= nb || done[b] {
                    continue;
                }
                if next[b] == tok::EOS || answers[b].len() + 1 >= A_MAX {
                    done[b] = true;
                } else {
                    answers[b].push(next[b]);
                    lps[b].push(logp[b]);
                    on_token(b, next[b], logp[b]);
                }
                cur[b] = next[b];
            }
        }

        Ok((0..nb)
            .map(|b| Response {
                tokens: answers[b].clone(),
                mean_logprob: if lps[b].is_empty() {
                    0.0
                } else {
                    lps[b].iter().sum::<f32>() / lps[b].len() as f32
                },
            })
            .collect())
    }

    /// The block-paged KV artifact set, or `None` when the manifest
    /// predates v4 (callers fall back to the dense `[L, genb, sctx, H,
    /// Dh]` slab). Buckets come back ascending so
    /// [`PagedArtifacts::install_for`] can first-fit.
    pub fn paged_artifacts(&self) -> Result<Option<PagedArtifacts>> {
        if !self.rt.manifest.has_paged_kv(&self.name) {
            return Ok(None);
        }
        let g = self.rt.manifest.globals;
        let decode = self.rt.exec(&format!("{}.decode_paged", self.name))?;
        let mut installs = Vec::new();
        for b in self.rt.manifest.kv_install_paged_buckets(&self.name) {
            installs.push((b, self.rt.exec(&format!("{}.kv_install_paged@{b}", self.name))?));
        }
        let block_copy = self.rt.exec(&format!("{}.kv_block_copy", self.name))?;
        Ok(Some(PagedArtifacts {
            decode,
            installs,
            block_copy,
            block: g.kvblock,
            nblk: g.kvpool,
            maxblk: g.kv_maxblk(),
        }))
    }

    /// The speculative `verify@K` artifact set, or `None` when the
    /// manifest predates v5 or this model was lowered without the
    /// family. Execs come back ascending by K so
    /// [`VerifyArtifacts::bucket_for`] can first-fit.
    pub fn verify_artifacts(&self) -> Result<Option<VerifyArtifacts>> {
        if !self.rt.manifest.has_verify(&self.name) {
            return Ok(None);
        }
        let mut execs = Vec::new();
        for k in self.rt.manifest.verify_buckets(&self.name) {
            execs.push((k, self.rt.exec(&format!("{}.verify@{k}", self.name))?));
        }
        Ok(Some(VerifyArtifacts { execs }))
    }

    /// Single-request latency path (B=1 artifacts) — used by the Table 2
    /// driver and the latency benches. Returns the response and the
    /// number of decode steps executed. The single-stream KV cache is
    /// device-resident across iterations, same as the batched path.
    pub fn generate_one(&self, prompt: &[i32], seed: u32, temp: f32) -> Result<(Response, usize)> {
        let g = self.rt.manifest.globals;
        let prefill = self.rt.exec(&format!("{}.prefill1", self.name))?;
        let decode = self.rt.exec(&format!("{}.decode1", self.name))?;
        let n = self.params.len();
        let mut resident = self.params.resident_map();
        let cache_dims = vec![self.meta.layers, 1, g.sctx, self.meta.heads, self.meta.headdim];

        let mut ptoks = vec![tok::PAD; g.sprompt];
        ensure!(prompt.len() <= g.sprompt);
        ptoks[..prompt.len()].copy_from_slice(prompt);
        let ptoks = Tensor::i32(vec![1, g.sprompt], ptoks);
        let lens_t = Tensor::i32(vec![1], vec![prompt.len() as i32]);
        let seeds_t = Tensor::u32(vec![1], vec![seed]);
        let temp_t = Tensor::f32(vec![], vec![temp]);
        let host: Vec<(usize, &Tensor)> = vec![
            (n, &ptoks),
            (n + 1, &lens_t),
            (n + 2, &seeds_t),
            (n + 3, &temp_t),
        ];
        let mut outs = prefill.run_resident(&resident, &host)?;
        let vc = outs.pop().context("vcache")?;
        let kc = outs.pop().context("kcache")?;
        let mut lp_cur = outs.pop().context("logp")?.into_tensor()?.as_f32()?[0];
        let mut cur = outs.pop().context("next")?.into_tensor()?.as_i32()?[0];
        let mut kv = KvCache::from_outputs(kc, vc, &cache_dims)?;

        let mut tokens = Vec::new();
        let mut lps: Vec<f32> = Vec::new();
        let mut pos = prompt.len() as i32;
        let mut steps = 0usize;
        while cur != tok::EOS && tokens.len() + 1 < A_MAX && (pos as usize) < g.sctx - 1 {
            tokens.push(cur);
            lps.push(lp_cur);
            let cur_t = Tensor::i32(vec![1], vec![cur]);
            let pos_t = Tensor::i32(vec![1], vec![pos]);
            let step_t = Tensor::i32(vec![], vec![steps as i32 + 1]);
            let mut host: Vec<(usize, &Tensor)> = vec![
                (n + 2, &cur_t),
                (n + 3, &pos_t),
                (n + 4, &step_t),
                (n + 5, &seeds_t),
                (n + 6, &temp_t),
            ];
            kv.bind(n, n + 1, &mut resident, &mut host);
            let mut outs = decode.run_resident(&resident, &host)?;
            let vc = outs.pop().context("vcache")?;
            let kc = outs.pop().context("kcache")?;
            lp_cur = outs.pop().context("logp")?.into_tensor()?.as_f32()?[0];
            cur = outs.pop().context("next")?.into_tensor()?.as_i32()?[0];
            kv.update(kc, vc)?;
            pos += 1;
            steps += 1;
        }
        let mean_logprob = if lps.is_empty() {
            0.0
        } else {
            lps.iter().sum::<f32>() / lps.len() as f32
        };
        Ok((Response { tokens, mean_logprob }, steps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_sequence_layout() {
        let prompt = vec![tok::BOS, tok::TASK0, tok::COLON, 9, tok::SEP];
        let answer = vec![9];
        let (seq, mask) = build_sequence(16, &prompt, &answer).unwrap();
        assert_eq!(
            &seq[..7],
            &[tok::BOS, tok::TASK0, tok::COLON, 9, tok::SEP, 9, tok::EOS]
        );
        assert!(seq[7..].iter().all(|&t| t == tok::PAD));
        assert_eq!(&mask[..8], &[0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn build_sequence_rejects_overflow() {
        let prompt = vec![1; 10];
        let answer = vec![9; 10];
        assert!(build_sequence(16, &prompt, &answer).is_err());
        assert!(build_sequence(21, &prompt, &answer).is_ok());
    }

    #[test]
    fn lr_schedule_shape() {
        let base = 1e-2;
        assert!(lr_schedule(base, 0, 100, 10) < lr_schedule(base, 9, 100, 10));
        assert!((lr_schedule(base, 9, 100, 10) - base).abs() / base < 0.11);
        assert!(lr_schedule(base, 99, 100, 10) < 0.2 * base);
        assert!(lr_schedule(base, 99, 100, 10) >= 0.09 * base);
    }
}
