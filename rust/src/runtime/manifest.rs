//! Parser for `artifacts/manifest.txt` — the contract between the
//! build-time python AOT path and this runtime (see `python/compile/aot.py`
//! for the writer). Line-oriented, whitespace-separated; unknown versions
//! are rejected.
//!
//! Version history:
//! * **v1** — fused tuple outputs; `out` lines carry name/dtype/dims only
//!   (every output implicitly `data`, downloaded to the host).
//! * **v2** — untupled outputs; `out` lines carry a residency class as a
//!   fourth field (`state` outputs stay device-resident across decode
//!   iterations, see `Exec::run_resident`).
//! * **v3** — device-side admission: per-model **bucketed prefill**
//!   artifacts (`<model>.prefill@B` for power-of-two buckets up to
//!   `genb`; `prefill`/`prefill1` are aliases of the `@genb`/`@1`
//!   buckets) and **`<model>.kv_install@B`** scatter artifacts that
//!   write prefill-output KV slots into the persistent worker cache on
//!   device. No new line grammar — v3 parses like v2; the version
//!   advertises artifact availability ([`Manifest::prefill_buckets`],
//!   [`Manifest::kv_install_buckets`]).
//! * **v4** — block-paged KV cache: the `global` line gains the pool
//!   geometry (`kvblock` tokens per block, `kvpool` blocks per layer;
//!   both 0 on older manifests) and each LM gains `<model>.decode_paged`
//!   (decode over `[L, kvpool, kvblock, H, Dh]` pools + per-request
//!   block tables), `<model>.kv_install_paged@B` (paged admission
//!   scatter) and `<model>.kv_block_copy` (copy-on-extend block moves).
//!   Dense v3 artifacts are still present, so v4 runs either path.
//! * **v5** — speculative draft–verify: each LM gains a bucketed
//!   **`<model>.verify@K`** family (multi-token paged decode: K draft
//!   tokens appended per lane through the block tables, with the model's
//!   own next-token choice emitted at *every* appended position) for
//!   power-of-two draft lengths up to `kvblock`. No new line grammar —
//!   v5 parses like v4; the version advertises availability
//!   ([`Manifest::verify_buckets`], [`Manifest::has_verify`]). The
//!   hybrid decoder falls back to per-request routing on v1–v4.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::io::DType;

/// Newest manifest version this runtime understands — what the current
/// AOT writer (`python/compile/aot.py: MANIFEST_VERSION`) emits.
pub const SUPPORTED_VERSION: u32 = 5;
/// All versions this runtime can execute (older versions run through the
/// fused-tuple / host-surgery / dense-KV / routed-decode fallback paths).
pub const SUPPORTED_VERSIONS: [u32; 5] = [1, 2, 3, 4, SUPPORTED_VERSION];

/// Global dims shared by all artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Globals {
    pub vocab: usize,
    pub sctx: usize,
    pub sprompt: usize,
    pub amax: usize,
    pub genb: usize,
    pub trainb: usize,
    pub scoreb: usize,
    /// Tokens per KV block (manifest v4 paged cache; 0 on older manifests).
    pub kvblock: usize,
    /// Pool blocks per layer (manifest v4 paged cache; 0 on older manifests).
    pub kvpool: usize,
}

impl Globals {
    /// Blocks per request table: enough to cover the full context.
    /// 0 on pre-v4 manifests (no paged geometry).
    pub fn kv_maxblk(&self) -> usize {
        if self.kvblock == 0 {
            0
        } else {
            self.sctx / self.kvblock
        }
    }
}

/// Transformer dims of one roster entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelMeta {
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub headdim: usize,
    pub nparams: usize,
    pub has_head: bool,
}

/// Input classification (drives device-residency decisions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgClass {
    /// Model parameter — resident on device between calls.
    Param,
    /// Optimizer state — resident during training.
    Opt,
    /// Mutable model state (KV caches). As an *input* class it marks
    /// tensors a caller may hold device-resident between calls; as an
    /// *output* class (manifest v2) it marks outputs `Exec::run_resident`
    /// leaves on device instead of downloading (see DESIGN.md §8).
    State,
    /// Per-call data (tokens, seeds, temperatures, ...).
    Data,
}

impl ArgClass {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "param" => ArgClass::Param,
            "opt" => ArgClass::Opt,
            "state" => ArgClass::State,
            "data" => ArgClass::Data,
            _ => bail!("unknown arg class {s}"),
        })
    }
}

/// One input or output tensor of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    /// Empty = scalar.
    pub dims: Vec<usize>,
    pub class: ArgClass,
}

impl IoSpec {
    pub fn elem_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-lowered HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub ins: Vec<IoSpec>,
    pub outs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Indices of inputs with the given class, in order.
    pub fn input_indices(&self, class: ArgClass) -> Vec<usize> {
        self.ins
            .iter()
            .enumerate()
            .filter(|(_, s)| s.class == class)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.ins
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {}: no input named {name}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {}: no output named {name}", self.name))
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest format version (one of [`SUPPORTED_VERSIONS`]).
    pub version: u32,
    pub globals: Globals,
    pub models: BTreeMap<String, ModelMeta>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "f32" => DType::F32,
        "s32" => DType::I32,
        "u32" => DType::U32,
        _ => bail!("unknown dtype {s}"),
    })
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

fn kvmap<'a>(parts: &'a [&'a str]) -> BTreeMap<&'a str, &'a str> {
    parts
        .chunks_exact(2)
        .map(|c| (c[0], c[1]))
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut globals = None;
        let mut models = BTreeMap::new();
        let mut artifacts: BTreeMap<String, ArtifactSpec> = BTreeMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        let mut saw_end = false;
        let mut version: Option<u32> = None;

        for (lineno, line) in text.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}", lineno + 1);
            match parts.first().copied() {
                None => {}
                Some("version") => {
                    let v: u32 = parts.get(1).context("version missing")?.parse()?;
                    if !SUPPORTED_VERSIONS.contains(&v) {
                        bail!(
                            "unsupported manifest version {v} (supported: {SUPPORTED_VERSIONS:?})"
                        );
                    }
                    version = Some(v);
                }
                Some("global") => {
                    let m = kvmap(&parts[1..]);
                    let g = |k: &str| -> Result<usize> {
                        m.get(k)
                            .with_context(|| format!("global {k} missing"))?
                            .parse()
                            .context("bad global")
                    };
                    // kvblock/kvpool appear from v4 on; default 0 so
                    // v1–v3 global lines keep parsing unchanged
                    let opt = |k: &str| -> Result<usize> {
                        m.get(k).map_or(Ok(0), |v| v.parse().context("bad global"))
                    };
                    globals = Some(Globals {
                        vocab: g("vocab")?,
                        sctx: g("sctx")?,
                        sprompt: g("sprompt")?,
                        amax: g("amax")?,
                        genb: g("genb")?,
                        trainb: g("trainb")?,
                        scoreb: g("scoreb")?,
                        kvblock: opt("kvblock")?,
                        kvpool: opt("kvpool")?,
                    });
                }
                Some("model") => {
                    let name = parts.get(1).with_context(ctx)?.to_string();
                    let m = kvmap(&parts[2..]);
                    let g = |k: &str| -> Result<usize> {
                        m.get(k)
                            .with_context(|| format!("model {name}: {k} missing"))?
                            .parse()
                            .context("bad model field")
                    };
                    models.insert(
                        name.clone(),
                        ModelMeta {
                            d: g("d")?,
                            layers: g("layers")?,
                            heads: g("heads")?,
                            ff: g("ff")?,
                            headdim: g("headdim")?,
                            nparams: g("nparams")?,
                            has_head: g("head")? == 1,
                        },
                    );
                }
                Some("artifact") => {
                    if let Some(a) = cur.take() {
                        artifacts.insert(a.name.clone(), a);
                    }
                    // artifact <name> file <fname>
                    let name = parts.get(1).with_context(ctx)?.to_string();
                    let file = parts.get(3).with_context(ctx)?.to_string();
                    cur = Some(ArtifactSpec { name, file, ins: vec![], outs: vec![] });
                }
                Some("in") => {
                    let a = cur.as_mut().with_context(ctx)?;
                    a.ins.push(IoSpec {
                        name: parts.get(1).with_context(ctx)?.to_string(),
                        dtype: parse_dtype(parts.get(2).with_context(ctx)?)?,
                        dims: parse_dims(parts.get(3).with_context(ctx)?)?,
                        class: ArgClass::parse(parts.get(4).with_context(ctx)?)?,
                    });
                }
                Some("out") => {
                    let a = cur.as_mut().with_context(ctx)?;
                    // v1 out lines carry no class (implicitly `data`);
                    // v2 appends the residency class as a fourth field
                    let class = match parts.get(4) {
                        Some(c) => ArgClass::parse(c)?,
                        None => ArgClass::Data,
                    };
                    a.outs.push(IoSpec {
                        name: parts.get(1).with_context(ctx)?.to_string(),
                        dtype: parse_dtype(parts.get(2).with_context(ctx)?)?,
                        dims: parse_dims(parts.get(3).with_context(ctx)?)?,
                        class,
                    });
                }
                Some("end") => saw_end = true,
                Some(other) => bail!("{}: unknown directive {other}", ctx()),
            }
        }
        if let Some(a) = cur.take() {
            artifacts.insert(a.name.clone(), a);
        }
        let version = version.context("manifest missing version line")?;
        if !saw_end {
            bail!("manifest truncated (missing `end`)");
        }
        Ok(Manifest {
            version,
            globals: globals.context("manifest missing global line")?,
            models,
            artifacts,
        })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Manifest::parse(&text).with_context(|| format!("parse {path:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("manifest has no artifact {name}"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model {name}"))
    }

    /// Parameter names (without the `p.` prefix) of a model, in artifact
    /// order, derived from its `init` artifact outputs.
    pub fn param_names(&self, model: &str) -> Result<Vec<String>> {
        let a = self.artifact(&format!("{model}.init"))?;
        Ok(a.outs
            .iter()
            .map(|o| o.name.strip_prefix("p.").unwrap_or(&o.name).to_string())
            .collect())
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Batch sizes of a model's bucketed `<model>.<kind>@B` artifacts,
    /// ascending. Empty on pre-v3 manifests (no bucketed artifacts).
    fn bucket_sizes(&self, model: &str, kind: &str) -> Vec<usize> {
        let prefix = format!("{model}.{kind}@");
        let mut out: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix(&prefix)?.parse().ok())
            .collect();
        out.sort_unstable();
        out
    }

    /// Bucketed-prefill batch sizes for `model` (manifest v3), ascending.
    pub fn prefill_buckets(&self, model: &str) -> Vec<usize> {
        self.bucket_sizes(model, "prefill")
    }

    /// `kv_install` scatter batch sizes for `model` (manifest v3),
    /// ascending. Admission can go fully device-side for a group of `n`
    /// requests iff [`bucket_for`] finds a bucket in *both* this list and
    /// [`Self::prefill_buckets`].
    pub fn kv_install_buckets(&self, model: &str) -> Vec<usize> {
        self.bucket_sizes(model, "kv_install")
    }

    /// `kv_install_paged` scatter batch sizes for `model` (manifest v4),
    /// ascending. Empty on pre-v4 manifests.
    pub fn kv_install_paged_buckets(&self, model: &str) -> Vec<usize> {
        self.bucket_sizes(model, "kv_install_paged")
    }

    /// True when `model` ships the full paged-KV artifact set (manifest
    /// v4): paged decode, at least one paged install bucket, and the
    /// copy-on-extend block mover, plus nonzero pool geometry.
    pub fn has_paged_kv(&self, model: &str) -> bool {
        self.globals.kvblock > 0
            && self.globals.kvpool > 0
            && self.has_artifact(&format!("{model}.decode_paged"))
            && self.has_artifact(&format!("{model}.kv_block_copy"))
            && !self.kv_install_paged_buckets(model).is_empty()
    }

    /// `verify@K` draft-length buckets for `model` (manifest v5),
    /// ascending. Empty on pre-v5 manifests.
    pub fn verify_buckets(&self, model: &str) -> Vec<usize> {
        self.bucket_sizes(model, "verify")
    }

    /// True when `model` can act as the verifier tier of the hybrid
    /// draft–verify loop (manifest v5): at least one `verify@K` bucket
    /// on top of the full paged-KV set the verifier's lanes live in.
    pub fn has_verify(&self, model: &str) -> bool {
        self.has_paged_kv(model) && !self.verify_buckets(model).is_empty()
    }
}

/// Smallest bucket `>= n` from an ascending bucket list (admission
/// bucket selection: prefill runs at this batch size instead of the full
/// generation batch). `None` when `n` exceeds every bucket or the list
/// is empty (pre-v3 manifests).
pub fn bucket_for(buckets: &[usize], n: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
version 1
global vocab 64 sctx 64 sprompt 40 amax 24 genb 16 trainb 32 scoreb 32
model nano d 32 layers 1 heads 2 ff 64 headdim 16 nparams 2 head 0
artifact nano.init file nano.init.hlo.txt
in seed u32 scalar data
out p.emb f32 64x32
out p.pos f32 64x32
artifact nano.fwd file nano.fwd.hlo.txt
in p.emb f32 64x32 param
in p.pos f32 64x32 param
in tok s32 16 data
out logits f32 16x64
end
";

    const SAMPLE_V2: &str = "\
version 2
global vocab 64 sctx 64 sprompt 40 amax 24 genb 16 trainb 32 scoreb 32
model nano d 32 layers 1 heads 2 ff 64 headdim 16 nparams 2 head 0
artifact nano.decode file nano.decode.hlo.txt
in p.emb f32 64x32 param
in kcache f32 1x16x64x2x16 state
in vcache f32 1x16x64x2x16 state
in tok s32 16 data
out next s32 16 data
out logp f32 16 data
out kcache f32 1x16x64x2x16 state
out vcache f32 1x16x64x2x16 state
end
";

    const SAMPLE_V3: &str = "\
version 3
global vocab 64 sctx 64 sprompt 40 amax 24 genb 4 trainb 32 scoreb 32
model nano d 32 layers 1 heads 2 ff 64 headdim 16 nparams 2 head 0
artifact nano.prefill@1 file nano.prefill@1.hlo.txt
in prompt s32 1x40 data
out next s32 1 data
out logp f32 1 data
out kcache f32 1x1x64x2x16 state
out vcache f32 1x1x64x2x16 state
artifact nano.prefill@2 file nano.prefill@2.hlo.txt
in prompt s32 2x40 data
out next s32 2 data
out logp f32 2 data
out kcache f32 1x2x64x2x16 state
out vcache f32 1x2x64x2x16 state
artifact nano.prefill@4 file nano.prefill@4.hlo.txt
in prompt s32 4x40 data
out next s32 4 data
out logp f32 4 data
out kcache f32 1x4x64x2x16 state
out vcache f32 1x4x64x2x16 state
artifact nano.prefill file nano.prefill@4.hlo.txt
in prompt s32 4x40 data
out next s32 4 data
out logp f32 4 data
out kcache f32 1x4x64x2x16 state
out vcache f32 1x4x64x2x16 state
artifact nano.kv_install@2 file nano.kv_install@2.hlo.txt
in kcache f32 1x4x64x2x16 state
in vcache f32 1x4x64x2x16 state
in src_k f32 1x2x64x2x16 state
in src_v f32 1x2x64x2x16 state
in slots s32 2 data
in count s32 scalar data
out kcache f32 1x4x64x2x16 state
out vcache f32 1x4x64x2x16 state
end
";

    const SAMPLE_V4: &str = "\
version 4
global vocab 64 sctx 64 sprompt 40 amax 24 genb 4 trainb 32 scoreb 32 kvblock 8 kvpool 41
model nano d 32 layers 1 heads 2 ff 64 headdim 16 nparams 2 head 0
artifact nano.decode_paged file nano.decode_paged.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in tables s32 4x8 data
in tok s32 4 data
out next s32 4 data
out logp f32 4 data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
artifact nano.kv_install_paged@2 file nano.kv_install_paged@2.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in src_k f32 1x2x64x2x16 state
in src_v f32 1x2x64x2x16 state
in dst_tables s32 2x8 data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
artifact nano.kv_block_copy file nano.kv_block_copy.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in src s32 4 data
in dst s32 4 data
in count s32 scalar data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
end
";

    const SAMPLE_V5: &str = "\
version 5
global vocab 64 sctx 64 sprompt 40 amax 24 genb 4 trainb 32 scoreb 32 kvblock 8 kvpool 41
model nano d 32 layers 1 heads 2 ff 64 headdim 16 nparams 2 head 0
artifact nano.decode_paged file nano.decode_paged.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in tables s32 4x8 data
in tok s32 4 data
out next s32 4 data
out logp f32 4 data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
artifact nano.kv_install_paged@2 file nano.kv_install_paged@2.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in src_k f32 1x2x64x2x16 state
in src_v f32 1x2x64x2x16 state
in dst_tables s32 2x8 data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
artifact nano.kv_block_copy file nano.kv_block_copy.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in src s32 4 data
in dst s32 4 data
in count s32 scalar data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
artifact nano.verify@2 file nano.verify@2.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in tables s32 4x8 data
in toks s32 4x2 data
in pos s32 4 data
out next s32 4x2 data
out logp f32 4x2 data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
artifact nano.verify@4 file nano.verify@4.hlo.txt
in kcache f32 1x41x8x2x16 state
in vcache f32 1x41x8x2x16 state
in tables s32 4x8 data
in toks s32 4x4 data
in pos s32 4 data
out next s32 4x4 data
out logp f32 4x4 data
out kcache f32 1x41x8x2x16 state
out vcache f32 1x41x8x2x16 state
end
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.globals.vocab, 64);
        assert_eq!(m.globals.genb, 16);
        assert_eq!(m.models["nano"].d, 32);
        assert!(!m.models["nano"].has_head);
        let a = m.artifact("nano.fwd").unwrap();
        assert_eq!(a.ins.len(), 3);
        assert_eq!(a.ins[2].dims, vec![16]);
        assert_eq!(a.ins[2].class, ArgClass::Data);
        assert_eq!(a.input_indices(ArgClass::Param), vec![0, 1]);
        assert_eq!(a.output_index("logits").unwrap(), 0);
        assert_eq!(m.param_names("nano").unwrap(), vec!["emb", "pos"]);
    }

    #[test]
    fn scalar_dims_empty() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("nano.init").unwrap();
        assert!(a.ins[0].dims.is_empty());
        assert_eq!(a.ins[0].elem_count(), 1);
    }

    #[test]
    fn v1_outs_default_to_data_class() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("nano.fwd").unwrap();
        assert!(a.outs.iter().all(|o| o.class == ArgClass::Data));
    }

    #[test]
    fn v2_out_classes_parse() {
        let m = Manifest::parse(SAMPLE_V2).unwrap();
        assert_eq!(m.version, 2);
        let a = m.artifact("nano.decode").unwrap();
        assert_eq!(a.outs.len(), 4);
        assert_eq!(a.outs[0].class, ArgClass::Data);
        assert_eq!(a.outs[1].class, ArgClass::Data);
        assert_eq!(a.outs[2].class, ArgClass::State);
        assert_eq!(a.outs[3].class, ArgClass::State);
        assert_eq!(a.output_index("kcache").unwrap(), 2);
        assert_eq!(a.ins[1].class, ArgClass::State);
        assert_eq!(a.outs[2].dims, vec![1, 16, 64, 2, 16]);
    }

    #[test]
    fn v3_bucketed_artifacts_discovered() {
        let m = Manifest::parse(SAMPLE_V3).unwrap();
        assert_eq!(m.version, 3);
        assert_eq!(m.prefill_buckets("nano"), vec![1, 2, 4]);
        assert_eq!(m.kv_install_buckets("nano"), vec![2]);
        // the alias resolves to the same file as the @genb bucket
        assert_eq!(
            m.artifact("nano.prefill").unwrap().file,
            m.artifact("nano.prefill@4").unwrap().file
        );
        assert!(m.has_artifact("nano.kv_install@2"));
        assert!(!m.has_artifact("nano.kv_install@1"));
        // install spec names resolve for index lookups
        let inst = m.artifact("nano.kv_install@2").unwrap();
        assert_eq!(inst.input_index("slots").unwrap(), 4);
        assert_eq!(inst.input_index("count").unwrap(), 5);
        assert_eq!(inst.ins[2].class, ArgClass::State);
        // pre-v3 manifests advertise no buckets
        let v2 = Manifest::parse(SAMPLE_V2).unwrap();
        assert!(v2.prefill_buckets("nano").is_empty());
        assert!(v2.kv_install_buckets("nano").is_empty());
    }

    #[test]
    fn v4_paged_geometry_and_artifacts() {
        let m = Manifest::parse(SAMPLE_V4).unwrap();
        assert_eq!(m.version, 4);
        assert_eq!(m.globals.kvblock, 8);
        assert_eq!(m.globals.kvpool, 41);
        assert_eq!(m.globals.kv_maxblk(), 8);
        assert_eq!(m.kv_install_paged_buckets("nano"), vec![2]);
        assert!(m.has_paged_kv("nano"));
        let dp = m.artifact("nano.decode_paged").unwrap();
        assert_eq!(dp.input_index("tables").unwrap(), 2);
        assert_eq!(dp.ins[2].dims, vec![4, 8]);
        assert_eq!(dp.outs[2].class, ArgClass::State);
        let inst = m.artifact("nano.kv_install_paged@2").unwrap();
        assert_eq!(inst.input_index("dst_tables").unwrap(), 4);
        // pre-v4 manifests: zero geometry, no paged path, and the
        // paged bucket scan does not collide with the dense one
        let v3 = Manifest::parse(SAMPLE_V3).unwrap();
        assert_eq!(v3.globals.kvblock, 0);
        assert_eq!(v3.globals.kvpool, 0);
        assert_eq!(v3.globals.kv_maxblk(), 0);
        assert!(v3.kv_install_paged_buckets("nano").is_empty());
        assert!(!v3.has_paged_kv("nano"));
        assert_eq!(m.kv_install_buckets("nano"), Vec::<usize>::new());
    }

    #[test]
    fn v5_verify_buckets_discovered() {
        let m = Manifest::parse(SAMPLE_V5).unwrap();
        assert_eq!(m.version, 5);
        assert_eq!(m.verify_buckets("nano"), vec![2, 4]);
        assert!(m.has_verify("nano"));
        let v = m.artifact("nano.verify@2").unwrap();
        assert_eq!(v.input_index("toks").unwrap(), 3);
        assert_eq!(v.ins[3].dims, vec![4, 2]);
        assert_eq!(v.output_index("next").unwrap(), 0);
        assert_eq!(v.outs[0].dims, vec![4, 2]);
        assert_eq!(v.outs[2].class, ArgClass::State);
        // the verify scan never collides with other bucket families,
        // and pre-v5 manifests advertise neither buckets nor the kit
        assert_eq!(m.kv_install_paged_buckets("nano"), vec![2]);
        let v4 = Manifest::parse(SAMPLE_V4).unwrap();
        assert!(v4.verify_buckets("nano").is_empty());
        assert!(!v4.has_verify("nano"));
        // verify without the paged-KV base set is not a verifier
        let no_paged = SAMPLE_V5.replace("artifact nano.kv_block_copy", "artifact nano.kv_other");
        let m2 = Manifest::parse(&no_paged).unwrap();
        assert!(!m2.has_verify("nano"));
    }

    #[test]
    fn bucket_selection_picks_smallest_fit() {
        let buckets = [1, 2, 4, 8, 16];
        assert_eq!(bucket_for(&buckets, 1), Some(1));
        assert_eq!(bucket_for(&buckets, 2), Some(2));
        assert_eq!(bucket_for(&buckets, 3), Some(4));
        assert_eq!(bucket_for(&buckets, 5), Some(8));
        assert_eq!(bucket_for(&buckets, 8), Some(8));
        assert_eq!(bucket_for(&buckets, 16), Some(16));
        // over the largest bucket or with no buckets at all: no fit
        assert_eq!(bucket_for(&buckets, 17), None);
        assert_eq!(bucket_for(&[], 1), None);
        // non-power-of-two lists (genb not a power of two) still work
        assert_eq!(bucket_for(&[1, 2, 3], 3), Some(3));
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("version 1", "version 99");
        assert!(Manifest::parse(&bad).is_err());
        // all shipped versions parse
        assert!(Manifest::parse(SAMPLE).is_ok());
        assert!(Manifest::parse(SAMPLE_V2).is_ok());
        assert!(Manifest::parse(SAMPLE_V3).is_ok());
        assert!(Manifest::parse(SAMPLE_V4).is_ok());
        assert!(Manifest::parse(SAMPLE_V5).is_ok());
    }

    #[test]
    fn rejects_truncated() {
        let bad = SAMPLE.replace("end\n", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_directive() {
        let bad = format!("{SAMPLE}\nwhatever 3\n");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(m.artifacts.len() >= 38, "{}", m.artifacts.len());
            for name in ["nano", "micro", "small", "medium", "large"] {
                for kind in ["init", "prefill", "decode", "train"] {
                    assert!(m.artifacts.contains_key(&format!("{name}.{kind}")));
                }
            }
        }
    }
}
