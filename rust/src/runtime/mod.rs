//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client (the `xla` crate). This is the only module that
//! touches XLA; everything above it works with [`crate::io::Tensor`]s or
//! opaque device buffers ([`OutValue`]).
//!
//! ## Residency model
//!
//! * **Parameters** live on device as [`xla::PjRtBuffer`]s ([`ParamSet`]),
//!   uploaded once (or after each train step) — the hot path never
//!   re-uploads weights (`execute_b`).
//! * **Outputs** are emitted *untupled* by the AOT path (manifest v2,
//!   `return_tuple=False` in `python/compile/aot.py`), so every output is
//!   its own `PjRtBuffer`. [`Exec::run_resident`] downloads only the
//!   outputs a caller can read (`data` class — sampled tokens, logprobs,
//!   scores) and hands back `state`-class outputs (KV caches) as
//!   device-resident buffers that feed straight into the next
//!   `execute_b` call. Steady-state decode therefore moves O(B) bytes
//!   per token across the host boundary instead of O(L·B·S·H·Dh).
//! * **Host fallback**: artifacts lowered before manifest v2 return one
//!   fused tuple buffer that this API cannot split on-device; for those
//!   every output falls back to a host download (`OutValue::Host`) and
//!   callers transparently get the seed's host-round-trip behavior.
//! * **Admission** (manifest v3): bucketed `prefill@B` artifacts plus a
//!   `kv_install@B` scatter let the serving layer install freshly
//!   prefilled KV slots into the persistent worker cache entirely on
//!   device ([`crate::batching::KvCache::install_slots_device`]) — the
//!   per-admission host traffic is O(B·sprompt) prompt bytes, not the
//!   full-cache round-trip the host-surgery fallback pays.
//!
//! All host↔device traffic through this module is metered by
//! [`TransferCounters`] (`Runtime::transfers`), which is how the benches
//! and integration tests assert the zero-copy property.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::io::{DType, Tensor};
pub use manifest::{bucket_for, ArgClass, ArtifactSpec, Globals, IoSpec, Manifest, ModelMeta};

/// Every supported element type (f32/s32/u32) is 4 bytes wide.
pub const ELEM_BYTES: usize = 4;

fn tensor_bytes(t: &Tensor) -> u64 {
    (t.len() * ELEM_BYTES) as u64
}

/// Convert a host tensor to an XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    #[cfg(target_endian = "little")]
    {
        // In-memory scalar layout on LE targets is already the LE byte
        // stream PJRT expects: reinterpret the payload in bulk instead of
        // converting element by element.
        let (ty, bytes): (xla::ElementType, &[u8]) = match t {
            Tensor::F32 { data, .. } => (xla::ElementType::F32, unsafe { data.align_to::<u8>().1 }),
            Tensor::I32 { data, .. } => (xla::ElementType::S32, unsafe { data.align_to::<u8>().1 }),
            Tensor::U32 { data, .. } => (xla::ElementType::U32, unsafe { data.align_to::<u8>().1 }),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, t.dims(), bytes)
            .map_err(|e| anyhow::anyhow!("literal create: {e}"))
    }
    #[cfg(not(target_endian = "little"))]
    {
        let (ty, bytes): (xla::ElementType, Vec<u8>) = match t {
            Tensor::F32 { data, .. } => (
                xla::ElementType::F32,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            Tensor::I32 { data, .. } => (
                xla::ElementType::S32,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            Tensor::U32 { data, .. } => (
                xla::ElementType::U32,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, t.dims(), &bytes)
            .map_err(|e| anyhow::anyhow!("literal create: {e}"))
    }
}

/// Convert an XLA literal back to a host tensor (bulk `to_vec` copy).
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow::anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = l.ty().map_err(|e| anyhow::anyhow!("ty: {e}"))?;
    Ok(match ty {
        xla::ElementType::F32 => Tensor::f32(
            dims,
            l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))?,
        ),
        xla::ElementType::S32 => Tensor::i32(
            dims,
            l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec i32: {e}"))?,
        ),
        xla::ElementType::U32 => Tensor::u32(
            dims,
            l.to_vec::<u32>().map_err(|e| anyhow::anyhow!("to_vec u32: {e}"))?,
        ),
        other => bail!("unsupported element type {other:?}"),
    })
}

/// Download a device buffer into a host tensor. Prefer [`Runtime::download`]
/// where a runtime is at hand so the transfer is metered.
pub fn download_buffer(buf: &xla::PjRtBuffer) -> Result<Tensor> {
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("download: {e}"))?;
    literal_to_tensor(&lit)
}

fn dtype_matches(spec: DType, t: &Tensor) -> bool {
    spec == t.dtype()
}

/// Upload a host tensor synchronously.
///
/// IMPORTANT: this must use `buffer_from_host_buffer` (semantics
/// `kImmutableOnlyDuringCall`, i.e. the copy completes before returning)
/// and NOT `buffer_from_host_literal`, whose H2D transfer is *async* and
/// requires the literal to outlive it — dropping the literal right after
/// (as a naive wrapper would) is a use-after-free that corrupts weights.
fn upload_tensor(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    let r = match t {
        Tensor::F32 { dims, data } => client.buffer_from_host_buffer::<f32>(data, dims, None),
        Tensor::I32 { dims, data } => client.buffer_from_host_buffer::<i32>(data, dims, None),
        Tensor::U32 { dims, data } => client.buffer_from_host_buffer::<u32>(data, dims, None),
    };
    r.map_err(|e| anyhow::anyhow!("upload: {e}"))
}

/// Cumulative host↔device traffic in bytes, shared by a [`Runtime`] and
/// every [`Exec`] it compiles. Relaxed counters: they feed perf reports
/// and residency assertions, not control flow.
#[derive(Default)]
pub struct TransferCounters {
    h2d: AtomicU64,
    d2h: AtomicU64,
}

/// Point-in-time copy of [`TransferCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TransferSnapshot {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

impl TransferCounters {
    fn add_h2d(&self, bytes: u64) {
        self.h2d.fetch_add(bytes, Ordering::Relaxed);
    }

    fn add_d2h(&self, bytes: u64) {
        self.d2h.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: self.h2d.load(Ordering::Relaxed),
            d2h_bytes: self.d2h.load(Ordering::Relaxed),
        }
    }
}

impl TransferSnapshot {
    /// Traffic between two snapshots (`later - self`).
    pub fn delta(&self, later: TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            h2d_bytes: later.h2d_bytes.saturating_sub(self.h2d_bytes),
            d2h_bytes: later.d2h_bytes.saturating_sub(self.d2h_bytes),
        }
    }
}

/// One output of [`Exec::run_resident`]: either downloaded to the host
/// (`data`/`param`/`opt` classes) or left resident on the device
/// (`state` class — KV caches on the decode hot path).
pub enum OutValue {
    Host(Tensor),
    Device(Arc<xla::PjRtBuffer>),
}

impl OutValue {
    /// Host tensor, downloading first when device-resident.
    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            OutValue::Host(t) => Ok(t),
            OutValue::Device(b) => download_buffer(&b),
        }
    }

    pub fn device(&self) -> Option<&Arc<xla::PjRtBuffer>> {
        match self {
            OutValue::Host(_) => None,
            OutValue::Device(b) => Some(b),
        }
    }

    pub fn is_device(&self) -> bool {
        matches!(self, OutValue::Device(_))
    }
}

/// A compiled artifact plus its manifest spec.
pub struct Exec {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    transfers: Arc<TransferCounters>,
}

impl Exec {
    /// Validate `ins` against the manifest spec (shape + dtype + count).
    fn validate(&self, ins: &[&Tensor]) -> Result<()> {
        if ins.len() != self.spec.ins.len() {
            bail!(
                "artifact {}: got {} inputs, expected {}",
                self.spec.name,
                ins.len(),
                self.spec.ins.len()
            );
        }
        for (t, s) in ins.iter().zip(&self.spec.ins) {
            if !dtype_matches(s.dtype, t) {
                bail!("artifact {} input {}: dtype mismatch", self.spec.name, s.name);
            }
            if t.dims() != s.dims.as_slice() {
                bail!(
                    "artifact {} input {}: dims {:?}, expected {:?}",
                    self.spec.name,
                    s.name,
                    t.dims(),
                    s.dims
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors (uploads everything; convenient path).
    pub fn run(&self, ins: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.validate(ins)?;
        let literals: Vec<xla::Literal> =
            ins.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        self.transfers
            .add_h2d(ins.iter().map(|t| tensor_bytes(t)).sum());
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.spec.name))?;
        let outs = self.first_device_outputs(bufs)?;
        self.collect_host(outs)
    }

    /// Execute with a mix of device-resident buffers and host tensors,
    /// downloading *every* output. Convenience wrapper over
    /// [`Self::run_resident`] for artifacts without `state` outputs
    /// (router/scorer forward passes, tests).
    pub fn run_with_resident(
        &self,
        resident: &HashMap<usize, Arc<xla::PjRtBuffer>>,
        host: &[(usize, &Tensor)],
    ) -> Result<Vec<Tensor>> {
        self.run_resident(resident, host)?
            .into_iter()
            .map(|o| o.into_tensor())
            .collect()
    }

    /// Buffer-level execution for the decode hot path: `resident[i]`
    /// provides input `i` as a device buffer (params, KV caches), `host`
    /// tensors are uploaded, and each output comes back as an
    /// [`OutValue`] — `state`-class outputs stay on device, everything
    /// else is downloaded. Pre-v2 (fused-tuple) artifacts fall back to
    /// downloading all outputs as `OutValue::Host`.
    pub fn run_resident(
        &self,
        resident: &HashMap<usize, Arc<xla::PjRtBuffer>>,
        host: &[(usize, &Tensor)],
    ) -> Result<Vec<OutValue>> {
        let args = self.assemble(resident, host)?;
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| a.as_ref()).collect();
        let bufs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&arg_refs)
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e}", self.spec.name))?;
        let outs = self.first_device_outputs(bufs)?;
        if outs.len() == self.spec.outs.len() && outs.len() > 1 {
            // untupled outputs: one buffer per manifest `out` line;
            // download selectively by residency class
            outs.into_iter()
                .zip(&self.spec.outs)
                .map(|(b, spec)| {
                    if spec.class == ArgClass::State {
                        Ok(OutValue::Device(Arc::new(b)))
                    } else {
                        let t = self.download_one(&b)?;
                        Ok(OutValue::Host(t))
                    }
                })
                .collect()
        } else {
            // fused tuple (or single output): host fallback
            Ok(self.collect_host(outs)?.into_iter().map(OutValue::Host).collect())
        }
    }

    /// Upload + slot assembly shared by the buffer-level paths.
    fn assemble(
        &self,
        resident: &HashMap<usize, Arc<xla::PjRtBuffer>>,
        host: &[(usize, &Tensor)],
    ) -> Result<Vec<Arc<xla::PjRtBuffer>>> {
        let client = self.exe.client();
        let mut slots: Vec<Option<Arc<xla::PjRtBuffer>>> = vec![None; self.spec.ins.len()];
        for (i, b) in resident {
            if *i >= slots.len() {
                bail!("artifact {}: resident input {i} out of range", self.spec.name);
            }
            slots[*i] = Some(b.clone());
        }
        for (i, t) in host {
            if *i >= slots.len() {
                bail!("artifact {}: host input {i} out of range", self.spec.name);
            }
            let spec = &self.spec.ins[*i];
            if t.dims() != spec.dims.as_slice() || !dtype_matches(spec.dtype, t) {
                bail!("artifact {} input {}: shape/dtype mismatch", self.spec.name, spec.name);
            }
            let buf = upload_tensor(client, t).with_context(|| format!("upload {}", spec.name))?;
            self.transfers.add_h2d(tensor_bytes(t));
            slots[*i] = Some(Arc::new(buf));
        }
        let mut args: Vec<Arc<xla::PjRtBuffer>> = Vec::with_capacity(slots.len());
        for (i, s) in slots.into_iter().enumerate() {
            match s {
                Some(b) => args.push(b),
                None => bail!(
                    "artifact {}: input {} ({}) not provided",
                    self.spec.name,
                    i,
                    self.spec.ins[i].name
                ),
            }
        }
        Ok(args)
    }

    /// Outputs of the single addressable device, with count sanity-check.
    fn first_device_outputs(
        &self,
        mut bufs: Vec<Vec<xla::PjRtBuffer>>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        if bufs.is_empty() || bufs[0].is_empty() {
            bail!("artifact {}: execution produced no outputs", self.spec.name);
        }
        let outs = bufs.remove(0);
        if outs.len() != 1 && outs.len() != self.spec.outs.len() {
            bail!(
                "artifact {}: got {} output buffers, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outs.len()
            );
        }
        Ok(outs)
    }

    /// Metered single-buffer download.
    fn download_one(&self, buf: &xla::PjRtBuffer) -> Result<Tensor> {
        let t = download_buffer(buf)
            .with_context(|| format!("download output of {}", self.spec.name))?;
        self.transfers.add_d2h(tensor_bytes(&t));
        Ok(t)
    }

    /// Download every output as a host tensor, handling both the
    /// untupled (one buffer per output) and the fused-tuple layouts.
    fn collect_host(&self, outs: Vec<xla::PjRtBuffer>) -> Result<Vec<Tensor>> {
        if outs.len() == self.spec.outs.len() && outs.len() > 1 {
            return outs.iter().map(|b| self.download_one(b)).collect();
        }
        // single buffer: either the sole (untupled) output or a fused tuple
        let lit = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download {}: {e}", self.spec.name))?;
        if self.spec.outs.len() == 1 {
            if let Ok(t) = literal_to_tensor(&lit) {
                self.transfers.add_d2h(tensor_bytes(&t));
                return Ok(vec![t]);
            }
        }
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e}", self.spec.name))?;
        if parts.len() != self.spec.outs.len() {
            bail!(
                "artifact {}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outs.len()
            );
        }
        let ts: Vec<Tensor> = parts.iter().map(literal_to_tensor).collect::<Result<_>>()?;
        self.transfers
            .add_d2h(ts.iter().map(tensor_bytes).sum());
        Ok(ts)
    }
}

/// The runtime: PJRT client + manifest + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Exec>>>,
    transfers: Arc<TransferCounters>,
}

impl Runtime {
    /// Load the manifest from `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Arc<Runtime>> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        if manifest.globals.vocab != crate::tokenizer::VOCAB {
            bail!(
                "manifest vocab {} != tokenizer VOCAB {}",
                manifest.globals.vocab,
                crate::tokenizer::VOCAB
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Arc::new(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
            transfers: Arc::new(TransferCounters::default()),
        }))
    }

    /// Default artifacts directory (`$HYBRID_LLM_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HYBRID_LLM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Cumulative host↔device traffic through this runtime (all execs).
    pub fn transfers(&self) -> TransferSnapshot {
        self.transfers.snapshot()
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn exec(&self, name: &str) -> Result<Arc<Exec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        let exec = Arc::new(Exec { spec, exe, transfers: self.transfers.clone() });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Upload a host tensor to a device buffer (synchronous, metered).
    pub fn upload(&self, t: &Tensor) -> Result<Arc<xla::PjRtBuffer>> {
        let buf = upload_tensor(&self.client, t)?;
        self.transfers.add_h2d(tensor_bytes(t));
        Ok(Arc::new(buf))
    }

    /// Download a device buffer to a host tensor (synchronous, metered).
    pub fn download(&self, buf: &xla::PjRtBuffer) -> Result<Tensor> {
        let t = download_buffer(buf)?;
        self.transfers.add_d2h(tensor_bytes(&t));
        Ok(t)
    }
}

/// A named set of model parameters: host copies (for persistence) plus
/// device-resident buffers (for `execute_b` hot paths).
pub struct ParamSet {
    pub names: Vec<String>,
    pub host: Vec<Tensor>,
    pub device: Vec<Arc<xla::PjRtBuffer>>,
}

impl ParamSet {
    /// Build from host tensors, uploading each to the device.
    pub fn from_host(rt: &Runtime, names: Vec<String>, host: Vec<Tensor>) -> Result<ParamSet> {
        anyhow::ensure!(names.len() == host.len());
        let device = host
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamSet { names, host, device })
    }

    /// Replace the host copies and re-upload (after a train step).
    pub fn update(&mut self, rt: &Runtime, host: Vec<Tensor>) -> Result<()> {
        anyhow::ensure!(host.len() == self.host.len());
        self.device = host
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        self.host = host;
        Ok(())
    }

    /// Resident-input map for generation artifacts (params are always
    /// inputs `0..n` by the manifest contract).
    pub fn resident_map(&self) -> HashMap<usize, Arc<xla::PjRtBuffer>> {
        self.device.iter().cloned().enumerate().collect()
    }

    pub fn len(&self) -> usize {
        self.host.len()
    }

    pub fn is_empty(&self) -> bool {
        self.host.is_empty()
    }

    /// Total parameter count (elements).
    pub fn elem_count(&self) -> usize {
        self.host.iter().map(|t| t.len()).sum()
    }

    /// Save host copies as `<dir>/<name>.tz`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let pairs: Vec<(String, Tensor)> = self
            .names
            .iter()
            .cloned()
            .zip(self.host.iter().cloned())
            .collect();
        crate::io::save_tensors(dir, &pairs)
    }

    /// Load from `<dir>/<name>.tz` for the given names and upload.
    pub fn load(rt: &Runtime, dir: &Path, names: Vec<String>) -> Result<ParamSet> {
        let host = crate::io::load_tensors(dir, &names)?;
        ParamSet::from_host(rt, names, host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, -3.5, 0.0, 1e-9, -1e9]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tensor_literal_roundtrip_i32_u32_scalar() {
        let t = Tensor::i32(vec![4], vec![-5, 0, 7, i32::MAX]);
        assert_eq!(literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap(), t);
        let u = Tensor::u32(vec![], vec![42]);
        assert_eq!(literal_to_tensor(&tensor_to_literal(&u).unwrap()).unwrap(), u);
    }

    #[test]
    fn tensor_literal_bulk_bytes_match_per_element() {
        // the bulk reinterpret must produce exactly the LE byte stream of
        // the seed's per-element path
        let t = Tensor::f32(vec![3], vec![1.5, -0.0, f32::MIN_POSITIVE]);
        let bulk: &[u8] = match &t {
            Tensor::F32 { data, .. } => unsafe { data.align_to::<u8>().1 },
            _ => unreachable!(),
        };
        let per_elem: Vec<u8> = match &t {
            Tensor::F32 { data, .. } => data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            _ => unreachable!(),
        };
        assert_eq!(bulk, per_elem.as_slice());
    }

    #[test]
    fn transfer_counters_accumulate_and_delta() {
        let c = TransferCounters::default();
        c.add_h2d(100);
        c.add_d2h(7);
        let s0 = c.snapshot();
        assert_eq!(s0, TransferSnapshot { h2d_bytes: 100, d2h_bytes: 7 });
        c.add_h2d(1);
        let d = s0.delta(c.snapshot());
        assert_eq!(d.h2d_bytes, 1);
        assert_eq!(d.d2h_bytes, 0);
    }
}
