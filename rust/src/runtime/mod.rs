//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on
//! the CPU PJRT client (the `xla` crate). This is the only module that
//! touches XLA; everything above it works with [`crate::io::Tensor`]s.
//!
//! ## Residency model
//!
//! * **Parameters** live on device as [`xla::PjRtBuffer`]s ([`ParamSet`]),
//!   uploaded once (or after each train step) — the hot path never
//!   re-uploads weights (`execute_b`).
//! * **Outputs** come back as a *single fused tuple buffer* (the shim's
//!   `ExecuteOptions` does not untuple, and tuple buffers cannot be split
//!   on-device through this API), so every output round-trips through a
//!   host [`xla::Literal`]. KV caches therefore flow host↔device each
//!   decode call; the fused multi-step decode artifact amortizes this
//!   (see DESIGN.md §8 and EXPERIMENTS.md §Perf).

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::io::{DType, Tensor};
pub use manifest::{ArgClass, ArtifactSpec, Globals, IoSpec, Manifest, ModelMeta};

/// Convert a host tensor to an XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, Vec<u8>) = match t {
        Tensor::F32 { data, .. } => (
            xla::ElementType::F32,
            data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Tensor::I32 { data, .. } => (
            xla::ElementType::S32,
            data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
        Tensor::U32 { data, .. } => (
            xla::ElementType::U32,
            data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        ),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, t.dims(), &bytes)
        .map_err(|e| anyhow::anyhow!("literal create: {e}"))
}

/// Convert an XLA literal back to a host tensor.
pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow::anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = l.ty().map_err(|e| anyhow::anyhow!("ty: {e}"))?;
    Ok(match ty {
        xla::ElementType::F32 => Tensor::f32(
            dims,
            l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))?,
        ),
        xla::ElementType::S32 => Tensor::i32(
            dims,
            l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("to_vec i32: {e}"))?,
        ),
        xla::ElementType::U32 => Tensor::u32(
            dims,
            l.to_vec::<u32>().map_err(|e| anyhow::anyhow!("to_vec u32: {e}"))?,
        ),
        other => bail!("unsupported element type {other:?}"),
    })
}

fn dtype_matches(spec: DType, t: &Tensor) -> bool {
    spec == t.dtype()
}

/// Upload a host tensor synchronously.
///
/// IMPORTANT: this must use `buffer_from_host_buffer` (semantics
/// `kImmutableOnlyDuringCall`, i.e. the copy completes before returning)
/// and NOT `buffer_from_host_literal`, whose H2D transfer is *async* and
/// requires the literal to outlive it — dropping the literal right after
/// (as a naive wrapper would) is a use-after-free that corrupts weights.
fn upload_tensor(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    let r = match t {
        Tensor::F32 { dims, data } => client.buffer_from_host_buffer::<f32>(data, dims, None),
        Tensor::I32 { dims, data } => client.buffer_from_host_buffer::<i32>(data, dims, None),
        Tensor::U32 { dims, data } => client.buffer_from_host_buffer::<u32>(data, dims, None),
    };
    r.map_err(|e| anyhow::anyhow!("upload: {e}"))
}

/// A compiled artifact plus its manifest spec.
pub struct Exec {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Exec {
    /// Validate `ins` against the manifest spec (shape + dtype + count).
    fn validate(&self, ins: &[&Tensor]) -> Result<()> {
        if ins.len() != self.spec.ins.len() {
            bail!(
                "artifact {}: got {} inputs, expected {}",
                self.spec.name,
                ins.len(),
                self.spec.ins.len()
            );
        }
        for (t, s) in ins.iter().zip(&self.spec.ins) {
            if !dtype_matches(s.dtype, t) {
                bail!("artifact {} input {}: dtype mismatch", self.spec.name, s.name);
            }
            if t.dims() != s.dims.as_slice() {
                bail!(
                    "artifact {} input {}: dims {:?}, expected {:?}",
                    self.spec.name,
                    s.name,
                    t.dims(),
                    s.dims
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors (uploads everything; convenient path).
    pub fn run(&self, ins: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.validate(ins)?;
        let literals: Vec<xla::Literal> =
            ins.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.spec.name))?;
        self.collect_outputs(bufs)
    }

    /// Execute with a mix of device-resident buffers (params/opt) and host
    /// tensors (data/state). `resident[i]` overrides input `i`.
    pub fn run_with_resident(
        &self,
        resident: &HashMap<usize, Arc<xla::PjRtBuffer>>,
        host: &[(usize, &Tensor)],
    ) -> Result<Vec<Tensor>> {
        let client = self.exe.client();
        let mut slots: Vec<Option<Arc<xla::PjRtBuffer>>> = vec![None; self.spec.ins.len()];
        for (i, b) in resident {
            slots[*i] = Some(b.clone());
        }
        for (i, t) in host {
            let spec = &self.spec.ins[*i];
            if t.dims() != spec.dims.as_slice() || !dtype_matches(spec.dtype, t) {
                bail!("artifact {} input {}: shape/dtype mismatch", self.spec.name, spec.name);
            }
            let buf = upload_tensor(client, t)
                .with_context(|| format!("upload {}", spec.name))?;
            slots[*i] = Some(Arc::new(buf));
        }
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(slots.len());
        for (i, s) in slots.iter().enumerate() {
            match s {
                Some(b) => args.push(b),
                None => bail!(
                    "artifact {}: input {} ({}) not provided",
                    self.spec.name,
                    i,
                    self.spec.ins[i].name
                ),
            }
        }
        let bufs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e}", self.spec.name))?;
        self.collect_outputs(bufs)
    }

    fn collect_outputs(&self, bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Tensor>> {
        // single device, single fused tuple output (return_tuple=True)
        let buf = &bufs[0][0];
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download {}: {e}", self.spec.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e}", self.spec.name))?;
        if parts.len() != self.spec.outs.len() {
            bail!(
                "artifact {}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outs.len()
            );
        }
        parts.iter().map(literal_to_tensor).collect()
    }
}

/// The runtime: PJRT client + manifest + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Exec>>>,
}

impl Runtime {
    /// Load the manifest from `dir` and create the CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Arc<Runtime>> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        if manifest.globals.vocab != crate::tokenizer::VOCAB {
            bail!(
                "manifest vocab {} != tokenizer VOCAB {}",
                manifest.globals.vocab,
                crate::tokenizer::VOCAB
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Arc::new(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        }))
    }

    /// Default artifacts directory (`$HYBRID_LLM_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("HYBRID_LLM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn exec(&self, name: &str) -> Result<Arc<Exec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        let exec = Arc::new(Exec { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Upload a host tensor to a device buffer (synchronous copy).
    pub fn upload(&self, t: &Tensor) -> Result<Arc<xla::PjRtBuffer>> {
        Ok(Arc::new(upload_tensor(&self.client, t)?))
    }
}

/// A named set of model parameters: host copies (for persistence) plus
/// device-resident buffers (for `execute_b` hot paths).
pub struct ParamSet {
    pub names: Vec<String>,
    pub host: Vec<Tensor>,
    pub device: Vec<Arc<xla::PjRtBuffer>>,
}

impl ParamSet {
    /// Build from host tensors, uploading each to the device.
    pub fn from_host(rt: &Runtime, names: Vec<String>, host: Vec<Tensor>) -> Result<ParamSet> {
        anyhow::ensure!(names.len() == host.len());
        let device = host
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamSet { names, host, device })
    }

    /// Replace the host copies and re-upload (after a train step).
    pub fn update(&mut self, rt: &Runtime, host: Vec<Tensor>) -> Result<()> {
        anyhow::ensure!(host.len() == self.host.len());
        self.device = host
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        self.host = host;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.host.len()
    }

    pub fn is_empty(&self) -> bool {
        self.host.is_empty()
    }

    /// Total parameter count (elements).
    pub fn elem_count(&self) -> usize {
        self.host.iter().map(|t| t.len()).sum()
    }

    /// Save host copies as `<dir>/<name>.tz`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let pairs: Vec<(String, Tensor)> = self
            .names
            .iter()
            .cloned()
            .zip(self.host.iter().cloned())
            .collect();
        crate::io::save_tensors(dir, &pairs)
    }

    /// Load from `<dir>/<name>.tz` for the given names and upload.
    pub fn load(rt: &Runtime, dir: &Path, names: Vec<String>) -> Result<ParamSet> {
        let host = crate::io::load_tensors(dir, &names)?;
        ParamSet::from_host(rt, names, host)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, -3.5, 0.0, 1e-9, -1e9]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tensor_literal_roundtrip_i32_u32_scalar() {
        let t = Tensor::i32(vec![4], vec![-5, 0, 7, i32::MAX]);
        assert_eq!(literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap(), t);
        let u = Tensor::u32(vec![], vec![42]);
        assert_eq!(literal_to_tensor(&tensor_to_literal(&u).unwrap()).unwrap(), u);
    }
}
