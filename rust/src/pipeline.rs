//! Reproduction pipeline orchestrator: corpus → LM pre-training →
//! response sampling → quality scoring → labels (t* search) → router
//! training → router scoring. Every stage is resumable (skipped when its
//! outputs already exist) and the whole thing is driven from rust — the
//! python side only ever produced the HLO artifacts.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::corpus::{self, Query, Scale, Split};
use crate::io::{self, Tensor};
use crate::labels::{self, QualitySamples};
use crate::lm::LmEngine;
use crate::router::{RouterEngine, RouterKind, TrainCfg, ALL_ROUTERS};
use crate::runtime::Runtime;
use crate::scorer::{oracle_rating, ScorerEngine};

/// Sampling temperature for the 10-responses-per-query protocol (§3.2).
pub const SAMPLE_TEMP: f32 = 0.8;

/// The LM roster, quality-ordered (weakest first).
pub const ROSTER: [&str; 5] = ["nano", "micro", "small", "medium", "large"];

/// Main-paper pairs: (small model, large model, regime) — §4.2.
pub const MAIN_PAIRS: [(&str, &str, &str); 3] = [
    ("small", "medium", "small-gap"),   // Llama-2 7b vs 13b
    ("medium", "large", "medium-gap"),  // Llama-2 13b vs GPT-3.5
    ("nano", "medium", "large-gap"),    // FLAN-t5 800m vs Llama-2 13b
];

/// Appendix pairs (Fig. 9 / Table 4).
pub const APPENDIX_PAIRS: [(&str, &str, &str); 4] = [
    ("nano", "micro", "small-gap"),   // FLAN-t5 800m vs 11b
    ("small", "large", "medium-gap"), // Llama-2 7b vs GPT-3.5
    ("nano", "large", "large-gap"),   // FLAN-t5 800m vs GPT-3.5
    ("micro", "large", "large-gap"),  // FLAN-t5 11b vs GPT-3.5
];

/// All pairs (main + appendix).
pub fn all_pairs() -> Vec<(&'static str, &'static str, &'static str)> {
    MAIN_PAIRS.iter().chain(APPENDIX_PAIRS.iter()).copied().collect()
}

/// Canonical pair id, e.g. `nano_medium`.
pub fn pair_id(small: &str, large: &str) -> String {
    format!("{small}_{large}")
}

/// Relative serving cost per roster model (large ≡ 1.0) — rough
/// parameter-count ratios, used as default tier cost weights for fleet
/// configs over the roster.
pub fn model_cost(model: &str) -> f64 {
    match model {
        "nano" => 0.02,
        "micro" => 0.08,
        "small" => 0.20,
        "medium" => 0.45,
        _ => 1.0,
    }
}

/// Quality-ordered tier specs over roster models (cheapest first), one
/// replica each, with [`model_cost`] weights — the fleet analogue of a
/// `MAIN_PAIRS` entry.
pub fn ladder_specs(models: &[&str]) -> Vec<crate::serve::TierSpec> {
    models
        .iter()
        .map(|m| crate::serve::TierSpec::new(*m, 1, model_cost(m)))
        .collect()
}

/// Pre-training budget per roster entry (scaled by [`Scale::train_mult`]).
pub fn train_steps(model: &str, scale: Scale) -> usize {
    let base = match model {
        "nano" => 300,
        "micro" => 500,
        "small" => 800,
        "medium" => 1100,
        "large" => 1400,
        "scorer" => 1200,
        _ => 500,
    };
    ((base as f64 * scale.train_mult()) as usize).max(20)
}

/// Base LR per roster entry.
pub fn base_lr(model: &str) -> f32 {
    match model {
        "nano" | "micro" => 1e-2,
        "small" => 7e-3,
        "medium" | "scorer" => 5e-3,
        _ => 4e-3,
    }
}

/// On-disk layout of one run.
#[derive(Debug, Clone)]
pub struct RunPaths {
    pub root: PathBuf,
}

impl RunPaths {
    pub fn new(root: &Path) -> Self {
        RunPaths { root: root.to_path_buf() }
    }

    pub fn corpus(&self) -> PathBuf {
        self.root.join("corpus.tsv")
    }

    pub fn params(&self, model: &str) -> PathBuf {
        self.root.join("params").join(model)
    }

    pub fn losses(&self, model: &str) -> PathBuf {
        self.root.join("params").join(format!("{model}.losses.tz"))
    }

    pub fn responses(&self, model: &str) -> PathBuf {
        self.root.join("responses").join(format!("{model}.tz"))
    }

    pub fn response_lens(&self, model: &str) -> PathBuf {
        self.root.join("responses").join(format!("{model}.lens.tz"))
    }

    pub fn scores(&self, model: &str) -> PathBuf {
        self.root.join("scores").join(format!("{model}.tz"))
    }

    pub fn oracle(&self, model: &str) -> PathBuf {
        self.root.join("scores").join(format!("{model}.oracle.tz"))
    }

    pub fn labels_kv(&self, pair: &str) -> PathBuf {
        self.root.join("labels").join(format!("{pair}.kv"))
    }

    pub fn labels_tz(&self, pair: &str, kind: RouterKind) -> PathBuf {
        self.root
            .join("labels")
            .join(format!("{pair}.{}.tz", kind.name()))
    }

    pub fn tstar_curve(&self, pair: &str) -> PathBuf {
        self.root.join("labels").join(format!("{pair}.curve.tz"))
    }

    pub fn router_dir(&self, pair: &str, kind: RouterKind) -> PathBuf {
        self.root
            .join("routers")
            .join(format!("{pair}_{}", kind.name()))
    }

    pub fn router_scores(&self, pair: &str, kind: RouterKind) -> PathBuf {
        self.root
            .join("router_scores")
            .join(format!("{pair}_{}.tz", kind.name()))
    }

    pub fn results(&self) -> PathBuf {
        self.root.join("results")
    }

    pub fn meta(&self) -> PathBuf {
        self.root.join("run.kv")
    }
}

/// The pipeline driver.
pub struct Pipeline {
    pub rt: Arc<Runtime>,
    pub paths: RunPaths,
    pub scale: Scale,
    pub seed: u64,
    pub verbose: bool,
}

impl Pipeline {
    pub fn new(rt: Arc<Runtime>, run_dir: &Path, scale: Scale) -> Pipeline {
        Pipeline {
            rt,
            paths: RunPaths::new(run_dir),
            scale,
            seed: 0xDEED,
            verbose: true,
        }
    }

    fn log(&self, msg: &str) {
        if self.verbose {
            println!("[pipeline] {msg}");
        }
    }

    /// Stage 1: corpus.
    pub fn ensure_corpus(&self) -> Result<Vec<Query>> {
        if self.paths.corpus().exists() {
            return corpus::load(&self.paths.corpus());
        }
        self.log(&format!("generating corpus (scale {:?})", self.scale));
        let c = corpus::generate(self.seed, self.scale);
        corpus::save(&self.paths.corpus(), &c)?;
        io::save_kv(
            &self.paths.meta(),
            &[
                ("scale".into(), format!("{:?}", self.scale)),
                ("seed".into(), self.seed.to_string()),
                ("n_samples".into(), self.scale.n_samples().to_string()),
            ],
        )?;
        Ok(c)
    }

    /// Stage 2: pre-train the roster + scorer (skips models already saved).
    pub fn ensure_lms(&self, corpus: &[Query]) -> Result<()> {
        let train_ids = corpus::split_ids(corpus, Split::Train);
        let queries: Vec<&Query> = train_ids.iter().map(|&i| &corpus[i]).collect();
        for (mi, model) in ROSTER.iter().chain(std::iter::once(&"scorer")).enumerate() {
            let dir = self.paths.params(model);
            if dir.join("p.emb.tz").exists() {
                continue;
            }
            let steps = train_steps(model, self.scale);
            let lr = base_lr(model);
            self.log(&format!("training {model}: {steps} steps @ lr {lr}"));
            let t0 = Instant::now();
            let losses: Vec<f32> = if *model == "scorer" {
                let mut eng = ScorerEngine::init(self.rt.clone(), 1000 + mi as u32)?;
                let losses = eng.train(&queries, steps, lr, self.seed ^ mi as u64, |s, l| {
                    if s % 100 == 0 {
                        println!("  [{model}] step {s}: loss {l:.4}");
                    }
                })?;
                eng.save(&dir)?;
                losses
            } else {
                let mut eng = LmEngine::init(self.rt.clone(), model, 1000 + mi as u32)?;
                let losses = eng.train(&queries, steps, lr, self.seed ^ mi as u64, |s, l| {
                    if s % 100 == 0 {
                        println!("  [{model}] step {s}: loss {l:.4}");
                    }
                })?;
                eng.save(&dir)?;
                losses
            };
            Tensor::f32(vec![losses.len()], losses.clone()).save(&self.paths.losses(model))?;
            self.log(&format!(
                "trained {model} in {:.1}s (final loss {:.4})",
                t0.elapsed().as_secs_f64(),
                losses.last().copied().unwrap_or(f32::NAN)
            ));
        }
        Ok(())
    }

    /// Stage 3: sample `n_samples` responses per (query, roster model).
    pub fn ensure_responses(&self, corpus: &[Query]) -> Result<()> {
        let ns = self.scale.n_samples();
        let nq = corpus.len();
        let amax = corpus::A_MAX;
        for model in ROSTER {
            if self.paths.responses(model).exists() {
                continue;
            }
            let t0 = Instant::now();
            self.log(&format!("sampling {ns} responses/query from {model} ({nq} queries)"));
            let eng = LmEngine::load(self.rt.clone(), model, &self.paths.params(model))?;
            let mut toks = vec![-1i32; nq * ns * amax];
            let mut lens = vec![0u32; nq * ns];
            // batch across queries for each sample index
            for s in 0..ns {
                let prompts: Vec<&[i32]> = corpus.iter().map(|q| q.prompt.as_slice()).collect();
                let seeds: Vec<u32> = corpus
                    .iter()
                    .map(|q| (q.id as u32).wrapping_mul(1699) ^ (s as u32).wrapping_mul(7919))
                    .collect();
                let resp = eng.generate(&prompts, &seeds, SAMPLE_TEMP)?;
                for (qi, r) in resp.iter().enumerate() {
                    let off = (qi * ns + s) * amax;
                    lens[qi * ns + s] = r.tokens.len() as u32;
                    toks[off..off + r.tokens.len()].copy_from_slice(&r.tokens);
                }
                self.log(&format!(
                    "  {model}: sample {}/{} done ({:.1}s elapsed)",
                    s + 1,
                    ns,
                    t0.elapsed().as_secs_f64()
                ));
            }
            Tensor::i32(vec![nq, ns, amax], toks).save(&self.paths.responses(model))?;
            Tensor::u32(vec![nq, ns], lens).save(&self.paths.response_lens(model))?;
        }
        Ok(())
    }

    /// Stage 4: quality scores — BART-analogue (scorer LM) + oracle rating.
    pub fn ensure_scores(&self, corpus: &[Query]) -> Result<()> {
        let ns = self.scale.n_samples();
        let nq = corpus.len();
        let scorer = ScorerEngine::load(self.rt.clone(), &self.paths.params("scorer"))?;
        for model in ROSTER {
            if self.paths.scores(model).exists() {
                continue;
            }
            self.log(&format!("scoring responses of {model}"));
            let responses = self.load_responses(model, corpus)?;
            let mut flat_pairs: Vec<(&[i32], &[i32])> = Vec::with_capacity(nq * ns);
            for (qi, q) in corpus.iter().enumerate() {
                for s in 0..ns {
                    flat_pairs.push((q.prompt.as_slice(), responses[qi][s].as_slice()));
                }
            }
            let scores = scorer.score(&flat_pairs)?;
            ensure!(scores.len() == nq * ns);
            Tensor::f32(vec![nq, ns], scores).save(&self.paths.scores(model))?;

            // oracle ratings (GPT-4-judge analogue)
            let mut oracle = vec![0.0f32; nq * ns];
            for (qi, q) in corpus.iter().enumerate() {
                for s in 0..ns {
                    oracle[qi * ns + s] = oracle_rating(&responses[qi][s], &q.reference) as f32;
                }
            }
            Tensor::f32(vec![nq, ns], oracle).save(&self.paths.oracle(model))?;
        }
        Ok(())
    }

    /// Stage 5: labels for every pair (t* from the train split only).
    pub fn ensure_labels(&self, corpus: &[Query]) -> Result<()> {
        for (small, large, _) in all_pairs() {
            let pair = pair_id(small, large);
            if self.paths.labels_kv(&pair).exists() {
                continue;
            }
            self.log(&format!("labels for pair {pair}"));
            let qs = self.load_quality(small, corpus)?;
            let ql = self.load_quality(large, corpus)?;
            let train_ids = corpus::split_ids(corpus, Split::Train);
            let qs_train = subset(&qs, &train_ids);
            let ql_train = subset(&ql, &train_ids);
            let search = labels::find_tstar(&qs_train, &ql_train, 41)?;

            let y_det = labels::y_det(&qs, &ql)?;
            let y_prob = labels::y_prob(&qs, &ql)?;
            let y_trans = labels::y_trans(&qs, &ql, search.tstar)?;
            let n = corpus.len();
            Tensor::f32(vec![n], y_det).save(&self.paths.labels_tz(&pair, RouterKind::Det))?;
            Tensor::f32(vec![n], y_prob).save(&self.paths.labels_tz(&pair, RouterKind::Prob))?;
            Tensor::f32(vec![n], y_trans).save(&self.paths.labels_tz(&pair, RouterKind::Trans))?;
            let curve: Vec<f32> = search
                .curve
                .iter()
                .flat_map(|(t, j)| [*t, *j as f32])
                .collect();
            Tensor::f32(vec![search.curve.len(), 2], curve).save(&self.paths.tstar_curve(&pair))?;
            io::save_kv(
                &self.paths.labels_kv(&pair),
                &[("tstar".into(), search.tstar.to_string())],
            )?;
        }
        Ok(())
    }

    /// Stage 6: train r_det / r_prob / r_trans for the main pairs (and any
    /// extra pairs requested), with best-checkpoint selection on val.
    pub fn ensure_routers(&self, corpus: &[Query], pairs: &[(String, String)]) -> Result<()> {
        let train_ids = corpus::split_ids(corpus, Split::Train);
        let val_ids = corpus::split_ids(corpus, Split::Val);
        for (small, large) in pairs {
            let pair = pair_id(small, large);
            for kind in ALL_ROUTERS {
                let dir = self.paths.router_dir(&pair, kind);
                if dir.join("p.emb.tz").exists() {
                    continue;
                }
                self.log(&format!("training router r_{} for {pair}", kind.name()));
                let y = Tensor::load(&self.paths.labels_tz(&pair, kind))?;
                let y = y.as_f32()?;
                let tp: Vec<&[i32]> = train_ids.iter().map(|&i| corpus[i].prompt.as_slice()).collect();
                let ty: Vec<f32> = train_ids.iter().map(|&i| y[i]).collect();
                let vp: Vec<&[i32]> = val_ids.iter().map(|&i| corpus[i].prompt.as_slice()).collect();
                let vy: Vec<f32> = val_ids.iter().map(|&i| y[i]).collect();
                let mut eng = RouterEngine::init(self.rt.clone(), 77)?;
                let cfg = TrainCfg { seed: self.seed ^ 0x50, ..TrainCfg::default() };
                let t0 = Instant::now();
                let (_losses, best) = eng.train(&tp, &ty, &vp, &vy, cfg, |e, s, l| {
                    if s % 50 == 0 {
                        println!("  [{pair}/{}] epoch {e} step {s}: loss {l:.4}", kind.name());
                    }
                })?;
                eng.save(&dir)?;
                self.log(&format!(
                    "router r_{} {pair}: best val BCE {best:.4} ({:.1}s)",
                    kind.name(),
                    t0.elapsed().as_secs_f64()
                ));
            }
        }
        Ok(())
    }

    /// Stage 7: router scores over the full corpus for every trained router.
    pub fn ensure_router_scores(&self, corpus: &[Query], pairs: &[(String, String)]) -> Result<()> {
        for (small, large) in pairs {
            let pair = pair_id(small, large);
            for kind in ALL_ROUTERS {
                let path = self.paths.router_scores(&pair, kind);
                if path.exists() {
                    continue;
                }
                self.log(&format!("scoring corpus with router r_{} {pair}", kind.name()));
                let eng = RouterEngine::load(self.rt.clone(), &self.paths.router_dir(&pair, kind))?;
                let prompts: Vec<&[i32]> = corpus.iter().map(|q| q.prompt.as_slice()).collect();
                let scores = eng.scores(&prompts)?;
                Tensor::f32(vec![scores.len()], scores).save(&path)?;
            }
        }
        Ok(())
    }

    /// Run every stage for the main pairs (+ appendix pairs' labels).
    pub fn run_all(&self) -> Result<()> {
        let corpus = self.ensure_corpus()?;
        self.ensure_lms(&corpus)?;
        self.ensure_responses(&corpus)?;
        self.ensure_scores(&corpus)?;
        self.ensure_labels(&corpus)?;
        let pairs: Vec<(String, String)> = all_pairs()
            .iter()
            .map(|(s, l, _)| (s.to_string(), l.to_string()))
            .collect();
        self.ensure_routers(&corpus, &pairs)?;
        self.ensure_router_scores(&corpus, &pairs)?;
        fs::create_dir_all(self.paths.results())?;
        Ok(())
    }

    // ----- accessors for the eval drivers --------------------------------

    /// Responses as ragged token vectors `[nq][ns]`.
    pub fn load_responses(&self, model: &str, corpus: &[Query]) -> Result<Vec<Vec<Vec<i32>>>> {
        let t = Tensor::load(&self.paths.responses(model))?;
        let l = Tensor::load(&self.paths.response_lens(model))?;
        let dims = t.dims().to_vec();
        ensure!(dims.len() == 3 && dims[0] == corpus.len());
        let (nq, ns, amax) = (dims[0], dims[1], dims[2]);
        let toks = t.as_i32()?;
        let lens = match &l {
            Tensor::U32 { data, .. } => data,
            _ => anyhow::bail!("lens must be u32"),
        };
        Ok((0..nq)
            .map(|qi| {
                (0..ns)
                    .map(|s| {
                        let len = lens[qi * ns + s] as usize;
                        let off = (qi * ns + s) * amax;
                        toks[off..off + len].to_vec()
                    })
                    .collect()
            })
            .collect())
    }

    /// BART-analogue quality samples for a model.
    pub fn load_quality(&self, model: &str, corpus: &[Query]) -> Result<QualitySamples> {
        load_samples(&self.paths.scores(model), corpus.len())
    }

    /// Oracle-rating samples for a model.
    pub fn load_oracle_quality(&self, model: &str, corpus: &[Query]) -> Result<QualitySamples> {
        load_samples(&self.paths.oracle(model), corpus.len())
    }

    /// Stored router scores over the full corpus.
    pub fn load_router_scores(&self, pair: &str, kind: RouterKind) -> Result<Vec<f32>> {
        Ok(Tensor::load(&self.paths.router_scores(pair, kind))?
            .as_f32()?
            .to_vec())
    }

    /// The t* recorded for a pair.
    pub fn load_tstar(&self, pair: &str) -> Result<f32> {
        let kv = io::load_kv(&self.paths.labels_kv(pair))?;
        io::kv_get(&kv, "tstar")
            .context("tstar missing")?
            .parse()
            .context("bad tstar")
    }
}

fn load_samples(path: &Path, nq: usize) -> Result<QualitySamples> {
    let t = Tensor::load(path)?;
    let dims = t.dims().to_vec();
    ensure!(dims.len() == 2 && dims[0] == nq, "bad sample tensor {dims:?}");
    let ns = dims[1];
    let data = t.as_f32()?;
    Ok(QualitySamples::new(
        (0..nq)
            .map(|i| data[i * ns..(i + 1) * ns].to_vec())
            .collect(),
    ))
}

/// Subset of quality samples by query ids.
pub fn subset(q: &QualitySamples, ids: &[usize]) -> QualitySamples {
    QualitySamples::new(ids.iter().map(|&i| q.q[i].clone()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_ids() {
        assert_eq!(pair_id("nano", "medium"), "nano_medium");
        assert_eq!(all_pairs().len(), 7);
    }

    #[test]
    fn step_budgets_ordered() {
        // larger models get more training
        let s = Scale::Default;
        assert!(train_steps("nano", s) < train_steps("micro", s));
        assert!(train_steps("micro", s) < train_steps("small", s));
        assert!(train_steps("small", s) < train_steps("medium", s));
        assert!(train_steps("medium", s) < train_steps("large", s));
        // smoke is cheaper
        assert!(train_steps("large", Scale::Smoke) < train_steps("large", Scale::Default));
    }

    #[test]
    fn model_costs_ordered_along_roster() {
        for w in ROSTER.windows(2) {
            assert!(model_cost(w[0]) < model_cost(w[1]), "{w:?}");
        }
        assert_eq!(model_cost("large"), 1.0);
        let specs = ladder_specs(&["nano", "medium", "large"]);
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].name, "nano");
        assert!(specs[0].cost < specs[1].cost && specs[1].cost < specs[2].cost);
        assert!(specs.iter().all(|s| s.replicas == 1));
    }

    #[test]
    fn run_paths_layout() {
        let p = RunPaths::new(Path::new("/tmp/run"));
        assert!(p.responses("nano").ends_with("responses/nano.tz"));
        assert!(p
            .router_dir("nano_medium", RouterKind::Trans)
            .ends_with("routers/nano_medium_trans"));
        assert!(p
            .router_scores("a_b", RouterKind::Det)
            .ends_with("router_scores/a_b_det.tz"));
    }

    #[test]
    fn subset_picks_rows() {
        let q = QualitySamples::new(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let s = subset(&q, &[2, 0]);
        assert_eq!(s.q, vec![vec![3.0], vec![1.0]]);
    }
}
