//! Response-quality evaluation.
//!
//! * [`ScorerEngine`] — the **BART-score analogue** (paper §2.3): a
//!   medium-size LM trained on (query → reference) pairs; the quality of
//!   a response is its mean per-token log-likelihood under this scorer,
//!   conditioned on the query. Same mathematical object as BART score,
//!   same scale (negative; higher = better).
//! * [`oracle_rating`] — the **GPT-4-judge analogue** (paper §4.6): an
//!   integer 1–10 rating derived from token-level edit similarity against
//!   the algorithmic reference (MixSynth gives us an exact judge).

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::io::Tensor;
use crate::lm::build_sequence;
use crate::runtime::{ParamSet, Runtime};

/// The scorer model name in the manifest.
pub const SCORER: &str = "scorer";

/// BART-score-analogue engine.
pub struct ScorerEngine {
    rt: Arc<Runtime>,
    pub params: ParamSet,
}

impl ScorerEngine {
    pub fn init(rt: Arc<Runtime>, seed: u32) -> Result<ScorerEngine> {
        let init = rt.exec(&format!("{SCORER}.init"))?;
        let host = init.run(&[&Tensor::u32(vec![], vec![seed])])?;
        let names: Vec<String> = init.spec.outs.iter().map(|o| o.name.clone()).collect();
        let params = ParamSet::from_host(&rt, names, host)?;
        Ok(ScorerEngine { rt, params })
    }

    pub fn load(rt: Arc<Runtime>, dir: &Path) -> Result<ScorerEngine> {
        let init = rt.exec(&format!("{SCORER}.init"))?;
        let names: Vec<String> = init.spec.outs.iter().map(|o| o.name.clone()).collect();
        let params = ParamSet::load(&rt, dir, names)?;
        Ok(ScorerEngine { rt, params })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        self.params.save(dir)
    }

    /// Quality `q(z) = mean log p(z | x)` for each (prompt, response)
    /// pair, batched through the `scorer.score` artifact.
    pub fn score(&self, pairs: &[(&[i32], &[i32])]) -> Result<Vec<f32>> {
        let g = self.rt.manifest.globals;
        let exec = self.rt.exec(&format!("{SCORER}.score"))?;
        let n = self.params.len();
        let resident: std::collections::HashMap<usize, Arc<xla::PjRtBuffer>> =
            self.params.device.iter().cloned().enumerate().collect();
        let bsz = g.scoreb;
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(bsz) {
            let mut toks = vec![0i32; bsz * g.sctx];
            let mut mask = vec![0.0f32; bsz * g.sctx];
            for (b, (prompt, resp)) in chunk.iter().enumerate() {
                // truncate over-long responses defensively (can happen at
                // high temperature before EOS)
                let budget = g.sctx - prompt.len() - 1;
                let resp = &resp[..resp.len().min(budget)];
                let (s, m) = build_sequence(g.sctx, prompt, resp)?;
                toks[b * g.sctx..(b + 1) * g.sctx].copy_from_slice(&s);
                mask[b * g.sctx..(b + 1) * g.sctx].copy_from_slice(&m);
            }
            let toks = Tensor::i32(vec![bsz, g.sctx], toks);
            let mask = Tensor::f32(vec![bsz, g.sctx], mask);
            let host: Vec<(usize, &Tensor)> = vec![(n, &toks), (n + 1, &mask)];
            let res = exec.run_with_resident(&resident, &host)?;
            out.extend(res[0].as_f32()?[..chunk.len()].iter().copied());
        }
        Ok(out)
    }

    /// Train the scorer exactly like an LM (query → reference answer).
    /// Delegates to the shared train artifact via a thin inline loop so
    /// the scorer does not need a full [`crate::lm::LmEngine`].
    pub fn train(
        &mut self,
        queries: &[&crate::corpus::Query],
        steps: usize,
        base_lr: f32,
        seed: u64,
        mut progress: impl FnMut(usize, f32),
    ) -> Result<Vec<f32>> {
        ensure!(!queries.is_empty());
        let g = self.rt.manifest.globals;
        let train = self.rt.exec(&format!("{SCORER}.train"))?;
        let n = self.params.len();
        let mut m: Vec<Tensor> = self
            .params
            .host
            .iter()
            .map(|t| Tensor::f32(t.dims().to_vec(), vec![0.0; t.len()]))
            .collect();
        let mut v = m.clone();
        let mut rng = crate::rng::Rng::new(seed);
        let mut losses = Vec::with_capacity(steps);
        for step in 0..steps {
            let mut toks = vec![0i32; g.trainb * g.sctx];
            let mut mask = vec![0.0f32; g.trainb * g.sctx];
            for b in 0..g.trainb {
                let q = queries[rng.below(queries.len())];
                let (s, mk) = build_sequence(g.sctx, &q.prompt, &q.reference)?;
                toks[b * g.sctx..(b + 1) * g.sctx].copy_from_slice(&s);
                mask[b * g.sctx..(b + 1) * g.sctx].copy_from_slice(&mk);
            }
            let toks = Tensor::i32(vec![g.trainb, g.sctx], toks);
            let mask = Tensor::f32(vec![g.trainb, g.sctx], mask);
            let lr = Tensor::f32(
                vec![],
                vec![crate::lm::lr_schedule(base_lr, step, steps, steps / 20 + 1)],
            );
            let stept = Tensor::i32(vec![], vec![step as i32 + 1]);
            let mut ins: Vec<&Tensor> = Vec::with_capacity(3 * n + 4);
            ins.extend(self.params.host.iter());
            ins.extend(m.iter());
            ins.extend(v.iter());
            ins.extend([&toks, &mask, &lr, &stept]);
            let mut out = train.run(&ins)?;
            let loss = out.pop().context("loss")?.as_f32()?[0];
            losses.push(loss);
            let new_v: Vec<Tensor> = out.drain(2 * n..).collect();
            let new_m: Vec<Tensor> = out.drain(n..).collect();
            m = new_m;
            v = new_v;
            self.params.update(&self.rt, out)?;
            progress(step, loss);
        }
        Ok(losses)
    }
}

/// Levenshtein distance between token sequences.
pub fn levenshtein(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Normalized edit similarity in [0, 1].
pub fn edit_similarity(a: &[i32], b: &[i32]) -> f64 {
    let ml = a.len().max(b.len());
    if ml == 0 {
        return 1.0;
    }
    1.0 - levenshtein(a, b) as f64 / ml as f64
}

/// GPT-4-judge analogue: integer rating 1..=10 from edit similarity
/// against the algorithmic reference.
pub fn oracle_rating(response: &[i32], reference: &[i32]) -> u8 {
    let sim = edit_similarity(response, reference);
    (1.0 + (9.0 * sim).round()).clamp(1.0, 10.0) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein(&[], &[]), 0);
        assert_eq!(levenshtein(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(levenshtein(&[1, 2, 3], &[1, 3]), 1);
        assert_eq!(levenshtein(&[1, 2, 3], &[4, 5, 6]), 3);
        assert_eq!(levenshtein(&[], &[1, 2]), 2);
        // kitten -> sitting (classic): 3
        let kitten: Vec<i32> = "kitten".bytes().map(|b| b as i32).collect();
        let sitting: Vec<i32> = "sitting".bytes().map(|b| b as i32).collect();
        assert_eq!(levenshtein(&kitten, &sitting), 3);
    }

    #[test]
    fn similarity_and_rating() {
        assert_eq!(edit_similarity(&[1, 2], &[1, 2]), 1.0);
        assert_eq!(oracle_rating(&[1, 2], &[1, 2]), 10);
        assert_eq!(oracle_rating(&[9, 9, 9], &[1, 2, 3]), 1);
        let half = oracle_rating(&[1, 2, 9, 9], &[1, 2, 3, 4]);
        assert!((5..=6).contains(&half), "{half}");
        assert_eq!(oracle_rating(&[], &[]), 10);
    }

    #[test]
    fn levenshtein_symmetry_property() {
        crate::testing::check("lev symmetry + triangle-ish", 200, |rng| {
            let mk = |rng: &mut crate::rng::Rng| {
                let n = rng.below(12);
                (0..n).map(|_| rng.below(5) as i32).collect::<Vec<_>>()
            };
            let a = mk(rng);
            let b = mk(rng);
            assert_eq!(levenshtein(&a, &b), levenshtein(&b, &a));
            // distance bounded by max length
            assert!(levenshtein(&a, &b) <= a.len().max(b.len()));
            // identity
            assert_eq!(levenshtein(&a, &a), 0);
        });
    }
}
