//! Synthetic-vocabulary tokenizer shared with the build-time python side
//! (`python/compile/common.py`); the manifest's `vocab` field is checked
//! against [`VOCAB`] at runtime startup.
//!
//! Token map (64 entries): `0 PAD, 1 BOS, 2 EOS, 3 SEP, 4..=29 'a'..'z',
//! 30..=39 '0'..'9', 40..=49 task keywords, 50 ':', 51..=63 reserved`.

pub const VOCAB: usize = 64;
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const LETTER0: i32 = 4;
pub const DIGIT0: i32 = 30;
pub const TASK0: i32 = 40;
pub const COLON: i32 = 50;

pub const N_LETTERS: i32 = 26;
pub const N_DIGITS: i32 = 10;

/// Task keyword names in token order (token = TASK0 + index).
pub const TASK_NAMES: [&str; 10] = [
    "COPY", "DOUBLE", "REV", "SORT", "DEDUP", "SUCC", "ADD", "COUNT", "EXTR", "ROT",
];

/// Letter token for `c` in `a..=z`.
pub fn letter(c: char) -> i32 {
    debug_assert!(c.is_ascii_lowercase());
    LETTER0 + (c as i32 - 'a' as i32)
}

/// Digit token for `d` in `0..=9`.
pub fn digit(d: u32) -> i32 {
    debug_assert!(d < 10);
    DIGIT0 + d as i32
}

/// Is `t` a letter token?
pub fn is_letter(t: i32) -> bool {
    (LETTER0..LETTER0 + N_LETTERS).contains(&t)
}

/// Is `t` a digit token?
pub fn is_digit(t: i32) -> bool {
    (DIGIT0..DIGIT0 + N_DIGITS).contains(&t)
}

/// Digit value of a digit token.
pub fn digit_val(t: i32) -> u32 {
    debug_assert!(is_digit(t));
    (t - DIGIT0) as u32
}

/// Encode a non-negative number as digit tokens (most-significant first).
pub fn encode_number(mut n: u32) -> Vec<i32> {
    if n == 0 {
        return vec![digit(0)];
    }
    let mut ds = Vec::new();
    while n > 0 {
        ds.push(digit(n % 10));
        n /= 10;
    }
    ds.reverse();
    ds
}

/// Human-readable rendering of a token sequence (for reports/examples).
pub fn detokenize(tokens: &[i32]) -> String {
    let mut s = String::new();
    for &t in tokens {
        match t {
            PAD => s.push('_'),
            BOS => s.push('^'),
            EOS => s.push('$'),
            SEP => s.push('|'),
            COLON => s.push(':'),
            t if is_letter(t) => s.push((b'a' + (t - LETTER0) as u8) as char),
            t if is_digit(t) => s.push((b'0' + (t - DIGIT0) as u8) as char),
            t if (TASK0..TASK0 + 10).contains(&t) => {
                s.push('[');
                s.push_str(TASK_NAMES[(t - TASK0) as usize]);
                s.push(']');
            }
            _ => s.push('?'),
        }
    }
    s
}

/// Parse the rendering produced by [`detokenize`] (used in tests and to
/// load hand-written example queries).
pub fn tokenize(text: &str) -> Option<Vec<i32>> {
    let mut out = Vec::new();
    let bytes: Vec<char> = text.chars().collect();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            '_' => out.push(PAD),
            '^' => out.push(BOS),
            '$' => out.push(EOS),
            '|' => out.push(SEP),
            ':' => out.push(COLON),
            'a'..='z' => out.push(letter(c)),
            '0'..='9' => out.push(digit(c as u32 - '0' as u32)),
            '[' => {
                let end = bytes[i..].iter().position(|&x| x == ']')? + i;
                let name: String = bytes[i + 1..end].iter().collect();
                let idx = TASK_NAMES.iter().position(|&n| n == name)? as i32;
                out.push(TASK0 + idx);
                i = end;
            }
            _ => return None,
        }
        i += 1;
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_simple() {
        let toks = vec![BOS, TASK0, COLON, letter('h'), letter('i'), SEP, EOS];
        let s = detokenize(&toks);
        assert_eq!(s, "^[COPY]:hi|$");
        assert_eq!(tokenize(&s).unwrap(), toks);
    }

    #[test]
    fn roundtrip_property_random_tokens() {
        // property: detokenize->tokenize is the identity on valid tokens
        let mut rng = Rng::new(123);
        for _ in 0..200 {
            let n = rng.range(1, 30);
            let toks: Vec<i32> = (0..n)
                .map(|_| {
                    // any token in [0, 51) — the renderable range
                    let t = rng.below(51) as i32;
                    t
                })
                .collect();
            let s = detokenize(&toks);
            assert_eq!(tokenize(&s).unwrap(), toks, "{s}");
        }
    }

    #[test]
    fn number_encoding() {
        assert_eq!(encode_number(0), vec![digit(0)]);
        assert_eq!(encode_number(7), vec![digit(7)]);
        assert_eq!(encode_number(42), vec![digit(4), digit(2)]);
        assert_eq!(encode_number(105), vec![digit(1), digit(0), digit(5)]);
    }

    #[test]
    fn classifications() {
        assert!(is_letter(letter('a')) && is_letter(letter('z')));
        assert!(!is_letter(DIGIT0) && !is_letter(PAD));
        assert!(is_digit(digit(0)) && is_digit(digit(9)));
        assert!(!is_digit(LETTER0));
        assert_eq!(digit_val(digit(7)), 7);
    }

    #[test]
    fn vocab_fits() {
        // highest used token must be < VOCAB
        assert!(COLON < VOCAB as i32);
        assert!(TASK0 + TASK_NAMES.len() as i32 <= COLON);
    }
}
