//! Minimal CLI argument parser (the offline environment has no `clap`).
//!
//! Supports `repro <subcommand> [--flag value] [--switch]` with typed
//! accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line: a subcommand plus `--key value` / `--switch` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags may appear before or after positionals.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let mut out = Args::default();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = a;
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// String flag with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Optional string flag (`None` when absent) — for flags whose
    /// default is computed from other flags, like `--tiers`.
    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Comma-separated list flag parsed element-wise (`None` when
    /// absent), e.g. `--thresholds 0.7,0.4`.
    pub fn get_csv<T: std::str::FromStr>(&self, key: &str) -> Option<Result<Vec<T>>>
    where
        T::Err: std::fmt::Display,
    {
        self.flags.get(key).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.parse::<T>()
                        .map_err(|e| anyhow::anyhow!("--{key}={v}: bad element {p:?}: {e}"))
                })
                .collect()
        })
    }

    /// Required string flag.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .with_context(|| format!("missing required flag --{key}"))
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Optional typed flag: `None` when absent, parse errors surfaced —
    /// for flags whose absence means "feature off" rather than a default
    /// value, like `serve-demo --quality`.
    pub fn get_parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.flags
            .get(key)
            .map(|v| {
                v.parse::<T>()
                    .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}"))
            })
            .transpose()
    }

    /// Boolean switch (`--verbose` style).
    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Optional millisecond-duration flag (`--deadline-ms 250` style):
    /// `None` when absent, a `Duration` otherwise. Shared by the
    /// serving/scenario commands so every duration flag parses the same
    /// way.
    pub fn get_ms(&self, key: &str) -> Result<Option<std::time::Duration>> {
        Ok(self
            .get_parse_opt::<u64>(key)?
            .map(std::time::Duration::from_millis))
    }

    /// All unknown-flag detection for strict commands.
    pub fn check_known(&self, known_flags: &[&str], known_switches: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known_flags.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known_flags.join(", "));
            }
        }
        for s in &self.switches {
            if !known_switches.contains(&s.as_str()) {
                bail!("unknown switch --{s}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("pipeline --run runs/x --scale smoke --verbose");
        assert_eq!(a.subcommand, "pipeline");
        assert_eq!(a.get("run", ""), "runs/x");
        assert_eq!(a.get("scale", "default"), "smoke");
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("eval --id=fig5 --pairs=small:medium");
        assert_eq!(a.get("id", ""), "fig5");
        assert_eq!(a.get("pairs", ""), "small:medium");
    }

    #[test]
    fn typed_and_defaults() {
        let a = parse("train --steps 200");
        assert_eq!(a.get_parse("steps", 10usize).unwrap(), 200);
        assert_eq!(a.get_parse("lr", 3e-3f64).unwrap(), 3e-3);
        assert!(a.get_parse::<usize>("steps", 0).is_ok());
        let b = parse("train --steps abc");
        assert!(b.get_parse::<usize>("steps", 0).is_err());
    }

    #[test]
    fn parse_opt_flag() {
        let a = parse("serve-demo --quality 0.8");
        assert_eq!(a.get_parse_opt::<f32>("quality").unwrap(), Some(0.8));
        assert_eq!(a.get_parse_opt::<f32>("missing").unwrap(), None);
        let b = parse("serve-demo --quality abc");
        assert!(b.get_parse_opt::<f32>("quality").is_err());
    }

    #[test]
    fn opt_and_csv_flags() {
        let a = parse("serve-demo --tiers nano:2,large --thresholds 0.7,0.4");
        assert_eq!(a.get_opt("tiers"), Some("nano:2,large"));
        assert_eq!(a.get_opt("missing"), None);
        let t: Vec<f32> = a.get_csv("thresholds").unwrap().unwrap();
        assert_eq!(t, vec![0.7, 0.4]);
        assert!(a.get_csv::<f32>("missing").is_none());
        let b = parse("x --thresholds 0.7,abc");
        assert!(b.get_csv::<f32>("thresholds").unwrap().is_err());
    }

    #[test]
    fn ms_duration_flag() {
        let a = parse("kick-tires --drain-timeout-ms 2500");
        assert_eq!(
            a.get_ms("drain-timeout-ms").unwrap(),
            Some(std::time::Duration::from_millis(2500))
        );
        assert_eq!(a.get_ms("missing").unwrap(), None);
        let b = parse("kick-tires --drain-timeout-ms soon");
        assert!(b.get_ms("drain-timeout-ms").is_err());
    }

    #[test]
    fn positionals() {
        let a = parse("eval fig5 table1 --run r");
        assert_eq!(a.subcommand, "eval");
        assert_eq!(a.positional, vec!["fig5", "table1"]);
    }

    #[test]
    fn require_missing_errors() {
        let a = parse("serve");
        assert!(a.require("run").is_err());
    }

    #[test]
    fn trailing_switch_before_flag() {
        let a = parse("x --fast --run r");
        assert!(a.switch("fast"));
        assert_eq!(a.get("run", ""), "r");
    }

    #[test]
    fn check_known_flags() {
        let a = parse("x --run r --oops 1");
        assert!(a.check_known(&["run"], &[]).is_err());
        assert!(a.check_known(&["run", "oops"], &[]).is_ok());
    }
}
