//! Empirical threshold determination (paper §4.5): pick the router-score
//! threshold on a small validation sample that maximizes cost advantage
//! subject to a performance-drop limit (default ≤ 1%), then report how it
//! generalizes to the test split (Table 3). [`calibrate_ladder`] is the
//! N-tier generalization: a proportional threshold ladder swept by a
//! single pivot under per-tier cost weights.

use crate::policy::{
    achieved_quality, achieved_quality_tiers, cost_advantage, cost_advantage_tiers, Policy,
    TierPolicy,
};
use crate::stats;

/// Outcome of calibrating on one labelled set.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    pub threshold: f32,
    pub cost_advantage: f64,
    pub drop_pct: f64,
}

/// Evaluate a fixed threshold on a labelled set.
pub fn evaluate_threshold(
    threshold: f32,
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
) -> Calibration {
    let assign = Policy::Threshold { threshold }.assign(scores);
    let base = stats::mean(q_large);
    let q = achieved_quality(&assign, q_small, q_large);
    Calibration {
        threshold,
        cost_advantage: cost_advantage(&assign),
        drop_pct: crate::metrics::quality_drop_pct(base, q),
    }
}

/// Grid-search the threshold delivering the highest cost advantage with
/// `drop_pct <= max_drop_pct` on the given (validation) sample. The grid
/// is the set of observed scores (every achievable assignment), exactly
/// what §4.5's grid search explores.
pub fn calibrate(
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
    max_drop_pct: f64,
) -> Calibration {
    if scores.is_empty() {
        // documented fallback instead of panicking: with nothing to
        // calibrate on, operate all-at-large (cost advantage 0, no
        // drop). INFINITY (not f32::MAX, a reachable score value)
        // guarantees no future score can clear the threshold.
        return Calibration { threshold: f32::INFINITY, cost_advantage: 0.0, drop_pct: 0.0 };
    }
    let mut candidates: Vec<f32> = scores.to_vec();
    candidates.push(f32::MAX); // all-at-large fallback (cost advantage 0)
    // total_cmp: observed scores can contain NaN (untrained router) and
    // the grid sort must not panic on them
    candidates.sort_by(f32::total_cmp);
    candidates.dedup();
    let mut best: Option<Calibration> = None;
    for &thr in &candidates {
        let c = evaluate_threshold(thr, scores, q_small, q_large);
        if c.drop_pct <= max_drop_pct {
            let better = match &best {
                None => true,
                Some(b) => {
                    c.cost_advantage > b.cost_advantage
                        || (c.cost_advantage == b.cost_advantage && c.drop_pct < b.drop_pct)
                }
            };
            if better {
                best = Some(c);
            }
        }
    }
    // the f32::MAX candidate (0% drop) is feasible for any non-negative
    // limit; a negative limit falls back to all-at-large rather than
    // panicking
    best.unwrap_or_else(|| evaluate_threshold(f32::MAX, scores, q_small, q_large))
}

/// Outcome of calibrating a threshold ladder over an N-tier fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct LadderCalibration {
    pub thresholds: Vec<f32>,
    pub cost_advantage: f64,
    pub drop_pct: f64,
}

/// Proportional K-tier ladder from a single pivot:
/// `t_i = pivot * (K-1-i)/(K-1)` for `i` in `0..K-1` — descending, with
/// `K == 2` reducing to the paper's single threshold `pivot`.
pub fn ladder_from_pivot(pivot: f32, k: usize) -> Vec<f32> {
    if k <= 1 {
        return Vec::new();
    }
    (0..k - 1)
        .map(|i| pivot * (k - 1 - i) as f32 / (k - 1) as f32)
        .collect()
}

/// Evaluate a fixed threshold ladder on a labelled set; `q_tiers[t][i]`
/// is query `i`'s expected quality at tier `t`, `costs` the per-tier
/// cost weights. Drop is vs all-at-most-expensive (the last tier).
pub fn evaluate_ladder(
    thresholds: &[f32],
    scores: &[f32],
    q_tiers: &[Vec<f64>],
    costs: &[f64],
) -> LadderCalibration {
    let assign = TierPolicy::Ladder { thresholds: thresholds.to_vec() }.assign(scores);
    let base = q_tiers.last().map(|row| stats::mean(row)).unwrap_or(0.0);
    let q = achieved_quality_tiers(&assign, q_tiers);
    LadderCalibration {
        thresholds: thresholds.to_vec(),
        cost_advantage: cost_advantage_tiers(&assign, costs),
        drop_pct: crate::metrics::quality_drop_pct(base, q),
    }
}

/// §4.5 generalized to K tiers: grid-search the proportional-ladder
/// pivot over the observed scores, keeping the ladder with the highest
/// cost advantage whose drop stays within `max_drop_pct`. The infinite
/// pivot (all-at-most-expensive, zero drop) keeps the search total on
/// any input, including empty score sets.
pub fn calibrate_ladder(
    scores: &[f32],
    q_tiers: &[Vec<f64>],
    costs: &[f64],
    max_drop_pct: f64,
) -> LadderCalibration {
    let k = q_tiers.len().max(1);
    let mut candidates: Vec<f32> = scores.iter().copied().filter(|s| s.is_finite()).collect();
    candidates.push(f32::INFINITY);
    candidates.sort_by(f32::total_cmp);
    candidates.dedup();
    let mut best: Option<LadderCalibration> = None;
    for &pivot in &candidates {
        let c = evaluate_ladder(&ladder_from_pivot(pivot, k), scores, q_tiers, costs);
        if c.drop_pct <= max_drop_pct {
            let better = match &best {
                None => true,
                Some(b) => {
                    c.cost_advantage > b.cost_advantage
                        || (c.cost_advantage == b.cost_advantage && c.drop_pct < b.drop_pct)
                }
            };
            if better {
                best = Some(c);
            }
        }
    }
    best.unwrap_or_else(|| {
        evaluate_ladder(&ladder_from_pivot(f32::INFINITY, k), scores, q_tiers, costs)
    })
}

/// Build a quality-indexed ladder family — §4.5 generalized along the
/// *quality* axis, the calibration behind the serving API's per-request
/// quality knob ([`crate::policy::LadderFamily`]).
///
/// Rung `j` of `0..=levels` targets quality level `q_j = j / levels`,
/// mapped to a drop budget by linear interpolation between the two
/// anchors the data pins down exactly: quality `1` allows `0%` drop
/// (all-at-most-expensive is always feasible) and quality `0` allows the
/// full drop of the all-at-cheapest assignment — the worst this fleet
/// can do, so the budget is never binding there. Each rung is then
/// calibrated with [`calibrate_ladder`] (max cost advantage subject to
/// its budget) and the family constructor enforces pointwise threshold
/// monotonicity across rungs, so raising a request's quality target can
/// only move it toward more capable tiers.
pub fn calibrate_quality_ladders(
    scores: &[f32],
    q_tiers: &[Vec<f64>],
    costs: &[f64],
    levels: usize,
) -> crate::Result<crate::policy::LadderFamily> {
    let k = q_tiers.len().max(1);
    let levels = levels.max(1);
    // drop of the all-at-cheapest assignment (thresholds nothing can
    // miss); a cheap tier that *beats* the top tier gives a negative
    // drop — clamp so budgets stay non-negative
    let all_cheap = vec![f32::NEG_INFINITY; k.saturating_sub(1)];
    let max_drop = evaluate_ladder(&all_cheap, scores, q_tiers, costs)
        .drop_pct
        .max(0.0);
    let rungs = (0..=levels)
        .map(|j| {
            let q = j as f32 / levels as f32;
            let budget = (1.0 - q as f64) * max_drop;
            let rung = calibrate_ladder(scores, q_tiers, costs, budget);
            (q, rung.thresholds)
        })
        .collect();
    crate::policy::LadderFamily::new(rungs)
}

/// Subsample `k` indices for the §4.5 "500 validation samples" protocol.
pub fn subsample(n: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = crate::rng::Rng::new(seed);
    rng.sample_indices(n, k.min(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic set: scores perfectly identify where small is as good.
    fn perfect_case(n: usize) -> (Vec<f32>, Vec<f64>, Vec<f64>) {
        let mut scores = Vec::new();
        let mut qs = Vec::new();
        let mut ql = Vec::new();
        for i in 0..n {
            if i % 4 == 0 {
                scores.push(0.9);
                qs.push(-1.0);
            } else {
                scores.push(0.1);
                qs.push(-4.0);
            }
            ql.push(-1.0);
        }
        (scores, qs, ql)
    }

    #[test]
    fn calibrate_finds_free_cost_advantage() {
        let (scores, qs, ql) = perfect_case(100);
        let c = calibrate(&scores, &qs, &ql, 1.0);
        // 25% of queries are free wins
        assert!((c.cost_advantage - 0.25).abs() < 1e-9, "{c:?}");
        assert!(c.drop_pct <= 1e-9);
    }

    #[test]
    fn calibrate_respects_drop_limit() {
        crate::testing::check("calibration never exceeds limit", 50, |rng| {
            let n = rng.range(10, 200);
            let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let qs: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
            let ql: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
            let limit = rng.next_f64() * 5.0;
            let c = calibrate(&scores, &qs, &ql, limit);
            assert!(c.drop_pct <= limit + 1e-9, "{c:?} limit {limit}");
        });
    }

    #[test]
    fn zero_limit_still_feasible() {
        let (scores, qs, ql) = perfect_case(40);
        let c = calibrate(&scores, &qs, &ql, 0.0);
        assert!(c.drop_pct <= 1e-12);
    }

    #[test]
    fn evaluate_threshold_extremes() {
        let (scores, qs, ql) = perfect_case(40);
        let all_large = evaluate_threshold(f32::MAX, &scores, &qs, &ql);
        assert_eq!(all_large.cost_advantage, 0.0);
        assert!(all_large.drop_pct.abs() < 1e-12);
        let all_small = evaluate_threshold(0.0, &scores, &qs, &ql);
        assert_eq!(all_small.cost_advantage, 1.0);
        assert!(all_small.drop_pct > 0.0);
    }

    #[test]
    fn calibrate_empty_input_falls_back_to_all_large() {
        let c = calibrate(&[], &[], &[], 1.0);
        assert_eq!(c.cost_advantage, 0.0);
        assert_eq!(c.drop_pct, 0.0);
        // INFINITY: unsatisfiable by any future score, unlike f32::MAX
        assert_eq!(c.threshold, f32::INFINITY);
        assert!(Policy::Threshold { threshold: c.threshold }
            .assign(&[f32::MAX])
            .iter()
            .all(|&s| !s));
    }

    #[test]
    fn ladder_from_pivot_shapes() {
        assert_eq!(ladder_from_pivot(0.6, 2), vec![0.6]);
        let t = ladder_from_pivot(0.6, 3);
        assert_eq!(t.len(), 2);
        assert!((t[0] - 0.6).abs() < 1e-6 && (t[1] - 0.3).abs() < 1e-6);
        assert!(ladder_from_pivot(0.6, 1).is_empty());
    }

    #[test]
    fn ladder_calibration_k2_matches_pair_calibration() {
        let (scores, qs, ql) = perfect_case(100);
        let pair = calibrate(&scores, &qs, &ql, 1.0);
        let ladder = calibrate_ladder(&scores, &[qs, ql], &[0.0, 1.0], 1.0);
        assert!((ladder.cost_advantage - pair.cost_advantage).abs() < 1e-9);
        assert!((ladder.drop_pct - pair.drop_pct).abs() < 1e-9);
    }

    #[test]
    fn ladder_calibration_three_tiers_respects_limit() {
        crate::testing::check("3-tier ladder respects drop limit", 30, |rng| {
            let n = rng.range(10, 150);
            let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let q: Vec<Vec<f64>> = (0..3)
                .map(|_| (0..n).map(|_| -(rng.next_f64() * 5.0)).collect())
                .collect();
            let costs = [0.0, 0.4, 1.0];
            let limit = rng.next_f64() * 4.0;
            let c = calibrate_ladder(&scores, &q, &costs, limit);
            assert!(c.drop_pct <= limit + 1e-9, "{c:?} limit {limit}");
            assert!((0.0..=1.0 + 1e-12).contains(&c.cost_advantage));
        });
    }

    #[test]
    fn ladder_calibration_empty_scores_is_total() {
        let c = calibrate_ladder(&[], &[vec![], vec![]], &[0.0, 1.0], 1.0);
        assert_eq!(c.cost_advantage, 0.0);
        assert_eq!(c.drop_pct, 0.0);
    }

    #[test]
    fn quality_ladders_anchor_the_extremes() {
        // separable 2-tier data: 25% of queries are free wins for the
        // cheap tier, the rest cost quality
        let (scores, qs, ql) = perfect_case(100);
        let fam = calibrate_quality_ladders(&scores, &[qs.clone(), ql.clone()], &[0.0, 1.0], 4)
            .unwrap();
        assert_eq!(fam.n_tiers(), 2);
        // quality 0: no budget binds — everything at the cheapest tier
        assert!(scores.iter().all(|&s| fam.assign_one(0.0, s) == 0));
        // quality 1: zero-drop budget — only the free wins stay cheap
        let assign: Vec<usize> = scores.iter().map(|&s| fam.assign_one(1.0, s)).collect();
        let q = crate::policy::achieved_quality_tiers(&assign, &[qs, ql.clone()]);
        let drop = crate::metrics::quality_drop_pct(crate::stats::mean(&ql), q);
        assert!(drop <= 1e-9, "quality-1 rung leaked drop: {drop}");
        let frac_cheap =
            assign.iter().filter(|&&t| t == 0).count() as f64 / assign.len() as f64;
        assert!((frac_cheap - 0.25).abs() < 1e-9, "{frac_cheap}");
    }

    #[test]
    fn quality_ladders_survive_nan_scores_and_degenerate_inputs() {
        // NaN scores must not panic the candidate sort (regression) and
        // empty inputs must produce a usable (all-conservative) family
        let scores = vec![0.9, f32::NAN, 0.1];
        let q = vec![vec![-3.0; 3], vec![-1.0; 3]];
        let fam = calibrate_quality_ladders(&scores, &q, &[0.0, 1.0], 3).unwrap();
        assert_eq!(fam.n_tiers(), 2);
        let fam = calibrate_quality_ladders(&[], &[vec![], vec![]], &[0.0, 1.0], 3).unwrap();
        assert_eq!(fam.assign_one(0.5, 0.9), 1, "no data => route conservatively");
    }

    #[test]
    fn subsample_is_deterministic_and_distinct() {
        let a = subsample(1000, 500, 7);
        let b = subsample(1000, 500, 7);
        assert_eq!(a, b);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 500);
    }
}
