//! Empirical threshold determination (paper §4.5): pick the router-score
//! threshold on a small validation sample that maximizes cost advantage
//! subject to a performance-drop limit (default ≤ 1%), then report how it
//! generalizes to the test split (Table 3).

use crate::policy::{achieved_quality, cost_advantage, Policy};
use crate::stats;

/// Outcome of calibrating on one labelled set.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    pub threshold: f32,
    pub cost_advantage: f64,
    pub drop_pct: f64,
}

/// Evaluate a fixed threshold on a labelled set.
pub fn evaluate_threshold(
    threshold: f32,
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
) -> Calibration {
    let assign = Policy::Threshold { threshold }.assign(scores);
    let base = stats::mean(q_large);
    let q = achieved_quality(&assign, q_small, q_large);
    Calibration {
        threshold,
        cost_advantage: cost_advantage(&assign),
        drop_pct: crate::metrics::quality_drop_pct(base, q),
    }
}

/// Grid-search the threshold delivering the highest cost advantage with
/// `drop_pct <= max_drop_pct` on the given (validation) sample. The grid
/// is the set of observed scores (every achievable assignment), exactly
/// what §4.5's grid search explores.
pub fn calibrate(
    scores: &[f32],
    q_small: &[f64],
    q_large: &[f64],
    max_drop_pct: f64,
) -> Calibration {
    assert!(!scores.is_empty());
    let mut candidates: Vec<f32> = scores.to_vec();
    candidates.push(f32::MAX); // all-at-large fallback (cost advantage 0)
    candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    candidates.dedup();
    let mut best: Option<Calibration> = None;
    for &thr in &candidates {
        let c = evaluate_threshold(thr, scores, q_small, q_large);
        if c.drop_pct <= max_drop_pct {
            let better = match &best {
                None => true,
                Some(b) => {
                    c.cost_advantage > b.cost_advantage
                        || (c.cost_advantage == b.cost_advantage && c.drop_pct < b.drop_pct)
                }
            };
            if better {
                best = Some(c);
            }
        }
    }
    // the f32::MAX fallback always satisfies the constraint (0% drop)
    best.expect("calibrate: all-at-large candidate must be feasible")
}

/// Subsample `k` indices for the §4.5 "500 validation samples" protocol.
pub fn subsample(n: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = crate::rng::Rng::new(seed);
    rng.sample_indices(n, k.min(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic set: scores perfectly identify where small is as good.
    fn perfect_case(n: usize) -> (Vec<f32>, Vec<f64>, Vec<f64>) {
        let mut scores = Vec::new();
        let mut qs = Vec::new();
        let mut ql = Vec::new();
        for i in 0..n {
            if i % 4 == 0 {
                scores.push(0.9);
                qs.push(-1.0);
            } else {
                scores.push(0.1);
                qs.push(-4.0);
            }
            ql.push(-1.0);
        }
        (scores, qs, ql)
    }

    #[test]
    fn calibrate_finds_free_cost_advantage() {
        let (scores, qs, ql) = perfect_case(100);
        let c = calibrate(&scores, &qs, &ql, 1.0);
        // 25% of queries are free wins
        assert!((c.cost_advantage - 0.25).abs() < 1e-9, "{c:?}");
        assert!(c.drop_pct <= 1e-9);
    }

    #[test]
    fn calibrate_respects_drop_limit() {
        crate::testing::check("calibration never exceeds limit", 50, |rng| {
            let n = rng.range(10, 200);
            let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let qs: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
            let ql: Vec<f64> = (0..n).map(|_| -(rng.next_f64() * 5.0)).collect();
            let limit = rng.next_f64() * 5.0;
            let c = calibrate(&scores, &qs, &ql, limit);
            assert!(c.drop_pct <= limit + 1e-9, "{c:?} limit {limit}");
        });
    }

    #[test]
    fn zero_limit_still_feasible() {
        let (scores, qs, ql) = perfect_case(40);
        let c = calibrate(&scores, &qs, &ql, 0.0);
        assert!(c.drop_pct <= 1e-12);
    }

    #[test]
    fn evaluate_threshold_extremes() {
        let (scores, qs, ql) = perfect_case(40);
        let all_large = evaluate_threshold(f32::MAX, &scores, &qs, &ql);
        assert_eq!(all_large.cost_advantage, 0.0);
        assert!(all_large.drop_pct.abs() < 1e-12);
        let all_small = evaluate_threshold(0.0, &scores, &qs, &ql);
        assert_eq!(all_small.cost_advantage, 1.0);
        assert!(all_small.drop_pct > 0.0);
    }

    #[test]
    fn subsample_is_deterministic_and_distinct() {
        let a = subsample(1000, 500, 7);
        let b = subsample(1000, 500, 7);
        assert_eq!(a, b);
        let mut d = a.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 500);
    }
}
