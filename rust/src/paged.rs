//! Block-paged KV storage + cross-request shared-prefix reuse
//! (manifest v4; DESIGN.md §10).
//!
//! Three pieces, composed by the serving worker:
//!
//! * [`BlockAllocator`] — refcounted free-list over a per-layer device
//!   block pool of `kvpool` blocks × `kvblock` tokens. Block 0 is the
//!   reserved *null block*: free decode lanes and unallocated table
//!   entries point at it, so their writes land harmlessly and their
//!   garbage keys sit behind the causal mask. It is never allocated and
//!   never freed.
//! * [`PagedKvCache`] — the device-resident pool buffer pair
//!   (`[L, kvpool, kvblock, H, Dh]` per K and V). Strictly
//!   device-resident: unlike the dense [`crate::batching::KvCache`]
//!   there is no host fallback — pre-v4 manifests keep the dense path
//!   instead (the fallback matrix in DESIGN.md §10).
//! * [`PrefixCache`] — a trie over `kvblock`-sized prompt-token chunks.
//!   Each edge holds a pool block whose KV is fully determined by the
//!   token prefix on the path (KV depends only on model weights and
//!   prefix tokens, never on seeds/temperature), so any request whose
//!   prompt shares the path reuses those blocks read-only. The trie
//!   holds one refcount per adopted block; requests hold one per table
//!   entry; a block returns to the free list when both drop it.
//!
//! **Sharing discipline** (the copy-on-extend rule): only *full* blocks
//! — entirely covered by the prompt — are ever shared. A request's tail
//! block (the one its decode writes land in) is private; the trie may
//! record a tail block for the exact-full-prompt greedy fast path, but a
//! hit *copies* it into a fresh private block (`kv_block_copy`) rather
//! than referencing it writable. Stale answer-KV copied along sits at
//! positions `> pos` — masked until progressively overwritten by the new
//! owner's own writes, the same invariant the dense path relies on.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::io::Tensor;
use crate::runtime::{OutValue, Runtime};

/// Pool blocks a prompt of `prompt_len` tokens needs: its full and
/// partial prompt blocks *plus* the block holding position `prompt_len`
/// (the first decode write, which happens before any growth check).
pub fn blocks_needed(prompt_len: usize, block_tokens: usize) -> usize {
    prompt_len / block_tokens + 1
}

/// Refcounted block allocator over a pool of `nblk` blocks; block 0 is
/// reserved (null) and never handed out. All failure modes are `Err`s,
/// not panics — pool exhaustion surfaces as `Ok(None)` from [`alloc`]
/// so the serving layer can evict or shed (`SubmitError::Busy`) instead
/// of crashing (pinned by property tests).
///
/// [`alloc`]: BlockAllocator::alloc
pub struct BlockAllocator {
    free: Vec<u32>,
    refcnt: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(nblk: usize) -> Self {
        assert!(nblk >= 2, "pool needs the null block plus at least one");
        let mut refcnt = vec![0u32; nblk];
        refcnt[0] = 1; // null block: permanently referenced
        BlockAllocator {
            // reversed so the first allocations are 1, 2, 3, ...
            free: (1..nblk as u32).rev().collect(),
            refcnt,
        }
    }

    /// Total pool size including the null block.
    pub fn capacity(&self) -> usize {
        self.refcnt.len()
    }

    /// Blocks available for allocation — O(1).
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Allocated fraction of the allocatable pool (excludes the null
    /// block): the `serving.kv_blocks_utilization` gauge.
    pub fn utilization(&self) -> f64 {
        let usable = self.capacity() - 1;
        if usable == 0 {
            return 0.0;
        }
        (usable - self.free_count()) as f64 / usable as f64
    }

    /// Allocate a block with refcount 1, or `None` when the pool is
    /// exhausted (caller evicts/sheds — never a panic).
    pub fn alloc(&mut self) -> Option<u32> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcnt[id as usize], 0);
        self.refcnt[id as usize] = 1;
        Some(id)
    }

    /// Add a reference to a live block (sharing it).
    pub fn incref(&mut self, id: u32) -> Result<()> {
        ensure!(id != 0, "incref on the null block");
        let rc = self
            .refcnt
            .get_mut(id as usize)
            .with_context(|| format!("incref: block {id} out of range"))?;
        ensure!(*rc > 0, "incref on free block {id}");
        *rc += 1;
        Ok(())
    }

    /// Drop a reference; returns `true` when this was the last one and
    /// the block went back on the free list. Double-frees are `Err`s.
    pub fn decref(&mut self, id: u32) -> Result<bool> {
        ensure!(id != 0, "decref on the null block");
        let rc = self
            .refcnt
            .get_mut(id as usize)
            .with_context(|| format!("decref: block {id} out of range"))?;
        ensure!(*rc > 0, "double free of block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
            Ok(true)
        } else {
            Ok(false)
        }
    }

    /// Current refcount (test/diagnostic).
    pub fn refcount(&self, id: u32) -> u32 {
        self.refcnt.get(id as usize).copied().unwrap_or(0)
    }
}

/// Drop one reference for every nonzero entry of a request's block
/// table and zero it (completion/cancel release).
pub fn release_table(table: &mut [u32], alloc: &mut BlockAllocator) -> Result<()> {
    for b in table.iter_mut() {
        if *b != 0 {
            alloc.decref(*b)?;
            *b = 0;
        }
    }
    Ok(())
}

/// Device-resident paged KV pool pair, shape `[L, kvpool, kvblock, H,
/// Dh]` per K and V. Created as zeros and uploaded once at worker start;
/// after that it only moves through `Exec::run_resident` state outputs
/// (`decode_paged`, `kv_install_paged@B`, `kv_block_copy`) and never
/// crosses the host boundary again — the paged extension of the §8
/// residency ladder.
pub struct PagedKvCache {
    k: Arc<xla::PjRtBuffer>,
    v: Arc<xla::PjRtBuffer>,
    pub layers: usize,
    pub nblk: usize,
    pub block: usize,
    pub heads: usize,
    pub head_dim: usize,
}

impl PagedKvCache {
    /// Allocate zeroed pools on the device (one-time metered upload).
    pub fn zeros_on_device(
        rt: &Runtime,
        layers: usize,
        nblk: usize,
        block: usize,
        heads: usize,
        head_dim: usize,
    ) -> Result<Self> {
        let dims = vec![layers, nblk, block, heads, head_dim];
        let n: usize = dims.iter().product();
        let zeros = Tensor::f32(dims, vec![0.0; n]);
        let k = rt.upload(&zeros)?;
        let v = rt.upload(&zeros)?;
        Ok(PagedKvCache { k, v, layers, nblk, block, heads, head_dim })
    }

    pub fn dims(&self) -> [usize; 5] {
        [self.layers, self.nblk, self.block, self.heads, self.head_dim]
    }

    /// Total size of both pools in bytes.
    pub fn byte_size(&self) -> u64 {
        2 * self.dims().iter().product::<usize>() as u64 * crate::runtime::ELEM_BYTES as u64
    }

    pub fn buffers(&self) -> (Arc<xla::PjRtBuffer>, Arc<xla::PjRtBuffer>) {
        (self.k.clone(), self.v.clone())
    }

    /// Bind the pools as resident artifact inputs `k_idx`/`v_idx`.
    pub fn bind(
        &self,
        k_idx: usize,
        v_idx: usize,
        resident: &mut HashMap<usize, Arc<xla::PjRtBuffer>>,
    ) {
        resident.insert(k_idx, self.k.clone());
        resident.insert(v_idx, self.v.clone());
    }

    /// Adopt the pools returned by a paged artifact. The paged path has
    /// no host fallback: a host output means the artifact was not
    /// untupled and would silently wreck the residency contract —
    /// refuse instead.
    pub fn update(&mut self, k: OutValue, v: OutValue) -> Result<()> {
        match (k, v) {
            (OutValue::Device(k), OutValue::Device(v)) => {
                self.k = k;
                self.v = v;
                Ok(())
            }
            _ => bail!("paged kv pool came back host-resident (artifact not untupled?)"),
        }
    }
}

/// Exact-full-prompt hit: everything admission needs to skip prefill.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FullHit {
    /// The cached tail block to copy-on-extend into a private block
    /// (`None` when the prompt length is block-aligned — the private
    /// first-write block starts empty).
    pub tail_block: Option<u32>,
    /// Greedy first token sampled when the entry was recorded.
    pub first_tok: i32,
    /// Its logprob.
    pub logp: f32,
}

/// Result of a prefix lookup.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixHit {
    /// Pool blocks for the longest matched run of *full* prompt chunks,
    /// in position order (`shared[j]` covers tokens `[j*kvblock,
    /// (j+1)*kvblock)`). Not yet referenced — the caller increfs the
    /// ones it adopts.
    pub shared: Vec<u32>,
    /// Exact whole-prompt match (only usable for greedy sampling: the
    /// recorded first token is seed-independent only at temp 0).
    pub full: Option<FullHit>,
}

impl PrefixHit {
    /// Prompt tokens whose prefill/install work the hit saves.
    pub fn shared_tokens(&self, block_tokens: usize, prompt_len: usize) -> usize {
        if self.full.is_some() {
            prompt_len
        } else {
            self.shared.len() * block_tokens
        }
    }
}

const MAX_TAILS_PER_NODE: usize = 8;

struct Tail {
    tail: Vec<i32>,
    /// 0 = no tail block (block-aligned prompt).
    block: u32,
    first_tok: i32,
    logp: f32,
    last_used: u64,
}

struct Node {
    /// Chunk tokens keying this node under `parent` (empty for root).
    key: Vec<i32>,
    parent: usize,
    /// Pool block holding this chunk's KV (0 for the root only).
    block: u32,
    children: HashMap<Vec<i32>, usize>,
    tails: Vec<Tail>,
    last_used: u64,
    live: bool,
}

/// Trie over block-sized prompt-token chunks mapping shared prefixes to
/// refcounted pool blocks. Single-owner (one per worker, same thread as
/// the decode loop). LRU eviction is leaf-only, so interior blocks —
/// still reachable by longer cached prefixes — are never freed under a
/// live descendant.
pub struct PrefixCache {
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    block_tokens: usize,
    clock: u64,
    /// Lookups that found at least one shared block (hit-rate metric).
    pub hits: u64,
    pub lookups: u64,
}

impl PrefixCache {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        PrefixCache {
            nodes: vec![Node {
                key: vec![],
                parent: 0,
                block: 0,
                children: HashMap::new(),
                tails: vec![],
                last_used: 0,
                live: true,
            }],
            free_nodes: vec![],
            block_tokens,
            clock: 0,
            hits: 0,
            lookups: 0,
        }
    }

    /// Fraction of lookups that reused at least one cached block.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Number of live trie entries (nodes excluding root, plus tails).
    pub fn len(&self) -> usize {
        let nodes = self.nodes.iter().filter(|n| n.live).count() - 1;
        let tails: usize = self.nodes.iter().filter(|n| n.live).map(|n| n.tails.len()).sum();
        nodes + tails
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Longest-prefix lookup. Touches LRU stamps on the matched path.
    pub fn lookup(&mut self, prompt: &[i32]) -> PrefixHit {
        self.clock += 1;
        self.lookups += 1;
        let bt = self.block_tokens;
        let full = prompt.len() / bt;
        let mut node = 0usize;
        let mut shared = Vec::new();
        for j in 0..full {
            let chunk = &prompt[j * bt..(j + 1) * bt];
            let Some(&c) = self.nodes[node].children.get(chunk) else { break };
            self.nodes[c].last_used = self.clock;
            shared.push(self.nodes[c].block);
            node = c;
        }
        let mut full_hit = None;
        if shared.len() == full {
            let tail = &prompt[full * bt..];
            let clock = self.clock;
            if let Some(t) = self.nodes[node].tails.iter_mut().find(|t| t.tail == tail) {
                t.last_used = clock;
                full_hit = Some(FullHit {
                    tail_block: (t.block != 0).then_some(t.block),
                    first_tok: t.first_tok,
                    logp: t.logp,
                });
            }
        }
        if !shared.is_empty() || full_hit.is_some() {
            self.hits += 1;
        }
        PrefixHit { shared, full: full_hit }
    }

    /// Record an admitted prompt's blocks. `table[j]` must hold the pool
    /// block covering chunk `j` (shared or freshly installed). Chunks
    /// already in the trie are left untouched (their blocks *are* the
    /// shared ones); new chunks adopt the request's block with an
    /// incref. `first` — the sampled first token and its logprob —
    /// is recorded as an exact-hit tail entry only when sampling was
    /// greedy (pass `None` otherwise: at temp > 0 the first token is
    /// seed-dependent and must not be replayed to other requests).
    pub fn insert(
        &mut self,
        prompt: &[i32],
        table: &[u32],
        first: Option<(i32, f32)>,
        alloc: &mut BlockAllocator,
    ) -> Result<()> {
        self.clock += 1;
        let bt = self.block_tokens;
        let full = prompt.len() / bt;
        ensure!(
            table.len() >= blocks_needed(prompt.len(), bt),
            "prefix insert: table covers {} blocks, prompt needs {}",
            table.len(),
            blocks_needed(prompt.len(), bt)
        );
        let mut node = 0usize;
        for j in 0..full {
            let chunk = &prompt[j * bt..(j + 1) * bt];
            let next = self.nodes[node].children.get(chunk).copied();
            node = match next {
                Some(c) => {
                    self.nodes[c].last_used = self.clock;
                    c
                }
                None => {
                    let b = table[j];
                    ensure!(b != 0, "prefix insert: chunk {j} has no block");
                    alloc.incref(b)?;
                    let idx = self.new_node(chunk.to_vec(), node, b);
                    self.nodes[node].children.insert(chunk.to_vec(), idx);
                    idx
                }
            };
        }
        if let Some((first_tok, logp)) = first {
            let tail = &prompt[full * bt..];
            if !self.nodes[node].tails.iter().any(|t| t.tail == tail) {
                let block = if tail.is_empty() {
                    0
                } else {
                    let b = table[full];
                    ensure!(b != 0, "prefix insert: tail chunk has no block");
                    alloc.incref(b)?;
                    b
                };
                if self.nodes[node].tails.len() >= MAX_TAILS_PER_NODE {
                    let oldest = self
                        .nodes[node]
                        .tails
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, t)| t.last_used)
                        .map(|(i, _)| i)
                        .expect("nonempty");
                    let t = self.nodes[node].tails.swap_remove(oldest);
                    if t.block != 0 {
                        alloc.decref(t.block)?;
                    }
                }
                let clock = self.clock;
                self.nodes[node].tails.push(Tail { tail: tail.to_vec(), block, first_tok, logp, last_used: clock });
            }
        }
        Ok(())
    }

    fn new_node(&mut self, key: Vec<i32>, parent: usize, block: u32) -> usize {
        let node = Node {
            key,
            parent,
            block,
            children: HashMap::new(),
            tails: vec![],
            last_used: self.clock,
            live: true,
        };
        match self.free_nodes.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Evict least-recently-used leaf entries (tails first-class, then
    /// childless/tailless nodes) until the allocator has at least
    /// `need_free` free blocks or nothing evictable remains. Returns the
    /// number of entries evicted. Interior nodes become leaves as their
    /// descendants go, so sustained pressure drains the whole trie.
    pub fn evict(&mut self, alloc: &mut BlockAllocator, need_free: usize) -> Result<usize> {
        let mut evicted = 0usize;
        while alloc.free_count() < need_free {
            // candidates: every tail entry, every leaf node
            let mut best: Option<(u64, usize, Option<usize>)> = None; // (stamp, node, tail idx)
            for (i, n) in self.nodes.iter().enumerate() {
                if !n.live {
                    continue;
                }
                for (ti, t) in n.tails.iter().enumerate() {
                    if best.map_or(true, |(s, _, _)| t.last_used < s) {
                        best = Some((t.last_used, i, Some(ti)));
                    }
                }
                if i != 0 && n.children.is_empty() && n.tails.is_empty() {
                    if best.map_or(true, |(s, _, _)| n.last_used < s) {
                        best = Some((n.last_used, i, None));
                    }
                }
            }
            let Some((_, i, tail)) = best else { break };
            match tail {
                Some(ti) => {
                    let t = self.nodes[i].tails.swap_remove(ti);
                    if t.block != 0 {
                        alloc.decref(t.block)?;
                    }
                }
                None => {
                    let (parent, key, block) = {
                        let n = &self.nodes[i];
                        (n.parent, n.key.clone(), n.block)
                    };
                    self.nodes[parent].children.remove(&key);
                    alloc.decref(block)?;
                    self.nodes[i].live = false;
                    self.nodes[i].children = HashMap::new();
                    self.nodes[i].key = vec![];
                    self.free_nodes.push(i);
                }
            }
            evicted += 1;
        }
        Ok(evicted)
    }

    /// Drop every entry, releasing all trie-held refcounts (worker
    /// shutdown / tests).
    pub fn clear(&mut self, alloc: &mut BlockAllocator) -> Result<()> {
        self.evict(alloc, usize::MAX)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_needed_includes_first_write() {
        assert_eq!(blocks_needed(0, 8), 1);
        assert_eq!(blocks_needed(7, 8), 1);
        assert_eq!(blocks_needed(8, 8), 2); // pos 8 = first write -> block 1
        assert_eq!(blocks_needed(9, 8), 2);
        assert_eq!(blocks_needed(16, 8), 3);
    }

    #[test]
    fn allocator_basics() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.capacity(), 4);
        assert_eq!(a.free_count(), 3);
        assert_eq!(a.utilization(), 0.0);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        assert_ne!(b1, 0);
        assert_ne!(b1, b2);
        assert!((a.utilization() - 2.0 / 3.0).abs() < 1e-12);
        a.incref(b1).unwrap();
        assert!(!a.decref(b1).unwrap()); // still shared
        assert!(a.decref(b1).unwrap()); // freed
        assert_eq!(a.free_count(), 2);
        // exhaustion is graceful
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_none());
    }

    #[test]
    fn allocator_rejects_null_and_double_free() {
        let mut a = BlockAllocator::new(3);
        assert!(a.incref(0).is_err());
        assert!(a.decref(0).is_err());
        assert!(a.incref(99).is_err());
        let b = a.alloc().unwrap();
        assert!(a.incref(b).is_ok());
        a.decref(b).unwrap();
        a.decref(b).unwrap();
        assert!(a.decref(b).is_err(), "double free must be an error");
        assert!(a.incref(b).is_err(), "incref on free block must be an error");
    }

    #[test]
    fn release_table_zeroes_and_frees() {
        let mut a = BlockAllocator::new(8);
        let mut table = vec![0u32; 4];
        table[0] = a.alloc().unwrap();
        table[2] = a.alloc().unwrap();
        release_table(&mut table, &mut a).unwrap();
        assert!(table.iter().all(|&b| b == 0));
        assert_eq!(a.free_count(), 7);
        // releasing an all-zero table is a no-op
        release_table(&mut table, &mut a).unwrap();
        assert_eq!(a.free_count(), 7);
    }

    #[test]
    fn allocator_property_refcount_balance() {
        crate::testing::check("allocator conservation", 60, |rng| {
            let cap = rng.range(2, 24);
            let mut a = BlockAllocator::new(cap);
            // model: refcounts we believe each block has
            let mut model: HashMap<u32, u32> = HashMap::new();
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        if let Some(b) = a.alloc() {
                            assert!(!model.contains_key(&b), "allocated a live block");
                            model.insert(b, 1);
                        } else {
                            assert_eq!(model.len(), cap - 1, "spurious exhaustion");
                        }
                    }
                    1 => {
                        let live: Vec<u32> = model.keys().copied().collect();
                        if !live.is_empty() {
                            let b = live[rng.below(live.len())];
                            a.incref(b).unwrap();
                            *model.get_mut(&b).unwrap() += 1;
                        }
                    }
                    _ => {
                        let live: Vec<u32> = model.keys().copied().collect();
                        if !live.is_empty() {
                            let b = live[rng.below(live.len())];
                            let freed = a.decref(b).unwrap();
                            let rc = model.get_mut(&b).unwrap();
                            *rc -= 1;
                            assert_eq!(freed, *rc == 0);
                            if *rc == 0 {
                                model.remove(&b);
                            }
                        }
                    }
                }
                assert_eq!(a.free_count(), cap - 1 - model.len());
                for (&b, &rc) in &model {
                    assert_eq!(a.refcount(b), rc);
                }
            }
            // drain: refcounts balance back to a fully free pool
            for (b, rc) in model.drain() {
                for i in 0..rc {
                    assert_eq!(a.decref(b).unwrap(), i + 1 == rc);
                }
            }
            assert_eq!(a.free_count(), cap - 1);
            assert_eq!(a.utilization(), 0.0);
        });
    }

    /// Build a table for `prompt`, reusing `hit.shared` and allocating
    /// the rest — the same steps the serving admission path takes.
    fn admit(
        prompt: &[i32],
        bt: usize,
        cache: &mut PrefixCache,
        alloc: &mut BlockAllocator,
    ) -> Option<Vec<u32>> {
        let hit = cache.lookup(prompt);
        let need = blocks_needed(prompt.len(), bt);
        let mut table = vec![0u32; need];
        for (j, &b) in hit.shared.iter().take(need).enumerate() {
            alloc.incref(b).unwrap();
            table[j] = b;
        }
        let have = hit.shared.len().min(need);
        for slot in table.iter_mut().skip(have) {
            match alloc.alloc() {
                Some(b) => *slot = b,
                None => {
                    // roll back partial allocation (what serve does
                    // before returning Busy)
                    release_table(&mut table, alloc).unwrap();
                    return None;
                }
            }
        }
        cache.insert(prompt, &table, Some((7, -0.5)), alloc).unwrap();
        Some(table)
    }

    #[test]
    fn prefix_trie_shares_full_blocks_only() {
        let bt = 4;
        let mut alloc = BlockAllocator::new(64);
        let mut cache = PrefixCache::new(bt);
        // 10 tokens: 2 full chunks + tail of 2
        let p1: Vec<i32> = (1..=10).collect();
        assert_eq!(cache.lookup(&p1), PrefixHit { shared: vec![], full: None });
        let t1 = admit(&p1, bt, &mut cache, &mut alloc).unwrap();
        assert_eq!(t1.len(), 3);
        // same prompt again: both full chunks shared + exact tail hit
        let hit = cache.lookup(&p1);
        assert_eq!(hit.shared, vec![t1[0], t1[1]]);
        let full = hit.full.unwrap();
        assert_eq!(full.tail_block, Some(t1[2]));
        assert_eq!(full.first_tok, 7);
        assert_eq!(hit.shared_tokens(bt, p1.len()), 10);
        // longer prompt with the same first 8 tokens: shares exactly the
        // full chunks, not the tail
        let mut p2 = p1.clone();
        p2.extend([99, 98, 97]); // 13 tokens: 3 full chunks + tail of 1
        let hit2 = cache.lookup(&p2);
        assert_eq!(hit2.shared, vec![t1[0], t1[1]]);
        assert!(hit2.full.is_none());
        assert_eq!(hit2.shared_tokens(bt, p2.len()), 8);
        // diverging prompt shares nothing
        let p3: Vec<i32> = (100..=110).collect();
        assert_eq!(cache.lookup(&p3).shared, vec![]);
    }

    #[test]
    fn copy_on_extend_never_mutates_parent_blocks() {
        let bt = 4;
        let mut alloc = BlockAllocator::new(64);
        let mut cache = PrefixCache::new(bt);
        let p1: Vec<i32> = (1..=10).collect();
        let t1 = admit(&p1, bt, &mut cache, &mut alloc).unwrap();
        let rc_before: Vec<u32> = t1.iter().map(|&b| alloc.refcount(b)).collect();
        // a request extending the shared prefix gets fresh blocks for
        // everything past the shared full chunks — the parent's block
        // ids keep their identity and gain refs only on the shared part
        let mut p2 = p1.clone();
        p2.extend([50, 51, 52, 53, 54, 55]); // 16 tokens: 4 chunks + first-write block
        let t2 = admit(&p2, bt, &mut cache, &mut alloc).unwrap();
        assert_eq!(&t2[..2], &t1[..2], "shared full chunks reuse parent blocks");
        assert_ne!(t2[2], t1[2], "tail/extension blocks are private");
        assert!(t2[2..].iter().all(|&b| b != 0 && !t1.contains(&b)));
        // parent's tail block refcount unchanged; shared chunks +1 user
        // +1 trie-adoption of p2's chunk-2... which is a different block
        assert_eq!(alloc.refcount(t1[2]), rc_before[2]);
        assert!(alloc.refcount(t1[0]) > rc_before[0]);
    }

    #[test]
    fn refcounts_balance_at_drain() {
        let bt = 4;
        crate::testing::check("prefix trie drain balance", 40, |rng| {
            let mut alloc = BlockAllocator::new(2 + rng.range(16, 96));
            let mut cache = PrefixCache::new(bt);
            let mut tables: Vec<Vec<u32>> = Vec::new();
            for _ in 0..rng.range(1, 30) {
                // prompts drawn from few shapes so prefixes collide often
                let base = rng.below(3) as i32 * 100;
                let len = rng.range(1, 19);
                let prompt: Vec<i32> = (0..len as i32).map(|i| base + i).collect();
                if let Some(t) = admit(&prompt, bt, &mut cache, &mut alloc) {
                    tables.push(t);
                }
            }
            // release every request, then drain the trie: the pool must
            // come back fully free with zero net refcounts
            for t in tables.iter_mut() {
                release_table(t, &mut alloc).unwrap();
            }
            cache.clear(&mut alloc).unwrap();
            assert!(cache.is_empty());
            assert_eq!(alloc.free_count(), alloc.capacity() - 1);
        });
    }

    #[test]
    fn exhaustion_is_graceful_and_eviction_recovers() {
        let bt = 4;
        // tiny pool: 1 null + 6 blocks
        let mut alloc = BlockAllocator::new(7);
        let mut cache = PrefixCache::new(bt);
        let p1: Vec<i32> = (1..=8).collect(); // needs 3 blocks
        let t1 = admit(&p1, bt, &mut cache, &mut alloc).unwrap();
        assert_eq!(alloc.free_count(), 3);
        let p2: Vec<i32> = (100..=111).collect(); // needs 4 > 3 free
        assert!(admit(&p2, bt, &mut cache, &mut alloc).is_none(), "graceful None, no panic");
        // failed admission must not leak: free count unchanged
        assert_eq!(alloc.free_count(), 3);
        // release the first request; its blocks stay cached (trie refs)
        let mut t1 = t1;
        release_table(&mut t1, &mut alloc).unwrap();
        assert_eq!(alloc.free_count(), 3, "trie still holds the blocks");
        // eviction frees them and the big prompt fits
        cache.evict(&mut alloc, 4).unwrap();
        assert!(alloc.free_count() >= 4);
        assert!(admit(&p2, bt, &mut cache, &mut alloc).is_some());
    }

    #[test]
    fn eviction_is_leaf_only_and_lru() {
        let bt = 2;
        let mut alloc = BlockAllocator::new(32);
        let mut cache = PrefixCache::new(bt);
        let short: Vec<i32> = vec![1, 2, 3, 4]; // 2 chunks
        let long: Vec<i32> = vec![1, 2, 3, 4, 5, 6]; // extends short
        let mut ts = admit(&short, bt, &mut cache, &mut alloc).unwrap();
        let mut tl = admit(&long, bt, &mut cache, &mut alloc).unwrap();
        release_table(&mut ts, &mut alloc).unwrap();
        release_table(&mut tl, &mut alloc).unwrap();
        let free0 = alloc.free_count();
        // evict one entry at a time: tails and the deepest node go
        // before the shared interior chunks
        let shared_interior = tl[0];
        cache.evict(&mut alloc, free0 + 1).unwrap();
        assert!(alloc.refcount(shared_interior) > 0, "interior survives leaf eviction");
        // drain completely: everything is eventually evictable
        cache.clear(&mut alloc).unwrap();
        assert_eq!(alloc.free_count(), alloc.capacity() - 1);
        assert!(cache.is_empty());
        // the freed node slots are recycled
        let mut t3 = admit(&short, bt, &mut cache, &mut alloc).unwrap();
        release_table(&mut t3, &mut alloc).unwrap();
        cache.clear(&mut alloc).unwrap();
    }

    #[test]
    fn greedy_tail_only_recorded_when_asked() {
        let bt = 4;
        let mut alloc = BlockAllocator::new(32);
        let mut cache = PrefixCache::new(bt);
        let p: Vec<i32> = (1..=6).collect();
        let hit = cache.lookup(&p);
        assert!(hit.shared.is_empty());
        let mut table = vec![0u32; blocks_needed(p.len(), bt)];
        for s in table.iter_mut() {
            *s = alloc.alloc().unwrap();
        }
        // sampled (non-greedy) admission: no tail entry
        cache.insert(&p, &table, None, &mut alloc).unwrap();
        assert!(cache.lookup(&p).full.is_none());
        // greedy admission records the exact-hit entry
        cache.insert(&p, &table, Some((3, -0.1)), &mut alloc).unwrap();
        let full = cache.lookup(&p).full.unwrap();
        assert_eq!(full.first_tok, 3);
        assert_eq!(full.tail_block, Some(table[1]));
        // block-aligned prompt: full hit with no tail block to copy
        let pa: Vec<i32> = (10..=17).collect(); // 8 tokens, aligned
        let mut ta = vec![0u32; blocks_needed(pa.len(), bt)];
        for s in ta.iter_mut() {
            *s = alloc.alloc().unwrap();
        }
        cache.insert(&pa, &ta, Some((5, -0.2)), &mut alloc).unwrap();
        let fa = cache.lookup(&pa).full.unwrap();
        assert_eq!(fa.tail_block, None);
        assert_eq!(fa.first_tok, 5);
        // hygiene: everything releases
        release_table(&mut table, &mut alloc).unwrap();
        release_table(&mut ta, &mut alloc).unwrap();
        cache.clear(&mut alloc).unwrap();
        assert_eq!(alloc.free_count(), alloc.capacity() - 1);
    }

    #[test]
    fn hit_rate_counts_lookups() {
        let bt = 4;
        let mut alloc = BlockAllocator::new(32);
        let mut cache = PrefixCache::new(bt);
        let p: Vec<i32> = (1..=8).collect();
        assert_eq!(cache.hit_rate(), 0.0);
        let _t = admit(&p, bt, &mut cache, &mut alloc).unwrap(); // 1 lookup, miss
        cache.lookup(&p); // hit
        cache.lookup(&[99, 98]); // miss
        assert!((cache.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }
}
