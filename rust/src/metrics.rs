//! Serving metrics: latency histograms, routing counters, cost advantage
//! (§2.3 — the fraction of queries routed to the small model), and
//! quality-drop bookkeeping relative to the `all-at-large` baseline.

use std::sync::Mutex;
use std::time::Duration;

use crate::stats;

/// Latency recorder with exact percentiles (stores samples; serving runs
/// here are ≤ millions of requests, exactness beats HDR bucketing).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples_us: Mutex<Vec<u64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.samples_us.lock().unwrap().push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.lock().unwrap().len()
    }

    pub fn snapshot(&self) -> LatencySummary {
        let samples = self.samples_us.lock().unwrap().clone();
        LatencySummary::from_us(&samples)
    }
}

/// Point-in-time latency summary (microseconds internally).
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_ms: f64,
    pub std_err_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    pub fn from_us(samples: &[u64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let ms: Vec<f64> = samples.iter().map(|&x| x as f64 / 1000.0).collect();
        let mut sorted = ms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            n: ms.len(),
            mean_ms: stats::mean(&ms),
            std_err_ms: stats::std_err(&ms),
            p50_ms: stats::percentile_sorted(&sorted, 50.0),
            p95_ms: stats::percentile_sorted(&sorted, 95.0),
            p99_ms: stats::percentile_sorted(&sorted, 99.0),
            max_ms: *sorted.last().unwrap(),
        }
    }
}

/// Routing counters — tracks the paper's *cost advantage* online.
#[derive(Debug, Default)]
pub struct RoutingCounters {
    inner: Mutex<RoutingCountersInner>,
}

#[derive(Debug, Default, Clone)]
struct RoutingCountersInner {
    to_small: u64,
    to_large: u64,
    completed: u64,
    quality_sum: f64,
}

impl RoutingCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn route_small(&self) {
        self.inner.lock().unwrap().to_small += 1;
    }

    pub fn route_large(&self) {
        self.inner.lock().unwrap().to_large += 1;
    }

    pub fn complete(&self, quality: f64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.quality_sum += quality;
    }

    pub fn snapshot(&self) -> RoutingSnapshot {
        let g = self.inner.lock().unwrap().clone();
        let total = g.to_small + g.to_large;
        RoutingSnapshot {
            to_small: g.to_small,
            to_large: g.to_large,
            completed: g.completed,
            cost_advantage: if total == 0 {
                0.0
            } else {
                g.to_small as f64 / total as f64
            },
            mean_quality: if g.completed == 0 {
                0.0
            } else {
                g.quality_sum / g.completed as f64
            },
        }
    }
}

/// Point-in-time routing summary.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingSnapshot {
    pub to_small: u64,
    pub to_large: u64,
    pub completed: u64,
    /// Fraction of queries routed to the small model (paper §2.3).
    pub cost_advantage: f64,
    pub mean_quality: f64,
}

/// Percentage response-quality drop w.r.t. the all-at-large baseline —
/// the y-axis of Fig. 5 / the cells of Table 1. BART-analogue scores are
/// negative (log-probs), so "drop" is measured on the score magnitude:
/// positive = worse than all-at-large, negative = better.
pub fn quality_drop_pct(all_at_large: f64, achieved: f64) -> f64 {
    let denom = all_at_large.abs().max(1e-9);
    (all_at_large - achieved) / denom * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_percentiles() {
        let us: Vec<u64> = (1..=100).map(|i| i * 1000).collect(); // 1..100 ms
        let s = LatencySummary::from_us(&us);
        assert_eq!(s.n, 100);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0 && s.p99_ms <= 100.0);
        assert_eq!(s.max_ms, 100.0);
    }

    #[test]
    fn recorder_thread_safe() {
        use std::sync::Arc;
        let r = Arc::new(LatencyRecorder::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for i in 0..250 {
                        r.record(Duration::from_micros(i));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(r.count(), 1000);
    }

    #[test]
    fn cost_advantage_math() {
        let c = RoutingCounters::new();
        for _ in 0..3 {
            c.route_small();
        }
        for _ in 0..7 {
            c.route_large();
        }
        let s = c.snapshot();
        assert!((s.cost_advantage - 0.3).abs() < 1e-12);
    }

    #[test]
    fn quality_drop_sign_convention() {
        // all-at-large -2.0; achieved -2.2 => 10% drop (worse)
        assert!((quality_drop_pct(-2.0, -2.2) - 10.0).abs() < 1e-9);
        // achieved better than baseline => negative drop
        assert!(quality_drop_pct(-2.0, -1.9) < 0.0);
        // zero when identical
        assert_eq!(quality_drop_pct(-2.0, -2.0), 0.0);
    }
}
