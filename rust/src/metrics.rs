//! Serving metrics: latency histograms, per-tier routing counters, cost
//! advantage (§2.3 — the fraction of queries routed to the small model,
//! generalized to cost-weighted spend saved across an N-tier fleet), and
//! quality-drop bookkeeping relative to the `all-at-large` baseline.

use std::sync::Mutex;
use std::time::Duration;

use crate::stats;

/// Latency recorder with exact percentiles (stores samples; serving runs
/// here are ≤ millions of requests, exactness beats HDR bucketing).
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples_us: Mutex<Vec<u64>>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.samples_us.lock().unwrap().push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.lock().unwrap().len()
    }

    pub fn snapshot(&self) -> LatencySummary {
        let samples = self.samples_us.lock().unwrap().clone();
        LatencySummary::from_us(&samples)
    }
}

/// Point-in-time latency summary (microseconds internally).
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_ms: f64,
    pub std_err_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    pub fn from_us(samples: &[u64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        let ms: Vec<f64> = samples.iter().map(|&x| x as f64 / 1000.0).collect();
        let mut sorted = ms.clone();
        sorted.sort_by(f64::total_cmp);
        LatencySummary {
            n: ms.len(),
            mean_ms: stats::mean(&ms),
            std_err_ms: stats::std_err(&ms),
            p50_ms: stats::percentile_sorted(&sorted, 50.0),
            p95_ms: stats::percentile_sorted(&sorted, 95.0),
            p99_ms: stats::percentile_sorted(&sorted, 99.0),
            max_ms: *sorted.last().unwrap(),
        }
    }
}

/// Per-tier routing counters keyed by tier name — tracks the paper's
/// *cost advantage* online, generalized to an N-tier fleet with per-tier
/// cost weights (tier 0 = cheapest, last tier = most expensive).
#[derive(Debug)]
pub struct RoutingCounters {
    names: Vec<String>,
    costs: Vec<f64>,
    inner: Mutex<RoutingCountersInner>,
}

#[derive(Debug, Default, Clone)]
struct RoutingCountersInner {
    routed: Vec<u64>,
    /// Requests cancelled after their routing decision (caller-initiated
    /// or handle dropped), per tier.
    cancelled: Vec<u64>,
    /// Requests shed at dispatch/admission because their deadline had
    /// already expired, per tier.
    shed: Vec<u64>,
    /// Requests that went terminal with `Event::Failed` after dispatch —
    /// worker death with an exhausted retry budget, or a whole-fleet
    /// outage (every breaker open), per tier.
    failed: Vec<u64>,
    completed: u64,
    quality_sum: f64,
}

impl RoutingCounters {
    /// `names[i]` / `costs[i]` describe tier `i`. A short `costs` vector
    /// is padded with 1.0 (the most-expensive-tier weight).
    pub fn new(names: Vec<String>, mut costs: Vec<f64>) -> Self {
        costs.resize(names.len(), 1.0);
        let zeros = vec![0u64; names.len()];
        RoutingCounters {
            costs,
            inner: Mutex::new(RoutingCountersInner {
                routed: zeros.clone(),
                cancelled: zeros.clone(),
                shed: zeros.clone(),
                failed: zeros,
                completed: 0,
                quality_sum: 0.0,
            }),
            names,
        }
    }

    /// The paper's small/large pair with costs 0 and 1, under which
    /// cost advantage reduces to the fraction routed small.
    pub fn two_tier() -> Self {
        RoutingCounters::new(vec!["small".into(), "large".into()], vec![0.0, 1.0])
    }

    /// Count one query routed to `tier` (clamped to the last tier).
    pub fn route(&self, tier: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(last) = g.routed.len().checked_sub(1) {
            let i = tier.min(last);
            g.routed[i] += 1;
        }
    }

    /// Count one request cancelled at `tier` (clamped). Cancellations
    /// after dispatch are counted in *both* `routed` and `cancelled`;
    /// cancellations caught at the routing decision only in `cancelled`.
    pub fn cancel(&self, tier: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(last) = g.cancelled.len().checked_sub(1) {
            let i = tier.min(last);
            g.cancelled[i] += 1;
        }
    }

    /// Count one deadline-expired request shed before decode at `tier`
    /// (clamped). A request shed at the routing decision is not counted
    /// in `routed`; one shed from a worker backlog (its deadline expired
    /// *after* dispatch) is in both — like `cancelled`, `routed` tracks
    /// dispatch, not decode work.
    pub fn shed(&self, tier: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(last) = g.shed.len().checked_sub(1) {
            let i = tier.min(last);
            g.shed[i] += 1;
        }
    }

    /// Count one request failed terminally at `tier` (clamped). Like
    /// `cancelled`/`shed`, a failure after dispatch leaves the request in
    /// `routed` too; a failure at the routing decision (no live tier)
    /// is counted only here.
    pub fn fail(&self, tier: usize) {
        let mut g = self.inner.lock().unwrap();
        if let Some(last) = g.failed.len().checked_sub(1) {
            let i = tier.min(last);
            g.failed[i] += 1;
        }
    }

    pub fn complete(&self, quality: f64) {
        let mut g = self.inner.lock().unwrap();
        g.completed += 1;
        g.quality_sum += quality;
    }

    pub fn snapshot(&self) -> RoutingSnapshot {
        let g = self.inner.lock().unwrap().clone();
        let total: u64 = g.routed.iter().sum();
        let cmax = self.costs.iter().cloned().fold(0.0f64, f64::max);
        let cost_advantage = if total == 0 || cmax <= 0.0 {
            0.0
        } else {
            let spent: f64 = g
                .routed
                .iter()
                .zip(&self.costs)
                .map(|(&n, &c)| n as f64 * c)
                .sum();
            1.0 - spent / (total as f64 * cmax)
        };
        RoutingSnapshot {
            tiers: self
                .names
                .iter()
                .zip(&self.costs)
                .enumerate()
                .map(|(i, (name, &cost))| TierRouting {
                    name: name.clone(),
                    cost,
                    routed: g.routed[i],
                    cancelled: g.cancelled[i],
                    shed: g.shed[i],
                    failed: g.failed[i],
                })
                .collect(),
            completed: g.completed,
            cost_advantage,
            mean_quality: if g.completed == 0 {
                0.0
            } else {
                g.quality_sum / g.completed as f64
            },
        }
    }
}

/// One tier's routing counts in a snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct TierRouting {
    pub name: String,
    pub cost: f64,
    pub routed: u64,
    /// Cancelled after the routing decision (see [`RoutingCounters::cancel`]).
    pub cancelled: u64,
    /// Deadline-shed before decode (see [`RoutingCounters::shed`]).
    pub shed: u64,
    /// Terminally failed (see [`RoutingCounters::fail`]).
    pub failed: u64,
}

/// Point-in-time routing summary.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingSnapshot {
    /// Per-tier counts, cheapest first.
    pub tiers: Vec<TierRouting>,
    pub completed: u64,
    /// Cost-weighted spend saved vs all-at-most-expensive; with two
    /// tiers at costs 0/1 this is the paper's fraction routed small
    /// (§2.3).
    pub cost_advantage: f64,
    pub mean_quality: f64,
}

impl RoutingSnapshot {
    /// Total routed queries across tiers.
    pub fn total(&self) -> u64 {
        self.tiers.iter().map(|t| t.routed).sum()
    }

    /// Queries routed to the cheapest tier (the seed's `to_small`).
    pub fn to_small(&self) -> u64 {
        self.tiers.first().map(|t| t.routed).unwrap_or(0)
    }

    /// Queries routed to the most expensive tier (the seed's `to_large`).
    pub fn to_large(&self) -> u64 {
        self.tiers.last().map(|t| t.routed).unwrap_or(0)
    }

    /// Total cancelled requests across tiers.
    pub fn cancelled_total(&self) -> u64 {
        self.tiers.iter().map(|t| t.cancelled).sum()
    }

    /// Total deadline-shed requests across tiers.
    pub fn shed_total(&self) -> u64 {
        self.tiers.iter().map(|t| t.shed).sum()
    }

    /// Total terminally failed requests across tiers.
    pub fn failed_total(&self) -> u64 {
        self.tiers.iter().map(|t| t.failed).sum()
    }
}

/// Percentage response-quality drop w.r.t. the all-at-large baseline —
/// the y-axis of Fig. 5 / the cells of Table 1. BART-analogue scores are
/// negative (log-probs), so "drop" is measured on the score magnitude:
/// positive = worse than all-at-large, negative = better.
pub fn quality_drop_pct(all_at_large: f64, achieved: f64) -> f64 {
    let denom = all_at_large.abs().max(1e-9);
    (all_at_large - achieved) / denom * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_summary_percentiles() {
        let us: Vec<u64> = (1..=100).map(|i| i * 1000).collect(); // 1..100 ms
        let s = LatencySummary::from_us(&us);
        assert_eq!(s.n, 100);
        assert!((s.mean_ms - 50.5).abs() < 1e-9);
        assert!((s.p50_ms - 50.5).abs() < 1.0);
        assert!(s.p99_ms > 98.0 && s.p99_ms <= 100.0);
        assert_eq!(s.max_ms, 100.0);
    }

    #[test]
    fn recorder_thread_safe() {
        use std::sync::Arc;
        let r = Arc::new(LatencyRecorder::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    for i in 0..250 {
                        r.record(Duration::from_micros(i));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(r.count(), 1000);
    }

    #[test]
    fn cost_advantage_math() {
        let c = RoutingCounters::two_tier();
        for _ in 0..3 {
            c.route(0);
        }
        for _ in 0..7 {
            c.route(1);
        }
        let s = c.snapshot();
        assert!((s.cost_advantage - 0.3).abs() < 1e-12);
        assert_eq!(s.to_small(), 3);
        assert_eq!(s.to_large(), 7);
        assert_eq!(s.total(), 10);
    }

    #[test]
    fn cost_advantage_weighted_three_tiers() {
        let c = RoutingCounters::new(
            vec!["device".into(), "edge".into(), "cloud".into()],
            vec![0.0, 0.5, 1.0],
        );
        for _ in 0..4 {
            c.route(0);
        }
        for _ in 0..4 {
            c.route(1);
        }
        for _ in 0..2 {
            c.route(2);
        }
        let s = c.snapshot();
        // spend = 4*0 + 4*0.5 + 2*1 = 4 of a 10-query all-at-cloud budget
        assert!((s.cost_advantage - 0.6).abs() < 1e-12, "{s:?}");
        assert_eq!(s.tiers[1].name, "edge");
        assert_eq!(s.tiers[1].routed, 4);
        // out-of-range tier clamps to the last
        c.route(99);
        assert_eq!(c.snapshot().to_large(), 3);
    }

    #[test]
    fn cancelled_and_shed_counted_per_tier() {
        let c = RoutingCounters::two_tier();
        c.route(0);
        c.route(1);
        c.cancel(1); // cancelled after dispatch: stays in routed too
        c.shed(0); // shed at dispatch: never routed
        c.shed(99); // clamps to the last tier
        c.fail(1); // worker death past the retry budget: stays in routed
        let s = c.snapshot();
        assert_eq!(s.total(), 2);
        assert_eq!(s.tiers[1].cancelled, 1);
        assert_eq!(s.tiers[0].cancelled, 0);
        assert_eq!(s.tiers[0].shed, 1);
        assert_eq!(s.tiers[1].shed, 1);
        assert_eq!(s.tiers[1].failed, 1);
        assert_eq!(s.cancelled_total(), 1);
        assert_eq!(s.shed_total(), 2);
        assert_eq!(s.failed_total(), 1);
        // cost advantage is computed over routed traffic only
        assert!((s.cost_advantage - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_fleet_snapshot_is_inert() {
        let c = RoutingCounters::new(Vec::new(), Vec::new());
        c.route(0); // must not panic
        c.cancel(0);
        c.shed(0);
        c.fail(0);
        let s = c.snapshot();
        assert_eq!(s.cancelled_total(), 0);
        assert_eq!(s.shed_total(), 0);
        assert_eq!(s.failed_total(), 0);
        assert_eq!(s.total(), 0);
        assert_eq!(s.cost_advantage, 0.0);
        assert_eq!(s.to_small(), 0);
        assert_eq!(s.to_large(), 0);
    }

    #[test]
    fn quality_drop_sign_convention() {
        // all-at-large -2.0; achieved -2.2 => 10% drop (worse)
        assert!((quality_drop_pct(-2.0, -2.2) - 10.0).abs() < 1e-9);
        // achieved better than baseline => negative drop
        assert!(quality_drop_pct(-2.0, -1.9) < 0.0);
        // zero when identical
        assert_eq!(quality_drop_pct(-2.0, -2.0), 0.0);
    }
}
