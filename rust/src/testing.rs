//! Property-testing helper (the offline environment has no `proptest`):
//! a tiny seeded-case runner. Each property runs `n` generated cases; on
//! failure the failing seed is printed so the case replays exactly.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't get the xla rpath link flags)
//! use hybrid_llm::testing::check;
//! check("sort is idempotent", 100, |rng| {
//!     let mut v: Vec<u32> = (0..rng.range(0, 20)).map(|_| rng.next_u32()).collect();
//!     v.sort(); let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use crate::rng::Rng;

/// Run `prop` against `n` deterministic seeds; panics (with the seed) on
/// the first failing case.
pub fn check<F: FnMut(&mut Rng)>(name: &str, n: u64, mut prop: F) {
    for case in 0..n {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Like [`check`] but the property returns `Result`; errors are failures.
pub fn check_result<F: FnMut(&mut Rng) -> anyhow::Result<()>>(name: &str, n: u64, mut prop: F) {
    check(name, n, |rng| {
        if let Err(e) = prop(rng) {
            panic!("property '{name}' returned error: {e:#}");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check("always true", 50, |_| {
            // counting via a local is fine: check is sequential
        });
        count += 50;
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("always false", 10, |_| panic!("boom"));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("record", 5, |rng| first.push(rng.next_u64()));
        let mut second: Vec<u64> = Vec::new();
        check("record", 5, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
