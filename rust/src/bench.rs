//! Criterion-style micro-benchmark harness (the offline environment has
//! no `criterion`): warmup, timed iterations, mean ± stderr, p50/p95, and
//! throughput reporting. Used by the `rust/benches/*.rs` targets (built
//! with `harness = false`) and by the Table 2 latency driver.

use std::time::{Duration, Instant};

use crate::stats;

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std_err: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean.as_secs_f64() <= 0.0 {
            return 0.0;
        }
        self.items_per_iter / self.mean.as_secs_f64()
    }

    /// Criterion-like one-line rendering.
    pub fn render(&self) -> String {
        let mut s = format!(
            "{:<36} time: [{} ± {}]  p50 {}  p95 {}  ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std_err),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.iters,
        );
        if self.items_per_iter > 0.0 {
            s.push_str(&format!("  thrpt: {:.1}/s", self.throughput_per_s()));
        }
        s
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            budget: Duration::from_secs(3),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(500),
            min_iters: 5,
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly; one call = one iteration.
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        self.bench_items(name, 1.0, &mut f)
    }

    /// Run with a declared items-per-iteration (throughput).
    pub fn bench_items<F: FnMut()>(&self, name: &str, items_per_iter: f64, f: &mut F) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.budget || samples_ns.len() < self.min_iters)
            && samples_ns.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        let mut sorted = samples_ns.clone();
        sorted.sort_by(f64::total_cmp);
        let d = |ns: f64| Duration::from_nanos(ns.max(0.0) as u64);
        BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean: d(stats::mean(&samples_ns)),
            std_err: d(stats::std_err(&samples_ns)),
            p50: d(stats::percentile_sorted(&sorted, 50.0)),
            p95: d(stats::percentile_sorted(&sorted, 95.0)),
            min: d(sorted.first().copied().unwrap_or(0.0)),
            max: d(sorted.last().copied().unwrap_or(0.0)),
            items_per_iter,
        }
    }
}

/// Print a group header + results like criterion does.
pub fn report(group: &str, results: &[BenchResult]) {
    println!("\n== bench group: {group} ==");
    for r in results {
        println!("{}", r.render());
    }
}

/// Parse a *flat* JSON object of `"key": number` pairs — the only shape
/// the perf-trajectory files use (no serde in the offline environment).
/// Keys must not contain `"`/`,`/`:`; returns `None` on anything else.
pub fn parse_flat_json(text: &str) -> Option<std::collections::BTreeMap<String, f64>> {
    let body = text.trim().strip_prefix('{')?.strip_suffix('}')?;
    let mut map = std::collections::BTreeMap::new();
    for part in body.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part.split_once(':')?;
        let k = k.trim().strip_prefix('"')?.strip_suffix('"')?;
        map.insert(k.to_string(), v.trim().parse::<f64>().ok()?);
    }
    Some(map)
}

/// Merge `entries` into the flat JSON metrics file at `path`, creating it
/// if absent and preserving keys written by other benches. This is how
/// `BENCH_serving.json` accumulates the perf trajectory (tokens/sec,
/// host-transfer bytes per decode step, ...) across bench binaries.
/// Non-finite values are recorded as 0 (JSON has no NaN).
pub fn merge_bench_json(path: &std::path::Path, entries: &[(String, f64)]) -> std::io::Result<()> {
    let mut map = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| parse_flat_json(&t))
        .unwrap_or_default();
    for (k, v) in entries {
        debug_assert!(!k.contains(['"', ',', ':']), "unrepresentable bench key {k}");
        map.insert(k.clone(), if v.is_finite() { *v } else { 0.0 });
    }
    let mut s = String::from("{\n");
    for (i, (k, v)) in map.iter().enumerate() {
        let sep = if i + 1 < map.len() { "," } else { "" };
        s.push_str(&format!("  \"{k}\": {v}{sep}\n"));
    }
    s.push_str("}\n");
    std::fs::write(path, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
        };
        let r = b.bench("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 3);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.p95 >= r.p50);
        assert!(r.max >= r.min);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(100),
            std_err: Duration::ZERO,
            p50: Duration::ZERO,
            p95: Duration::ZERO,
            min: Duration::ZERO,
            max: Duration::ZERO,
            items_per_iter: 50.0,
        };
        assert!((r.throughput_per_s() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn flat_json_roundtrip_and_merge() {
        let d = std::env::temp_dir().join(format!("hybrid_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("BENCH_test.json");
        let _ = std::fs::remove_file(&p);
        merge_bench_json(&p, &[("a.tok_s".to_string(), 10.5), ("b".to_string(), 2.0)]).unwrap();
        let m = parse_flat_json(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(m["a.tok_s"], 10.5);
        assert_eq!(m["b"], 2.0);
        // merge preserves existing keys, overwrites repeated ones, and
        // sanitizes non-finite values
        merge_bench_json(
            &p,
            &[("b".to_string(), 3.0), ("c".to_string(), f64::NAN)],
        )
        .unwrap();
        let m = parse_flat_json(&std::fs::read_to_string(&p).unwrap()).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m["a.tok_s"], 10.5);
        assert_eq!(m["b"], 3.0);
        assert_eq!(m["c"], 0.0);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn flat_json_rejects_garbage() {
        assert!(parse_flat_json("not json").is_none());
        assert!(parse_flat_json("{\"a\": x}").is_none());
        assert_eq!(parse_flat_json("{}").unwrap().len(), 0);
        assert_eq!(parse_flat_json("{ \"a\" : 1.5 }").unwrap()["a"], 1.5);
    }

    #[test]
    fn fmt_dur_ranges() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(500)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(500)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
