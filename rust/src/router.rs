//! Router engine — the paper's §3 contribution: a BERT-style encoder
//! (DeBERTa analogue) scoring each query in [0, 1], trained with BCE on
//! one of three label constructions (deterministic / probabilistic /
//! probabilistic-with-transformation — see [`crate::labels`]).
//!
//! Training runs from rust over the `router.train` artifact (fused
//! fwd+bwd+AdamW), 5 epochs by default with best-checkpoint selection on
//! the validation split, mirroring the paper's §4.1 setup.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::io::Tensor;
use crate::rng::Rng;
use crate::runtime::{ParamSet, Runtime};
use crate::tokenizer as tok;

/// The three router variants of §3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RouterKind {
    /// §3.1 — hard labels from a single sample pair.
    Det,
    /// §3.2 — soft labels Pr[H(x) >= 0].
    Prob,
    /// §3.3 — soft labels Pr[H(x) >= -t*] with the data transformation.
    Trans,
}

pub const ALL_ROUTERS: [RouterKind; 3] = [RouterKind::Det, RouterKind::Prob, RouterKind::Trans];

impl RouterKind {
    pub fn name(self) -> &'static str {
        match self {
            RouterKind::Det => "det",
            RouterKind::Prob => "prob",
            RouterKind::Trans => "trans",
        }
    }

    pub fn from_name(s: &str) -> Option<RouterKind> {
        match s {
            "det" => Some(RouterKind::Det),
            "prob" => Some(RouterKind::Prob),
            "trans" => Some(RouterKind::Trans),
            _ => None,
        }
    }
}

/// Hyper-parameters for router training.
#[derive(Debug, Clone, Copy)]
pub struct TrainCfg {
    pub epochs: usize,
    pub base_lr: f32,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        // 5 epochs as in the paper (§4.1)
        TrainCfg { epochs: 5, base_lr: 1e-3, seed: 17 }
    }
}

/// Encoder + score head bound to the runtime.
pub struct RouterEngine {
    rt: Arc<Runtime>,
    pub params: ParamSet,
}

impl RouterEngine {
    pub fn init(rt: Arc<Runtime>, seed: u32) -> Result<RouterEngine> {
        let init = rt.exec("router.init")?;
        let host = init.run(&[&Tensor::u32(vec![], vec![seed])])?;
        let names: Vec<String> = init.spec.outs.iter().map(|o| o.name.clone()).collect();
        let params = ParamSet::from_host(&rt, names, host)?;
        Ok(RouterEngine { rt, params })
    }

    pub fn load(rt: Arc<Runtime>, dir: &Path) -> Result<RouterEngine> {
        let init = rt.exec("router.init")?;
        let names: Vec<String> = init.spec.outs.iter().map(|o| o.name.clone()).collect();
        let params = ParamSet::load(&rt, dir, names)?;
        Ok(RouterEngine { rt, params })
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        self.params.save(dir)
    }

    fn resident(&self) -> HashMap<usize, Arc<xla::PjRtBuffer>> {
        self.params.device.iter().cloned().enumerate().collect()
    }

    /// Pack prompts into the router's fixed [B, sprompt] layout.
    fn pack(&self, prompts: &[&[i32]], bsz: usize) -> Result<(Tensor, Tensor)> {
        let g = self.rt.manifest.globals;
        ensure!(prompts.len() <= bsz);
        let mut toks = vec![tok::PAD; bsz * g.sprompt];
        let mut lens = vec![1i32; bsz];
        for (b, p) in prompts.iter().enumerate() {
            ensure!(p.len() <= g.sprompt, "prompt too long");
            toks[b * g.sprompt..b * g.sprompt + p.len()].copy_from_slice(p);
            lens[b] = p.len() as i32;
        }
        Ok((
            Tensor::i32(vec![bsz, g.sprompt], toks),
            Tensor::i32(vec![bsz], lens),
        ))
    }

    /// Router scores `p_w(x)` for a set of prompts (batched, resident
    /// params — the serving hot path uses this).
    pub fn scores(&self, prompts: &[&[i32]]) -> Result<Vec<f32>> {
        let g = self.rt.manifest.globals;
        let exec = self.rt.exec("router.fwd")?;
        let n = self.params.len();
        let resident = self.resident();
        let bsz = g.trainb;
        let mut out = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(bsz) {
            let (toks, lens) = self.pack(chunk, bsz)?;
            let host: Vec<(usize, &Tensor)> = vec![(n, &toks), (n + 1, &lens)];
            let res = exec.run_with_resident(&resident, &host)?;
            out.extend(res[0].as_f32()?[..chunk.len()].iter().copied());
        }
        Ok(out)
    }

    /// Single-query score via the B=1 artifact (latency path, Table 2).
    pub fn score_one(&self, prompt: &[i32]) -> Result<f32> {
        let g = self.rt.manifest.globals;
        let exec = self.rt.exec("router.fwd1")?;
        let n = self.params.len();
        let resident = self.resident();
        let mut toks = vec![tok::PAD; g.sprompt];
        ensure!(prompt.len() <= g.sprompt);
        toks[..prompt.len()].copy_from_slice(prompt);
        let toks = Tensor::i32(vec![1, g.sprompt], toks);
        let lens = Tensor::i32(vec![1], vec![prompt.len() as i32]);
        let host: Vec<(usize, &Tensor)> = vec![(n, &toks), (n + 1, &lens)];
        let res = exec.run_with_resident(&resident, &host)?;
        Ok(res[0].as_f32()?[0])
    }

    /// Mean BCE of current params on a labelled set (validation metric).
    pub fn bce(&self, prompts: &[&[i32]], labels: &[f32]) -> Result<f64> {
        let scores = self.scores(prompts)?;
        ensure!(scores.len() == labels.len());
        let mut acc = 0.0f64;
        for (s, y) in scores.iter().zip(labels) {
            let s = (*s as f64).clamp(1e-6, 1.0 - 1e-6);
            let y = *y as f64;
            acc -= y * s.ln() + (1.0 - y) * (1.0 - s).ln();
        }
        Ok(acc / scores.len().max(1) as f64)
    }

    /// Train with (soft) BCE labels; keeps the best-validation-loss
    /// checkpoint (paper §4.1: "use the validation set to choose the best
    /// checkpoints"). Returns (train losses per step, best val loss).
    pub fn train(
        &mut self,
        train_prompts: &[&[i32]],
        train_labels: &[f32],
        val_prompts: &[&[i32]],
        val_labels: &[f32],
        cfg: TrainCfg,
        mut progress: impl FnMut(usize, usize, f32),
    ) -> Result<(Vec<f32>, f64)> {
        ensure!(train_prompts.len() == train_labels.len());
        ensure!(!train_prompts.is_empty());
        let g = self.rt.manifest.globals;
        let train = self.rt.exec("router.train")?;
        let n = self.params.len();
        let bsz = g.trainb;
        let mut m: Vec<Tensor> = self
            .params
            .host
            .iter()
            .map(|t| Tensor::f32(t.dims().to_vec(), vec![0.0; t.len()]))
            .collect();
        let mut v = m.clone();
        let mut rng = Rng::new(cfg.seed);
        let steps_per_epoch = train_prompts.len().div_ceil(bsz);
        let total_steps = steps_per_epoch * cfg.epochs;
        let mut losses = Vec::with_capacity(total_steps);
        let mut best: Option<(f64, Vec<Tensor>)> = None;
        let mut order: Vec<usize> = (0..train_prompts.len()).collect();
        let mut gstep = 0usize;

        for epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(bsz) {
                // assemble batch (wrap around to fill fixed B)
                let mut idx = chunk.to_vec();
                while idx.len() < bsz {
                    idx.push(order[rng.below(order.len())]);
                }
                let prompts: Vec<&[i32]> = idx.iter().map(|&i| train_prompts[i]).collect();
                let (toks, lens) = self.pack(&prompts, bsz)?;
                let labels: Vec<f32> = idx.iter().map(|&i| train_labels[i]).collect();
                let labels = Tensor::f32(vec![bsz], labels);
                let lr = Tensor::f32(
                    vec![],
                    vec![crate::lm::lr_schedule(
                        cfg.base_lr,
                        gstep,
                        total_steps,
                        total_steps / 20 + 1,
                    )],
                );
                let stept = Tensor::i32(vec![], vec![gstep as i32 + 1]);
                let mut ins: Vec<&Tensor> = Vec::with_capacity(3 * n + 5);
                ins.extend(self.params.host.iter());
                ins.extend(m.iter());
                ins.extend(v.iter());
                ins.extend([&toks, &lens, &labels, &lr, &stept]);
                let mut out = train.run(&ins)?;
                let loss = out.pop().context("loss")?.as_f32()?[0];
                losses.push(loss);
                let new_v: Vec<Tensor> = out.drain(2 * n..).collect();
                let new_m: Vec<Tensor> = out.drain(n..).collect();
                m = new_m;
                v = new_v;
                self.params.update(&self.rt, out)?;
                progress(epoch, gstep, loss);
                gstep += 1;
            }
            // checkpoint selection on validation
            if !val_prompts.is_empty() {
                let vloss = self.bce(val_prompts, val_labels)?;
                if best.as_ref().map(|(b, _)| vloss < *b).unwrap_or(true) {
                    best = Some((vloss, self.params.host.clone()));
                }
            }
        }
        let best_loss = if let Some((vloss, params)) = best {
            self.params.update(&self.rt, params)?;
            vloss
        } else {
            f64::NAN
        };
        Ok((losses, best_loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in ALL_ROUTERS {
            assert_eq!(RouterKind::from_name(k.name()), Some(k));
        }
        assert_eq!(RouterKind::from_name("nope"), None);
    }

    #[test]
    fn default_cfg_matches_paper() {
        let c = TrainCfg::default();
        assert_eq!(c.epochs, 5);
    }
}
