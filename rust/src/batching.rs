//! Continuous-batching primitives for the decode workers: fixed-capacity
//! slot management (the artifacts have a static batch dimension) and
//! host-side KV-cache slot surgery (merging freshly-prefilled sequences
//! into the persistent cache).
//!
//! This is the Orca/vLLM-style iteration-level scheduler scaled to the
//! reproduction's fixed-shape artifacts: every decode call steps *all*
//! occupied slots; free slots ride along as padding; new requests are
//! admitted into free slots between steps (or, in the run-to-completion
//! ablation, only when the batch drains empty).

use anyhow::{ensure, Result};

use crate::io::Tensor;

/// Scheduling discipline for a decode worker (the batching ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Admit new requests into free slots every iteration (default).
    Continuous,
    /// Admit only when all slots are free (classic static batching).
    RunToCompletion,
}

/// State of one decode slot.
#[derive(Debug, Clone)]
pub struct Slot<T> {
    /// Caller-provided payload (request handle).
    pub payload: T,
    /// Tokens generated so far (EOS excluded).
    pub answer: Vec<i32>,
    /// Sum of sampled-token logprobs (for mean at completion).
    pub logprob_sum: f32,
    /// Current input token (last sampled).
    pub cur: i32,
    /// Position of `cur` in the sequence (== prompt_len + generated).
    pub pos: i32,
    /// Per-slot sampling seed.
    pub seed: u32,
}

/// Fixed-capacity slot table.
pub struct SlotTable<T> {
    slots: Vec<Option<Slot<T>>>,
}

impl<T> SlotTable<T> {
    pub fn new(capacity: usize) -> Self {
        SlotTable { slots: (0..capacity).map(|_| None).collect() }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.occupied() == 0
    }

    pub fn free_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn occupied_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
            .collect()
    }

    /// Insert into a specific free slot.
    pub fn insert(&mut self, idx: usize, slot: Slot<T>) -> Result<()> {
        ensure!(idx < self.slots.len(), "slot index out of range");
        ensure!(self.slots[idx].is_none(), "slot {idx} already occupied");
        self.slots[idx] = Some(slot);
        Ok(())
    }

    pub fn get(&self, idx: usize) -> Option<&Slot<T>> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut Slot<T>> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    /// Remove and return the slot contents.
    pub fn take(&mut self, idx: usize) -> Option<Slot<T>> {
        self.slots.get_mut(idx).and_then(|s| s.take())
    }

    /// Batched decode inputs over the full (fixed) capacity: free slots
    /// contribute PAD tokens at pos 0 (pure padding work).
    pub fn decode_inputs(&self) -> (Vec<i32>, Vec<i32>, Vec<u32>) {
        let mut cur = vec![crate::tokenizer::PAD; self.capacity()];
        let mut pos = vec![0i32; self.capacity()];
        let mut seeds = vec![0u32; self.capacity()];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                cur[i] = s.cur;
                pos[i] = s.pos;
                seeds[i] = s.seed;
            }
        }
        (cur, pos, seeds)
    }
}

/// Persistent KV cache pair for a decode worker: host tensors of shape
/// `[L, B, S, H, Dh]` that round-trip through each decode call.
pub struct KvCache {
    pub k: Tensor,
    pub v: Tensor,
    pub layers: usize,
    pub batch: usize,
    pub seq: usize,
    pub heads: usize,
    pub head_dim: usize,
}

impl KvCache {
    pub fn zeros(layers: usize, batch: usize, seq: usize, heads: usize, head_dim: usize) -> Self {
        let dims = vec![layers, batch, seq, heads, head_dim];
        let n: usize = dims.iter().product();
        KvCache {
            k: Tensor::f32(dims.clone(), vec![0.0; n]),
            v: Tensor::f32(dims, vec![0.0; n]),
            layers,
            batch,
            seq,
            heads,
            head_dim,
        }
    }

    fn slot_stride(&self) -> usize {
        self.seq * self.heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.batch * self.slot_stride()
    }

    /// Copy slot `src_b` of `src` (same L/S/H/Dh geometry, any batch) into
    /// slot `dst_b` of `self`, for both K and V.
    pub fn copy_slot_from(&mut self, src: &KvCache, src_b: usize, dst_b: usize) -> Result<()> {
        ensure!(
            src.layers == self.layers
                && src.seq == self.seq
                && src.heads == self.heads
                && src.head_dim == self.head_dim,
            "kv geometry mismatch"
        );
        ensure!(src_b < src.batch && dst_b < self.batch);
        let ss = src.slot_stride();
        let ds = self.slot_stride();
        debug_assert_eq!(ss, ds);
        for l in 0..self.layers {
            let so = l * src.layer_stride() + src_b * ss;
            let do_ = l * self.layer_stride() + dst_b * ds;
            let (sk, sv) = (src.k.as_f32()?, src.v.as_f32()?);
            let dk = match &mut self.k {
                Tensor::F32 { data, .. } => data,
                _ => unreachable!(),
            };
            dk[do_..do_ + ds].copy_from_slice(&sk[so..so + ss]);
            let dv = match &mut self.v {
                Tensor::F32 { data, .. } => data,
                _ => unreachable!(),
            };
            dv[do_..do_ + ds].copy_from_slice(&sv[so..so + ss]);
        }
        Ok(())
    }

    /// Replace both tensors (after a decode call returns updated caches).
    pub fn replace(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        ensure!(k.dims() == self.k.dims() && v.dims() == self.v.dims(), "kv dims changed");
        self.k = k;
        self.v = v;
        Ok(())
    }

    /// Wrap tensors returned by a prefill call.
    pub fn from_tensors(k: Tensor, v: Tensor) -> Result<KvCache> {
        let d = k.dims().to_vec();
        ensure!(d.len() == 5, "kv tensors must be rank 5");
        ensure!(k.dims() == v.dims());
        Ok(KvCache {
            layers: d[0],
            batch: d[1],
            seq: d[2],
            heads: d[3],
            head_dim: d[4],
            k,
            v,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(tok: i32) -> Slot<u32> {
        Slot { payload: 0, answer: vec![], logprob_sum: 0.0, cur: tok, pos: 5, seed: 1 }
    }

    #[test]
    fn slot_table_lifecycle() {
        let mut t: SlotTable<u32> = SlotTable::new(4);
        assert_eq!(t.capacity(), 4);
        assert!(t.is_empty());
        assert_eq!(t.free_indices(), vec![0, 1, 2, 3]);
        t.insert(1, slot(9)).unwrap();
        t.insert(3, slot(10)).unwrap();
        assert_eq!(t.occupied(), 2);
        assert_eq!(t.occupied_indices(), vec![1, 3]);
        assert_eq!(t.free_indices(), vec![0, 2]);
        // double insert fails
        assert!(t.insert(1, slot(8)).is_err());
        // out of range fails
        assert!(t.insert(9, slot(8)).is_err());
        let s = t.take(1).unwrap();
        assert_eq!(s.cur, 9);
        assert!(t.take(1).is_none());
        assert_eq!(t.occupied(), 1);
    }

    #[test]
    fn decode_inputs_pad_free_slots() {
        let mut t: SlotTable<u32> = SlotTable::new(3);
        t.insert(1, slot(7)).unwrap();
        let (cur, pos, seeds) = t.decode_inputs();
        assert_eq!(cur, vec![crate::tokenizer::PAD, 7, crate::tokenizer::PAD]);
        assert_eq!(pos, vec![0, 5, 0]);
        assert_eq!(seeds, vec![0, 1, 0]);
    }

    #[test]
    fn kv_slot_copy_moves_only_target_slot() {
        let (l, b, s, h, dh) = (2, 3, 4, 2, 2);
        let mut dst = KvCache::zeros(l, b, s, h, dh);
        let mut src = KvCache::zeros(l, 2, s, h, dh);
        // fill src slot 1 with a recognizable pattern
        if let Tensor::F32 { data, .. } = &mut src.k {
            for (i, x) in data.iter_mut().enumerate() {
                *x = i as f32;
            }
        }
        if let Tensor::F32 { data, .. } = &mut src.v {
            for (i, x) in data.iter_mut().enumerate() {
                *x = -(i as f32);
            }
        }
        dst.copy_slot_from(&src, 1, 2).unwrap();
        let stride = s * h * dh;
        let k = dst.k.as_f32().unwrap();
        let sk = src.k.as_f32().unwrap();
        for layer in 0..l {
            let dst_off = layer * b * stride + 2 * stride;
            let src_off = layer * 2 * stride + stride;
            assert_eq!(&k[dst_off..dst_off + stride], &sk[src_off..src_off + stride]);
            // other slots stay zero
            let other = layer * b * stride;
            assert!(k[other..other + stride].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn kv_geometry_checked() {
        let mut a = KvCache::zeros(2, 2, 4, 2, 2);
        let b = KvCache::zeros(3, 2, 4, 2, 2);
        assert!(a.copy_slot_from(&b, 0, 0).is_err());
        let c = KvCache::zeros(2, 2, 4, 2, 2);
        assert!(a.copy_slot_from(&c, 5, 0).is_err());
    }

    #[test]
    fn slot_table_property_no_lost_or_duplicated() {
        crate::testing::check("slot table conservation", 100, |rng| {
            let cap = rng.range(1, 8);
            let mut t: SlotTable<u64> = SlotTable::new(cap);
            let mut live: std::collections::HashSet<u64> = Default::default();
            let mut next_id = 0u64;
            for _ in 0..50 {
                if rng.next_f64() < 0.5 {
                    if let Some(&i) = t.free_indices().first() {
                        let mut s = slot(1).clone();
                        // payload type differs; rebuild
                        let s = Slot {
                            payload: next_id,
                            answer: vec![],
                            logprob_sum: 0.0,
                            cur: s.cur,
                            pos: s.pos,
                            seed: s.seed,
                        };
                        t.insert(i, s).unwrap();
                        live.insert(next_id);
                        next_id += 1;
                    }
                } else {
                    let occ = t.occupied_indices();
                    if !occ.is_empty() {
                        let i = occ[rng.below(occ.len())];
                        let s = t.take(i).unwrap();
                        assert!(live.remove(&s.payload), "duplicate/lost payload");
                    }
                }
                assert_eq!(t.occupied(), live.len());
                assert_eq!(t.occupied() + t.free_indices().len(), cap);
            }
        });
    }
}
