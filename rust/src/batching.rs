//! Continuous-batching primitives for the decode workers: fixed-capacity
//! slot management (the artifacts have a static batch dimension) and
//! KV-cache management over *either* host tensors or device-resident
//! PJRT buffers.
//!
//! This is the Orca/vLLM-style iteration-level scheduler scaled to the
//! reproduction's fixed-shape artifacts: every decode call steps *all*
//! occupied slots; free slots ride along as padding; new requests are
//! admitted into free slots between steps (or, in the run-to-completion
//! ablation, only when the batch drains empty).
//!
//! [`KvCache`] is an enum over two residency states:
//!
//! * **Host** — plain `[L, B, S, H, Dh]` tensors. Needed for slot
//!   surgery at admission ([`KvCache::copy_slot_from`]) on pre-v3
//!   artifacts and the only state reachable with pre-v2 (fused-tuple)
//!   artifacts.
//! * **Device** — `Arc<xla::PjRtBuffer>` pairs that feed straight back
//!   into the next `execute_b` call ([`KvCache::bind`]), the steady-state
//!   of the decode loop: zero KV bytes cross the host boundary per
//!   generated token.
//!
//! [`KvCache::update`] follows whatever residency the runtime returns, so
//! the same decode loop transparently runs device-resident against v2
//! artifacts and host-round-trip against v1 artifacts. On manifest-v3
//! artifacts admission stays device-side too:
//! [`KvCache::install_slots_device`] drives the `kv_install@B` scatter,
//! writing freshly-prefilled KV slots into the persistent cache without
//! either cache crossing the host boundary ([`KvCache::copy_slot_from`]
//! remains the host-surgery fallback, equivalence-tested against it).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::io::Tensor;
use crate::runtime::{Exec, OutValue, Runtime};

/// Scheduling discipline for a decode worker (the batching ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Admit new requests into free slots every iteration (default).
    Continuous,
    /// Admit only when all slots are free (classic static batching).
    RunToCompletion,
}

/// State of one decode slot.
#[derive(Debug, Clone)]
pub struct Slot<T> {
    /// Caller-provided payload (request handle).
    pub payload: T,
    /// Tokens generated so far (EOS excluded).
    pub answer: Vec<i32>,
    /// Sum of sampled-token logprobs (for mean at completion).
    pub logprob_sum: f32,
    /// Current input token (last sampled).
    pub cur: i32,
    /// Position of `cur` in the sequence (== prompt_len + generated).
    pub pos: i32,
    /// Per-slot sampling seed.
    pub seed: u32,
}

/// Fixed-capacity slot table with an O(1) occupancy count and an O(1)
/// free-list, so admission finds open slots without a linear scan over
/// capacity; index enumeration is allocation-free (iterators) so the
/// per-token decode loop never heap-allocates for bookkeeping.
pub struct SlotTable<T> {
    slots: Vec<Option<Slot<T>>>,
    occupied: usize,
    /// Stack of free slot indices (top = next slot handed to admission).
    free: Vec<usize>,
    /// `free_at[i]` = position of slot `i` in `free`, or `usize::MAX`
    /// when occupied — makes `insert` at an arbitrary free index O(1)
    /// (swap-remove from the stack).
    free_at: Vec<usize>,
}

impl<T> SlotTable<T> {
    pub fn new(capacity: usize) -> Self {
        SlotTable {
            slots: (0..capacity).map(|_| None).collect(),
            occupied: 0,
            // reversed so the stack top starts at slot 0 and fresh
            // tables hand out ascending indices
            free: (0..capacity).rev().collect(),
            free_at: (0..capacity).map(|i| capacity - 1 - i).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of occupied slots — O(1).
    pub fn occupied(&self) -> usize {
        self.occupied
    }

    /// O(1).
    pub fn is_empty(&self) -> bool {
        self.occupied == 0
    }

    /// O(1): whether at least one slot is free.
    pub fn has_free(&self) -> bool {
        !self.free.is_empty()
    }

    /// Any one free slot index — O(1) (top of the free stack). `None`
    /// when full.
    pub fn first_free(&self) -> Option<usize> {
        self.free.last().copied()
    }

    /// Up to `n` distinct free slot indices from the free stack — O(n)
    /// in the number returned, independent of capacity. Does not
    /// reserve: pair with [`Self::insert`], which pops the stack.
    pub fn free_slots(&self, n: usize) -> Vec<usize> {
        self.free.iter().rev().take(n).copied().collect()
    }

    /// Indices of free slots, ascending (allocation-free scan; use
    /// [`Self::free_slots`] on the admission hot path).
    pub fn free_indices(&self) -> impl Iterator<Item = usize> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
    }

    /// Indices of occupied slots, ascending (allocation-free).
    pub fn occupied_indices(&self) -> impl Iterator<Item = usize> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
    }

    /// Insert into a specific free slot — O(1) (swap-removes the index
    /// from the free stack via `free_at`).
    pub fn insert(&mut self, idx: usize, slot: Slot<T>) -> Result<()> {
        ensure!(idx < self.slots.len(), "slot index out of range");
        ensure!(self.slots[idx].is_none(), "slot {idx} already occupied");
        let at = self.free_at[idx];
        debug_assert_eq!(self.free[at], idx, "free-list desync");
        self.free.swap_remove(at);
        if let Some(&moved) = self.free.get(at) {
            self.free_at[moved] = at;
        }
        self.free_at[idx] = usize::MAX;
        self.slots[idx] = Some(slot);
        self.occupied += 1;
        Ok(())
    }

    pub fn get(&self, idx: usize) -> Option<&Slot<T>> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut Slot<T>> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    /// Remove and return the slot contents — O(1) (pushes the index back
    /// onto the free stack, so it is the next slot admission reuses).
    pub fn take(&mut self, idx: usize) -> Option<Slot<T>> {
        let s = self.slots.get_mut(idx).and_then(|s| s.take());
        if s.is_some() {
            self.occupied -= 1;
            self.free_at[idx] = self.free.len();
            self.free.push(idx);
        }
        s
    }

    /// Remove every occupied slot whose payload matches `pred`, returning
    /// the removed `(index, slot)` pairs in ascending slot order. This is
    /// the mid-decode cancellation surgery: a released slot immediately
    /// reads as free (padding in the next decode wave, reusable by
    /// admission) while every other slot's KV state and position are
    /// untouched.
    pub fn take_matching(
        &mut self,
        mut pred: impl FnMut(&T) -> bool,
    ) -> Vec<(usize, Slot<T>)> {
        let mut out = Vec::new();
        for i in 0..self.slots.len() {
            let hit = self.slots[i].as_ref().is_some_and(|s| pred(&s.payload));
            if hit {
                out.push((i, self.take(i).expect("slot checked occupied")));
            }
        }
        out
    }

    /// Refill caller-owned decode-input buffers in place over the full
    /// (fixed) capacity: free slots contribute PAD tokens at pos 0 (pure
    /// padding work). Scratch reuse — the per-token decode loop
    /// allocates nothing. Buffers must be capacity-sized. Returns the
    /// maximum live position (0 when empty) — the decode artifact's
    /// `step` scalar is `max_pos + 1`.
    pub fn fill_decode_inputs(&self, cur: &mut [i32], pos: &mut [i32], seeds: &mut [u32]) -> i32 {
        assert_eq!(cur.len(), self.capacity());
        assert_eq!(pos.len(), self.capacity());
        assert_eq!(seeds.len(), self.capacity());
        let mut max_pos = 0;
        for (i, s) in self.slots.iter().enumerate() {
            match s {
                Some(s) => {
                    cur[i] = s.cur;
                    pos[i] = s.pos;
                    seeds[i] = s.seed;
                    max_pos = max_pos.max(s.pos);
                }
                None => {
                    cur[i] = crate::tokenizer::PAD;
                    pos[i] = 0;
                    seeds[i] = 0;
                }
            }
        }
        max_pos
    }
}

/// Where a KV-cache pair currently lives.
enum KvStore {
    /// Plain host tensors of shape `[L, B, S, H, Dh]`.
    Host { k: Tensor, v: Tensor },
    /// Device-resident buffers of the same logical shape.
    Device { k: Arc<xla::PjRtBuffer>, v: Arc<xla::PjRtBuffer> },
}

/// Persistent KV cache pair for a decode worker, resident on either the
/// host (admission-time slot surgery, v1-artifact fallback) or the device
/// (steady-state decode). See the module docs for the residency protocol.
pub struct KvCache {
    store: KvStore,
    pub layers: usize,
    pub batch: usize,
    pub seq: usize,
    pub heads: usize,
    pub head_dim: usize,
}

impl KvCache {
    pub fn zeros(layers: usize, batch: usize, seq: usize, heads: usize, head_dim: usize) -> Self {
        let dims = vec![layers, batch, seq, heads, head_dim];
        let n: usize = dims.iter().product();
        KvCache {
            store: KvStore::Host {
                k: Tensor::f32(dims.clone(), vec![0.0; n]),
                v: Tensor::f32(dims, vec![0.0; n]),
            },
            layers,
            batch,
            seq,
            heads,
            head_dim,
        }
    }

    /// Wrap host tensors (e.g. prefill outputs downloaded to the host).
    pub fn from_tensors(k: Tensor, v: Tensor) -> Result<KvCache> {
        let d = k.dims().to_vec();
        ensure!(d.len() == 5, "kv tensors must be rank 5");
        ensure!(k.dims() == v.dims());
        Ok(KvCache {
            layers: d[0],
            batch: d[1],
            seq: d[2],
            heads: d[3],
            head_dim: d[4],
            store: KvStore::Host { k, v },
        })
    }

    /// Wrap a pair of [`OutValue`]s returned by `Exec::run_resident`,
    /// adopting whatever residency the runtime produced. `dims` is the
    /// logical `[L, B, S, H, Dh]` shape from the artifact's output spec
    /// (device buffers do not carry a host-visible shape).
    pub fn from_outputs(k: OutValue, v: OutValue, dims: &[usize]) -> Result<KvCache> {
        ensure!(dims.len() == 5, "kv caches must be rank 5");
        let store = match (k, v) {
            (OutValue::Device(k), OutValue::Device(v)) => KvStore::Device { k, v },
            (k, v) => {
                let k = k.into_tensor()?;
                let v = v.into_tensor()?;
                ensure!(k.dims() == dims && v.dims() == dims, "kv dims mismatch");
                KvStore::Host { k, v }
            }
        };
        Ok(KvCache {
            store,
            layers: dims[0],
            batch: dims[1],
            seq: dims[2],
            heads: dims[3],
            head_dim: dims[4],
        })
    }

    pub fn dims(&self) -> [usize; 5] {
        [self.layers, self.batch, self.seq, self.heads, self.head_dim]
    }

    pub fn is_device(&self) -> bool {
        matches!(self.store, KvStore::Device { .. })
    }

    /// Total size of both caches in bytes (the per-token transfer the
    /// host-round-trip path pays and the device-resident path avoids).
    pub fn byte_size(&self) -> u64 {
        2 * self.dims().iter().product::<usize>() as u64 * crate::runtime::ELEM_BYTES as u64
    }

    /// Host tensors, failing when device-resident (call
    /// [`Self::to_host`] first).
    pub fn host_tensors(&self) -> Result<(&Tensor, &Tensor)> {
        match &self.store {
            KvStore::Host { k, v } => Ok((k, v)),
            KvStore::Device { .. } => bail!("kv cache is device-resident"),
        }
    }

    /// Bind this cache as artifact inputs `k_idx`/`v_idx`: device buffers
    /// go into `resident` (and stale host entries are cleared), host
    /// tensors into the `host` upload list (and stale resident entries
    /// are cleared). The same call sites therefore serve both residency
    /// states.
    pub fn bind<'a>(
        &'a self,
        k_idx: usize,
        v_idx: usize,
        resident: &mut HashMap<usize, Arc<xla::PjRtBuffer>>,
        host: &mut Vec<(usize, &'a Tensor)>,
    ) {
        match &self.store {
            KvStore::Device { k, v } => {
                resident.insert(k_idx, k.clone());
                resident.insert(v_idx, v.clone());
            }
            KvStore::Host { k, v } => {
                resident.remove(&k_idx);
                resident.remove(&v_idx);
                host.push((k_idx, k));
                host.push((v_idx, v));
            }
        }
    }

    /// Adopt the caches returned by a prefill/decode call, following the
    /// runtime's residency: device buffers keep the cache on device
    /// (zero-copy steady state), host tensors (v1 fallback) keep it on
    /// the host.
    pub fn update(&mut self, k: OutValue, v: OutValue) -> Result<()> {
        match (k, v) {
            (OutValue::Device(k), OutValue::Device(v)) => {
                self.store = KvStore::Device { k, v };
            }
            (k, v) => {
                let k = k.into_tensor()?;
                let v = v.into_tensor()?;
                ensure!(
                    k.dims() == self.dims().as_slice() && v.dims() == self.dims().as_slice(),
                    "kv dims changed"
                );
                self.store = KvStore::Host { k, v };
            }
        }
        Ok(())
    }

    /// Replace both host tensors (host-path equivalent of [`Self::update`]).
    pub fn replace(&mut self, k: Tensor, v: Tensor) -> Result<()> {
        ensure!(
            k.dims() == self.dims().as_slice() && v.dims() == self.dims().as_slice(),
            "kv dims changed"
        );
        self.store = KvStore::Host { k, v };
        Ok(())
    }

    /// Materialize on the host (metered download); no-op when already
    /// host-resident. Needed before slot surgery.
    pub fn to_host(&mut self, rt: &Runtime) -> Result<()> {
        if let KvStore::Device { k, v } = &self.store {
            let kt = rt.download(k)?;
            let vt = rt.download(v)?;
            ensure!(
                kt.dims() == self.dims().as_slice() && vt.dims() == self.dims().as_slice(),
                "device kv dims {:?} disagree with cache geometry {:?}",
                kt.dims(),
                self.dims()
            );
            self.store = KvStore::Host { k: kt, v: vt };
        }
        Ok(())
    }

    /// Upload to the device (metered); no-op when already device-resident.
    pub fn to_device(&mut self, rt: &Runtime) -> Result<()> {
        if let KvStore::Host { k, v } = &self.store {
            let kb = rt.upload(k)?;
            let vb = rt.upload(v)?;
            self.store = KvStore::Device { k: kb, v: vb };
        }
        Ok(())
    }

    /// Device-side admission install (manifest v3): run a
    /// `<model>.kv_install@B` scatter writing the first `slots.len()`
    /// batch entries of the bucketed prefill outputs `src_k`/`src_v`
    /// into this cache at the given slot indices. The KV state never
    /// crosses the host boundary — the only host inputs are the O(B)
    /// slot indices and the valid count; bucket entries beyond
    /// `slots.len()` are masked out inside the artifact. Produces
    /// byte-identical cache contents to the host-surgery path
    /// ([`Self::copy_slot_from`] of each entry), pinned by the
    /// integration suite.
    ///
    /// A host-resident cache is uploaded first (one-time cost at worker
    /// start; a device-resident steady state makes it a no-op).
    pub fn install_slots_device(
        &mut self,
        rt: &Runtime,
        install: &Exec,
        src_k: &Arc<xla::PjRtBuffer>,
        src_v: &Arc<xla::PjRtBuffer>,
        slots: &[usize],
    ) -> Result<()> {
        let spec = &install.spec;
        let i_k = spec.input_index("kcache")?;
        let i_v = spec.input_index("vcache")?;
        let i_sk = spec.input_index("src_k")?;
        let i_sv = spec.input_index("src_v")?;
        let i_slots = spec.input_index("slots")?;
        let i_count = spec.input_index("count")?;
        let bucket = spec.ins[i_slots].dims.first().copied().unwrap_or(0);
        ensure!(
            !slots.is_empty() && slots.len() <= bucket,
            "{}: {} slots exceed bucket {bucket}",
            spec.name,
            slots.len()
        );
        ensure!(
            slots.iter().all(|&s| s < self.batch),
            "{}: slot index out of range (batch {})",
            spec.name,
            self.batch
        );
        self.to_device(rt)?;
        let (k, v) = match &self.store {
            KvStore::Device { k, v } => (k.clone(), v.clone()),
            KvStore::Host { .. } => unreachable!("to_device() above"),
        };
        let mut slot_v = vec![0i32; bucket];
        for (dst, &s) in slot_v.iter_mut().zip(slots) {
            *dst = s as i32;
        }
        let slots_t = Tensor::i32(vec![bucket], slot_v);
        let count_t = Tensor::i32(vec![], vec![slots.len() as i32]);
        let mut resident: HashMap<usize, Arc<xla::PjRtBuffer>> = HashMap::with_capacity(4);
        resident.insert(i_k, k);
        resident.insert(i_v, v);
        resident.insert(i_sk, src_k.clone());
        resident.insert(i_sv, src_v.clone());
        let host: Vec<(usize, &Tensor)> = vec![(i_slots, &slots_t), (i_count, &count_t)];
        let mut outs = install.run_resident(&resident, &host)?;
        let vc = outs.pop().context("kv_install: vcache")?;
        let kc = outs.pop().context("kv_install: kcache")?;
        // a fused/tupled install artifact would silently demote the cache
        // to host residency and wreck the admission byte accounting —
        // refuse instead (v3 artifacts are untupled by construction)
        ensure!(
            kc.is_device() && vc.is_device(),
            "{}: install returned host outputs (artifact not untupled?)",
            spec.name
        );
        self.update(kc, vc)
    }

    fn slot_stride(&self) -> usize {
        self.seq * self.heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.batch * self.slot_stride()
    }

    /// Copy slot `src_b` of `src` (same L/S/H/Dh geometry, any batch) into
    /// slot `dst_b` of `self`, for both K and V. Host-only slot surgery:
    /// both caches must be host-resident (`to_host` first).
    pub fn copy_slot_from(&mut self, src: &KvCache, src_b: usize, dst_b: usize) -> Result<()> {
        ensure!(
            src.layers == self.layers
                && src.seq == self.seq
                && src.heads == self.heads
                && src.head_dim == self.head_dim,
            "kv geometry mismatch"
        );
        ensure!(src_b < src.batch && dst_b < self.batch);
        let ss = src.slot_stride();
        let ds = self.slot_stride();
        debug_assert_eq!(ss, ds);
        let src_ls = src.layer_stride();
        let dst_ls = self.layer_stride();
        // match the payloads once, outside the per-layer loop
        let (sk, sv) = match &src.store {
            KvStore::Host { k, v } => (k.as_f32()?, v.as_f32()?),
            KvStore::Device { .. } => bail!("copy_slot_from: src is device-resident"),
        };
        let (dk, dv) = match &mut self.store {
            KvStore::Host {
                k: Tensor::F32 { data: dk, .. },
                v: Tensor::F32 { data: dv, .. },
            } => (dk, dv),
            KvStore::Host { .. } => bail!("kv caches must be f32"),
            KvStore::Device { .. } => bail!("copy_slot_from: dst is device-resident"),
        };
        for l in 0..src.layers {
            let so = l * src_ls + src_b * ss;
            let do_ = l * dst_ls + dst_b * ds;
            dk[do_..do_ + ds].copy_from_slice(&sk[so..so + ss]);
            dv[do_..do_ + ds].copy_from_slice(&sv[so..so + ss]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(tok: i32) -> Slot<u32> {
        Slot { payload: 0, answer: vec![], logprob_sum: 0.0, cur: tok, pos: 5, seed: 1 }
    }

    fn host_k(kv: &KvCache) -> &[f32] {
        kv.host_tensors().unwrap().0.as_f32().unwrap()
    }

    #[test]
    fn slot_table_lifecycle() {
        let mut t: SlotTable<u32> = SlotTable::new(4);
        assert_eq!(t.capacity(), 4);
        assert!(t.is_empty());
        assert!(t.has_free());
        assert_eq!(t.free_indices().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        t.insert(1, slot(9)).unwrap();
        t.insert(3, slot(10)).unwrap();
        assert_eq!(t.occupied(), 2);
        assert_eq!(t.occupied_indices().collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(t.free_indices().collect::<Vec<_>>(), vec![0, 2]);
        // double insert fails and does not corrupt the count
        assert!(t.insert(1, slot(8)).is_err());
        assert_eq!(t.occupied(), 2);
        // out of range fails
        assert!(t.insert(9, slot(8)).is_err());
        let s = t.take(1).unwrap();
        assert_eq!(s.cur, 9);
        assert!(t.take(1).is_none());
        assert_eq!(t.occupied(), 1);
        assert!(!t.is_empty());
        t.take(3).unwrap();
        assert!(t.is_empty());
        assert!(t.has_free());
    }

    #[test]
    fn occupied_count_stays_consistent_with_scan() {
        let mut t: SlotTable<u32> = SlotTable::new(5);
        t.insert(0, slot(1)).unwrap();
        t.insert(4, slot(2)).unwrap();
        t.insert(2, slot(3)).unwrap();
        assert_eq!(t.occupied(), t.occupied_indices().count());
        t.take(0);
        t.take(0); // double take is a no-op
        assert_eq!(t.occupied(), t.occupied_indices().count());
        assert_eq!(t.occupied() + t.free_indices().count(), t.capacity());
    }

    #[test]
    fn take_matching_releases_only_predicate_slots() {
        let mut t: SlotTable<u32> = SlotTable::new(4);
        for (i, p) in [(0usize, 10u32), (1, 11), (3, 13)] {
            let mut s = slot(1);
            s.payload = p;
            t.insert(i, s).unwrap();
        }
        let removed = t.take_matching(|&p| p % 2 == 1);
        assert_eq!(
            removed.iter().map(|(i, s)| (*i, s.payload)).collect::<Vec<_>>(),
            vec![(1, 11), (3, 13)]
        );
        assert_eq!(t.occupied(), 1);
        assert_eq!(t.get(0).unwrap().payload, 10);
        assert_eq!(t.free_indices().collect::<Vec<_>>(), vec![1, 2, 3]);
        assert!(t.take_matching(|_| false).is_empty());
        assert_eq!(t.occupied(), 1);
    }

    #[test]
    fn full_table_has_no_free() {
        let mut t: SlotTable<u32> = SlotTable::new(2);
        t.insert(0, slot(1)).unwrap();
        assert!(t.has_free());
        t.insert(1, slot(2)).unwrap();
        assert!(!t.has_free());
        assert_eq!(t.free_indices().next(), None);
    }

    #[test]
    fn fill_decode_inputs_overwrites_stale_scratch() {
        let mut t: SlotTable<u32> = SlotTable::new(3);
        t.insert(1, slot(7)).unwrap();
        let mut s = slot(8);
        s.pos = 9;
        t.insert(2, s).unwrap();
        // scratch carries garbage from a previous iteration
        let mut cur = vec![99i32; 3];
        let mut pos = vec![99i32; 3];
        let mut seeds = vec![99u32; 3];
        let max_pos = t.fill_decode_inputs(&mut cur, &mut pos, &mut seeds);
        assert_eq!(max_pos, 9);
        assert_eq!(cur, vec![crate::tokenizer::PAD, 7, 8]);
        assert_eq!(pos, vec![0, 5, 9]);
        assert_eq!(seeds, vec![0, 1, 1]);
        // releasing a slot turns its lane back into padding
        t.take(2).unwrap();
        let max_pos = t.fill_decode_inputs(&mut cur, &mut pos, &mut seeds);
        assert_eq!(max_pos, 5);
        assert_eq!(cur, vec![crate::tokenizer::PAD, 7, crate::tokenizer::PAD]);
        assert_eq!(pos, vec![0, 5, 0]);
        // empty table: all padding, max pos 0
        t.take(1).unwrap();
        assert_eq!(t.fill_decode_inputs(&mut cur, &mut pos, &mut seeds), 0);
        assert!(pos.iter().all(|&p| p == 0));
    }

    #[test]
    fn kv_slot_copy_moves_only_target_slot() {
        let (l, b, s, h, dh) = (2, 3, 4, 2, 2);
        let mut dst = KvCache::zeros(l, b, s, h, dh);
        let stride = s * h * dh;
        // fill src slot 1 with a recognizable pattern
        let n = l * 2 * stride;
        let kdata: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let vdata: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let dims = vec![l, 2, s, h, dh];
        let src =
            KvCache::from_tensors(Tensor::f32(dims.clone(), kdata), Tensor::f32(dims, vdata))
                .unwrap();
        dst.copy_slot_from(&src, 1, 2).unwrap();
        let k = host_k(&dst);
        let sk = host_k(&src);
        for layer in 0..l {
            let dst_off = layer * b * stride + 2 * stride;
            let src_off = layer * 2 * stride + stride;
            assert_eq!(&k[dst_off..dst_off + stride], &sk[src_off..src_off + stride]);
            // other slots stay zero
            let other = layer * b * stride;
            assert!(k[other..other + stride].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn kv_geometry_checked() {
        let mut a = KvCache::zeros(2, 2, 4, 2, 2);
        let b = KvCache::zeros(3, 2, 4, 2, 2);
        assert!(a.copy_slot_from(&b, 0, 0).is_err());
        let c = KvCache::zeros(2, 2, 4, 2, 2);
        assert!(a.copy_slot_from(&c, 5, 0).is_err());
    }

    #[test]
    fn kv_host_update_and_replace_check_dims() {
        let mut a = KvCache::zeros(1, 2, 4, 2, 2);
        assert!(!a.is_device());
        assert_eq!(a.dims(), [1, 2, 4, 2, 2]);
        assert_eq!(a.byte_size(), 2 * 32 * 4);
        let n = a.dims().iter().product::<usize>();
        let good = Tensor::f32(a.dims().to_vec(), vec![1.0; n]);
        a.replace(good.clone(), good.clone()).unwrap();
        assert_eq!(host_k(&a)[0], 1.0);
        let bad = Tensor::f32(vec![1, 2, 4, 2, 1], vec![0.0; 16]);
        assert!(a.replace(bad.clone(), bad.clone()).is_err());
        // update() with host OutValues follows the same checks
        a.update(
            crate::runtime::OutValue::Host(good.clone()),
            crate::runtime::OutValue::Host(good),
        )
        .unwrap();
        assert!(!a.is_device());
    }

    #[test]
    fn kv_bind_host_populates_upload_list() {
        let a = KvCache::zeros(1, 1, 2, 1, 1);
        let mut resident: HashMap<usize, Arc<xla::PjRtBuffer>> = HashMap::new();
        let mut host: Vec<(usize, &Tensor)> = Vec::new();
        a.bind(3, 4, &mut resident, &mut host);
        assert_eq!(host.len(), 2);
        assert_eq!(host[0].0, 3);
        assert_eq!(host[1].0, 4);
        assert!(resident.is_empty());
    }

    #[test]
    fn slot_table_free_list_hands_out_fresh_indices_in_order() {
        let mut t: SlotTable<u32> = SlotTable::new(4);
        // fresh table: the free stack matches the ascending scan
        assert_eq!(t.first_free(), Some(0));
        assert_eq!(t.free_slots(2), vec![0, 1]);
        assert_eq!(t.free_slots(9), vec![0, 1, 2, 3]);
        t.insert(0, slot(1)).unwrap();
        t.insert(1, slot(2)).unwrap();
        assert_eq!(t.first_free(), Some(2));
        // a released slot is the next one handed out (LIFO reuse keeps
        // the working set of KV slots small)
        t.take(0).unwrap();
        assert_eq!(t.first_free(), Some(0));
        assert_eq!(t.free_slots(3), vec![0, 2, 3]);
        // inserting at an index deeper in the stack still works (O(1)
        // swap-remove), and the stack stays consistent
        t.insert(3, slot(3)).unwrap();
        assert_eq!(t.free_slots(9).len(), 2);
        t.insert(0, slot(4)).unwrap();
        t.insert(2, slot(5)).unwrap();
        assert_eq!(t.first_free(), None);
        assert!(t.free_slots(1).is_empty());
        assert!(!t.has_free());
    }

    #[test]
    fn slot_table_property_no_lost_or_duplicated() {
        crate::testing::check("slot table conservation", 100, |rng| {
            let cap = rng.range(1, 8);
            let mut t: SlotTable<u64> = SlotTable::new(cap);
            let mut live: std::collections::HashSet<u64> = Default::default();
            let mut next_id = 0u64;
            for _ in 0..50 {
                if rng.next_f64() < 0.5 {
                    // alternate allocation paths: the O(1) free stack
                    // (admission hot path) and the ascending scan must
                    // stay interchangeable
                    let pick = if rng.next_f64() < 0.5 {
                        t.first_free()
                    } else {
                        t.free_indices().next()
                    };
                    if let Some(i) = pick {
                        let s = Slot {
                            payload: next_id,
                            answer: vec![],
                            logprob_sum: 0.0,
                            cur: 1,
                            pos: 5,
                            seed: 1,
                        };
                        t.insert(i, s).unwrap();
                        live.insert(next_id);
                        next_id += 1;
                    }
                } else {
                    let occ: Vec<usize> = t.occupied_indices().collect();
                    if !occ.is_empty() {
                        let i = occ[rng.below(occ.len())];
                        let s = t.take(i).unwrap();
                        assert!(live.remove(&s.payload), "duplicate/lost payload");
                    }
                }
                assert_eq!(t.occupied(), live.len());
                assert_eq!(t.occupied() + t.free_indices().count(), cap);
                assert_eq!(t.has_free(), t.occupied() < cap);
                // the free stack and the slot scan agree as sets, and
                // free_slots never repeats or returns occupied indices
                let mut from_stack = t.free_slots(cap);
                let from_scan: Vec<usize> = t.free_indices().collect();
                assert_eq!(from_stack.len(), from_scan.len());
                from_stack.sort_unstable();
                assert_eq!(from_stack, from_scan);
                assert_eq!(t.first_free().is_none(), !t.has_free());
            }
        });
    }
}
