//! `repro` — CLI for the Hybrid-LLM reproduction.
//!
//! ```text
//! repro pipeline --run runs/default [--scale smoke|default|paper]
//! repro eval <id>... --run runs/default      # fig1 fig3 ... table5, or `all`
//! repro table2 --run runs/default [--queries 200]
//! repro serve-demo --run runs/default [--requests 64] [--threshold 0.5]
//! repro kick-tires --run runs/default [--smoke] [--chaos] [--overload]  # scenario sweep + invariant gate
//! repro corpus-stats [--scale default]
//! ```

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};
use hybrid_llm::batching::BatchMode;
use hybrid_llm::cli::Args;
use hybrid_llm::corpus::{self, Scale};
use hybrid_llm::eval::Eval;
use hybrid_llm::pipeline::Pipeline;
use hybrid_llm::policy::TierPolicy;
use hybrid_llm::runtime::Runtime;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.as_str() {
        "pipeline" => cmd_pipeline(&args),
        "eval" => cmd_eval(&args),
        "table2" => cmd_table2(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "kick-tires" => cmd_kick_tires(&args),
        "corpus-stats" => cmd_corpus_stats(&args),
        "" | "help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other}\n{HELP}"),
    }
}

const HELP: &str = "repro — Hybrid LLM (ICLR 2024) reproduction
subcommands:
  pipeline     --run DIR [--scale smoke|default|paper]   run all stages
  eval ID...   --run DIR                                  regenerate tables/figures (or `all`)
  table2       --run DIR [--queries N]                    live latency measurement (Table 2)
  serve-demo   --run DIR [--requests N] [--threshold T] [--mode cont|rtc]
               [--tiers m[:replicas[:cost]],...] [--thresholds T1,T2,...] [--select rr|sq]
               [--quality Q] [--queue-cap N] [--deadline-ms MS] [--admit device|host]
               [--decode-timeout-ms MS] [--retry-budget N] [--decode routed|hybrid]
               [--brownout-target-ms MS] [--priority interactive|batch|best-effort]
  kick-tires   --run DIR [--smoke] [--chaos] [--overload] [--small M] [--large M]
               [--seed N] [--scenarios a,b,...] [--json PATH] [--drain-timeout-ms MS]
               run the whole trace-replay scenario suite (--chaos adds the
               fault-injection suite, --overload the brownout suite), gate
               on serving invariants, and merge metrics into the perf
               trajectory
  corpus-stats [--scale S]                                print corpus stats without a run";

fn scale_of(args: &Args) -> Result<Scale> {
    Scale::from_name(args.get("scale", "default")).context("bad --scale")
}

fn open(args: &Args) -> Result<(std::sync::Arc<Runtime>, Pipeline)> {
    let run_dir = PathBuf::from(args.get("run", "runs/default"));
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let rt = Runtime::load(&artifacts)?;
    let scale = scale_of(args)?;
    let pl = Pipeline::new(rt.clone(), &run_dir, scale);
    Ok((rt, pl))
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let (_rt, pl) = open(args)?;
    pl.run_all()?;
    println!("[pipeline] complete: {:?}", pl.paths.root);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (_rt, pl) = open(args)?;
    let corpus = pl.ensure_corpus()?;
    let ev = Eval::new(&pl, &corpus);
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional.iter().any(|s| s == "all")
    {
        Eval::all_ids().iter().map(|s| s.to_string()).collect()
    } else {
        args.positional.clone()
    };
    for id in ids {
        let report = ev.run(&id)?;
        println!("\n{report}");
    }
    Ok(())
}

/// Table 2 — live per-query latency: router vs each LM (B=1 artifacts).
fn cmd_table2(args: &Args) -> Result<()> {
    let (rt, pl) = open(args)?;
    let corpus = pl.ensure_corpus()?;
    let n: usize = args.get_parse("queries", 100)?;
    let test: Vec<&corpus::Query> = corpus
        .iter()
        .filter(|q| q.split == corpus::Split::Test)
        .take(n)
        .collect();
    anyhow::ensure!(!test.is_empty(), "no test queries");

    let mut body = String::from("# Table 2 — per-query latency (mean ± stderr)\n\n");
    let mut rows = Vec::new();

    // router
    let router_dir = pl.paths.router_dir(
        &hybrid_llm::pipeline::pair_id("medium", "large"),
        hybrid_llm::router::RouterKind::Trans,
    );
    let router = hybrid_llm::router::RouterEngine::load(rt.clone(), &router_dir)?;
    let mut samples = Vec::new();
    router.score_one(&test[0].prompt)?; // warm compile
    for q in &test {
        let t0 = std::time::Instant::now();
        router.score_one(&q.prompt)?;
        samples.push(t0.elapsed().as_secs_f64());
    }
    rows.push(vec![
        "router".to_string(),
        format!(
            "{:.4} ± {:.4}",
            hybrid_llm::stats::mean(&samples),
            hybrid_llm::stats::std_err(&samples)
        ),
        "1 (encoder pass)".to_string(),
    ]);

    for model in hybrid_llm::pipeline::ROSTER {
        let eng = hybrid_llm::lm::LmEngine::load(rt.clone(), model, &pl.paths.params(model))?;
        eng.generate_one(&test[0].prompt, 0, 0.0)?; // warm compile
        let mut samples = Vec::new();
        let mut steps_total = 0usize;
        for q in &test {
            let t0 = std::time::Instant::now();
            let (_r, steps) = eng.generate_one(&q.prompt, q.id as u32, 0.0)?;
            samples.push(t0.elapsed().as_secs_f64());
            steps_total += steps + 1;
        }
        rows.push(vec![
            model.to_string(),
            format!(
                "{:.4} ± {:.4}",
                hybrid_llm::stats::mean(&samples),
                hybrid_llm::stats::std_err(&samples)
            ),
            format!("{:.1} (autoregressive)", steps_total as f64 / test.len() as f64),
        ]);
    }
    body.push_str(&hybrid_llm::eval::md_table(
        &["model", "latency (s)", "fwd passes/query"],
        &rows,
    ));
    std::fs::create_dir_all(pl.paths.results())?;
    std::fs::write(pl.paths.results().join("table2.md"), &body)?;
    println!("{body}");
    Ok(())
}

/// Split a router directory name (`<pair>_<kind>`, e.g.
/// `medium_large_trans`) into the stored-score pair id and kind. Random
/// routing (empty name) or an unrecognized suffix yields `None`.
fn router_score_source(router: &str) -> Option<(&str, hybrid_llm::router::RouterKind)> {
    let (pair, kind) = router.rsplit_once('_')?;
    Some((pair, hybrid_llm::router::RouterKind::from_name(kind)?))
}

/// Best-effort calibrated quality→ladder family for the fleet: needs a
/// completed pipeline run (stored scores for the configured router,
/// per-tier-model quality samples). `None` when any input is missing —
/// the server then falls back to its synthetic family.
fn calibrated_quality_family(
    pl: &hybrid_llm::pipeline::Pipeline,
    corpus: &[corpus::Query],
    tiers: &[hybrid_llm::serve::TierSpec],
    router_pair: &str,
    kind: hybrid_llm::router::RouterKind,
) -> Option<hybrid_llm::policy::LadderFamily> {
    let val = corpus::split_ids(corpus, corpus::Split::Val);
    let all_scores = pl.load_router_scores(router_pair, kind).ok()?;
    let scores: Vec<f32> = val
        .iter()
        .map(|&i| all_scores.get(i).copied())
        .collect::<Option<Vec<f32>>>()?;
    let mut quals: Vec<Vec<f64>> = Vec::new();
    for t in tiers {
        let q = pl.load_quality(&t.model, corpus).ok()?;
        quals.push(hybrid_llm::pipeline::subset(&q, &val).mean());
    }
    let costs: Vec<f64> = tiers.iter().map(|t| t.cost).collect();
    hybrid_llm::calibrate::calibrate_quality_ladders(&scores, &quals, &costs, 8).ok()
}

/// End-to-end serving demo: batched requests through the router and the
/// tier fleet (default: the paper's two-tier small/large pair).
fn cmd_serve_demo(args: &Args) -> Result<()> {
    let run_dir = PathBuf::from(args.get("run", "runs/default"));
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let n: usize = args.get_parse("requests", 64)?;
    let threshold: f32 = args.get_parse("threshold", 0.5)?;
    let quality: Option<f32> = args.get_parse_opt("quality")?;
    let queue_cap: usize = args.get_parse("queue-cap", hybrid_llm::serve::DEFAULT_QUEUE_CAP)?;
    let deadline_ms: Option<u64> = args.get_parse_opt("deadline-ms")?;
    // failure handling: stall detection (off by default — a timeout is
    // workload-dependent) and the per-request requeue budget
    let decode_timeout = args.get_ms("decode-timeout-ms")?;
    let retry_budget: u32 = args.get_parse("retry-budget", 2)?;
    // --brownout-target-ms: arm the overload controller with a CoDel-style
    // target sojourn; absent, the server runs without one (byte-identical
    // routing to the pre-brownout build)
    let brownout_target = args.get_ms("brownout-target-ms")?;
    let priority = match args.get("priority", "interactive") {
        "interactive" => hybrid_llm::policy::Priority::Interactive,
        "batch" => hybrid_llm::policy::Priority::Batch,
        "best-effort" => hybrid_llm::policy::Priority::BestEffort,
        other => anyhow::bail!("bad --priority {other:?} (interactive|batch|best-effort)"),
    };
    let mode = match args.get("mode", "cont") {
        "rtc" => BatchMode::RunToCompletion,
        _ => BatchMode::Continuous,
    };
    // --admit host: force the host slot-surgery install (A/B baseline
    // for the v3 device-side admission path)
    let force_host_admission = match args.get("admit", "device") {
        "host" => true,
        "device" => false,
        other => anyhow::bail!("bad --admit {other:?} (device|host)"),
    };
    // --kv dense: keep the dense slab on v4 artifacts (A/B baseline for
    // the block-paged pool); --prefix-cache off: paged without sharing
    let force_dense_kv = match args.get("kv", "paged") {
        "dense" => true,
        "paged" => false,
        other => anyhow::bail!("bad --kv {other:?} (paged|dense)"),
    };
    let disable_prefix_cache = match args.get("prefix-cache", "on") {
        "off" => true,
        "on" => false,
        other => anyhow::bail!("bad --prefix-cache {other:?} (on|off)"),
    };
    // --decode hybrid: token-level draft–verify between the boundary
    // tiers (v5 artifacts); requests fall back to routed when the
    // artifacts can't support the protocol
    let decode = match args.get("decode", "routed") {
        "hybrid" => hybrid_llm::serve::DecodeMode::Hybrid,
        "routed" => hybrid_llm::serve::DecodeMode::Routed,
        other => anyhow::bail!("bad --decode {other:?} (routed|hybrid)"),
    };
    let pair_small = args.get("small", "medium").to_string();
    let pair_large = args.get("large", "large").to_string();

    // fleet: --tiers spec, else the seed-compatible two-tier pair
    let tiers = match args.get_opt("tiers") {
        Some(spec) => hybrid_llm::serve::parse_tiers(spec)?,
        None => hybrid_llm::serve::two_tier(&pair_small, &pair_large),
    };
    // ladder: --thresholds, else --threshold for two tiers / even bands
    let policy = match args.get_csv::<f32>("thresholds") {
        Some(t) => TierPolicy::Ladder { thresholds: t? },
        None if tiers.len() == 2 => TierPolicy::Ladder { thresholds: vec![threshold] },
        None => TierPolicy::even_ladder(tiers.len()),
    };
    let select = match args.get("select", "rr") {
        "sq" => hybrid_llm::serve::ReplicaSelect::ShortestQueue,
        _ => hybrid_llm::serve::ReplicaSelect::RoundRobin,
    };
    let first = tiers.first().map(|t| t.model.clone()).unwrap_or_default();
    let last = tiers.last().map(|t| t.model.clone()).unwrap_or_default();
    let default_router = format!("{first}_{last}_trans");
    let router = args.get("router", &default_router).to_string();

    // corpus for prompts
    let rt = Runtime::load(&artifacts)?;
    let manifest_version = rt.manifest.version;
    let scale = scale_of(args)?;
    let pl = Pipeline::new(rt, &run_dir, scale);
    let corpus = pl.ensure_corpus()?;
    let test: Vec<_> = corpus
        .iter()
        .filter(|q| q.split == corpus::Split::Test)
        .take(n)
        .collect();

    let fleet_desc: Vec<String> = tiers
        .iter()
        .map(|t| format!("{}x{} (cost {:.2})", t.name, t.replicas, t.cost))
        .collect();
    // quality→ladder family: calibrated against the *configured*
    // router's stored validation scores when available, synthetic
    // otherwise (only consulted by requests carrying --quality)
    let quality_ladders = match router_score_source(&router)
        .and_then(|(pair, kind)| calibrated_quality_family(&pl, &corpus, &tiers, pair, kind))
    {
        Some(f) => {
            println!(
                "[serve] quality ladders calibrated from {router}'s validation scores in {run_dir:?}"
            );
            Some(f)
        }
        None => {
            println!("[serve] quality ladders synthetic (no calibration data in the run dir)");
            None
        }
    };
    let cfg = hybrid_llm::serve::ServeConfig {
        artifacts_dir: artifacts,
        run_dir,
        tiers,
        router,
        policy,
        select,
        temp: 0.0,
        mode,
        batch_window: Duration::from_millis(5),
        queue_cap,
        quality_ladders,
        force_host_admission,
        force_dense_kv,
        disable_prefix_cache,
        decode_timeout,
        retry_budget,
        fault_plan: None,
        decode,
        brownout_target,
    };
    println!(
        "[serve] starting fleet [{}], {mode:?}, queue cap {queue_cap}{}",
        fleet_desc.join(", "),
        quality.map_or(String::new(), |q| format!(", quality target {q}"))
    );
    let server = hybrid_llm::serve::Server::start(cfg)?;
    let t0 = std::time::Instant::now();
    let mut submit_rng = hybrid_llm::rng::Rng::new(0x5EB0FF);
    let mut handles = Vec::new();
    for q in &test {
        let mut req = hybrid_llm::serve::Request::new(q.prompt.clone()).priority(priority);
        if let Some(qt) = quality {
            req = req.quality(qt);
        }
        if let Some(ms) = deadline_ms {
            req = req.deadline(Duration::from_millis(ms));
        }
        // bounded admission: shared jittered-backoff Busy retry
        match hybrid_llm::serve::submit_with_retry(
            &server,
            &req,
            &mut submit_rng,
            Duration::from_secs(120),
            || {},
        ) {
            Ok(Some(h)) => handles.push(h),
            Ok(None) => anyhow::bail!("admission window stayed full for 120s"),
            Err(e) => return Err(anyhow::anyhow!(e)).context("submit"),
        }
    }
    let mut completions = Vec::new();
    let mut shed = 0usize;
    for h in handles {
        match h.wait() {
            Ok(c) => completions.push(c),
            Err(hybrid_llm::serve::RequestError::Failed(_)) => shed += 1,
            Err(e) => return Err(anyhow::anyhow!(e)).context("completion dropped"),
        }
    }
    let wall = t0.elapsed();
    let stats = server.shutdown()?;

    println!("\n== serving report ==");
    println!(
        "requests: {} completed / {} shed   wall: {:.2}s   throughput: {:.1} req/s",
        completions.len(),
        shed,
        wall.as_secs_f64(),
        completions.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "cost advantage: {:.1}% ({} small / {} large)   cancelled: {}   deadline-shed: {}",
        stats.routing.cost_advantage * 100.0,
        stats.routing.to_small(),
        stats.routing.to_large(),
        stats.routing.cancelled_total(),
        stats.routing.shed_total()
    );
    println!(
        "failovers: {}   degraded: {}   retries: {}   worker deaths: {}   breakers: [{}]",
        stats.failovers,
        stats.degraded,
        stats.retries,
        stats.worker_deaths,
        stats.breaker_state.join(", ")
    );
    println!(
        "router latency: mean {:.2} ms   e2e p50 {:.0} ms  p95 {:.0} ms",
        stats.router_latency.mean_ms, stats.e2e_latency.p50_ms, stats.e2e_latency.p95_ms
    );
    println!(
        "queue delay: p50 {:.2} ms  p99 {:.2} ms   brownout level: {}   \
         effective quality delta: {:.3}",
        stats.queue_delay.p50_ms,
        stats.queue_delay.p99_ms,
        stats.brownout_level,
        stats.effective_quality_delta
    );
    for p in hybrid_llm::policy::Priority::all() {
        let i = p.index();
        if stats.class_admitted[i] > 0 || stats.class_shed[i] > 0 {
            println!(
                "class {:<12} admitted {:>5}   shed {:>5}",
                p.name(),
                stats.class_admitted[i],
                stats.class_shed[i]
            );
        }
    }
    let total = stats.routing.total().max(1);
    for (ts, tr) in stats.tiers.iter().zip(&stats.routing.tiers) {
        println!(
            "tier {:<10} routed {:>5} ({:>5.1}%)   e2e p50 {:>6.0} ms  p95 {:>6.0} ms",
            ts.name,
            tr.routed,
            tr.routed as f64 / total as f64 * 100.0,
            ts.latency.p50_ms,
            ts.latency.p95_ms
        );
    }
    let eff = if stats.decode_steps > 0 {
        stats.decode_slot_steps as f64 / (stats.decode_steps as f64 * 16.0)
    } else {
        0.0
    };
    println!(
        "decode iterations: {}   slot efficiency: {:.2}",
        stats.decode_steps, eff
    );
    println!(
        "host transfer per decode step: {:.1} KiB down / {:.1} KiB up (device-resident KV); \
         admissions moved {:.1} KiB down / {:.1} KiB up total",
        stats.d2h_bytes_per_step() / 1024.0,
        stats.h2d_bytes_per_step() / 1024.0,
        stats.admit_d2h_bytes as f64 / 1024.0,
        stats.admit_h2d_bytes as f64 / 1024.0
    );
    // label by what actually runs, not just the flag: pre-v3 artifacts
    // fall back to host surgery regardless of --admit
    let admit_path = if force_host_admission {
        "host surgery (--admit host)"
    } else if manifest_version >= 3 {
        "device install (v3 artifacts)"
    } else {
        "host surgery (pre-v3 artifacts)"
    };
    println!(
        "admissions: {} waves / {} requests ({admit_path})   p50 {:.2} ms   {:.2} KiB per request",
        stats.admissions,
        stats.admitted,
        stats.admit_latency.p50_ms,
        stats.admit_bytes_per_req() / 1024.0
    );
    let kv_path = if force_dense_kv {
        "dense slab (--kv dense)"
    } else if manifest_version >= 4 {
        "block-paged pool (v4 artifacts)"
    } else {
        "dense slab (pre-v4 artifacts)"
    };
    println!(
        "kv cache: {kv_path}   block utilization {:.0}%   prefix hit rate {:.0}% \
         ({} shared tokens, {} prefilled)",
        stats.kv_blocks_utilization * 100.0,
        stats.prefix_hit_rate * 100.0,
        stats.prefix_shared_tokens,
        stats.prefill_tokens
    );
    if stats.hybrid_requests > 0 {
        println!(
            "hybrid decode: {} requests   draft accept rate {:.0}%   large-call fraction {:.2} \
             ({} verify calls / {} emitted, {} degraded blocks)",
            stats.hybrid_requests,
            stats.draft_accept_rate * 100.0,
            stats.large_call_fraction,
            stats.verify_calls,
            stats.hybrid_emitted,
            stats.hybrid_degraded_blocks
        );
    }
    Ok(())
}

/// One-command scenario sweep: replay every built-in traffic scenario
/// (bursts, diurnal swings, long tails, mixed quality, overload, cancel
/// storms) against a fresh two-tier fleet, gate each on the serving
/// invariants, regenerate `results/scenarios.md`, and merge per-scenario
/// metrics into the perf trajectory. Exits non-zero on any invariant
/// violation — this is the CI smoke gate (`kick-tires --smoke`).
fn cmd_kick_tires(args: &Args) -> Result<()> {
    let run_dir = PathBuf::from(args.get("run", "runs/default"));
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    if !artifacts.join("manifest.txt").exists() {
        println!(
            "kick-tires: skipping — artifacts not built at {artifacts:?} (run `make artifacts`)"
        );
        return Ok(());
    }
    let mut opts = hybrid_llm::scenario::KickTiresOpts::new(artifacts.clone(), run_dir.clone());
    opts.small = args.get("small", "small").to_string();
    opts.large = args.get("large", "medium").to_string();
    opts.smoke = args.switch("smoke");
    opts.chaos = args.switch("chaos");
    opts.overload = args.switch("overload");
    opts.seed = args.get_parse("seed", opts.seed)?;
    opts.only = args.get_csv::<String>("scenarios").transpose()?;
    opts.bench_json = Some(PathBuf::from(args.get("json", "BENCH_serving.json")));
    opts.drain_timeout = args.get_ms("drain-timeout-ms")?;

    // seed init weights for any tier model the run dir doesn't have yet
    // (replay latency is weight-independent, so a pipeline run is not
    // required to kick the serving loop's tires)
    {
        let rt = Runtime::load(&artifacts)?;
        for model in [opts.small.as_str(), opts.large.as_str()] {
            let dir = run_dir.join("params").join(model);
            if !dir.exists() {
                println!("kick-tires: seeding init weights for {model} in {dir:?}");
                hybrid_llm::lm::LmEngine::init(rt.clone(), model, 3)?.save(&dir)?;
            }
        }
    }

    let mode = if opts.smoke { "smoke" } else { "full" };
    println!(
        "kick-tires: {mode} sweep, fleet {}/{}, seed {:#x}",
        opts.small, opts.large, opts.seed
    );
    let report = hybrid_llm::scenario::kick_tires(&opts)?;
    print!("{}", report.render());
    println!(
        "\nwrote {:?} and merged {} metrics into {:?}",
        run_dir.join("results").join("scenarios.md"),
        report.bench_entries().len(),
        opts.bench_json.as_ref().unwrap()
    );
    let violations = report.total_violations();
    anyhow::ensure!(
        violations == 0,
        "{violations} invariant violation(s) — see the report above"
    );
    println!("all scenarios passed their invariants");
    Ok(())
}

fn cmd_corpus_stats(args: &Args) -> Result<()> {
    let scale = scale_of(args)?;
    let c = corpus::generate(0xDEED, scale);
    let mut by: std::collections::BTreeMap<&str, usize> = Default::default();
    for q in &c {
        *by.entry(q.task.source()).or_default() += 1;
    }
    println!("MixSynth @ {scale:?}: {} examples", c.len());
    for (s, n) in by {
        println!("  {s:<14} {n}");
    }
    Ok(())
}
