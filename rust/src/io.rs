//! On-disk formats for run state (no serde in the offline environment):
//!
//! * **Tensor files** (`*.tz`): a tiny binary format — magic `RTEN`,
//!   dtype tag, rank, little-endian u32 dims, raw LE data. Used for model
//!   parameters, generated responses, and score matrices.
//! * **Key-value text** (`*.kv`): `key<TAB>value` lines for small run
//!   metadata (thresholds, t*, counts).

use std::fs::{self, File};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"RTEN";

/// Element type tag for tensor files.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
        }
    }

    fn from_tag(t: u8) -> Result<Self> {
        Ok(match t {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            _ => bail!("unknown dtype tag {t}"),
        })
    }
}

/// A host-side dense tensor (f32/i32/u32 payloads).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U32 { dims: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } | Tensor::U32 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
            Tensor::U32 { .. } => DType::U32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
            Tensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Mutable payload access (dims are fixed) — lets hot paths refill a
    /// scratch tensor in place instead of allocating a new one per call.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32_mut(&mut self) -> Result<&mut [i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u32_mut(&mut self) -> Result<&mut [u32]> {
        match self {
            Tensor::U32 { data, .. } => Ok(data),
            _ => bail!("tensor is not u32"),
        }
    }

    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::F32 { dims, data }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::I32 { dims, data }
    }

    pub fn u32(dims: Vec<usize>, data: Vec<u32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::U32 { dims, data }
    }

    /// Write in the `RTEN` binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path).with_context(|| format!("create {path:?}"))?);
        w.write_all(MAGIC)?;
        w.write_all(&[self.dtype().tag(), self.dims().len() as u8])?;
        for &d in self.dims() {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        match self {
            Tensor::F32 { data, .. } => {
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Tensor::I32 { data, .. } => {
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            Tensor::U32 { data, .. } => {
                for &x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Read an `RTEN` file.
    pub fn load(path: &Path) -> Result<Self> {
        let mut r = BufReader::new(File::open(path).with_context(|| format!("open {path:?}"))?);
        let mut head = [0u8; 6];
        r.read_exact(&mut head)?;
        if &head[..4] != MAGIC {
            bail!("{path:?}: bad magic");
        }
        let dtype = DType::from_tag(head[4])?;
        let rank = head[5] as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            dims.push(u32::from_le_bytes(b) as usize);
        }
        let n: usize = dims.iter().product();
        let mut raw = vec![0u8; n * 4];
        r.read_exact(&mut raw)?;
        Ok(match dtype {
            DType::F32 => Tensor::F32 {
                dims,
                data: raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            DType::I32 => Tensor::I32 {
                dims,
                data: raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
            DType::U32 => Tensor::U32 {
                dims,
                data: raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect(),
            },
        })
    }
}

/// Save a list of named tensors as `<dir>/<name>.tz` (name slashes -> `_`).
pub fn save_tensors(dir: &Path, tensors: &[(String, Tensor)]) -> Result<()> {
    fs::create_dir_all(dir)?;
    for (name, t) in tensors {
        t.save(&dir.join(format!("{}.tz", name.replace('/', "_"))))?;
    }
    Ok(())
}

/// Load `<dir>/<name>.tz` for each requested name, in order.
pub fn load_tensors(dir: &Path, names: &[String]) -> Result<Vec<Tensor>> {
    names
        .iter()
        .map(|n| Tensor::load(&dir.join(format!("{}.tz", n.replace('/', "_")))))
        .collect()
}

/// Write `key<TAB>value` lines.
pub fn save_kv(path: &Path, pairs: &[(String, String)]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut s = String::new();
    for (k, v) in pairs {
        assert!(!k.contains('\t') && !v.contains('\n'));
        s.push_str(&format!("{k}\t{v}\n"));
    }
    fs::write(path, s)?;
    Ok(())
}

/// Read `key<TAB>value` lines.
pub fn load_kv(path: &Path) -> Result<Vec<(String, String)>> {
    let text = fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut out = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('\t')
            .with_context(|| format!("bad kv line: {line}"))?;
        out.push((k.to_string(), v.to_string()));
    }
    Ok(out)
}

/// Look up a key in kv pairs.
pub fn kv_get<'a>(pairs: &'a [(String, String)], key: &str) -> Option<&'a str> {
    pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("hybrid_llm_io_{name}_{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn tensor_roundtrip_f32() {
        let d = tmpdir("f32");
        let t = Tensor::f32(vec![2, 3], vec![1.0, -2.5, 3.25, 0.0, f32::MIN, f32::MAX]);
        let p = d.join("a.tz");
        t.save(&p).unwrap();
        assert_eq!(Tensor::load(&p).unwrap(), t);
    }

    #[test]
    fn tensor_roundtrip_i32_u32() {
        let d = tmpdir("i32");
        let t = Tensor::i32(vec![4], vec![i32::MIN, -1, 0, i32::MAX]);
        let p = d.join("b.tz");
        t.save(&p).unwrap();
        assert_eq!(Tensor::load(&p).unwrap(), t);
        let u = Tensor::u32(vec![2, 1], vec![0, u32::MAX]);
        let q = d.join("c.tz");
        u.save(&q).unwrap();
        assert_eq!(Tensor::load(&q).unwrap(), u);
    }

    #[test]
    fn tensor_mutable_payload_access() {
        let mut t = Tensor::i32(vec![3], vec![1, 2, 3]);
        t.as_i32_mut().unwrap()[1] = 9;
        assert_eq!(t.as_i32().unwrap(), &[1, 9, 3]);
        assert!(t.as_f32_mut().is_err());
        assert!(t.as_u32_mut().is_err());
        let mut u = Tensor::u32(vec![2], vec![0, 0]);
        u.as_u32_mut().unwrap()[0] = 7;
        assert!(matches!(u, Tensor::U32 { ref data, .. } if data[0] == 7));
        let mut f = Tensor::f32(vec![1], vec![0.0]);
        f.as_f32_mut().unwrap()[0] = 1.5;
        assert_eq!(f.as_f32().unwrap(), &[1.5]);
    }

    #[test]
    fn tensor_scalar_rank0() {
        let d = tmpdir("scalar");
        let t = Tensor::f32(vec![], vec![3.5]);
        let p = d.join("s.tz");
        t.save(&p).unwrap();
        let r = Tensor::load(&p).unwrap();
        assert_eq!(r.dims(), &[] as &[usize]);
        assert_eq!(r.as_f32().unwrap(), &[3.5]);
    }

    #[test]
    fn named_tensor_roundtrip() {
        let d = tmpdir("named");
        let ts = vec![
            ("p.emb".to_string(), Tensor::f32(vec![2], vec![1.0, 2.0])),
            ("p.l00.wq".to_string(), Tensor::f32(vec![1], vec![3.0])),
        ];
        save_tensors(&d, &ts).unwrap();
        let names: Vec<String> = ts.iter().map(|(n, _)| n.clone()).collect();
        let back = load_tensors(&d, &names).unwrap();
        assert_eq!(back[0], ts[0].1);
        assert_eq!(back[1], ts[1].1);
    }

    #[test]
    fn kv_roundtrip() {
        let d = tmpdir("kv");
        let p = d.join("meta.kv");
        let pairs = vec![
            ("tstar".to_string(), "0.25".to_string()),
            ("n_train".to_string(), "2000".to_string()),
        ];
        save_kv(&p, &pairs).unwrap();
        let back = load_kv(&p).unwrap();
        assert_eq!(back, pairs);
        assert_eq!(kv_get(&back, "tstar"), Some("0.25"));
        assert_eq!(kv_get(&back, "missing"), None);
    }

    #[test]
    fn bad_magic_rejected() {
        let d = tmpdir("bad");
        let p = d.join("x.tz");
        fs::write(&p, b"NOPE\x00\x00").unwrap();
        assert!(Tensor::load(&p).is_err());
    }
}
