//! Deterministic PRNG for the coordinator (corpus generation, shuffles,
//! random-routing baseline, property tests).
//!
//! The offline environment has no `rand` crate; this is SplitMix64 (for
//! seeding) + xoshiro256** (for the stream), the standard public-domain
//! constructions. Determinism matters: every experiment in
//! `EXPERIMENTS.md` is reproducible from a seed recorded in its driver.

/// SplitMix64 step — used to expand a seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded construction (SplitMix64-expanded; any seed is fine, incl. 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task.
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: first k positions are a uniform sample
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 7);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(11);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
