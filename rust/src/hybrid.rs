//! Token-level hybrid decoding: the speculative draft–verify protocol
//! between adjacent tiers (DESIGN.md §12).
//!
//! The small tier streams a block of draft tokens from its own KV
//! state; the large tier verifies the whole block in **one** forward
//! pass through the manifest-v5 `verify@K` artifact, which scores K
//! appended positions through the paged block tables and returns the
//! large model's next-token choice at every one. Longest-prefix greedy
//! acceptance plus a correction token pins the emitted stream to what
//! large-only greedy decoding would produce — byte-identical when every
//! block verifies (the [`crate::policy::ALWAYS_VERIFY_QUALITY`] regime)
//! — while spending one large forward pass per *block* instead of one
//! per *token*.
//!
//! This module holds the pure protocol logic — acceptance, block
//! planning, the token ledger, and the verify-path circuit breaker —
//! all unit-testable without artifacts. The threaded worker that drives
//! it against real executables lives in [`crate::serve`] (hybrid
//! dispatch mode), and the per-token escalation policy deciding *which*
//! blocks are worth a large forward pass lives in [`crate::policy`]
//! ([`crate::policy::should_verify`]).

use std::time::{Duration, Instant};

/// Longest accepted draft prefix: the number of leading draft tokens
/// that match the large tier's own next-token choices.
///
/// `verified[i]` is the large model's choice after consuming the
/// current token plus `drafts[..i]` — so `drafts[i]` is accepted iff it
/// equals `verified[i]`, and acceptance is prefix-closed (the first
/// mismatch invalidates every later draft, whose context already
/// diverged).
pub fn accept_len(drafts: &[i32], verified: &[i32]) -> usize {
    drafts
        .iter()
        .zip(verified)
        .take_while(|(d, v)| d == v)
        .count()
}

/// Resolve one verify call: returns `(accepted, emit)` where `accepted`
/// is the accepted draft-prefix length and `emit` the tokens to stream.
///
/// `emit` is always `verified[..=accepted]`: the accepted drafts (which
/// *are* the large model's choices at those positions) followed by one
/// more large-chosen token — the correction at the first mismatch, or
/// the bonus token when every draft survived. Every emitted token is
/// therefore the large model's greedy choice, which is the whole
/// byte-identity argument. With K−1 drafts per `verify@K` call the
/// large tier emits up to K tokens per forward pass.
pub fn resolve_verify(drafts: &[i32], verified: &[i32]) -> (usize, Vec<i32>) {
    debug_assert!(drafts.len() < verified.len(), "verify@K covers K-1 drafts plus the current token");
    let a = accept_len(drafts, verified);
    (a, verified[..=a.min(verified.len() - 1)].to_vec())
}

/// Largest verify bucket not exceeding `cap` — block planning near the
/// end of the context window, where a full-size block would write past
/// the reserved EOS slot. `buckets` ascending (manifest order);
/// `None` means not even a 1-token verify fits (the lane must finish).
pub fn largest_bucket_at_most(buckets: &[usize], cap: usize) -> Option<usize> {
    buckets.iter().rev().find(|&&b| b <= cap).copied()
}

/// Tokens a lane may still consume before its next write position hits
/// the reserved EOS slot: positions `lpos .. sctx-1` exclusive
/// (mirrors [`crate::serve`]'s `context_full` stop rule).
pub fn context_room(lpos: usize, sctx: usize) -> usize {
    (sctx.saturating_sub(1)).saturating_sub(lpos)
}

/// Per-worker draft/verify token ledger. The serving layer mirrors
/// these into [`crate::serve::ServerStats`]; scenario invariant checks
/// ([`crate::scenario`]) re-derive the same inequalities fleet-wide.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Ledger {
    /// Tokens drafted by the small tier (catch-up steps excluded).
    pub draft_tokens: u64,
    /// Drafted tokens accepted by a large-tier verify call.
    pub draft_accepted: u64,
    /// Drafted tokens streamed without verification (escalation policy
    /// short-circuit, or verify-breaker degradation).
    pub local_accepted: u64,
    /// Per-lane verify invocations — each is one large forward pass for
    /// that lane.
    pub verify_calls: u64,
    /// Tokens emitted (streamed) by hybrid lanes, all sources.
    pub emitted: u64,
    /// Blocks streamed unverified because the verify breaker was open
    /// (large-tier outage degraded to pure small-tier drafting).
    pub degraded_blocks: u64,
}

impl Ledger {
    /// Fold one resolved verify call into the ledger.
    pub fn record_verify(&mut self, drafted: usize, accepted: usize, emitted: usize) {
        self.draft_tokens += drafted as u64;
        self.draft_accepted += accepted as u64;
        self.verify_calls += 1;
        self.emitted += emitted as u64;
    }

    /// Fold one locally-accepted (unverified) block into the ledger.
    pub fn record_local(&mut self, drafted: usize, emitted: usize, degraded: bool) {
        self.draft_tokens += drafted as u64;
        self.local_accepted += emitted as u64;
        self.emitted += emitted as u64;
        if degraded {
            self.degraded_blocks += 1;
        }
    }

    /// Fraction of drafted tokens that survived verification (1.0 when
    /// nothing was drafted — an empty ledger is not a failing one).
    pub fn accept_rate(&self) -> f64 {
        if self.draft_tokens == 0 {
            1.0
        } else {
            self.draft_accepted as f64 / self.draft_tokens as f64
        }
    }

    /// Large forward passes per emitted token — the cost headline. Pure
    /// large-tier decoding is 1.0 by construction; hybrid decoding sits
    /// below it whenever any draft is accepted or streamed locally.
    pub fn large_call_fraction(&self) -> f64 {
        if self.emitted == 0 {
            0.0
        } else {
            self.verify_calls as f64 / self.emitted as f64
        }
    }

    /// The ledger's internal accounting invariants; violation means the
    /// draft/verify bookkeeping desynced from the token stream.
    pub fn check(&self) -> Result<(), String> {
        if self.draft_accepted > self.draft_tokens {
            return Err(format!(
                "accepted {} drafts but only {} were drafted",
                self.draft_accepted, self.draft_tokens
            ));
        }
        if self.local_accepted > self.draft_tokens {
            return Err(format!(
                "locally accepted {} drafts but only {} were drafted",
                self.local_accepted, self.draft_tokens
            ));
        }
        if self.draft_accepted + self.local_accepted > self.draft_tokens {
            return Err(format!(
                "accepted {} + local {} exceeds drafted {}",
                self.draft_accepted, self.local_accepted, self.draft_tokens
            ));
        }
        if self.emitted < self.draft_accepted + self.local_accepted {
            return Err(format!(
                "emitted {} < accepted {} + local {} (every accepted draft is streamed)",
                self.emitted, self.draft_accepted, self.local_accepted
            ));
        }
        Ok(())
    }
}

/// Consecutive verify-path failures before the breaker opens.
pub const VERIFY_BREAKER_TRIP: u32 = 3;

/// How long an open verify breaker degrades to pure small-tier
/// drafting before probing the large tier again.
pub const VERIFY_BREAKER_COOLDOWN: Duration = Duration::from_millis(250);

/// Circuit breaker on the hybrid worker's verify path. The fleet-level
/// [`crate::serve::FleetHealth`] breakers guard whole tiers of routed
/// workers; this one guards the *internal* large-tier dependency of a
/// single hybrid worker, whose failure mode is not "route elsewhere"
/// but "degrade to pure small-tier drafting" — requests keep streaming
/// (unverified, counted in [`Ledger::degraded_blocks`]) instead of
/// failing, and a half-open probe retries the large tier after the
/// cooldown.
#[derive(Debug)]
pub struct VerifyBreaker {
    failures: u32,
    opened: Option<Instant>,
}

impl VerifyBreaker {
    pub fn new() -> VerifyBreaker {
        VerifyBreaker { failures: 0, opened: None }
    }

    /// May the next block attempt a verify call at `now`? Closed and
    /// half-open (cooldown elapsed — one probe) say yes; open says no.
    pub fn allow(&self, now: Instant) -> bool {
        match self.opened {
            None => true,
            Some(at) => now.duration_since(at) >= VERIFY_BREAKER_COOLDOWN,
        }
    }

    /// A verify call failed. Trips open after
    /// [`VERIFY_BREAKER_TRIP`] consecutive failures; a failed half-open
    /// probe re-opens immediately (the cooldown restarts).
    pub fn record_failure(&mut self, now: Instant) {
        self.failures += 1;
        if self.failures >= VERIFY_BREAKER_TRIP || self.opened.is_some() {
            self.opened = Some(now);
        }
    }

    /// A verify call succeeded: close and reset.
    pub fn record_success(&mut self) {
        self.failures = 0;
        self.opened = None;
    }

    /// `"closed"` / `"open"` / `"half-open"`, mirroring
    /// [`crate::serve::FleetHealth::states`]' vocabulary.
    pub fn state(&self, now: Instant) -> &'static str {
        match self.opened {
            None => "closed",
            Some(at) if now.duration_since(at) >= VERIFY_BREAKER_COOLDOWN => "half-open",
            Some(_) => "open",
        }
    }
}

impl Default for VerifyBreaker {
    fn default() -> Self {
        VerifyBreaker::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_len_is_longest_matching_prefix() {
        assert_eq!(accept_len(&[], &[9]), 0);
        assert_eq!(accept_len(&[5], &[5, 7]), 1);
        assert_eq!(accept_len(&[5], &[6, 7]), 0);
        assert_eq!(accept_len(&[5, 6, 7], &[5, 6, 7, 8]), 3);
        assert_eq!(accept_len(&[5, 6, 7], &[5, 9, 7, 8]), 1);
        // a later match after a mismatch must NOT count: the context
        // diverged at the first rejection
        assert_eq!(accept_len(&[5, 6, 7], &[9, 6, 7, 8]), 0);
    }

    #[test]
    fn resolve_verify_emits_accepted_prefix_plus_correction() {
        // full acceptance: every draft plus the bonus token
        let (a, emit) = resolve_verify(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!((a, emit), (3, vec![5, 6, 7, 8]));
        // mid-block rejection: accepted prefix plus the correction
        let (a, emit) = resolve_verify(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!((a, emit), (1, vec![5, 9]));
        // immediate rejection still makes progress: one correction
        let (a, emit) = resolve_verify(&[5, 6, 7], &[9, 6, 7, 8]);
        assert_eq!((a, emit), (0, vec![9]));
        // K=1 degenerate case: no drafts, pure large decode
        let (a, emit) = resolve_verify(&[], &[4]);
        assert_eq!((a, emit), (0, vec![4]));
    }

    #[test]
    fn every_emitted_token_is_large_chosen() {
        // the byte-identity core: emit is literally a prefix of the
        // large model's own choices, regardless of the drafts
        let verified = [10, 11, 12, 13];
        for drafts in [[10, 11, 12], [10, 99, 12], [99, 11, 12]] {
            let (a, emit) = resolve_verify(&drafts, &verified);
            assert_eq!(emit, verified[..=a], "drafts {drafts:?}");
        }
    }

    #[test]
    fn bucket_planning_near_the_context_edge() {
        let buckets = [1, 2, 4, 8];
        assert_eq!(largest_bucket_at_most(&buckets, 8), Some(8));
        assert_eq!(largest_bucket_at_most(&buckets, 9), Some(8));
        assert_eq!(largest_bucket_at_most(&buckets, 7), Some(4));
        assert_eq!(largest_bucket_at_most(&buckets, 1), Some(1));
        assert_eq!(largest_bucket_at_most(&buckets, 0), None);
        // room mirrors context_full: with sctx=64 the last writable
        // position is 62, so a lane at lpos=61 has room for 2 tokens
        assert_eq!(context_room(61, 64), 2);
        assert_eq!(context_room(62, 64), 1);
        assert_eq!(context_room(63, 64), 0);
        assert_eq!(context_room(64, 64), 0);
        assert_eq!(context_room(0, 0), 0);
    }

    #[test]
    fn ledger_accounting_and_rates() {
        let mut l = Ledger::default();
        assert_eq!(l.accept_rate(), 1.0);
        assert_eq!(l.large_call_fraction(), 0.0);
        l.check().unwrap();
        // one verify round: 7 drafts, 5 accepted, 6 emitted (correction)
        l.record_verify(7, 5, 6);
        // one local block: 7 drafted, all streamed unverified
        l.record_local(7, 7, false);
        // one degraded block
        l.record_local(3, 3, true);
        assert_eq!(l.draft_tokens, 17);
        assert_eq!(l.draft_accepted, 5);
        assert_eq!(l.local_accepted, 10);
        assert_eq!(l.verify_calls, 1);
        assert_eq!(l.emitted, 16);
        assert_eq!(l.degraded_blocks, 1);
        assert!((l.accept_rate() - 5.0 / 17.0).abs() < 1e-12);
        assert!((l.large_call_fraction() - 1.0 / 16.0).abs() < 1e-12);
        l.check().unwrap();
    }

    #[test]
    fn ledger_check_catches_desyncs() {
        let l = Ledger { draft_tokens: 2, draft_accepted: 3, ..Default::default() };
        assert!(l.check().is_err());
        let l = Ledger { draft_tokens: 2, local_accepted: 3, ..Default::default() };
        assert!(l.check().is_err());
        let l = Ledger {
            draft_tokens: 4,
            draft_accepted: 2,
            local_accepted: 2,
            emitted: 3,
            ..Default::default()
        };
        assert!(l.check().is_err());
    }

    #[test]
    fn ledger_stays_balanced_when_emit_truncates_the_accepted_prefix() {
        // a stop rule (EOS / token budget / context edge) inside the
        // accepted prefix truncates the emit loop: the serving layer
        // must clamp accepted to the streamed count before recording,
        // or `emitted >= accepted` breaks
        let mut l = Ledger::default();
        let streamed = 2usize;
        let accepted = 5usize.min(streamed);
        l.record_verify(5, accepted, streamed);
        assert_eq!(l.draft_accepted, 2);
        assert_eq!(l.emitted, 2);
        l.check().unwrap();
        // the unclamped record is exactly what check() rejects
        let mut bad = Ledger::default();
        bad.record_verify(5, 5, 2);
        assert!(bad.check().is_err());
    }

    #[test]
    fn breaker_trips_cools_down_and_probes() {
        let t0 = Instant::now();
        let mut b = VerifyBreaker::new();
        assert!(b.allow(t0));
        assert_eq!(b.state(t0), "closed");
        b.record_failure(t0);
        b.record_failure(t0);
        assert!(b.allow(t0), "under the trip count the breaker stays closed");
        b.record_failure(t0);
        assert!(!b.allow(t0), "third consecutive failure opens it");
        assert_eq!(b.state(t0), "open");
        // cooldown elapses: half-open, one probe allowed
        let later = t0 + VERIFY_BREAKER_COOLDOWN;
        assert!(b.allow(later));
        assert_eq!(b.state(later), "half-open");
        // failed probe re-opens immediately (no 3-strike grace)
        b.record_failure(later);
        assert!(!b.allow(later + Duration::from_millis(1)));
        // successful probe closes and resets the strike count
        let probe2 = later + VERIFY_BREAKER_COOLDOWN;
        assert!(b.allow(probe2));
        b.record_success();
        assert_eq!(b.state(probe2), "closed");
        b.record_failure(probe2);
        assert!(b.allow(probe2), "success reset the consecutive-failure count");
    }
}
