//! The serving system (Fig. 2), generalized from the paper's two-model
//! pair to an **N-tier model fleet**: a query-router front end
//! dispatching to per-tier continuous-batching decode workers. Each
//! [`TierSpec`] names a tier (e.g. `device` / `edge` / `cloud`), the
//! model it serves, a relative cost weight, and `1..N` replica worker
//! threads; the default [`two_tier`] fleet reproduces the paper's
//! small/large setup exactly.
//!
//! Threading model: the `xla` crate's PJRT client is `Rc`-based and
//! therefore `!Send`, so **each replica thread owns its own PJRT client,
//! runtime, and engine** (loaded from the shared artifacts + run
//! directories); channels carry only plain data. This mirrors a real
//! deployment more closely anyway — the device, edge, and cloud backends
//! do not share an address space.
//!
//! The request boundary is a first-class API: [`Request`] (builder:
//! prompt, per-request quality target, token budget, deadline, policy
//! override) is submitted through a bounded admission window
//! ([`Server::submit`] returns [`SubmitError::Busy`] when full,
//! [`SubmitError::Closed`] when the server is gone) and yields a
//! [`RequestHandle`]: a stream of [`Event`]s (`Routed`, per-token
//! `Token`s, and exactly one terminal `Done`/`Failed`/`Cancelled`), a
//! [`RequestHandle::cancel`] knob that frees the request's KV slot
//! mid-decode, and a blocking [`RequestHandle::wait`] for callers that
//! only want the [`Completion`].
//!
//! * router thread — drains the ingress queue with a batching window,
//!   scores queries through the router encoder (single pass, §3), maps
//!   scores to tiers via a [`TierPolicy`] (threshold ladder) or, for
//!   requests carrying a quality target, the quality-indexed
//!   [`LadderFamily`], sheds deadline-expired requests, and picks a
//!   replica by round-robin or shortest-queue;
//! * decode workers — slot-based continuous batching ([`BatchMode`]),
//!   persistent KV caches, iteration-level admission, mid-decode
//!   cancellation surgery, and per-token event streaming.
//!
//! Admission is device-side on manifest-v3 artifacts: prefill runs at
//! the smallest power-of-two bucket that fits the admitted group
//! (`prefill@B`) and the fresh KV slots are scattered into the
//! persistent worker cache by the `kv_install@B` artifact
//! ([`KvCache::install_slots_device`]) — per admission the host moves
//! O(B·sprompt) prompt bytes, never the `[L, genb, sctx, H, Dh]` cache
//! pair the host-surgery fallback (v1/v2 artifacts, or
//! [`ServeConfig::force_host_admission`]) round-trips.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::batching::{BatchMode, KvCache, Slot, SlotTable};
use crate::hybrid::{self, VerifyBreaker};
use crate::io::Tensor;
use crate::lm::{LmEngine, PagedArtifacts, VerifyArtifacts};
use crate::metrics::{LatencyRecorder, LatencySummary, RoutingCounters, RoutingSnapshot};
use crate::paged::{blocks_needed, release_table, BlockAllocator, PagedKvCache, PrefixCache, PrefixHit};
use crate::policy::{self, LadderFamily, Priority, TierPolicy, PRIORITY_CLASSES};
use crate::rng::Rng;
use crate::router::RouterEngine;
use crate::runtime::{Exec, Globals, Manifest, Runtime, ELEM_BYTES};
use crate::tokenizer as tok;

/// Default bound on accepted-but-unfinished requests ([`ServeConfig::queue_cap`]).
pub const DEFAULT_QUEUE_CAP: usize = 256;

/// Rung count of the synthetic quality-ladder family used when
/// [`ServeConfig::quality_ladders`] carries no calibrated family.
const DEFAULT_QUALITY_LEVELS: usize = 8;

/// One tier of the fleet: a named model backend with a relative cost
/// weight and a replica count (worker threads serving this tier).
#[derive(Debug, Clone)]
pub struct TierSpec {
    /// Display/metrics name (defaults to the model name).
    pub name: String,
    /// Roster model this tier serves.
    pub model: String,
    /// Worker threads for this tier (each owns its own PJRT client).
    pub replicas: usize,
    /// Relative per-query cost weight (most expensive tier defines the
    /// cost-advantage baseline).
    pub cost: f64,
}

impl TierSpec {
    pub fn new(model: impl Into<String>, replicas: usize, cost: f64) -> TierSpec {
        let model = model.into();
        TierSpec { name: model.clone(), model, replicas, cost }
    }

    pub fn named(name: impl Into<String>, model: impl Into<String>, replicas: usize, cost: f64) -> TierSpec {
        TierSpec { name: name.into(), model: model.into(), replicas, cost }
    }
}

/// The paper's two-model fleet: `small` (tier 0, cost 0) and `large`
/// (tier 1, cost 1), one replica each — cost advantage reduces to the
/// fraction routed small, as in §2.3.
pub fn two_tier(small: &str, large: &str) -> Vec<TierSpec> {
    vec![TierSpec::new(small, 1, 0.0), TierSpec::new(large, 1, 1.0)]
}

/// Parse a `--tiers` fleet spec: comma-separated `model[:replicas[:cost]]`
/// entries, cheapest tier first, e.g. `small:1,large:1` or
/// `nano:2:0.02,medium:1:0.45,large:1:1`. Omitted costs default to even
/// spacing over `[0, 1]` (two tiers → `0, 1`, matching the seed).
pub fn parse_tiers(spec: &str) -> Result<Vec<TierSpec>> {
    let mut parsed: Vec<(String, usize, Option<f64>)> = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let mut fields = part.split(':');
        let model = fields.next().unwrap_or("").trim().to_string();
        anyhow::ensure!(!model.is_empty(), "empty tier name in --tiers spec {spec:?}");
        let replicas = match fields.next() {
            None => 1,
            Some(r) => r
                .trim()
                .parse::<usize>()
                .with_context(|| format!("bad replica count in tier {part:?}"))?,
        };
        anyhow::ensure!(replicas >= 1, "tier {part:?} needs at least one replica");
        let cost = match fields.next() {
            None => None,
            Some(c) => {
                let c = c
                    .trim()
                    .parse::<f64>()
                    .with_context(|| format!("bad cost in tier {part:?}"))?;
                anyhow::ensure!(
                    c.is_finite() && c >= 0.0,
                    "tier {part:?} cost must be finite and >= 0"
                );
                Some(c)
            }
        };
        anyhow::ensure!(fields.next().is_none(), "too many `:` fields in tier {part:?}");
        parsed.push((model, replicas, cost));
    }
    anyhow::ensure!(!parsed.is_empty(), "--tiers spec {spec:?} names no tiers");
    let k = parsed.len();
    Ok(parsed
        .into_iter()
        .enumerate()
        .map(|(i, (model, replicas, cost))| {
            let cost =
                cost.unwrap_or(if k <= 1 { 1.0 } else { i as f64 / (k - 1) as f64 });
            TierSpec::new(model, replicas, cost)
        })
        .collect())
}

/// How the server turns a routed request into tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// Per-request tier routing (the paper's baseline): the router picks
    /// one tier and that tier's worker decodes the whole answer.
    Routed,
    /// Token-level speculative draft–verify between the cheapest and the
    /// most expensive tier (DESIGN.md §12): the small tier drafts blocks
    /// from its own KV state, the large tier verifies each block in one
    /// `verify@K` forward pass, and longest-prefix acceptance plus a
    /// correction token keeps the stream byte-identical to large-only
    /// greedy decoding whenever every block verifies. Requires manifest
    /// v5 `verify@K` artifacts plus the paged-KV path on both tiers;
    /// otherwise requests silently fall back to `Routed`.
    Hybrid,
}

/// Replica selection within a tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaSelect {
    /// Rotate through replicas (fair under uniform work).
    RoundRobin,
    /// Send to the replica with the fewest in-flight requests.
    ShortestQueue,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    /// Run directory holding trained params (`params/<model>/`,
    /// `routers/<router>/`).
    pub run_dir: PathBuf,
    /// The fleet, cheapest tier first.
    pub tiers: Vec<TierSpec>,
    /// Router params subdirectory under `run_dir/routers/` (empty =>
    /// random scores fed through `policy`).
    pub router: String,
    /// Score → tier mapping (a threshold ladder in the paper's setup).
    pub policy: TierPolicy,
    /// Replica selection within a tier.
    pub select: ReplicaSelect,
    pub temp: f32,
    pub mode: BatchMode,
    /// How long the router waits to fill a batch.
    pub batch_window: Duration,
    /// Admission-control bound: maximum accepted-but-unfinished requests
    /// (queued + decoding). [`Server::submit`] returns
    /// [`SubmitError::Busy`] once the window is full — explicit
    /// backpressure instead of unbounded queueing.
    pub queue_cap: usize,
    /// Quality-indexed threshold-ladder family resolving per-request
    /// quality targets to tiers (built from calibration data via
    /// [`crate::calibrate::calibrate_quality_ladders`] and loaded at
    /// server start). `None` falls back to an uncalibrated
    /// [`LadderFamily::synthetic`] family over the fleet's tier count.
    pub quality_ladders: Option<LadderFamily>,
    /// Route admission through the host slot-surgery path even when the
    /// artifacts (manifest v3) support the device-side `kv_install`
    /// scatter. Bucketed prefill still applies, so this toggles *only*
    /// the install mechanism — the A/B knob behind the
    /// device-vs-host-admission equivalence tests and benches. No effect
    /// on v1/v2 artifacts (host surgery is their only path).
    pub force_host_admission: bool,
    /// Keep the dense `[L, genb, sctx, H, Dh]` KV slab even when the
    /// artifacts (manifest v4) carry the block-paged pool path — the A/B
    /// knob behind the dense-vs-paged token-equivalence test and
    /// benches, mirroring [`ServeConfig::force_host_admission`]. No
    /// effect on pre-v4 artifacts (dense is their only path).
    pub force_dense_kv: bool,
    /// Run paged but without cross-request shared-prefix reuse: every
    /// admission allocates fresh blocks and installs its full prompt.
    /// The A/B baseline for the prefix-cache bench gate (prefill work
    /// on a prefix-heavy trace must drop when the cache is on). No
    /// effect on the dense path, which never shares.
    pub disable_prefix_cache: bool,
    /// Stall detection: a replica whose decode loop makes no progress
    /// for this long while holding work is declared stalled — its tier
    /// breaker records a failure and the router routes around it
    /// (`--decode-timeout-ms`). `None` disables the stall monitor.
    pub decode_timeout: Option<Duration>,
    /// How many times a request orphaned by a dying worker is requeued
    /// (re-scored, re-resolved, `Routed` re-emitted) before it goes
    /// terminal with [`Event::Failed`] (`--retry-budget`).
    pub retry_budget: u32,
    /// Deterministic fault injection for the chaos scenarios — a
    /// **test-only hook**: workers check the plan at loop safe points
    /// (never while holding unpublished request state), so an injected
    /// crash/stall exercises exactly the recovery machinery a real one
    /// would. `None` (the default everywhere outside the chaos suite)
    /// compiles to an always-empty check.
    pub fault_plan: Option<FaultPlan>,
    /// Default decode mode for requests without a per-request override
    /// ([`Request::decode`]). [`DecodeMode::Hybrid`] needs a ≥2-tier
    /// fleet, manifest-v5 `verify@K` artifacts on the large tier, and
    /// the paged-KV path on both ends (`force_dense_kv` /
    /// `force_host_admission` disable it); when unavailable the server
    /// serves every request `Routed` and reports zero hybrid activity in
    /// [`ServerStats`].
    pub decode: DecodeMode,
    /// Overload brownout controller (DESIGN.md §13): the CoDel-style
    /// target sojourn for submit→dispatch queue delay. `Some(target)`
    /// arms the controller — the router senses sustained pressure
    /// (queue-delay EWMA vs this target, admission-window depth vs
    /// `queue_cap`, shed rate) and actuates
    /// [`ServerStats::brownout_level`]: L1 caps effective quality
    /// targets (routes cheaper), L2 relaxes hybrid escalation and
    /// shrinks draft blocks, L3 applies priority-weighted admission.
    /// `None` (the default) builds no controller at all: the level is
    /// pinned to 0 and routing is byte-identical to a server without
    /// brownout (A/B-gated in `tests/serve_integration.rs`).
    pub brownout_target: Option<Duration>,
}

/// One injected fault: fires in tier `tier`, replica `replica`, when
/// that worker's cumulative decode-step counter reaches `at_step`.
/// Counters survive respawn (they live outside the supervisor's unwind
/// boundary), so multi-fault plans describe a deterministic schedule
/// over the worker's whole lifetime.
#[derive(Debug, Clone)]
pub struct Fault {
    pub tier: usize,
    pub replica: usize,
    /// Cumulative decode steps completed by the worker when the fault
    /// fires (0 = before the first step).
    pub at_step: u64,
    pub kind: FaultKind,
}

/// What an injected [`Fault`] does at its safe point.
#[derive(Debug, Clone)]
pub enum FaultKind {
    /// Panic the worker's serve loop — the supervisor catches it,
    /// retires/requeues the in-flight requests, and respawns in place.
    Crash,
    /// Freeze the serve loop (heartbeat stops ticking) for this long —
    /// long stalls trip the decode-timeout monitor.
    Stall { ms: u64 },
    /// Sleep before each of the next `steps` decode steps — degraded
    /// but alive; must NOT trip the stall monitor (the heartbeat keeps
    /// advancing).
    SlowDecode { ms: u64, steps: u64 },
    /// Fail the admission path once with an error — the supervisor
    /// treats worker-loop errors like panics.
    AdmitError,
}

/// A seeded, deterministic fault schedule for the chaos scenarios.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new(faults: Vec<Fault>) -> FaultPlan {
        FaultPlan { faults }
    }

    /// The faults destined for one worker, in firing order.
    fn for_worker(&self, tier: usize, replica: usize) -> Vec<Fault> {
        let mut v: Vec<Fault> = self
            .faults
            .iter()
            .filter(|f| f.tier == tier && f.replica == replica)
            .cloned()
            .collect();
        v.sort_by_key(|f| f.at_step);
        v
    }
}

impl ServeConfig {
    /// Seed-compatible two-tier config: `score >= threshold` routes to
    /// `small`, one replica per tier. Adjust `temp`/`mode`/`batch_window`
    /// on the returned value as needed.
    pub fn two_tier(
        artifacts_dir: PathBuf,
        run_dir: PathBuf,
        small: &str,
        large: &str,
        router: String,
        threshold: f32,
    ) -> ServeConfig {
        ServeConfig {
            artifacts_dir,
            run_dir,
            tiers: two_tier(small, large),
            router,
            policy: TierPolicy::Ladder { thresholds: vec![threshold] },
            select: ReplicaSelect::RoundRobin,
            temp: 0.0,
            mode: BatchMode::Continuous,
            batch_window: Duration::from_millis(5),
            queue_cap: DEFAULT_QUEUE_CAP,
            quality_ladders: None,
            force_host_admission: false,
            force_dense_kv: false,
            disable_prefix_cache: false,
            decode_timeout: None,
            retry_budget: 2,
            fault_plan: None,
            decode: DecodeMode::Routed,
            brownout_target: None,
        }
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Index of the tier that served the request (0 = cheapest).
    pub tier: usize,
    pub router_score: f32,
    pub mean_logprob: f32,
    /// Ingress → completion.
    pub e2e: Duration,
    /// Ingress → routed to a worker queue.
    pub routing: Duration,
}

/// One serving request, built fluently and submitted with
/// [`Server::submit`]:
///
/// ```ignore
/// let handle = server.submit(
///     Request::new(prompt)
///         .quality(0.9)                      // per-request quality target
///         .max_new_tokens(32)                // token budget
///         .deadline(Duration::from_secs(2)), // shed if not decoding by then
/// )?;
/// let completion = handle.wait()?;
/// ```
#[derive(Debug, Clone, Default)]
pub struct Request {
    prompt: Vec<i32>,
    quality: Option<f32>,
    max_new_tokens: Option<usize>,
    deadline: Option<Duration>,
    policy: Option<TierPolicy>,
    truncate: bool,
    decode: Option<DecodeMode>,
    priority: Priority,
}

impl Request {
    pub fn new(prompt: Vec<i32>) -> Request {
        Request { prompt, ..Default::default() }
    }

    /// Quality target in `[0, 1]`: `0` routes for cost, `1` for
    /// quality. Resolved to a tier at routing time through the server's
    /// quality-indexed [`LadderFamily`], so two requests in the same
    /// batch window can route under different targets. Without a target
    /// (and without a [`Request::policy`] override) the server's
    /// default [`ServeConfig::policy`] applies. NaN or out-of-range
    /// targets are rejected at submit with
    /// [`SubmitError::InvalidQuality`] — earlier revisions let them
    /// flow into the ladder resolution with unspecified semantics.
    pub fn quality(mut self, q: f32) -> Request {
        self.quality = Some(q);
        self
    }

    /// Priority class for admission and shedding under overload
    /// (default [`Priority::Interactive`]). Below brownout level 3
    /// every class is admitted alike; at level 3 admission is
    /// priority-weighted and shedding is strictly lowest-class-first —
    /// `BestEffort` absorbs the shedding so `Interactive` goodput
    /// survives the overload (DESIGN.md §13).
    pub fn priority(mut self, p: Priority) -> Request {
        self.priority = p;
        self
    }

    /// Cap generated tokens at `n` (the artifact-wide answer budget
    /// still applies). `n = 0` is unsatisfiable — the decode wave
    /// samples a token at prefill before any budget check can run — so
    /// [`Server::submit`] rejects it with
    /// [`SubmitError::ZeroTokenBudget`] instead of silently promoting
    /// it to 1 as earlier revisions did.
    pub fn max_new_tokens(mut self, n: usize) -> Request {
        self.max_new_tokens = Some(n);
        self
    }

    /// Relative deadline: an expired request is shed ([`Event::Failed`])
    /// instead of doing work nobody is waiting for — before dispatch
    /// (`deadline expired before decode`) or between decode steps
    /// (`deadline expired mid-decode`, releasing its KV slot/blocks).
    /// Earlier revisions only checked before dispatch, so an expired
    /// in-flight request burned decode steps to completion.
    pub fn deadline(mut self, d: Duration) -> Request {
        self.deadline = Some(d);
        self
    }

    /// Per-request routing-policy override (takes precedence over the
    /// quality target and the server default).
    pub fn policy(mut self, p: TierPolicy) -> Request {
        self.policy = Some(p);
        self
    }

    /// Accept oversized prompts by clipping them to the artifacts'
    /// prompt window (`sprompt`) at submit time. Without this,
    /// [`Server::submit`] rejects them with
    /// [`SubmitError::PromptTooLong`] — the seed silently copied
    /// `prompt.len()` tokens into the fixed window and panicked in the
    /// decode worker instead.
    pub fn truncate_prompt(mut self) -> Request {
        self.truncate = true;
        self
    }

    /// Per-request decode-mode override (takes precedence over
    /// [`ServeConfig::decode`]): opt one request into token-level hybrid
    /// draft–verify decoding, or pin it to classic per-request routing,
    /// regardless of the server default. Hybrid requests fall back to
    /// `Routed` when the artifacts cannot support the protocol (pre-v5
    /// manifest, single-tier fleet, dense-KV mode).
    pub fn decode(mut self, mode: DecodeMode) -> Request {
        self.decode = Some(mode);
        self
    }
}

/// Lifecycle events streamed to a [`RequestHandle`]. Order is
/// `Routed`, then zero or more `Token`s, then exactly one terminal
/// `Done` / `Failed` / `Cancelled` (requests retired before routing
/// skip straight to the terminal event).
#[derive(Debug, Clone)]
pub enum Event {
    /// Routing decision made; the request now sits in a worker queue.
    Routed { tier: usize, score: f32 },
    /// One decoded token, streamed as the decode wave samples it.
    /// Concatenating a request's `Token`s reproduces
    /// [`Completion::tokens`] exactly.
    Token { token: i32, logprob: f32 },
    /// Terminal: the request completed.
    Done(Completion),
    /// Terminal: the request was shed or errored before completing
    /// (e.g. its deadline expired while queued).
    Failed { reason: String },
    /// Terminal: the request was cancelled ([`RequestHandle::cancel`] or
    /// the handle was dropped). An in-flight request's KV slot is
    /// released within one decode step.
    Cancelled,
}

/// Errors surfaced by [`Server::submit`] — the request was **not**
/// accepted.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitError {
    /// The admission window ([`ServeConfig::queue_cap`]) is full —
    /// backpressure; retry after completions drain. Under brownout
    /// level 3 lower-priority classes see `Busy` at a reduced
    /// per-class window ([`crate::policy::class_queue_cap`]), so
    /// shedding is strictly lowest-class-first.
    Busy,
    /// The server's ingress is gone (router thread exited). The seed
    /// silently dropped such requests and left callers blocked forever.
    Closed,
    /// The prompt exceeds the artifacts' `sprompt` window and the
    /// request did not opt into [`Request::truncate_prompt`]. Rejected
    /// at submit — the seed copied it into the fixed prefill window
    /// unchecked and panicked mid-decode instead.
    PromptTooLong {
        /// Submitted prompt length in tokens.
        len: usize,
        /// The artifacts' prompt window (`sprompt`).
        max: usize,
    },
    /// The request asked for `max_new_tokens(0)`: the decode wave always
    /// samples at least one token at prefill, so a zero budget cannot be
    /// honored. Earlier revisions silently promoted it to 1; rejecting
    /// at submit makes the contract explicit.
    ZeroTokenBudget,
    /// The request carried a NaN or out-of-`[0, 1]` quality target.
    /// Rejected at submit — earlier revisions let such values flow into
    /// the [`LadderFamily`] resolution with unspecified semantics
    /// (non-finite silently routed to the most capable tier).
    InvalidQuality {
        /// The offending target.
        quality: f32,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "server busy: admission window full"),
            SubmitError::Closed => write!(f, "server closed: ingress is gone"),
            SubmitError::PromptTooLong { len, max } => write!(
                f,
                "prompt too long: {len} tokens > {max}-token prompt window \
                 (opt into Request::truncate_prompt to clip)"
            ),
            SubmitError::ZeroTokenBudget => write!(
                f,
                "max_new_tokens(0) is unsatisfiable: decode samples at \
                 least one token at prefill"
            ),
            SubmitError::InvalidQuality { quality } => write!(
                f,
                "invalid quality target {quality}: must be finite and in [0, 1]"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Errors surfaced by the blocking [`RequestHandle::wait`] family.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// The request was cancelled before completing.
    Cancelled,
    /// The request failed; the payload is [`Event::Failed`]'s reason.
    Failed(String),
    /// The event channel closed without a terminal event (server died).
    Disconnected,
    /// `wait_timeout` expired before a terminal event arrived.
    Timeout,
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Cancelled => write!(f, "request cancelled"),
            RequestError::Failed(r) => write!(f, "request failed: {r}"),
            RequestError::Disconnected => write!(f, "server dropped the request"),
            RequestError::Timeout => write!(f, "timed out waiting for completion"),
        }
    }
}

impl std::error::Error for RequestError {}

/// Caller's side of an accepted request: the [`Event`] stream plus the
/// cancellation knob. Dropping the handle cancels the request (nobody is
/// listening, so the fleet stops paying for it).
pub struct RequestHandle {
    id: u64,
    events: Receiver<Event>,
    cancel: Arc<AtomicBool>,
}

impl RequestHandle {
    /// Server-assigned request id (matches [`Completion::id`]).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation. Queued requests are retired at the next
    /// routing/admission sweep; an in-flight request's KV slot is
    /// released within one decode step without touching other slots.
    /// The terminal [`Event::Cancelled`] confirms (unless the request
    /// won the race by completing first).
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// The event stream, for callers consuming [`Event`]s directly
    /// (streaming tokens as they decode).
    pub fn events(&self) -> &Receiver<Event> {
        &self.events
    }

    /// Block until the terminal event and reduce it to a [`Completion`]
    /// — the mechanical migration from the seed's
    /// `submit(prompt).recv()`.
    pub fn wait(self) -> std::result::Result<Completion, RequestError> {
        loop {
            match self.events.recv() {
                Ok(Event::Done(c)) => return Ok(c),
                Ok(Event::Cancelled) => return Err(RequestError::Cancelled),
                Ok(Event::Failed { reason }) => return Err(RequestError::Failed(reason)),
                Ok(_) => continue,
                Err(_) => return Err(RequestError::Disconnected),
            }
        }
    }

    /// [`RequestHandle::wait`] with an overall timeout.
    pub fn wait_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<Completion, RequestError> {
        let deadline = Instant::now() + timeout;
        loop {
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return Err(RequestError::Timeout);
            };
            match self.events.recv_timeout(left) {
                Ok(Event::Done(c)) => return Ok(c),
                Ok(Event::Cancelled) => return Err(RequestError::Cancelled),
                Ok(Event::Failed { reason }) => return Err(RequestError::Failed(reason)),
                Ok(_) => continue,
                Err(RecvTimeoutError::Timeout) => return Err(RequestError::Timeout),
                Err(RecvTimeoutError::Disconnected) => return Err(RequestError::Disconnected),
            }
        }
    }
}

impl Drop for RequestHandle {
    fn drop(&mut self) {
        // a request nobody can observe should stop consuming the fleet
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// RAII admission-window slot: decrements the shared in-flight counter
/// on drop. Tying the decrement to ownership (instead of explicit calls
/// on every terminal path) means error paths that *drop* a request —
/// a router/worker thread failing mid-batch, shutdown with work still
/// pending — can never leak the window shut and wedge `Server::submit`
/// on [`SubmitError::Busy`] forever.
struct AdmissionGuard(Arc<AtomicU64>);

impl Drop for AdmissionGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Server-side request state.
struct InFlight {
    id: u64,
    prompt: Vec<i32>,
    quality: Option<f32>,
    policy: Option<TierPolicy>,
    max_new: Option<usize>,
    /// Absolute deadline (resolved from the relative builder value at
    /// submit time).
    deadline: Option<Instant>,
    t0: Instant,
    tx: Sender<Event>,
    cancel: Arc<AtomicBool>,
    /// Times this request has been requeued after a worker death;
    /// bounded by [`ServeConfig::retry_budget`].
    retries: u32,
    /// Resolved decode mode: serve through the hybrid draft–verify
    /// worker instead of a routed tier. Set at submit from the request
    /// override / server default, and only when the artifacts support
    /// the protocol; stripped on requeue after a hybrid-worker death so
    /// the retry lands on the routed path.
    hybrid: bool,
    /// Priority class for admission/shedding under overload
    /// (DESIGN.md §13).
    priority: Priority,
    /// Holds the admission-window slot for this request's lifetime.
    _admission: AdmissionGuard,
}

impl InFlight {
    fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    fn expired(&self) -> bool {
        self.expired_at(Instant::now())
    }

    /// Deadline check against a caller-supplied clock reading, so a sweep
    /// over a whole backlog reads the clock once (and both passes of the
    /// sweep agree on who is doomed).
    fn expired_at(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Effective token budget under the artifact-wide answer cap
    /// (`amax`); the default reproduces the seed's `len + 1 >= amax`
    /// stop rule. The lower clamp to 1 is defense in depth only:
    /// `max_new_tokens(0)` is rejected at [`Server::submit`]
    /// ([`SubmitError::ZeroTokenBudget`]) and never reaches a worker.
    fn token_limit(&self, amax: usize) -> usize {
        let cap = amax.saturating_sub(1).max(1);
        self.max_new.map_or(cap, |m| m.clamp(1, cap))
    }
}

enum RouterMsg {
    Req(InFlight),
    Shutdown,
}

struct Work {
    req: InFlight,
    score: f32,
    routed: Instant,
}

enum WorkMsg {
    Work(Work),
    Shutdown,
}

/// Deliver the terminal event and retire the request: dropping `req`
/// releases its [`AdmissionGuard`], freeing the admission-window slot.
fn finish(req: InFlight, ev: Event) {
    let _ = req.tx.send(ev);
}

/// Context-window stop rule, shared in spirit with `lm.rs`'s generate
/// loops (equivalence-pinned): a slot whose next write position is
/// `sctx - 1` or beyond must stop, because the training layout
/// `[prompt, answer, EOS, pad]` reserves the final position for EOS —
/// `sctx = sprompt + amax` leaves exactly `amax - 1` sampled tokens for
/// a full-width prompt. `pos` here is the position *after* the decode
/// step's increment, i.e. where the next token would land.
fn context_full(next_pos: usize, sctx: usize) -> bool {
    next_pos >= sctx.saturating_sub(1)
}

/// Dispatch state for one tier, owned by the router thread.
struct TierDispatch {
    txs: Vec<Sender<WorkMsg>>,
    /// Per-replica in-flight counts (incremented at dispatch,
    /// decremented at completion) for shortest-queue selection.
    depths: Vec<Arc<AtomicU64>>,
    rr: usize,
}

/// Circuit-breaker state for one tier (DESIGN.md §11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    /// Healthy: all traffic admitted.
    Closed,
    /// Tripped: no traffic until the cooldown elapses.
    Open,
    /// Cooled down: one probe request at a time tests the tier.
    HalfOpen,
}

#[derive(Debug)]
struct TierBreaker {
    state: BreakerState,
    /// Consecutive failures while `Closed`; reset by any success.
    consecutive: u32,
    opened_at: Instant,
    /// A half-open probe is outstanding (claimed but not yet resolved).
    probing: bool,
    probing_since: Instant,
}

/// Consecutive failures that trip a tier's breaker `Closed → Open`.
const BREAKER_TRIP: u32 = 3;
/// How long an `Open` breaker waits before admitting a half-open probe.
const BREAKER_COOLDOWN: Duration = Duration::from_millis(250);
/// A claimed-but-unresolved probe (e.g. its request was cancelled before
/// reaching the tier) stops blocking further probes after this long.
const PROBE_TIMEOUT: Duration = Duration::from_secs(5);

/// Fleet availability, shared by the router (availability mask +
/// quality-aware degradation), the worker supervisors (failure/success
/// signals on death/completion), and the stall monitor. Tier breakers
/// follow the classic state machine: `Closed` trips to `Open` after
/// [`BREAKER_TRIP`] consecutive failures, `Open` relaxes to `HalfOpen`
/// after [`BREAKER_COOLDOWN`], and a half-open tier admits one probe
/// request at a time — a success closes the breaker, a failure reopens
/// it.
struct FleetHealth {
    breakers: Vec<Mutex<TierBreaker>>,
    /// Per-tier, per-replica liveness: `false` between a replica's death
    /// and its respawn (or permanently, past the respawn cap).
    replica_up: Vec<Vec<AtomicBool>>,
    /// Set by the stall monitor while a replica holds work but its
    /// heartbeat is frozen; cleared when the heartbeat advances again.
    replica_stalled: Vec<Vec<AtomicBool>>,
}

impl FleetHealth {
    fn new(replicas_per_tier: &[usize]) -> FleetHealth {
        let now = Instant::now();
        FleetHealth {
            breakers: replicas_per_tier
                .iter()
                .map(|_| {
                    Mutex::new(TierBreaker {
                        state: BreakerState::Closed,
                        consecutive: 0,
                        opened_at: now,
                        probing: false,
                        probing_since: now,
                    })
                })
                .collect(),
            replica_up: replicas_per_tier
                .iter()
                .map(|&n| (0..n).map(|_| AtomicBool::new(true)).collect())
                .collect(),
            replica_stalled: replicas_per_tier
                .iter()
                .map(|&n| (0..n).map(|_| AtomicBool::new(false)).collect())
                .collect(),
        }
    }

    /// One failure signal (worker death, stall detection, failed probe).
    fn record_failure(&self, tier: usize) {
        let Some(m) = self.breakers.get(tier) else { return };
        let mut b = m.lock().unwrap();
        b.probing = false;
        match b.state {
            BreakerState::Closed => {
                b.consecutive += 1;
                if b.consecutive >= BREAKER_TRIP {
                    b.state = BreakerState::Open;
                    b.opened_at = Instant::now();
                }
            }
            // a failed probe (or a straggler failure) restarts the cooldown
            BreakerState::HalfOpen | BreakerState::Open => {
                b.state = BreakerState::Open;
                b.opened_at = Instant::now();
            }
        }
    }

    /// One success signal (any completion on the tier): closes the
    /// breaker and resets the consecutive-failure count.
    fn record_success(&self, tier: usize) {
        let Some(m) = self.breakers.get(tier) else { return };
        let mut b = m.lock().unwrap();
        b.consecutive = 0;
        b.probing = false;
        b.state = BreakerState::Closed;
    }

    fn claim_probe(b: &mut TierBreaker, now: Instant) -> bool {
        if b.probing && now.duration_since(b.probing_since) < PROBE_TIMEOUT {
            return false;
        }
        b.probing = true;
        b.probing_since = now;
        true
    }

    /// Would this tier accept a request right now? `Open` breakers relax
    /// to `HalfOpen` lazily once the cooldown has elapsed; a half-open
    /// tier admits (and claims the slot for) one probe at a time. A tier
    /// with every replica down/stalled never admits — its breaker may
    /// lag the replica flags by one failure signal.
    fn tier_admits(&self, tier: usize, now: Instant) -> bool {
        if !self.any_replica_live(tier) {
            return false;
        }
        let Some(m) = self.breakers.get(tier) else { return false };
        let mut b = m.lock().unwrap();
        match b.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if now.duration_since(b.opened_at) >= BREAKER_COOLDOWN {
                    b.state = BreakerState::HalfOpen;
                    b.probing = false;
                    Self::claim_probe(&mut b, now)
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => Self::claim_probe(&mut b, now),
        }
    }

    /// Quality-aware degradation: resolve `want` over the live tiers.
    /// Prefer the resolved tier itself; otherwise scan *down* (cheaper
    /// tiers — a measured quality drop, the paper's knob turned by the
    /// outage), then *up* (a cost bump beats a failure). `None` means no
    /// tier is live — the request sheds with a distinct reason.
    fn degrade(&self, want: usize, now: Instant) -> Option<usize> {
        if self.tier_admits(want, now) {
            return Some(want);
        }
        for t in (0..want).rev() {
            if self.tier_admits(t, now) {
                return Some(t);
            }
        }
        for t in want + 1..self.breakers.len() {
            if self.tier_admits(t, now) {
                return Some(t);
            }
        }
        None
    }

    fn any_replica_live(&self, tier: usize) -> bool {
        self.replica_up
            .get(tier)
            .is_some_and(|reps| (0..reps.len()).any(|r| self.replica_live(tier, r)))
    }

    fn replica_live(&self, tier: usize, rep: usize) -> bool {
        self.replica_up[tier][rep].load(Ordering::Relaxed)
            && !self.replica_stalled[tier][rep].load(Ordering::Relaxed)
    }

    fn set_replica_up(&self, tier: usize, rep: usize, up: bool) {
        self.replica_up[tier][rep].store(up, Ordering::Relaxed);
    }

    fn set_replica_stalled(&self, tier: usize, rep: usize, stalled: bool) {
        self.replica_stalled[tier][rep].store(stalled, Ordering::Relaxed);
    }

    /// Set the stall flag, returning the previous value (edge detection
    /// for the monitor's one-failure-per-stall signal).
    fn swap_replica_stalled(&self, tier: usize, rep: usize, stalled: bool) -> bool {
        self.replica_stalled[tier][rep].swap(stalled, Ordering::Relaxed)
    }

    /// Per-tier breaker states for [`ServerStats::breaker_state`].
    fn states(&self) -> Vec<&'static str> {
        self.breakers
            .iter()
            .map(|m| match m.lock().unwrap().state {
                BreakerState::Closed => "closed",
                BreakerState::Open => "open",
                BreakerState::HalfOpen => "half-open",
            })
            .collect()
    }
}

/// Shared (Send) metrics.
pub struct ServerMetrics {
    /// Accepted-but-unfinished requests — the admission window
    /// [`Server::submit`] gates on ([`ServeConfig::queue_cap`]).
    /// `Arc`'d separately so each request's [`AdmissionGuard`] can hold
    /// the counter and release its slot on drop, whichever thread drops
    /// it.
    pub in_flight: Arc<AtomicU64>,
    pub router_latency: LatencyRecorder,
    pub e2e_latency: LatencyRecorder,
    /// Per-tier e2e latency, indexed like `ServeConfig::tiers`.
    pub tier_latency: Vec<LatencyRecorder>,
    pub routing: RoutingCounters,
    pub decode_steps: AtomicU64,
    pub decode_slot_steps: AtomicU64,
    /// Host→device bytes moved by decode iterations (all workers). With
    /// device-resident KV caches this is the O(B) token/pos/seed upload
    /// per step; the seed paid the full KV pair both ways on every step.
    pub decode_h2d_bytes: AtomicU64,
    /// Device→host bytes moved by decode iterations (all workers).
    pub decode_d2h_bytes: AtomicU64,
    /// Host↔device bytes moved by admissions, kept separate so the
    /// decode counters stay a pure per-iteration signal. Device-side
    /// admission (manifest v3) keeps this at O(B·sprompt) prompt bytes
    /// per admission; the host-surgery fallback adds the full KV-cache
    /// round-trip.
    pub admit_h2d_bytes: AtomicU64,
    pub admit_d2h_bytes: AtomicU64,
    /// Admission waves executed (one prefill + install each).
    pub admissions: AtomicU64,
    /// Requests admitted into decode slots (sum of wave sizes).
    pub admitted: AtomicU64,
    /// Wall-clock latency of each admission wave (prefill + install).
    pub admit_latency: LatencyRecorder,
    /// Shared-prefix cache lookups (paged admissions with the cache on).
    pub prefix_lookups: AtomicU64,
    /// Lookups that reused at least one cached block.
    pub prefix_hits: AtomicU64,
    /// Prompt tokens served from shared blocks (or a full-hit replay)
    /// instead of fresh prefill + install.
    pub prefix_shared_tokens: AtomicU64,
    /// Prompt tokens the workers actually prefilled and installed —
    /// `Σ (plen − shared)` per admitted request. The prefix-reuse bench
    /// gate compares this across cache-on/off runs of the same trace.
    pub prefill_tokens: AtomicU64,
    /// Block-pool utilization gauge, sampled once per paged admission
    /// as `(sample count, Σ utilization‰)` so the snapshot can report a
    /// mean without a float atomic.
    pub kv_util_samples: AtomicU64,
    pub kv_util_permille: AtomicU64,
    /// Requests dispatched to a tier other than the one routing resolved
    /// (the resolved tier's breaker was open or its replicas dead).
    pub failovers: AtomicU64,
    /// The subset of `failovers` that landed on a *cheaper* tier — the
    /// outage-as-quality-drop headline counter.
    pub degraded: AtomicU64,
    /// Requests requeued after a worker death (each requeue counts once;
    /// bounded per request by [`ServeConfig::retry_budget`]).
    pub retries: AtomicU64,
    /// Worker serve-loop deaths absorbed by the supervisor (panic or
    /// error; each respawn-in-place increments once).
    pub worker_deaths: AtomicU64,
    /// Requests served by the hybrid draft–verify worker.
    pub hybrid_requests: AtomicU64,
    /// Tokens drafted by the small tier in hybrid lanes (catch-up
    /// steps excluded).
    pub draft_tokens: AtomicU64,
    /// Drafted tokens accepted by a large-tier verify call.
    pub draft_accepted: AtomicU64,
    /// Drafted tokens streamed without verification (escalation-policy
    /// short-circuit or verify-breaker degradation).
    pub draft_local_accepted: AtomicU64,
    /// Per-lane verify invocations — each is one large-tier forward
    /// pass for that lane.
    pub verify_calls: AtomicU64,
    /// Tokens emitted by hybrid lanes (all sources, first token
    /// excluded — it comes from the large tier's prefill).
    pub hybrid_emitted: AtomicU64,
    /// Draft blocks streamed unverified because the verify breaker was
    /// open (large-tier outage degraded to pure small-tier drafting).
    pub hybrid_degraded_blocks: AtomicU64,
    /// Occupied-slot decode steps on the most expensive tier's routed
    /// workers — per-lane large forward passes, the routed-side term of
    /// the hybrid-vs-routed cost comparison (hybrid's term is
    /// `verify_calls`).
    pub large_slot_steps: AtomicU64,
    /// Admission waves cut short by KV block-pool exhaustion *after*
    /// LRU eviction (the evict-then-requeue path in paged admission).
    /// Distinct from ordinary slot-table pressure: sustained growth here
    /// means the pool, not the batch, is the bottleneck.
    pub pool_exhausted_requeues: AtomicU64,
    /// Per-request submit→dispatch wait, recorded by the router at both
    /// dispatch sites — the brownout controller's primary sensor and
    /// the `queue_delay_ms` observability satellite.
    pub queue_delay: LatencyRecorder,
    /// Brownout level in force (0 with the controller disarmed),
    /// published by the router's control tick and read by `submit`
    /// (L3 per-class admission) and the hybrid worker (L2 escalation).
    pub brownout_level: AtomicU64,
    /// Requests accepted through the admission window, per priority
    /// class ([`Priority::index`] order: best-effort, batch,
    /// interactive).
    pub class_admitted: [AtomicU64; PRIORITY_CLASSES],
    /// Requests shed per priority class — submit-time `Busy` rejections
    /// plus deadline sheds, same index order as `class_admitted`. The
    /// sum feeds the controller's shed-rate sensor.
    pub class_shed: [AtomicU64; PRIORITY_CLASSES],
    /// Effective-quality-reduction gauge, sampled per quality-carrying
    /// request routed under brownout as `(sample count, Σ delta‰)` —
    /// the same no-float-atomic pattern as `kv_util_*`.
    pub eq_delta_samples: AtomicU64,
    pub eq_delta_permille: AtomicU64,
}

/// Sum of per-class sheds — the brownout controller's shed-rate sensor
/// reads the delta of this between control ticks.
fn class_shed_total(metrics: &ServerMetrics) -> u64 {
    metrics.class_shed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
}

/// Point-in-time per-tier report.
#[derive(Debug, Clone)]
pub struct TierStats {
    pub name: String,
    pub latency: LatencySummary,
}

/// Point-in-time server report.
#[derive(Debug, Clone)]
pub struct ServerStats {
    /// Accepted-but-unfinished requests at snapshot time.
    pub in_flight: u64,
    pub router_latency: LatencySummary,
    pub e2e_latency: LatencySummary,
    /// Per-tier latency keyed by tier name, cheapest first (routing
    /// counts live in `routing.tiers`).
    pub tiers: Vec<TierStats>,
    pub routing: RoutingSnapshot,
    pub decode_steps: u64,
    /// Occupied-slot decode steps (batching efficiency =
    /// `decode_slot_steps / (decode_steps * capacity)`).
    pub decode_slot_steps: u64,
    /// Host↔device traffic attributable to decode iterations.
    pub decode_h2d_bytes: u64,
    pub decode_d2h_bytes: u64,
    /// Host↔device traffic attributable to admissions (prefill + KV
    /// slot install).
    pub admit_h2d_bytes: u64,
    pub admit_d2h_bytes: u64,
    /// Admission waves executed.
    pub admissions: u64,
    /// Requests admitted into decode slots.
    pub admitted: u64,
    /// Admission-wave latency (prefill + install).
    pub admit_latency: LatencySummary,
    /// Fraction of prefix-cache lookups that reused at least one cached
    /// block (0 on the dense path or with the cache disabled).
    pub prefix_hit_rate: f64,
    /// Prompt tokens served from shared prefix blocks.
    pub prefix_shared_tokens: u64,
    /// Prompt tokens actually prefilled + installed (`Σ plen − shared`).
    pub prefill_tokens: u64,
    /// Mean KV block-pool utilization sampled at each paged admission
    /// (0 on the dense path).
    pub kv_blocks_utilization: f64,
    /// Requests dispatched to a tier other than the one routing resolved
    /// (dead/tripped tier absorbed by a live one).
    pub failovers: u64,
    /// `failovers` that landed on a cheaper tier: outages surface as a
    /// measured quality drop, not lost requests.
    pub degraded: u64,
    /// Requeues after worker deaths (per-request bound:
    /// [`ServeConfig::retry_budget`]).
    pub retries: u64,
    /// Worker serve-loop deaths absorbed by supervisors.
    pub worker_deaths: u64,
    /// Per-tier breaker state at snapshot time (`"closed"` / `"open"` /
    /// `"half-open"`), indexed like `tiers`.
    pub breaker_state: Vec<&'static str>,
    /// Requests served by the hybrid draft–verify worker (0 in routed
    /// mode or when the artifacts cannot support the protocol).
    pub hybrid_requests: u64,
    /// Tokens drafted by the small tier in hybrid lanes.
    pub draft_tokens: u64,
    /// Drafted tokens a large-tier verify call accepted.
    pub draft_accepted: u64,
    /// Drafted tokens streamed without verification (escalation-policy
    /// short-circuit or verify-breaker degradation).
    pub draft_local_accepted: u64,
    /// Per-lane verify invocations (one large forward pass each).
    pub verify_calls: u64,
    /// Tokens emitted by hybrid lanes (prefill first token excluded).
    pub hybrid_emitted: u64,
    /// Draft blocks streamed unverified under an open verify breaker.
    pub hybrid_degraded_blocks: u64,
    /// `draft_accepted / draft_tokens` (0 with no hybrid drafting) —
    /// the draft-quality headline.
    pub draft_accept_rate: f64,
    /// `verify_calls / hybrid_emitted` (0 with no hybrid traffic):
    /// large forward passes per emitted hybrid token. Pure large-tier
    /// decoding is 1.0 by construction; anything below it is the
    /// speculative win.
    pub large_call_fraction: f64,
    /// Occupied-slot decode steps on the most expensive tier's routed
    /// workers — per-lane large forward passes on the routed path.
    pub large_slot_steps: u64,
    /// Paged-admission waves requeued on KV block-pool exhaustion after
    /// LRU eviction — the pool (not the slot table) was the bottleneck.
    pub pool_exhausted_requeues: u64,
    /// Submit→dispatch wait per request (`queue_delay_ms` p50/p99 are
    /// the serve-demo/bench headline) — the brownout sensor.
    pub queue_delay: LatencySummary,
    /// Brownout level at snapshot time (0 unless
    /// [`ServeConfig::brownout_target`] armed the controller and load
    /// tripped it; always back to 0 once load recedes).
    pub brownout_level: u64,
    /// Requests admitted per priority class, [`Priority::index`] order
    /// (best-effort, batch, interactive).
    pub class_admitted: [u64; PRIORITY_CLASSES],
    /// Requests shed per priority class (submit `Busy` + deadline
    /// sheds), same order. Under brownout L3 shedding is strictly
    /// lowest-class-first.
    pub class_shed: [u64; PRIORITY_CLASSES],
    /// Mean reduction applied to quality-carrying requests' targets by
    /// the L1 brownout actuator (0.0 at level 0 / controller off).
    pub effective_quality_delta: f64,
}

impl ServerStats {
    /// Mean device→host bytes per decode iteration — the residency
    /// headline number: O(B·token) when KV caches stay on device,
    /// O(L·B·S·H·Dh) when they round-trip.
    pub fn d2h_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_d2h_bytes as f64 / self.decode_steps as f64
        }
    }

    /// Mean host→device bytes per decode iteration.
    pub fn h2d_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_h2d_bytes as f64 / self.decode_steps as f64
        }
    }

    /// Mean host↔device bytes per *admitted request* — the admission
    /// headline number: O(sprompt·token) with device-side install
    /// (manifest v3), O(L·genb·sctx·H·Dh) when slot surgery round-trips
    /// the worker cache.
    pub fn admit_bytes_per_req(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            (self.admit_h2d_bytes + self.admit_d2h_bytes) as f64 / self.admitted as f64
        }
    }
}

/// Upper bound on legitimate per-admission host bytes for device-side
/// (manifest v3) admission: the full-bucket prompt upload plus O(B)
/// control/sample lanes — 16 lanes of slack over `sprompt` covers
/// lens/seeds/slots/count/temp and the sampled-token download. One
/// definition shared by the `serving_e2e` CI gate and the integration
/// suite so they enforce the same invariant.
pub fn admission_byte_bound(g: &Globals) -> f64 {
    (g.genb * (g.sprompt + 16) * ELEM_BYTES) as f64
}

/// Size in bytes of the smallest per-worker KV cache pair across
/// `models` — the transfer that host-surgery admission round-trips per
/// admission and device-side admission must never approach. Companion
/// of [`admission_byte_bound`] for the same gates.
pub fn min_kv_pair_bytes(manifest: &Manifest, models: &[&str]) -> Result<f64> {
    anyhow::ensure!(!models.is_empty(), "no models given");
    let g = manifest.globals;
    let mut min = f64::MAX;
    for m in models {
        let meta = *manifest.model(m)?;
        let pair =
            (2 * meta.layers * g.genb * g.sctx * meta.heads * meta.headdim * ELEM_BYTES) as f64;
        min = min.min(pair);
    }
    Ok(min)
}

/// Handle to a running server.
pub struct Server {
    ingress: Sender<RouterMsg>,
    tier_txs: Vec<Vec<Sender<WorkMsg>>>,
    tier_names: Vec<String>,
    router_handle: JoinHandle<Result<()>>,
    worker_handles: Vec<JoinHandle<Result<()>>>,
    metrics: Arc<ServerMetrics>,
    health: Arc<FleetHealth>,
    /// Stall-monitor thread (spawned only with a decode timeout set) and
    /// its stop flag.
    monitor_handle: Option<JoinHandle<()>>,
    monitor_stop: Arc<AtomicBool>,
    next_id: AtomicU64,
    queue_cap: u64,
    /// The artifacts' prompt window, for submit-time length validation.
    sprompt: usize,
    /// Shutdown channel to the hybrid draft–verify worker (`None` when
    /// the artifacts cannot support the protocol; its join handle lives
    /// in `worker_handles`).
    hybrid_tx: Option<Sender<WorkMsg>>,
    /// Resolved at start: `submit` only flags a request hybrid when a
    /// worker exists to serve it, so the router never holds an
    /// unserviceable hybrid request.
    hybrid_available: bool,
    /// Server-wide default decode mode ([`ServeConfig::decode`]).
    default_decode: DecodeMode,
}

fn snapshot_stats(
    metrics: &ServerMetrics,
    tier_names: &[String],
    health: &FleetHealth,
) -> ServerStats {
    ServerStats {
        in_flight: metrics.in_flight.load(Ordering::Relaxed),
        router_latency: metrics.router_latency.snapshot(),
        e2e_latency: metrics.e2e_latency.snapshot(),
        tiers: tier_names
            .iter()
            .zip(&metrics.tier_latency)
            .map(|(name, rec)| TierStats { name: name.clone(), latency: rec.snapshot() })
            .collect(),
        routing: metrics.routing.snapshot(),
        decode_steps: metrics.decode_steps.load(Ordering::Relaxed),
        decode_slot_steps: metrics.decode_slot_steps.load(Ordering::Relaxed),
        decode_h2d_bytes: metrics.decode_h2d_bytes.load(Ordering::Relaxed),
        decode_d2h_bytes: metrics.decode_d2h_bytes.load(Ordering::Relaxed),
        admit_h2d_bytes: metrics.admit_h2d_bytes.load(Ordering::Relaxed),
        admit_d2h_bytes: metrics.admit_d2h_bytes.load(Ordering::Relaxed),
        admissions: metrics.admissions.load(Ordering::Relaxed),
        admitted: metrics.admitted.load(Ordering::Relaxed),
        admit_latency: metrics.admit_latency.snapshot(),
        prefix_hit_rate: {
            let lookups = metrics.prefix_lookups.load(Ordering::Relaxed);
            if lookups == 0 {
                0.0
            } else {
                metrics.prefix_hits.load(Ordering::Relaxed) as f64 / lookups as f64
            }
        },
        prefix_shared_tokens: metrics.prefix_shared_tokens.load(Ordering::Relaxed),
        prefill_tokens: metrics.prefill_tokens.load(Ordering::Relaxed),
        kv_blocks_utilization: {
            let samples = metrics.kv_util_samples.load(Ordering::Relaxed);
            if samples == 0 {
                0.0
            } else {
                metrics.kv_util_permille.load(Ordering::Relaxed) as f64
                    / samples as f64
                    / 1000.0
            }
        },
        failovers: metrics.failovers.load(Ordering::Relaxed),
        degraded: metrics.degraded.load(Ordering::Relaxed),
        retries: metrics.retries.load(Ordering::Relaxed),
        worker_deaths: metrics.worker_deaths.load(Ordering::Relaxed),
        breaker_state: health.states(),
        hybrid_requests: metrics.hybrid_requests.load(Ordering::Relaxed),
        draft_tokens: metrics.draft_tokens.load(Ordering::Relaxed),
        draft_accepted: metrics.draft_accepted.load(Ordering::Relaxed),
        draft_local_accepted: metrics.draft_local_accepted.load(Ordering::Relaxed),
        verify_calls: metrics.verify_calls.load(Ordering::Relaxed),
        hybrid_emitted: metrics.hybrid_emitted.load(Ordering::Relaxed),
        hybrid_degraded_blocks: metrics.hybrid_degraded_blocks.load(Ordering::Relaxed),
        draft_accept_rate: {
            let drafted = metrics.draft_tokens.load(Ordering::Relaxed);
            if drafted == 0 {
                0.0
            } else {
                metrics.draft_accepted.load(Ordering::Relaxed) as f64 / drafted as f64
            }
        },
        large_call_fraction: {
            let emitted = metrics.hybrid_emitted.load(Ordering::Relaxed);
            if emitted == 0 {
                0.0
            } else {
                metrics.verify_calls.load(Ordering::Relaxed) as f64 / emitted as f64
            }
        },
        large_slot_steps: metrics.large_slot_steps.load(Ordering::Relaxed),
        pool_exhausted_requeues: metrics.pool_exhausted_requeues.load(Ordering::Relaxed),
        queue_delay: metrics.queue_delay.snapshot(),
        brownout_level: metrics.brownout_level.load(Ordering::Relaxed),
        class_admitted: std::array::from_fn(|i| metrics.class_admitted[i].load(Ordering::Relaxed)),
        class_shed: std::array::from_fn(|i| metrics.class_shed[i].load(Ordering::Relaxed)),
        effective_quality_delta: {
            let samples = metrics.eq_delta_samples.load(Ordering::Relaxed);
            if samples == 0 {
                0.0
            } else {
                metrics.eq_delta_permille.load(Ordering::Relaxed) as f64
                    / samples as f64
                    / 1000.0
            }
        },
    }
}

impl Server {
    /// Spawn the router plus one decode worker per tier replica.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        anyhow::ensure!(!cfg.tiers.is_empty(), "fleet needs at least one tier");
        for t in &cfg.tiers {
            anyhow::ensure!(t.replicas >= 1, "tier {} needs at least one replica", t.name);
        }
        if let Some(k) = cfg.policy.n_tiers() {
            anyhow::ensure!(
                k == cfg.tiers.len(),
                "policy distinguishes {k} tiers but the fleet has {}",
                cfg.tiers.len()
            );
        }
        if let TierPolicy::Fixed { tier } = &cfg.policy {
            anyhow::ensure!(*tier < cfg.tiers.len(), "fixed tier {tier} out of range");
        }
        anyhow::ensure!(cfg.queue_cap >= 1, "queue_cap must admit at least one request");
        if let Some(fam) = &cfg.quality_ladders {
            anyhow::ensure!(
                fam.n_tiers() == cfg.tiers.len(),
                "quality-ladder family routes {} tiers but the fleet has {}",
                fam.n_tiers(),
                cfg.tiers.len()
            );
        }
        // the manifest is the source of truth for the prompt window
        // (submit() rejects oversized prompts before they reach a
        // prefill) and for hybrid availability — a text parse, no PJRT
        let manifest = Manifest::load(&cfg.artifacts_dir.join("manifest.txt"))?;
        let sprompt = manifest.globals.sprompt;
        // hybrid draft–verify worker (DESIGN.md §12): spawned only when
        // the artifacts can honour the protocol — a ≥2-tier fleet, the
        // paged-KV path on both ends, and manifest-v5 `verify@K`
        // artifacts on the most expensive tier
        let hybrid_available = cfg.tiers.len() >= 2
            && !cfg.force_dense_kv
            && !cfg.force_host_admission
            && manifest.has_verify(&cfg.tiers[cfg.tiers.len() - 1].model)
            && manifest.has_paged_kv(&cfg.tiers[0].model);
        let tier_names: Vec<String> = cfg.tiers.iter().map(|t| t.name.clone()).collect();
        let costs: Vec<f64> = cfg.tiers.iter().map(|t| t.cost).collect();
        let metrics = Arc::new(ServerMetrics {
            in_flight: Arc::new(AtomicU64::new(0)),
            router_latency: LatencyRecorder::new(),
            e2e_latency: LatencyRecorder::new(),
            tier_latency: cfg.tiers.iter().map(|_| LatencyRecorder::new()).collect(),
            routing: RoutingCounters::new(tier_names.clone(), costs),
            decode_steps: AtomicU64::new(0),
            decode_slot_steps: AtomicU64::new(0),
            decode_h2d_bytes: AtomicU64::new(0),
            decode_d2h_bytes: AtomicU64::new(0),
            admit_h2d_bytes: AtomicU64::new(0),
            admit_d2h_bytes: AtomicU64::new(0),
            admissions: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            admit_latency: LatencyRecorder::new(),
            prefix_lookups: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_shared_tokens: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            kv_util_samples: AtomicU64::new(0),
            kv_util_permille: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            worker_deaths: AtomicU64::new(0),
            hybrid_requests: AtomicU64::new(0),
            draft_tokens: AtomicU64::new(0),
            draft_accepted: AtomicU64::new(0),
            draft_local_accepted: AtomicU64::new(0),
            verify_calls: AtomicU64::new(0),
            hybrid_emitted: AtomicU64::new(0),
            hybrid_degraded_blocks: AtomicU64::new(0),
            large_slot_steps: AtomicU64::new(0),
            pool_exhausted_requeues: AtomicU64::new(0),
            queue_delay: LatencyRecorder::new(),
            brownout_level: AtomicU64::new(0),
            class_admitted: std::array::from_fn(|_| AtomicU64::new(0)),
            class_shed: std::array::from_fn(|_| AtomicU64::new(0)),
            eq_delta_samples: AtomicU64::new(0),
            eq_delta_permille: AtomicU64::new(0),
        });
        let replicas: Vec<usize> = cfg.tiers.iter().map(|t| t.replicas).collect();
        let health = Arc::new(FleetHealth::new(&replicas));
        let (ingress, router_rx) = mpsc::channel::<RouterMsg>();
        // readiness barrier: threads ack after compiling their executables
        // so `start` returns a warm server (PJRT compilation is seconds;
        // without this the first requests' latency measures the compiler)
        let (ready_tx, ready_rx) = mpsc::channel::<()>();

        let mut worker_handles = Vec::new();
        let mut dispatch = Vec::new();
        let mut tier_txs = Vec::new();
        // (tier, replica, depth, heartbeat) per worker, for the monitor
        let mut watch: Vec<(usize, usize, Arc<AtomicU64>, Arc<AtomicU64>)> = Vec::new();
        let mut n_workers = 0usize;
        for (ti, tier) in cfg.tiers.iter().enumerate() {
            let mut txs = Vec::new();
            let mut depths = Vec::new();
            for r in 0..tier.replicas {
                let (tx, rx) = mpsc::channel::<WorkMsg>();
                let depth = Arc::new(AtomicU64::new(0));
                let heartbeat = Arc::new(AtomicU64::new(0));
                let cfg = cfg.clone();
                let links = WorkerLinks {
                    rx,
                    depth: depth.clone(),
                    metrics: metrics.clone(),
                    health: health.clone(),
                    heartbeat: heartbeat.clone(),
                    ingress: ingress.clone(),
                    ready: ready_tx.clone(),
                };
                worker_handles.push(
                    std::thread::Builder::new()
                        .name(format!("worker-{}-{r}", tier.name))
                        .spawn(move || worker_thread(cfg, ti, r, links))?,
                );
                watch.push((ti, r, depth.clone(), heartbeat));
                txs.push(tx);
                depths.push(depth);
                n_workers += 1;
            }
            dispatch.push(TierDispatch { txs: txs.clone(), depths, rr: 0 });
            tier_txs.push(txs);
        }
        // the hybrid worker sits outside the tier fleet: its own
        // channel, depth, and heartbeat, supervised like a replica but
        // never watched by the stall monitor (verify outages degrade to
        // drafting instead of stalling, so its heartbeat semantics
        // differ)
        let hybrid = if hybrid_available {
            let (tx, rx) = mpsc::channel::<WorkMsg>();
            let depth = Arc::new(AtomicU64::new(0));
            let links = WorkerLinks {
                rx,
                depth: depth.clone(),
                metrics: metrics.clone(),
                health: health.clone(),
                heartbeat: Arc::new(AtomicU64::new(0)),
                ingress: ingress.clone(),
                ready: ready_tx.clone(),
            };
            let cfg = cfg.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name("hybrid".into())
                    .spawn(move || hybrid_thread(cfg, links))?,
            );
            n_workers += 1;
            Some((tx, depth))
        } else {
            None
        };
        let router_handle = {
            let cfg = cfg.clone();
            let m = metrics.clone();
            let h = health.clone();
            let rtx = ready_tx.clone();
            let hd = hybrid.clone();
            std::thread::Builder::new()
                .name("router".into())
                .spawn(move || router_thread(cfg, router_rx, dispatch, m, h, rtx, hd))?
        };
        drop(ready_tx);
        for _ in 0..n_workers + 1 {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("server thread died during warm-up"))?;
        }
        let monitor_stop = Arc::new(AtomicBool::new(false));
        let monitor_handle = match cfg.decode_timeout {
            Some(timeout) => {
                let health = health.clone();
                let stop = monitor_stop.clone();
                Some(
                    std::thread::Builder::new()
                        .name("stall-monitor".into())
                        .spawn(move || stall_monitor(watch, health, timeout, stop))?,
                )
            }
            None => None,
        };
        Ok(Server {
            ingress,
            tier_txs,
            tier_names,
            router_handle,
            worker_handles,
            metrics,
            health,
            monitor_handle,
            monitor_stop,
            next_id: AtomicU64::new(0),
            queue_cap: cfg.queue_cap as u64,
            sprompt,
            hybrid_tx: hybrid.map(|(tx, _)| tx),
            hybrid_available,
            default_decode: cfg.decode,
        })
    }

    /// Submit a request through the bounded admission window; returns
    /// the [`RequestHandle`] streaming its [`Event`]s.
    ///
    /// Errors are explicit instead of silent: a full window is
    /// [`SubmitError::Busy`] (backpressure — retry after completions
    /// drain), a dead ingress is [`SubmitError::Closed`] (the seed
    /// ignored the failed send and left the caller blocked on a
    /// receiver forever), and a prompt wider than the artifacts' window
    /// is [`SubmitError::PromptTooLong`] unless the request opted into
    /// [`Request::truncate_prompt`] (the seed copied it into the fixed
    /// prefill buffer unchecked and panicked in the decode worker).
    pub fn submit(&self, mut req: Request) -> std::result::Result<RequestHandle, SubmitError> {
        if req.max_new_tokens == Some(0) {
            return Err(SubmitError::ZeroTokenBudget);
        }
        if let Some(q) = req.quality {
            if !q.is_finite() || !(0.0..=1.0).contains(&q) {
                return Err(SubmitError::InvalidQuality { quality: q });
            }
        }
        if req.prompt.len() > self.sprompt {
            if req.truncate {
                req.prompt.truncate(self.sprompt);
            } else {
                return Err(SubmitError::PromptTooLong {
                    len: req.prompt.len(),
                    max: self.sprompt,
                });
            }
        }
        // reserve an admission slot (CAS loop: submit is called from
        // many client threads). The bound is per priority class: below
        // brownout level 3 every class sees the full queue_cap (so the
        // level-0 path is byte-identical to the pre-brownout server);
        // at level 3 lower classes see a reduced window, which is what
        // makes shedding strictly lowest-class-first.
        let level = self.metrics.brownout_level.load(Ordering::Relaxed) as u8;
        let class_cap =
            policy::class_queue_cap(level, req.priority, self.queue_cap as usize) as u64;
        let mut cur = self.metrics.in_flight.load(Ordering::Acquire);
        loop {
            if cur >= class_cap {
                self.metrics.class_shed[req.priority.index()].fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Busy);
            }
            match self.metrics.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.metrics.class_admitted[req.priority.index()].fetch_add(1, Ordering::Relaxed);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let now = Instant::now();
        let inflight = InFlight {
            id,
            prompt: req.prompt,
            quality: req.quality,
            policy: req.policy,
            max_new: req.max_new_tokens,
            deadline: req.deadline.map(|d| now + d),
            t0: now,
            tx,
            cancel: cancel.clone(),
            retries: 0,
            hybrid: self.hybrid_available
                && req.decode.unwrap_or(self.default_decode) == DecodeMode::Hybrid,
            priority: req.priority,
            _admission: AdmissionGuard(self.metrics.in_flight.clone()),
        };
        // a failed send returns (and drops) the request, releasing its
        // admission slot via the guard
        if self.ingress.send(RouterMsg::Req(inflight)).is_err() {
            return Err(SubmitError::Closed);
        }
        Ok(RequestHandle { id, events: rx, cancel })
    }

    pub fn stats(&self) -> ServerStats {
        snapshot_stats(&self.metrics, &self.tier_names, &self.health)
    }

    /// Accepted-but-unfinished requests right now — the counter the
    /// admission window gates on. Cheap (one atomic load), so replay
    /// harnesses can sample it per-submit to check the bounded-queue
    /// invariant without paying for a full [`Server::stats`] snapshot.
    pub fn in_flight(&self) -> u64 {
        self.metrics.in_flight.load(Ordering::Relaxed)
    }

    /// The admission-window bound this server enforces
    /// ([`ServeConfig::queue_cap`]).
    pub fn queue_cap(&self) -> u64 {
        self.queue_cap
    }

    /// Graceful shutdown: drains in-flight work, joins all threads.
    ///
    /// Drain protocol: the router is joined *before* the workers are
    /// signalled. The router may still be dispatching when `Shutdown`
    /// arrives; signalling workers concurrently let a worker with an
    /// empty backlog exit while the router still held work for it,
    /// turning graceful shutdown into a "worker channel closed" error
    /// (and dropping the request). Joining the router first guarantees
    /// every routed request sits in a worker queue ahead of the worker's
    /// `Shutdown` message, and workers drain their queue before exiting.
    pub fn shutdown(self) -> Result<ServerStats> {
        let Server {
            ingress,
            tier_txs,
            tier_names,
            router_handle,
            worker_handles,
            metrics,
            health,
            monitor_handle,
            monitor_stop,
            hybrid_tx,
            ..
        } = self;
        let _ = ingress.send(RouterMsg::Shutdown);
        let router_res = match router_handle.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("router thread panicked")),
        };
        // the workers hold ingress clones (their requeue path); those
        // clones die with the worker threads below, after which no
        // requeued work can be in flight anywhere
        drop(ingress);
        // all dispatches are now enqueued (or the router failed); workers
        // may stop once they drain (the hybrid worker joins with the
        // tier workers — its handle lives in `worker_handles`)
        if let Some(tx) = &hybrid_tx {
            let _ = tx.send(WorkMsg::Shutdown);
        }
        for txs in &tier_txs {
            for tx in txs {
                let _ = tx.send(WorkMsg::Shutdown);
            }
        }
        let mut worker_err: Option<anyhow::Error> = None;
        for h in worker_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => worker_err = Some(anyhow::anyhow!("worker thread panicked")),
            }
        }
        monitor_stop.store(true, Ordering::Relaxed);
        if let Some(h) = monitor_handle {
            let _ = h.join();
        }
        router_res?;
        if let Some(e) = worker_err {
            return Err(e);
        }
        // snapshot after the full drain so completions that raced the
        // shutdown call are included
        Ok(snapshot_stats(&metrics, &tier_names, &health))
    }
}

/// Submit with bounded retry on [`SubmitError::Busy`]: jittered
/// exponential backoff (200 µs doubling to a 5 ms cap, ±50% jitter from
/// the caller's seeded [`Rng`] for deterministic replay), giving up
/// after `retry_for` of wall time. `between` runs before every sleep —
/// replay harnesses drain completed handles there so the admission
/// window can actually open up instead of busy-waiting against a full
/// queue.
///
/// Returns `Ok(Some(handle))` on acceptance, `Ok(None)` when the window
/// stayed full for the whole budget (the caller counts a shed), and
/// propagates every non-`Busy` error (`Closed`, `PromptTooLong`, …)
/// immediately — those never resolve by waiting.
pub fn submit_with_retry(
    server: &Server,
    req: &Request,
    rng: &mut Rng,
    retry_for: Duration,
    mut between: impl FnMut(),
) -> std::result::Result<Option<RequestHandle>, SubmitError> {
    const BASE: Duration = Duration::from_micros(200);
    const CAP: Duration = Duration::from_millis(5);
    let t0 = Instant::now();
    let mut backoff = BASE;
    loop {
        match server.submit(req.clone()) {
            Ok(h) => return Ok(Some(h)),
            Err(SubmitError::Busy) => {
                if t0.elapsed() >= retry_for {
                    return Ok(None);
                }
                between();
                // ±50% jitter decorrelates concurrent submitters
                let jitter = 0.5 + rng.next_f64();
                std::thread::sleep(backoff.mul_f64(jitter).min(CAP));
                backoff = (backoff * 2).min(CAP);
            }
            Err(e) => return Err(e),
        }
    }
}

/// Shed reason when routing finds no live tier to degrade to.
const NO_LIVE_TIER: &str = "no live tier: every breaker is open or every replica is down";

/// Cadence of the router's brownout control tick.
const BROWNOUT_TICK: Duration = Duration::from_millis(10);

/// The router's side of the brownout control loop: owns the (optional)
/// [`policy::BrownoutController`] plus the tick clock and the shed
/// watermark its rate sensor differentiates. Ticked from the top of the
/// router loop *and* from the batching window's idle-timeout branch —
/// the window blocks while the server is idle, and recovery back to
/// level 0 must not wait for traffic to arrive.
struct BrownoutTick {
    ctrl: Option<policy::BrownoutController>,
    last_tick: Instant,
    last_shed: u64,
}

impl BrownoutTick {
    fn new(cfg: &ServeConfig, metrics: &ServerMetrics) -> BrownoutTick {
        BrownoutTick {
            ctrl: cfg
                .brownout_target
                .map(|t| policy::BrownoutController::new(t.as_secs_f64() * 1e3)),
            last_tick: Instant::now(),
            last_shed: class_shed_total(metrics),
        }
    }

    /// Fold one observed submit→dispatch delay into the delay EWMA.
    fn observe(&mut self, delay: Duration) {
        if let Some(c) = &mut self.ctrl {
            c.observe_delay_ms(delay.as_secs_f64() * 1e3);
        }
    }

    /// Level in force right now (0 with the controller disarmed).
    fn level(&self) -> u8 {
        self.ctrl.as_ref().map_or(0, |c| c.level())
    }

    /// Run one control tick if the cadence has elapsed, publishing the
    /// level to [`ServerMetrics::brownout_level`] for `submit` (L3
    /// admission) and the hybrid worker (L2 escalation).
    fn maybe_tick(&mut self, metrics: &ServerMetrics, queue_cap: usize) {
        let Some(ctrl) = &mut self.ctrl else { return };
        let now = Instant::now();
        if now.duration_since(self.last_tick) < BROWNOUT_TICK {
            return;
        }
        self.last_tick = now;
        let depth =
            metrics.in_flight.load(Ordering::Relaxed) as f64 / queue_cap.max(1) as f64;
        let shed = class_shed_total(metrics);
        let level = ctrl.tick(depth, shed.saturating_sub(self.last_shed));
        self.last_shed = shed;
        metrics.brownout_level.store(level as u64, Ordering::Relaxed);
    }
}

fn router_thread(
    cfg: ServeConfig,
    rx: Receiver<RouterMsg>,
    mut tiers: Vec<TierDispatch>,
    metrics: Arc<ServerMetrics>,
    health: Arc<FleetHealth>,
    ready: Sender<()>,
    hybrid: Option<(Sender<WorkMsg>, Arc<AtomicU64>)>,
) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let router = if cfg.router.is_empty() {
        None
    } else {
        let eng = RouterEngine::load(
            rt.clone(),
            &cfg.run_dir.join("routers").join(&cfg.router),
        )?;
        rt.exec("router.fwd")?; // warm compile
        Some(eng)
    };
    let _ = ready.send(());
    let mut rng = crate::rng::Rng::new(0xA5);
    let max_batch = rt.manifest.globals.trainb;
    let last_tier = tiers.len() - 1;
    // per-request quality targets resolve through the calibrated family
    // loaded at server start (or the uncalibrated synthetic fallback)
    let family = cfg
        .quality_ladders
        .clone()
        .unwrap_or_else(|| LadderFamily::synthetic(tiers.len(), DEFAULT_QUALITY_LEVELS));
    let mut pending: Vec<InFlight> = Vec::new();
    let mut shutdown = false;
    // overload brownout controller (DESIGN.md §13): armed only by
    // `brownout_target` — disarmed, the level is pinned to 0 and every
    // brownout branch below is the identity, so routing stays
    // byte-identical to a server built without the controller
    let mut brownout = BrownoutTick::new(&cfg, &metrics);

    while !shutdown {
        brownout.maybe_tick(&metrics, cfg.queue_cap);
        // batching window: collect until deadline or max batch
        let deadline = Instant::now() + cfg.batch_window;
        while pending.len() < max_batch {
            let now = Instant::now();
            let wait = if pending.is_empty() {
                // nap short while a brownout level is in force: the
                // recovery ticks below must keep firing on an idle
                // server or the level could never walk back to 0
                if brownout.level() > 0 { BROWNOUT_TICK } else { Duration::from_millis(50) }
            } else if now >= deadline {
                break;
            } else {
                deadline - now
            };
            match rx.recv_timeout(wait) {
                Ok(RouterMsg::Req(r)) => pending.push(r),
                Ok(RouterMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    brownout.maybe_tick(&metrics, cfg.queue_cap);
                    if !pending.is_empty() {
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        let batch: Vec<InFlight> = pending.drain(..).collect();
        let t_score = Instant::now();
        let scores = match &router {
            Some(r) => {
                let prompts: Vec<&[i32]> = batch.iter().map(|r| r.prompt.as_slice()).collect();
                r.scores(&prompts)?
            }
            None => batch.iter().map(|_| rng.next_f32()).collect(),
        };
        let per_query = t_score.elapsed() / batch.len() as u32;
        let assigns = cfg.policy.assign(&scores);
        let level = brownout.level();
        for ((mut req, score), default_tier) in batch.into_iter().zip(scores).zip(assigns) {
            metrics.router_latency.record(per_query);
            // per-request resolution: an explicit policy override wins,
            // then the quality target through the ladder family, then
            // the server-wide default — so one batch window can mix
            // quality targets. Under brownout the L1 actuator caps the
            // *effective* quality target (the paper's dial, turned by
            // load): quality-carrying requests resolve through the
            // capped target, and default-policy requests resolve as if
            // they carried the cap. Level 0 is the identity on every
            // arm. Policy overrides are explicit tier pins — brownout
            // never rewrites them.
            let want = match (&req.policy, req.quality) {
                // a seeded Random policy replays the same stream on
                // every assign() call, and overrides are evaluated one
                // request at a time — fold the request id into the seed
                // so a shared Random override keeps its weighted split
                // instead of collapsing to one fixed tier
                (Some(TierPolicy::Random { weights, seed }), _) => {
                    TierPolicy::Random { weights: weights.clone(), seed: seed ^ req.id }
                        .assign(std::slice::from_ref(&score))
                        .first()
                        .copied()
                        .unwrap_or(default_tier)
                }
                (Some(p), _) => p
                    .assign(std::slice::from_ref(&score))
                    .first()
                    .copied()
                    .unwrap_or(default_tier),
                (None, Some(q)) => {
                    let eff = policy::brownout_effective_quality(level, q);
                    if level > 0 {
                        metrics.eq_delta_samples.fetch_add(1, Ordering::Relaxed);
                        metrics
                            .eq_delta_permille
                            .fetch_add(((q - eff).max(0.0) * 1000.0) as u64, Ordering::Relaxed);
                    }
                    family.assign_one(eff, score)
                }
                (None, None) if level > 0 => {
                    family.assign_one(policy::brownout_quality_cap(level), score)
                }
                (None, None) => default_tier,
            }
            .min(last_tier);
            if req.cancelled() {
                metrics.routing.cancel(want);
                finish(req, Event::Cancelled);
                continue;
            }
            if req.expired() {
                metrics.routing.shed(want);
                metrics.class_shed[req.priority.index()].fetch_add(1, Ordering::Relaxed);
                finish(req, Event::Failed { reason: "deadline expired before dispatch".into() });
                continue;
            }
            // submit→dispatch wait, recorded once per routing pass (the
            // cancelled/expired requests above never reached dispatch)
            // and folded into the brownout controller's delay EWMA
            let qdelay = Instant::now().duration_since(req.t0);
            metrics.queue_delay.record(qdelay);
            brownout.observe(qdelay);
            // hybrid dispatch: draft–verify requests bypass tier
            // selection (both boundary tiers participate) and go to the
            // dedicated hybrid worker; the `Routed` announcement names
            // the large tier, whose output the stream is pinned to. A
            // dead hybrid channel strips the flag and falls through to
            // classic routing instead of failing the request.
            if req.hybrid {
                match &hybrid {
                    Some((htx, hdepth)) => {
                        if req.tx.send(Event::Routed { tier: last_tier, score }).is_err() {
                            // handle already dropped: implicit
                            // cancellation, the drop frees the slot
                            metrics.routing.cancel(last_tier);
                            continue;
                        }
                        hdepth.fetch_add(1, Ordering::Relaxed);
                        let routed = Instant::now();
                        match htx.send(WorkMsg::Work(Work { req, score, routed })) {
                            Ok(()) => {
                                metrics.routing.route(last_tier);
                                continue;
                            }
                            Err(mpsc::SendError(WorkMsg::Work(w))) => {
                                hdepth.fetch_sub(1, Ordering::Relaxed);
                                req = w.req;
                                req.hybrid = false;
                            }
                            Err(mpsc::SendError(WorkMsg::Shutdown)) => {
                                unreachable!("router only sends Work")
                            }
                        }
                    }
                    None => req.hybrid = false,
                }
            }
            let routed = Instant::now();
            // availability mask: re-resolve the decision over live tiers
            // only — a dead tier degrades to a cheaper live one (or
            // escalates to a costlier one) instead of failing
            let Some(first_choice) = health.degrade(want, routed) else {
                metrics.routing.fail(want);
                finish(req, Event::Failed { reason: NO_LIVE_TIER.into() });
                continue;
            };
            // dispatch with dead-replica recovery: a replica can die
            // between the health check and the send — recover the work
            // from the SendError, mark the replica down, and retry the
            // next live replica (or the next live tier). The router
            // itself never dies on a dead worker channel.
            let mut tier = first_choice;
            let mut announced: Option<usize> = None;
            let mut work = Work { req, score, routed };
            let delivered = loop {
                if announced != Some(tier) {
                    // announce (or, on failover, re-announce) the
                    // routing decision; clients treat repeated `Routed`
                    // events as an update, never a terminal
                    if work.req.tx.send(Event::Routed { tier, score }).is_err() {
                        // handle already dropped: implicit cancellation —
                        // dropping the work frees its admission slot
                        metrics.routing.cancel(tier);
                        break false;
                    }
                    announced = Some(tier);
                }
                let d = &mut tiers[tier];
                let nrep = d.txs.len();
                let rep = match cfg.select {
                    ReplicaSelect::RoundRobin => {
                        let mut pick = None;
                        for k in 0..nrep {
                            let r = (d.rr + k) % nrep;
                            if health.replica_live(tier, r) {
                                d.rr = r.wrapping_add(1);
                                pick = Some(r);
                                break;
                            }
                        }
                        pick
                    }
                    ReplicaSelect::ShortestQueue => (0..nrep)
                        .filter(|&r| health.replica_live(tier, r))
                        .min_by_key(|&r| d.depths[r].load(Ordering::Relaxed)),
                };
                let Some(rep) = rep else {
                    // every replica of this tier is down/stalled;
                    // tier_admits sees that too, so degrade() cannot
                    // hand the same tier back
                    match health.degrade(tier, Instant::now()) {
                        Some(t) => {
                            tier = t;
                            continue;
                        }
                        None => {
                            metrics.routing.fail(tier);
                            finish(work.req, Event::Failed { reason: NO_LIVE_TIER.into() });
                            break false;
                        }
                    }
                };
                d.depths[rep].fetch_add(1, Ordering::Relaxed);
                match d.txs[rep].send(WorkMsg::Work(work)) {
                    Ok(()) => break true,
                    Err(mpsc::SendError(msg)) => {
                        d.depths[rep].fetch_sub(1, Ordering::Relaxed);
                        health.set_replica_up(tier, rep, false);
                        health.record_failure(tier);
                        let WorkMsg::Work(w) = msg else {
                            unreachable!("router only sends Work")
                        };
                        work = w;
                    }
                }
            };
            if delivered {
                // `route` counts at (successful) dispatch, like before
                metrics.routing.route(tier);
                if tier != first_choice || tier != want {
                    metrics.failovers.fetch_add(1, Ordering::Relaxed);
                    if tier < want {
                        metrics.degraded.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
    // late arrivals racing the shutdown drain (worker crash-requeues)
    // still get a terminal event instead of a silent drop
    while let Ok(RouterMsg::Req(req)) = rx.try_recv() {
        metrics.routing.fail(0);
        finish(req, Event::Failed { reason: "server shutting down".into() });
    }
    Ok(())
}

/// One admission bucket: a `prefill@size` artifact plus — when the
/// device-side path is enabled — the matching `kv_install@size` scatter.
/// `install: false` means bucketed prefill with host slot surgery
/// ([`ServeConfig::force_host_admission`], or a manifest missing the
/// install artifact for this bucket). Executables are compiled lazily on
/// a bucket's first admission (`Runtime::exec` caches by name), so
/// worker startup only pays for the full-batch bucket it warms
/// explicitly, not every bucket a run may never admit at.
#[derive(Clone, Copy)]
struct AdmitBucket {
    size: usize,
    install: bool,
}

/// Per-worker state built **once** at thread start: compiled executables,
/// the resident-params maps, the trace flag, the persistent KV cache, and
/// the decode-input scratch tensors. The seed rebuilt the resident
/// `HashMap` (and re-read `HYBRID_SERVE_TRACE`) on every admit/decode
/// call and allocated fresh input tensors every decode step — pure
/// per-token overhead.
struct WorkerCtx {
    engine: LmEngine,
    table: SlotTable<Work>,
    kv: KvCache,
    tier: usize,
    /// This worker serves the most expensive tier: its per-slot decode
    /// work feeds [`ServerMetrics::large_slot_steps`], the routed-mode
    /// term of the hybrid-vs-routed large-pass comparison.
    large_tier: bool,
    depth: Arc<AtomicU64>,
    /// Fleet availability: completions feed the tier breaker's success
    /// signal ([`FleetHealth::record_success`]).
    health: Arc<FleetHealth>,
    /// Full-batch prefill — the admission fallback when no bucket fits
    /// (pre-v3 manifests; on v3 it is the `@genb` bucket's exec).
    prefill: Arc<Exec>,
    decode: Arc<Exec>,
    /// Admission buckets, ascending by size; empty on pre-v3 manifests.
    admit_buckets: Vec<AdmitBucket>,
    /// Params-only resident map for prefill (input layout: params + data;
    /// never mutated).
    prefill_resident: HashMap<usize, Arc<xla::PjRtBuffer>>,
    /// Resident map for decode: params plus — while the cache is
    /// device-resident — the KV buffers at indices `n`/`n+1`, swapped in
    /// place each iteration by [`KvCache::bind`].
    decode_resident: HashMap<usize, Arc<xla::PjRtBuffer>>,
    /// Logical `[L, genb, sctx, H, Dh]` KV shape (for adopting prefill
    /// outputs).
    cache_dims: Vec<usize>,
    /// Decode-input scratch tensors, refilled in place every iteration
    /// ([`SlotTable::fill_decode_inputs`]) — no per-step allocation.
    cur_t: Tensor,
    pos_t: Tensor,
    step_t: Tensor,
    seeds_t: Tensor,
    /// Reusable scalar temperature tensor.
    temp_t: Tensor,
    /// `HYBRID_SERVE_TRACE` read once at startup.
    trace: bool,
    /// Block-paged KV state (manifest v4, unless
    /// [`ServeConfig::force_dense_kv`]); `None` keeps the dense slab.
    paged: Option<PagedCtx>,
}

/// Per-worker block-paged KV state (DESIGN.md §10): the device block
/// pools, the refcounted allocator, the shared-prefix trie, and the
/// per-slot block tables. Taken out of [`WorkerCtx`] for the duration
/// of paged admission/decode calls (split-borrow hygiene) and always
/// put back.
struct PagedCtx {
    arts: PagedArtifacts,
    pool: PagedKvCache,
    alloc: BlockAllocator,
    prefix: PrefixCache,
    /// Per-slot block tables `[genb][maxblk]`; entry 0 = unallocated
    /// (the null block). Free lanes are all-zero, so their decode
    /// writes land in block 0 and their garbage keys sit behind the
    /// causal mask.
    tables: Vec<Vec<u32>>,
    /// Decode-input scratch: the `[genb, maxblk]` i32 table tensor
    /// refilled in place and uploaded each step — O(B) bytes, the paged
    /// path's only addition to the per-step host traffic.
    tables_t: Tensor,
    /// Cross-request prefix reuse enabled
    /// (![`ServeConfig::disable_prefix_cache`]).
    use_prefix: bool,
    /// Sampling is greedy (`temp == 0`): exact full-prompt hits may
    /// replay the cached first token and skip prefill entirely. At
    /// `temp > 0` the first token is seed-dependent, so full hits
    /// degrade to shared-block reuse plus a real prefill.
    greedy: bool,
}

/// Channels and shared state linking one replica worker back to the
/// fleet, bundled so the spawn site, the supervisor, and the serve loop
/// pass one handle.
struct WorkerLinks {
    rx: Receiver<WorkMsg>,
    depth: Arc<AtomicU64>,
    metrics: Arc<ServerMetrics>,
    health: Arc<FleetHealth>,
    /// Ticked once per serve-loop iteration; frozen while `depth > 0` is
    /// what the stall monitor calls a stall.
    heartbeat: Arc<AtomicU64>,
    /// Requeue path for requests orphaned by a worker death — the router
    /// re-scores, re-resolves over live tiers, and re-emits `Routed`.
    ingress: Sender<RouterMsg>,
    ready: Sender<()>,
}

/// Per-worker respawn budget: after this many serve-loop deaths the
/// supervisor stops respawning — a tier replica terminally fails its
/// arrivals (siblings still cover the tier); the hybrid worker bounces
/// them back through ingress as routed requests (it has no sibling).
const MAX_RESPAWNS: u32 = 8;

/// Deterministic fault-injection state for one worker (the chaos
/// suite's test-only hook; empty everywhere else). Lives OUTSIDE the
/// supervisor's unwind boundary so `steps`/`next` survive respawns and a
/// multi-fault plan describes one schedule over the worker's lifetime.
struct FaultState {
    /// This worker's faults, ascending by `at_step`.
    faults: Vec<Fault>,
    /// First unfired fault.
    next: usize,
    /// Cumulative decode steps, across respawns.
    steps: u64,
    /// Active slow-decode fault: (per-step sleep ms, steps left).
    slow: Option<(u64, u64)>,
}

impl FaultState {
    fn new(faults: Vec<Fault>) -> FaultState {
        FaultState { faults, next: 0, steps: 0, slow: None }
    }

    fn empty() -> FaultState {
        FaultState::new(Vec::new())
    }

    /// Fire due faults at the serve-loop safe point, where the backlog
    /// and slot table own every request (nothing half-published), so an
    /// injected crash exercises exactly the recovery a real one would.
    /// `Crash` panics and `AdmitError` returns `Err` — both absorbed by
    /// the supervisor; `Stall` blocks the loop (the heartbeat freezes,
    /// tripping the decode-timeout monitor); `SlowDecode` arms a
    /// per-step sleep that keeps the heartbeat ticking — degraded, not
    /// stalled.
    fn poll(&mut self) -> Result<()> {
        while self.next < self.faults.len() && self.faults[self.next].at_step <= self.steps {
            let f = self.faults[self.next].clone();
            self.next += 1;
            match f.kind {
                FaultKind::Crash => {
                    panic!("injected fault: crash at decode step {}", f.at_step)
                }
                FaultKind::Stall { ms } => std::thread::sleep(Duration::from_millis(ms)),
                FaultKind::SlowDecode { ms, steps } => self.slow = Some((ms, steps)),
                FaultKind::AdmitError => anyhow::bail!(
                    "injected fault: admission error at decode step {}",
                    f.at_step
                ),
            }
        }
        if let Some((ms, left)) = &mut self.slow {
            std::thread::sleep(Duration::from_millis(*ms));
            *left -= 1;
            if *left == 0 {
                self.slow = None;
            }
        }
        Ok(())
    }
}

/// Retire one request orphaned by a worker death: cancelled requests
/// retire as `Cancelled`; under the retry budget (and outside shutdown,
/// when the router is gone or going) the request requeues through
/// ingress for re-scoring and re-resolution over the surviving tiers;
/// otherwise it goes terminal with [`Event::Failed`]. Never silently
/// dropped.
fn retire_orphan(cfg: &ServeConfig, w: Work, links: &WorkerLinks, tier: usize, shutdown: bool) {
    links.depth.fetch_sub(1, Ordering::Relaxed);
    let mut req = w.req;
    if req.cancelled() {
        links.metrics.routing.cancel(tier);
        finish(req, Event::Cancelled);
        return;
    }
    if !shutdown && req.retries < cfg.retry_budget {
        req.retries += 1;
        links.metrics.retries.fetch_add(1, Ordering::Relaxed);
        match links.ingress.send(RouterMsg::Req(req)) {
            Ok(()) => return,
            // the router is gone (shutdown raced the death): fall
            // through to the terminal event
            Err(mpsc::SendError(RouterMsg::Req(r))) => req = r,
            Err(_) => return,
        }
    }
    links.metrics.routing.fail(tier);
    finish(
        req,
        Event::Failed { reason: format!("worker died with the request in flight (tier {tier})") },
    );
}

/// Stall monitor (spawned only with [`ServeConfig::decode_timeout`]):
/// watches every worker's heartbeat. A replica holding work whose
/// heartbeat stays frozen past the timeout is flagged stalled — the
/// router routes around it and its tier breaker records one failure. A
/// thread cannot be killed from outside, so stalls are *contained*, not
/// cured: if the loop thaws, the flag clears and the tier heals through
/// the breaker's half-open probe.
fn stall_monitor(
    watch: Vec<(usize, usize, Arc<AtomicU64>, Arc<AtomicU64>)>,
    health: Arc<FleetHealth>,
    timeout: Duration,
    stop: Arc<AtomicBool>,
) {
    let mut last: Vec<(u64, Instant)> = watch
        .iter()
        .map(|(_, _, _, hb)| (hb.load(Ordering::Relaxed), Instant::now()))
        .collect();
    let poll = (timeout / 4).max(Duration::from_millis(5));
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(poll);
        let now = Instant::now();
        for (i, (tier, rep, depth, hb)) in watch.iter().enumerate() {
            let cur = hb.load(Ordering::Relaxed);
            if cur != last[i].0 {
                last[i] = (cur, now);
                // thawed: clear the flag; the next completion closes the
                // breaker through its record_success
                health.set_replica_stalled(*tier, *rep, false);
            } else if depth.load(Ordering::Relaxed) > 0
                && now.duration_since(last[i].1) >= timeout
                && !health.swap_replica_stalled(*tier, *rep, true)
            {
                // newly stalled (edge-triggered): one failure signal
                health.record_failure(*tier);
            }
        }
    }
}

fn worker_thread(cfg: ServeConfig, tier: usize, replica: usize, links: WorkerLinks) -> Result<()> {
    let model = cfg.tiers[tier].model.clone();
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let g = rt.manifest.globals;
    let meta = *rt.manifest.model(&model)?;
    let engine = LmEngine::load(rt.clone(), &model, &cfg.run_dir.join("params").join(&model))?;
    // warm compiles before accepting work (PJRT compile is seconds):
    // decode, the full-batch prefill, and — on v3 — the full-batch
    // install, the bucket every high-load admission hits. Smaller
    // buckets compile lazily on first use through the `Runtime::exec`
    // cache, so startup does not pay for buckets never admitted at.
    let decode = rt.exec(&format!("{model}.decode"))?;
    let install_buckets = rt.manifest.kv_install_buckets(&model);
    let admit_buckets: Vec<AdmitBucket> = rt
        .manifest
        .prefill_buckets(&model)
        .into_iter()
        .filter(|&b| b <= g.genb) // larger than the slot table — unreachable
        .map(|b| AdmitBucket {
            size: b,
            install: !cfg.force_host_admission && install_buckets.contains(&b),
        })
        .collect();
    // on v3 the `prefill` name aliases the @genb bucket's HLO file, so
    // this also warms the largest bucket
    let prefill = rt.exec(&format!("{model}.prefill"))?;
    if admit_buckets.iter().any(|b| b.size == g.genb && b.install) {
        rt.exec(&format!("{model}.kv_install@{}", g.genb))?;
    }
    let prefill_resident = engine.params.resident_map();
    let decode_resident = prefill_resident.clone();
    // block-paged KV path (manifest v4): device block pools + prefix
    // trie instead of the dense slab. `force_dense_kv` is the A/B knob;
    // `force_host_admission` implies dense too — host slot surgery has
    // no meaning against a device-resident block pool. A closure because
    // the supervisor rebuilds this state fresh when a panic fires while
    // it was checked out of the ctx (and so unwound away).
    let make_paged = |engine: &LmEngine| -> Result<Option<PagedCtx>> {
        if cfg.force_dense_kv || cfg.force_host_admission {
            return Ok(None);
        }
        let Some(arts) = engine.paged_artifacts()? else {
            return Ok(None);
        };
        let pool = PagedKvCache::zeros_on_device(
            &rt,
            meta.layers,
            arts.nblk,
            arts.block,
            meta.heads,
            meta.headdim,
        )?;
        let alloc = BlockAllocator::new(arts.nblk);
        let maxblk = arts.maxblk;
        Ok(Some(PagedCtx {
            pool,
            alloc,
            prefix: PrefixCache::new(arts.block),
            tables: vec![vec![0u32; maxblk]; g.genb],
            tables_t: Tensor::i32(vec![g.genb, maxblk], vec![0; g.genb * maxblk]),
            use_prefix: !cfg.disable_prefix_cache,
            greedy: cfg.temp == 0.0,
            arts,
        }))
    };
    let paged = make_paged(&engine)?;
    let mut ctx = WorkerCtx {
        table: SlotTable::new(g.genb),
        kv: KvCache::zeros(meta.layers, g.genb, g.sctx, meta.heads, meta.headdim),
        tier,
        large_tier: tier + 1 == cfg.tiers.len(),
        depth: links.depth.clone(),
        health: links.health.clone(),
        prefill,
        decode,
        admit_buckets,
        prefill_resident,
        decode_resident,
        cache_dims: vec![meta.layers, g.genb, g.sctx, meta.heads, meta.headdim],
        cur_t: Tensor::i32(vec![g.genb], vec![tok::PAD; g.genb]),
        pos_t: Tensor::i32(vec![g.genb], vec![0; g.genb]),
        step_t: Tensor::i32(vec![], vec![1]),
        seeds_t: Tensor::u32(vec![g.genb], vec![0; g.genb]),
        temp_t: Tensor::f32(vec![], vec![cfg.temp]),
        trace: std::env::var_os("HYBRID_SERVE_TRACE").is_some(),
        paged,
        engine,
    };
    if ctx.paged.is_none() && ctx.admit_buckets.iter().any(|b| b.install) {
        // device-side admission never pulls the cache to the host: put
        // the zeroed cache on device once, at startup, so the first
        // admission's byte count is already O(B·sprompt). The paged
        // path never touches the dense slab, so it skips this upload.
        ctx.kv.to_device(&rt)?;
    }
    let _ = links.ready.send(());
    let mut backlog: Vec<Work> = Vec::new();
    let mut shutdown = false;
    let mut faults = match &cfg.fault_plan {
        Some(p) => FaultState::new(p.for_worker(tier, replica)),
        None => FaultState::empty(),
    };
    let had_paged = ctx.paged.is_some();
    let mut deaths = 0u32;

    // supervisor: the serve loop runs under catch_unwind while
    // `ctx`/`backlog`/`shutdown`/`faults` stay out here, on the far side
    // of the unwind boundary — a panic (or error) leaves every request
    // the worker held recoverable, to be retired or requeued below, and
    // the worker respawns in place.
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            serve_loop(&cfg, &model, &mut ctx, &links, &mut backlog, &mut shutdown, &mut faults)
        }));
        let err = match run {
            // graceful: shutdown signalled and the drain completed
            Ok(Ok(())) => return Ok(()),
            Ok(Err(e)) => format!("error: {e:#}"),
            Err(p) => match p.downcast_ref::<&str>() {
                Some(s) => format!("panic: {s}"),
                None => match p.downcast_ref::<String>() {
                    Some(s) => format!("panic: {s}"),
                    None => "panic".into(),
                },
            },
        };
        deaths += 1;
        links.metrics.worker_deaths.fetch_add(1, Ordering::Relaxed);
        links.health.set_replica_up(tier, replica, false);
        links.health.record_failure(tier);
        eprintln!(
            "[serve] worker {model} replica {replica} died ({err}); {}",
            if deaths < MAX_RESPAWNS { "respawning" } else { "respawn budget exhausted" }
        );
        // every request this worker held is retired or requeued — never
        // silently dropped; KV blocks go back through the normal
        // refcount-release path (a no-op if the paged state itself
        // unwound away — it is rebuilt wholesale below)
        for (idx, slot) in ctx.table.take_matching(|_| true) {
            release_slot_blocks(&mut ctx, idx)?;
            retire_orphan(&cfg, slot.payload, &links, tier, shutdown);
        }
        for w in backlog.drain(..) {
            retire_orphan(&cfg, w, &links, tier, shutdown);
        }
        if had_paged && ctx.paged.is_none() {
            // the panic fired while the paged state was checked out of
            // the ctx (admission/decode split-borrow) and it unwound
            // away: rebuild fresh — zeroed pool, empty allocator/trie
            ctx.paged = make_paged(&ctx.engine)?;
        }
        if deaths >= MAX_RESPAWNS {
            break;
        }
        // respawn in place: mark the replica live and keep serving
        links.health.set_replica_up(tier, replica, true);
    }
    // respawn budget exhausted: the replica stays down, but arrivals
    // that raced the death still get terminal events until shutdown
    loop {
        let msg = if shutdown {
            match links.rx.try_recv() {
                Ok(m) => m,
                Err(_) => break,
            }
        } else {
            match links.rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            }
        };
        match msg {
            WorkMsg::Work(w) => {
                links.depth.fetch_sub(1, Ordering::Relaxed);
                links.metrics.routing.fail(tier);
                finish(
                    w.req,
                    Event::Failed {
                        reason: format!("tier {tier} replica {replica}: respawn budget exhausted"),
                    },
                );
            }
            WorkMsg::Shutdown => shutdown = true,
        }
    }
    Err(anyhow::anyhow!(
        "worker {model} replica {replica} died {deaths} times; respawn budget exhausted"
    ))
}

/// One supervised serve loop: pull work, sweep, admit, decode — until
/// shutdown completes its drain. Owns **no** request state: everything
/// lives in `ctx`/`backlog` on the caller's side of the unwind boundary,
/// which is what makes the supervisor's recovery exhaustive.
fn serve_loop(
    cfg: &ServeConfig,
    model: &str,
    ctx: &mut WorkerCtx,
    links: &WorkerLinks,
    backlog: &mut Vec<Work>,
    shutdown: &mut bool,
    faults: &mut FaultState,
) -> Result<()> {
    let metrics = &links.metrics;
    while !(*shutdown && ctx.table.is_empty() && backlog.is_empty()) {
        // progress watermark for the stall monitor: one tick per
        // iteration (the idle recv timeout below keeps an idle worker
        // ticking; only a genuinely frozen loop stops)
        links.heartbeat.fetch_add(1, Ordering::Relaxed);

        // 1. pull work (non-blocking while busy; blocking when idle)
        loop {
            let msg = if ctx.table.is_empty() && backlog.is_empty() && !*shutdown {
                match links.rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        *shutdown = true;
                        break;
                    }
                }
            } else {
                match links.rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                WorkMsg::Work(w) => backlog.push(w),
                WorkMsg::Shutdown => *shutdown = true,
            }
        }

        // 1.4 injected faults fire here — the safe point where the
        // backlog and slot table own every request, so a crash/stall
        // exercises exactly the recovery machinery a real one would
        if !(backlog.is_empty() && ctx.table.is_empty()) {
            faults.poll()?;
        }

        // 1.5 retire cancelled / deadline-expired queued work before it
        // costs a prefill, and release cancelled *or expired* in-flight
        // slots — the freed slot pads the next decode wave and is
        // immediately reusable by admission; other slots' KV state is
        // untouched. The expired half is the mid-decode deadline sweep:
        // a request whose deadline passes while decoding used to burn
        // decode steps (and KV blocks) to completion; now its slot and
        // block refcounts release within one iteration and it sheds
        // with a distinct terminal reason.
        sweep_backlog(backlog, ctx, metrics);
        let now = Instant::now();
        for (idx, slot) in ctx
            .table
            .take_matching(|w| w.req.cancelled() || w.req.expired_at(now))
        {
            release_slot_blocks(ctx, idx)?;
            if slot.payload.req.cancelled() {
                cancel_work(ctx, slot.payload, metrics);
            } else {
                shed_work(ctx, slot.payload, "deadline expired mid-decode", metrics);
            }
        }

        // 2. admission per batching mode
        let can_admit = match cfg.mode {
            BatchMode::Continuous => true,
            BatchMode::RunToCompletion => ctx.table.is_empty(),
        };
        if can_admit && !backlog.is_empty() && ctx.table.has_free() {
            let n_new = backlog
                .len()
                .min(ctx.table.capacity() - ctx.table.occupied());
            let free: Vec<usize> = ctx.table.free_slots(n_new);
            let admitted: Vec<Work> = backlog.drain(..n_new).collect();
            // paged admission can come up short on pool blocks even
            // after LRU eviction; the unadmitted tail goes back to the
            // front of the backlog in order. Sustained exhaustion keeps
            // `in_flight` pinned, so callers see `SubmitError::Busy` at
            // the admission window instead of a worker panic.
            let leftover = admit(ctx, &free, admitted, metrics)?;
            for (i, w) in leftover.into_iter().enumerate() {
                backlog.insert(i, w);
            }
        }

        // 3. one decode iteration over the occupied slots
        if !ctx.table.is_empty() {
            let t0 = Instant::now();
            decode_step(ctx, metrics)?;
            faults.steps += 1;
            if ctx.trace {
                eprintln!(
                    "[trace {model}] decode iter {:.1} ms occ {} kv {}",
                    t0.elapsed().as_secs_f64() * 1e3,
                    ctx.table.occupied(),
                    if ctx.kv.is_device() { "device" } else { "host" },
                );
            }
        }
    }
    Ok(())
}

/// Prefill newly-admitted requests and install them into slots.
///
/// Prefill runs at the smallest admission bucket that fits the group
/// (`prefill@B`, manifest v3) instead of always padding to `genb`, and
/// the fresh KV slots are scattered into the persistent worker cache on
/// device ([`KvCache::install_slots_device`]) — per admission the host
/// moves O(B·sprompt) prompt bytes and the O(B) sampled tokens, never
/// the cache pair. On pre-v3 manifests (or with
/// [`ServeConfig::force_host_admission`]) slot surgery falls back to the
/// host round-trip (`to_host`, [`KvCache::copy_slot_from`],
/// `to_device`); the steady-state decode loop stays zero-copy either
/// way. All admission traffic is metered into `admit_*_bytes`, separate
/// from the decode counters.
///
/// Returns the requests that could **not** be admitted this wave (only
/// the paged path can come up short — on pool exhaustion after LRU
/// eviction — and the caller requeues them at the backlog front).
fn admit(
    ctx: &mut WorkerCtx,
    slots: &[usize],
    work: Vec<Work>,
    metrics: &Arc<ServerMetrics>,
) -> Result<Vec<Work>> {
    if ctx.paged.is_some() {
        admit_paged(ctx, slots, work, metrics)
    } else {
        admit_dense(ctx, slots, work, metrics)?;
        Ok(Vec::new())
    }
}

fn admit_dense(
    ctx: &mut WorkerCtx,
    slots: &[usize],
    work: Vec<Work>,
    metrics: &Arc<ServerMetrics>,
) -> Result<()> {
    let t0 = Instant::now();
    let rt = ctx.engine.runtime().clone();
    let before = rt.transfers();
    let g = rt.manifest.globals;
    let n = ctx.engine.params.len();
    let n_req = work.len();
    debug_assert_eq!(n_req, slots.len());

    // bucket selection: smallest bucketed prefill >= the group size;
    // the full generation batch when no bucket fits (pre-v3 manifests).
    // Executables resolve through the `Runtime::exec` cache — compiled
    // once on a bucket's first admission, a name lookup after that
    // (admission is off the per-token path).
    let bucket = ctx.admit_buckets.iter().find(|b| b.size >= n_req).copied();
    let (bsz, prefill, install) = match bucket {
        Some(b) => {
            let model = &ctx.engine.name;
            let prefill = if b.size == g.genb {
                // `prefill` aliases the @genb bucket's HLO (warmed at
                // worker start) — don't compile the same file twice
                ctx.prefill.clone()
            } else {
                rt.exec(&format!("{model}.prefill@{}", b.size))?
            };
            let install = if b.install {
                Some(rt.exec(&format!("{model}.kv_install@{}", b.size))?)
            } else {
                None
            };
            (b.size, prefill, install)
        }
        None => (g.genb, ctx.prefill.clone(), None),
    };

    let mut ptoks = vec![tok::PAD; bsz * g.sprompt];
    let mut lens = vec![1i32; bsz];
    let mut seedv = vec![0u32; bsz];
    for (b, w) in work.iter().enumerate() {
        let p = &w.req.prompt;
        // Server::submit rejects or truncates oversized prompts; this
        // guards library callers reaching the worker some other way
        anyhow::ensure!(
            p.len() <= g.sprompt,
            "admitted prompt of {} tokens exceeds the {}-token window",
            p.len(),
            g.sprompt
        );
        ptoks[b * g.sprompt..b * g.sprompt + p.len()].copy_from_slice(p);
        lens[b] = p.len() as i32;
        seedv[b] = w.req.id as u32;
    }
    let ptoks = Tensor::i32(vec![bsz, g.sprompt], ptoks);
    let lens_t = Tensor::i32(vec![bsz], lens.clone());
    let seeds_t = Tensor::u32(vec![bsz], seedv);
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &ptoks),
        (n + 1, &lens_t),
        (n + 2, &seeds_t),
        (n + 3, &ctx.temp_t),
    ];
    let mut outs = prefill.run_resident(&ctx.prefill_resident, &host)?;
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?.into_tensor()?;
    let first = outs.pop().context("next")?.into_tensor()?;

    let device_install = match (install, kc.device().cloned(), vc.device().cloned()) {
        (Some(inst), Some(kb), Some(vb)) => {
            // device path: scatter the fresh slots into the persistent
            // cache without either cache crossing the host boundary
            ctx.kv.install_slots_device(&rt, &inst, &kb, &vb, slots)?;
            true
        }
        _ => {
            // host-surgery fallback: v1/v2 artifacts, forced host
            // admission, or (defensively) prefill outputs that came back
            // host-resident
            let mut dims = ctx.cache_dims.clone();
            dims[1] = bsz;
            let mut fresh = KvCache::from_outputs(kc, vc, &dims)?;
            fresh.to_host(&rt)?;
            ctx.kv.to_host(&rt)?;
            for (b, &slot_idx) in slots.iter().enumerate() {
                ctx.kv.copy_slot_from(&fresh, b, slot_idx)?;
            }
            // hand the merged cache back to the device so steady-state
            // decode starts zero-copy immediately (a no-op gain on
            // pre-v2 artifacts, whose decode outputs pull it back to
            // the host anyway)
            ctx.kv.to_device(&rt)?;
            false
        }
    };

    let first = first.as_i32()?;
    let logp = logp.as_f32()?;
    for (b, (w, &slot_idx)) in work.into_iter().zip(slots).enumerate() {
        let plen = lens[b];
        if first[b] == tok::EOS {
            complete(ctx, w, vec![], 0.0, metrics);
            continue;
        }
        // stream the first token; a dropped handle cancels the request
        // and the prefilled slot simply stays free
        if w.req.tx.send(Event::Token { token: first[b], logprob: logp[b] }).is_err() {
            cancel_work(ctx, w, metrics);
            continue;
        }
        let slot = Slot {
            answer: vec![first[b]],
            logprob_sum: logp[b],
            cur: first[b],
            pos: plen,
            seed: w.req.id as u32,
            payload: w,
        };
        ctx.table.insert(slot_idx, slot)?;
    }
    let moved = before.delta(rt.transfers());
    // device-side admission must never move the cache pair: its host
    // traffic is the bucketed prompt upload plus O(B) control/sample
    // bytes, orders of magnitude under the cache size
    debug_assert!(
        !device_install || moved.h2d_bytes + moved.d2h_bytes < ctx.kv.byte_size() / 4,
        "device admission moved {} B — the KV cache is round-tripping (cache pair = {} B)",
        moved.h2d_bytes + moved.d2h_bytes,
        ctx.kv.byte_size()
    );
    metrics
        .admit_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .admit_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    metrics.admissions.fetch_add(1, Ordering::Relaxed);
    metrics.admitted.fetch_add(n_req as u64, Ordering::Relaxed);
    metrics.prefill_tokens.fetch_add(
        lens.iter().take(n_req).map(|&l| l as u64).sum::<u64>(),
        Ordering::Relaxed,
    );
    metrics.admit_latency.record(t0.elapsed());
    Ok(())
}

/// Paged admission (manifest v4, DESIGN.md §10). Per request: consult
/// the shared-prefix trie, adopt (incref) cached blocks for the matched
/// full prompt chunks, and allocate fresh blocks for the rest — LRU-
/// evicting cold trie entries under pressure, and requeueing the
/// request (graceful, never a panic) when the pool still cannot hold
/// it. Exact full-prompt hits under greedy sampling skip prefill
/// entirely: the cached tail block is copied into a private block
/// (`kv_block_copy`, copy-on-extend) and the cached first token is
/// replayed. Everyone else goes through the usual bucketed dense
/// prefill, but `kv_install_paged@B` scatters **only the non-shared
/// blocks** into the pool (`dst_tables` entry 0 = skip) — a hot system
/// prompt is prefill-installed once, fleet-wide per worker. Admission
/// traffic stays O(B·sprompt): prompt upload + the O(B) table/sample
/// lanes, never a pool crossing.
fn admit_paged(
    ctx: &mut WorkerCtx,
    slots: &[usize],
    work: Vec<Work>,
    metrics: &Arc<ServerMetrics>,
) -> Result<Vec<Work>> {
    let t0 = Instant::now();
    let rt = ctx.engine.runtime().clone();
    let before = rt.transfers();
    let g = rt.manifest.globals;
    let n = ctx.engine.params.len();
    // take the paged state out for the call (split borrows of ctx);
    // every exit below puts it back
    let mut p = ctx.paged.take().expect("admit_paged without paged state");
    let block = p.arts.block;
    let maxblk = p.arts.maxblk;

    // phase 1: prefix lookup + block-table construction, per request
    struct Admit1 {
        w: Work,
        slot: usize,
        plen: usize,
        /// Full prompt chunks adopted from the trie (install skips them).
        shared_blocks: usize,
        /// Full-hit replay: (first token, logprob) — skips prefill.
        fast: Option<(i32, f32)>,
    }
    let mut pend: Vec<Admit1> = Vec::with_capacity(work.len());
    let mut copies: Vec<(u32, u32)> = Vec::new(); // (src, dst) tail copy pairs
    let mut leftover: Vec<Work> = Vec::new();
    let mut work_iter = work.into_iter();
    let mut slot_iter = slots.iter().copied();
    while let Some(w) = work_iter.next() {
        let Some(slot_idx) = slot_iter.next() else {
            leftover.push(w);
            leftover.extend(&mut work_iter);
            break;
        };
        let plen = w.req.prompt.len();
        anyhow::ensure!(
            plen <= g.sprompt,
            "admitted prompt of {plen} tokens exceeds the {}-token window",
            g.sprompt
        );
        let hit = if p.use_prefix {
            p.prefix.lookup(&w.req.prompt)
        } else {
            PrefixHit { shared: vec![], full: None }
        };
        // the cached first token is only replayable under greedy
        // sampling; otherwise a full hit degrades to shared blocks
        let full_hit = if p.greedy { hit.full } else { None };
        let need = blocks_needed(plen, block).min(maxblk);
        let shared_n = hit.shared.len().min(need.saturating_sub(1));
        let fresh_needed = need - shared_n;
        if p.alloc.free_count() < fresh_needed && p.use_prefix {
            p.prefix.evict(&mut p.alloc, fresh_needed)?;
        }
        if p.alloc.free_count() < fresh_needed {
            // pool exhausted even after eviction: requeue this request
            // and the rest of the wave in order (no starvation — they
            // go back to the backlog front and retry first). Counted
            // distinctly so operators can tell pool pressure from
            // admission-window backpressure in `ServerStats`.
            metrics
                .pool_exhausted_requeues
                .fetch_add(1, Ordering::Relaxed);
            leftover.push(w);
            leftover.extend(&mut work_iter);
            break;
        }
        let mut table = vec![0u32; maxblk];
        for (j, &b) in hit.shared.iter().take(shared_n).enumerate() {
            p.alloc.incref(b)?;
            table[j] = b;
        }
        for slot in table.iter_mut().take(need).skip(shared_n) {
            *slot = p
                .alloc
                .alloc()
                .context("kv pool exhausted despite the reservation check")?;
        }
        let fast = match full_hit {
            Some(f) => {
                if let Some(src) = f.tail_block {
                    // copy-on-extend: the cached tail block becomes this
                    // request's private first-write block
                    copies.push((src, table[plen / block]));
                }
                Some((f.first_tok, f.logp))
            }
            None => None,
        };
        let shared_tokens = if fast.is_some() { plen } else { shared_n * block };
        if p.use_prefix {
            metrics.prefix_lookups.fetch_add(1, Ordering::Relaxed);
            if shared_tokens > 0 {
                metrics.prefix_hits.fetch_add(1, Ordering::Relaxed);
            }
            metrics
                .prefix_shared_tokens
                .fetch_add(shared_tokens as u64, Ordering::Relaxed);
        }
        metrics
            .prefill_tokens
            .fetch_add((plen - shared_tokens) as u64, Ordering::Relaxed);
        p.tables[slot_idx] = table;
        pend.push(Admit1 { w, slot: slot_idx, plen, shared_blocks: shared_n, fast });
    }
    if pend.is_empty() {
        ctx.paged = Some(p);
        return Ok(leftover);
    }

    // phase 2: bucketed prefill for everyone without a full-hit replay,
    // installing only the non-shared blocks into the pool. Entries
    // without a replayed first token start as a sentinel the prefill
    // loop below must overwrite — (i32::MIN, NAN) is unmistakable in a
    // token stream, where the old (0, 0.0) fallback silently decoded
    // token 0 if a lane ever fell through the group
    let mut firsts: Vec<(i32, f32)> =
        pend.iter().map(|a| a.fast.unwrap_or((i32::MIN, f32::NAN))).collect();
    let group: Vec<usize> = (0..pend.len()).filter(|&i| pend[i].fast.is_none()).collect();
    if !group.is_empty() {
        let n_group = group.len();
        let bucket = ctx.admit_buckets.iter().find(|b| b.size >= n_group).copied();
        let (bsz, prefill) = match bucket {
            Some(b) => {
                let prefill = if b.size == g.genb {
                    ctx.prefill.clone()
                } else {
                    rt.exec(&format!("{}.prefill@{}", ctx.engine.name, b.size))?
                };
                (b.size, prefill)
            }
            None => (g.genb, ctx.prefill.clone()),
        };
        let (ib, install) = p
            .arts
            .install_for(bsz)
            .with_context(|| format!("no kv_install_paged bucket covers {bsz}"))?;
        anyhow::ensure!(
            ib == bsz,
            "paged install bucket {ib} does not match prefill bucket {bsz}"
        );

        let mut ptoks = vec![tok::PAD; bsz * g.sprompt];
        let mut lens = vec![1i32; bsz];
        let mut seedv = vec![0u32; bsz];
        let mut dst = vec![0i32; bsz * maxblk];
        for (bi, &pi) in group.iter().enumerate() {
            let a = &pend[pi];
            let prompt = &a.w.req.prompt;
            ptoks[bi * g.sprompt..bi * g.sprompt + prompt.len()].copy_from_slice(prompt);
            lens[bi] = prompt.len() as i32;
            seedv[bi] = a.w.req.id as u32;
            let table = &p.tables[a.slot];
            // dst_tables entry 0 = skip: shared chunks keep their cached
            // contents; entries ≥ `need` were never allocated
            for j in a.shared_blocks..blocks_needed(a.plen, block).min(maxblk) {
                dst[bi * maxblk + j] = table[j] as i32;
            }
        }
        let ptoks = Tensor::i32(vec![bsz, g.sprompt], ptoks);
        let lens_t = Tensor::i32(vec![bsz], lens);
        let seeds_t = Tensor::u32(vec![bsz], seedv);
        let host: Vec<(usize, &Tensor)> = vec![
            (n, &ptoks),
            (n + 1, &lens_t),
            (n + 2, &seeds_t),
            (n + 3, &ctx.temp_t),
        ];
        let mut outs = prefill.run_resident(&ctx.prefill_resident, &host)?;
        let vc = outs.pop().context("paged prefill: vcache")?;
        let kc = outs.pop().context("paged prefill: kcache")?;
        let logp = outs.pop().context("paged prefill: logp")?.into_tensor()?;
        let first = outs.pop().context("paged prefill: next")?.into_tensor()?;
        let (Some(kb), Some(vb)) = (kc.device().cloned(), vc.device().cloned()) else {
            anyhow::bail!(
                "{}: paged admission needs device-resident prefill outputs",
                ctx.engine.name
            );
        };
        let dst_t = Tensor::i32(vec![bsz, maxblk], dst);
        let mut resident: HashMap<usize, Arc<xla::PjRtBuffer>> = HashMap::with_capacity(4);
        p.pool.bind(0, 1, &mut resident);
        resident.insert(2, kb);
        resident.insert(3, vb);
        let ihost: Vec<(usize, &Tensor)> = vec![(4, &dst_t)];
        let mut iouts = install.run_resident(&resident, &ihost)?;
        let pv = iouts.pop().context("paged install: vcache")?;
        let pk = iouts.pop().context("paged install: kcache")?;
        p.pool.update(pk, pv)?;

        let first = first.as_i32()?;
        let logp = logp.as_f32()?;
        for (bi, &pi) in group.iter().enumerate() {
            firsts[pi] = (first[bi], logp[bi]);
        }
        // every lane either replayed a cached first token or was just
        // prefilled — no sentinel may survive into decode
        debug_assert!(
            firsts.iter().all(|&(t, _)| t != i32::MIN),
            "paged admission left a lane without a first token"
        );
        // record the freshly installed prompts so later requests share
        // them; the trie only ever adopts blocks fully covered by the
        // prompt, plus — under greedy sampling — the tail entry that
        // powers the full-hit replay
        if p.use_prefix {
            for &pi in &group {
                let a = &pend[pi];
                let table = p.tables[a.slot].clone();
                let tail = p.greedy.then_some(firsts[pi]);
                p.prefix.insert(&a.w.req.prompt, &table, tail, &mut p.alloc)?;
            }
        }
    }

    // phase 3: copy-on-extend tail copies for the full-hit replays —
    // one batched device-side kv_block_copy for the whole wave
    if !copies.is_empty() {
        anyhow::ensure!(copies.len() <= g.genb, "more tail copies than lanes");
        let mut src = vec![0i32; g.genb];
        let mut dstv = vec![0i32; g.genb];
        for (i, &(s, d)) in copies.iter().enumerate() {
            src[i] = s as i32;
            dstv[i] = d as i32;
        }
        let src_t = Tensor::i32(vec![g.genb], src);
        let dst_t = Tensor::i32(vec![g.genb], dstv);
        let count_t = Tensor::i32(vec![], vec![copies.len() as i32]);
        let mut resident: HashMap<usize, Arc<xla::PjRtBuffer>> = HashMap::with_capacity(2);
        p.pool.bind(0, 1, &mut resident);
        let chost: Vec<(usize, &Tensor)> = vec![(2, &src_t), (3, &dst_t), (4, &count_t)];
        let mut couts = p.arts.block_copy.run_resident(&resident, &chost)?;
        let cv = couts.pop().context("kv_block_copy: vcache")?;
        let ck = couts.pop().context("kv_block_copy: kcache")?;
        p.pool.update(ck, cv)?;
    }

    // phase 4: stream first tokens and occupy slots
    let n_admitted = pend.len();
    for (a, (ft, lp)) in pend.into_iter().zip(firsts) {
        if ft == tok::EOS {
            release_table(&mut p.tables[a.slot], &mut p.alloc)?;
            complete(ctx, a.w, vec![], 0.0, metrics);
            continue;
        }
        if a.w.req.tx.send(Event::Token { token: ft, logprob: lp }).is_err() {
            release_table(&mut p.tables[a.slot], &mut p.alloc)?;
            cancel_work(ctx, a.w, metrics);
            continue;
        }
        let slot = Slot {
            answer: vec![ft],
            logprob_sum: lp,
            cur: ft,
            pos: a.plen as i32,
            seed: a.w.req.id as u32,
            payload: a.w,
        };
        ctx.table.insert(a.slot, slot)?;
    }

    let moved = before.delta(rt.transfers());
    // the §8 residency contract, paged edition: admission moves the
    // bucketed prompt upload plus O(B) table/sample lanes — never the
    // block pools
    debug_assert!(
        moved.h2d_bytes + moved.d2h_bytes < p.pool.byte_size() / 4,
        "paged admission moved {} B — a pool is crossing the host boundary (pool pair = {} B)",
        moved.h2d_bytes + moved.d2h_bytes,
        p.pool.byte_size()
    );
    metrics
        .admit_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .admit_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    metrics.admissions.fetch_add(1, Ordering::Relaxed);
    metrics.admitted.fetch_add(n_admitted as u64, Ordering::Relaxed);
    metrics.kv_util_samples.fetch_add(1, Ordering::Relaxed);
    metrics
        .kv_util_permille
        .fetch_add((p.alloc.utilization() * 1000.0) as u64, Ordering::Relaxed);
    metrics.admit_latency.record(t0.elapsed());
    ctx.paged = Some(p);
    Ok(leftover)
}

/// One decode iteration for every occupied slot.
///
/// Steady state: the KV caches are device-resident, so the only
/// host↔device traffic is the O(B) token/pos/seed upload and the O(B)
/// next/logp download — per-token cost scales with model compute, not
/// KV-cache size (the seed moved the full `[L, B, S, H, Dh]` pair both
/// ways on every call).
fn decode_step(ctx: &mut WorkerCtx, metrics: &Arc<ServerMetrics>) -> Result<()> {
    let rt = ctx.engine.runtime().clone();
    let g = rt.manifest.globals;

    // refill the per-worker scratch tensors in place — the per-token
    // loop allocates nothing for its inputs
    {
        let cur = ctx.cur_t.as_i32_mut()?;
        let pos = ctx.pos_t.as_i32_mut()?;
        let seeds = ctx.seeds_t.as_u32_mut()?;
        let max_pos = ctx.table.fill_decode_inputs(cur, pos, seeds);
        ctx.step_t.as_i32_mut()?[0] = max_pos + 1;
    }
    let (next, logp) = if ctx.paged.is_some() {
        run_decode_paged(ctx, metrics)?
    } else {
        run_decode_dense(ctx, metrics)?
    };

    for idx in 0..ctx.table.capacity() {
        if ctx.table.get(idx).is_none() {
            continue;
        }
        let (finished, dead);
        {
            let slot = ctx.table.get_mut(idx).unwrap();
            slot.pos += 1;
            let nxt = next[idx];
            let limit = slot.payload.req.token_limit(g.amax);
            let full = slot.answer.len() >= limit || context_full(slot.pos as usize, g.sctx);
            if nxt == tok::EOS || full {
                finished = true;
                dead = false;
            } else {
                slot.answer.push(nxt);
                slot.logprob_sum += logp[idx];
                slot.cur = nxt;
                finished = false;
                // stream the token; a dropped handle cancels the slot
                dead = slot
                    .payload
                    .req
                    .tx
                    .send(Event::Token { token: nxt, logprob: logp[idx] })
                    .is_err();
            }
        }
        if finished {
            // the slot is owned now — move the answer out, no clone on
            // the per-token hot path
            let slot = ctx.table.take(idx).unwrap();
            release_slot_blocks(ctx, idx)?;
            let mean = slot.logprob_sum / slot.answer.len().max(1) as f32;
            complete(ctx, slot.payload, slot.answer, mean, metrics);
        } else if dead {
            let slot = ctx.table.take(idx).unwrap();
            release_slot_blocks(ctx, idx)?;
            cancel_work(ctx, slot.payload, metrics);
        }
    }
    Ok(())
}

/// Drop a retired slot's block-table references back into the pool
/// (decref; blocks still shared through the prefix trie stay live).
/// No-op on the dense path.
fn release_slot_blocks(ctx: &mut WorkerCtx, idx: usize) -> Result<()> {
    if let Some(p) = ctx.paged.as_mut() {
        release_table(&mut p.tables[idx], &mut p.alloc)?;
    }
    Ok(())
}

/// Dense decode: bind the `[L, genb, sctx, H, Dh]` slab at `n`/`n+1`
/// and run `decode`. Returns the sampled `(next, logp)` lanes.
fn run_decode_dense(
    ctx: &mut WorkerCtx,
    metrics: &Arc<ServerMetrics>,
) -> Result<(Vec<i32>, Vec<f32>)> {
    let rt = ctx.engine.runtime().clone();
    let n = ctx.engine.params.len();
    let mut host: Vec<(usize, &Tensor)> = vec![
        (n + 2, &ctx.cur_t),
        (n + 3, &ctx.pos_t),
        (n + 4, &ctx.step_t),
        (n + 5, &ctx.seeds_t),
        (n + 6, &ctx.temp_t),
    ];
    ctx.kv.bind(n, n + 1, &mut ctx.decode_resident, &mut host);
    let before = rt.transfers();
    let mut outs = ctx.decode.run_resident(&ctx.decode_resident, &host)?;
    let moved = before.delta(rt.transfers());
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?.into_tensor()?;
    let next = outs.pop().context("next")?.into_tensor()?;
    ctx.kv.update(kc, vc)?;
    let next = next.as_i32()?.to_vec();
    let logp = logp.as_f32()?.to_vec();

    metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
    metrics
        .decode_slot_steps
        .fetch_add(ctx.table.occupied() as u64, Ordering::Relaxed);
    if ctx.large_tier {
        metrics
            .large_slot_steps
            .fetch_add(ctx.table.occupied() as u64, Ordering::Relaxed);
    }
    metrics
        .decode_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .decode_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    Ok((next, logp))
}

/// Paged decode: grow any live slot about to write into an unallocated
/// block, upload the `[genb, maxblk]` block tables (O(B) bytes — the
/// paged path's only addition to per-step host traffic), bind the block
/// pools at `n`/`n+1`, and run `decode_paged`.
fn run_decode_paged(
    ctx: &mut WorkerCtx,
    metrics: &Arc<ServerMetrics>,
) -> Result<(Vec<i32>, Vec<f32>)> {
    let rt = ctx.engine.runtime().clone();
    let n = ctx.engine.params.len();
    let mut p = ctx.paged.take().expect("run_decode_paged without paged state");
    let block = p.arts.block;
    let maxblk = p.arts.maxblk;

    // growth: this step writes each live slot's K/V at `pos`; make sure
    // block pos/block is backed before the kernel runs. `sctx/block <=
    // maxblk` by pool geometry, so a live slot (pos < sctx) always has
    // a table entry to grow into; the pool is sized so genb slots at
    // maxblk blocks each fit (DESIGN.md §10), so after trie eviction
    // the allocation cannot fail.
    for idx in 0..ctx.table.capacity() {
        let Some(slot) = ctx.table.get(idx) else { continue };
        let j = slot.pos as usize / block;
        if j < maxblk && p.tables[idx][j] == 0 {
            if p.alloc.free_count() == 0 && p.use_prefix {
                p.prefix.evict(&mut p.alloc, 1)?;
            }
            p.tables[idx][j] = p
                .alloc
                .alloc()
                .context("kv pool exhausted growing a live slot (pool undersized)")?;
        }
    }
    {
        let tt = p.tables_t.as_i32_mut()?;
        for (i, table) in p.tables.iter().enumerate() {
            for (j, &b) in table.iter().enumerate() {
                tt[i * maxblk + j] = b as i32;
            }
        }
    }
    let host: Vec<(usize, &Tensor)> = vec![
        (n + 2, &p.tables_t),
        (n + 3, &ctx.cur_t),
        (n + 4, &ctx.pos_t),
        (n + 5, &ctx.step_t),
        (n + 6, &ctx.seeds_t),
        (n + 7, &ctx.temp_t),
    ];
    p.pool.bind(n, n + 1, &mut ctx.decode_resident);
    let before = rt.transfers();
    let run = p.arts.decode.run_resident(&ctx.decode_resident, &host);
    let moved = before.delta(rt.transfers());
    let mut outs = match run {
        Ok(o) => o,
        Err(e) => {
            ctx.paged = Some(p);
            return Err(e);
        }
    };
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?.into_tensor()?;
    let next = outs.pop().context("next")?.into_tensor()?;
    p.pool.update(kc, vc)?;
    // §8, paged edition: steady-state decode never moves a pool
    debug_assert!(
        moved.h2d_bytes + moved.d2h_bytes < p.pool.byte_size() / 4,
        "paged decode moved {} B — a block pool is crossing the host boundary (pool pair = {} B)",
        moved.h2d_bytes + moved.d2h_bytes,
        p.pool.byte_size()
    );
    let next = next.as_i32()?.to_vec();
    let logp = logp.as_f32()?.to_vec();

    metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
    metrics
        .decode_slot_steps
        .fetch_add(ctx.table.occupied() as u64, Ordering::Relaxed);
    if ctx.large_tier {
        metrics
            .large_slot_steps
            .fetch_add(ctx.table.occupied() as u64, Ordering::Relaxed);
    }
    metrics
        .decode_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .decode_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    ctx.paged = Some(p);
    Ok((next, logp))
}

/// Retire cancelled / deadline-expired work still waiting in a worker's
/// backlog (routed, not yet admitted to a slot).
///
/// Runs every worker iteration, so the common nothing-doomed case is a
/// single allocation-free scan; only when something must be retired is
/// the backlog rebuilt — one pass, not the O(n²) `Vec::remove` shuffle
/// per retired entry. Both passes read the clock once and agree on who
/// is expired.
fn sweep_backlog(backlog: &mut Vec<Work>, ctx: &mut WorkerCtx, metrics: &Arc<ServerMetrics>) {
    let now = Instant::now();
    if !backlog
        .iter()
        .any(|w| w.req.cancelled() || w.req.expired_at(now))
    {
        return;
    }
    let mut kept: Vec<Work> = Vec::with_capacity(backlog.len());
    for w in backlog.drain(..) {
        if w.req.cancelled() {
            cancel_work(ctx, w, metrics);
        } else if w.req.expired_at(now) {
            shed_work(ctx, w, "deadline expired before decode", metrics);
        } else {
            kept.push(w);
        }
    }
    *backlog = kept;
}

/// Retire one cancelled request owned by this worker (backlog entry or
/// released slot payload).
fn cancel_work(ctx: &mut WorkerCtx, w: Work, metrics: &Arc<ServerMetrics>) {
    metrics.routing.cancel(ctx.tier);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    finish(w.req, Event::Cancelled);
}

/// Shed one deadline-expired request owned by this worker — queued
/// (`"deadline expired before decode"`) or already decoding
/// (`"deadline expired mid-decode"`, caller releases the slot first).
/// Counts under `shed` on this tier plus the request's priority class.
fn shed_work(ctx: &mut WorkerCtx, w: Work, reason: &str, metrics: &Arc<ServerMetrics>) {
    metrics.routing.shed(ctx.tier);
    metrics.class_shed[w.req.priority.index()].fetch_add(1, Ordering::Relaxed);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    finish(w.req, Event::Failed { reason: reason.into() });
}

fn complete(
    ctx: &mut WorkerCtx,
    w: Work,
    tokens: Vec<i32>,
    mean_logprob: f32,
    metrics: &Arc<ServerMetrics>,
) {
    let Work { req, score, routed } = w;
    let e2e = req.t0.elapsed();
    metrics.e2e_latency.record(e2e);
    metrics.tier_latency[ctx.tier].record(e2e);
    metrics.routing.complete(0.0);
    // any completion is the breaker's success signal: it closes a
    // half-open breaker (successful probe) and resets failure counts
    ctx.health.record_success(ctx.tier);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    let done = Event::Done(Completion {
        id: req.id,
        tokens,
        tier: ctx.tier,
        router_score: score,
        mean_logprob,
        e2e,
        routing: routed - req.t0,
    });
    finish(req, done);
}

// ---------------------------------------------------------------------------
// Hybrid draft–verify worker (DESIGN.md §12)
// ---------------------------------------------------------------------------

/// One in-flight hybrid request. Token bookkeeping (positions are
/// 0-based sequence indices; `seq[i]` sits at position `i`):
///
/// * `seq` = prompt ++ every streamed token — the committed stream;
/// * `spos` — the small tier's KV is valid for positions `< spos`
///   (later positions may hold rejected-draft state, overwritten on the
///   next catch-up pass);
/// * `lpos` — the large tier's KV is valid for positions `< lpos` and
///   `seq[lpos..]` is the *unverified tail*: streamed (local-accepted)
///   tokens the large tier has not consumed yet. After any successful
///   verify call `lpos == seq.len() - 1` (the tail is empty and only
///   the newest token awaits the next call).
struct HybridLane {
    work: Work,
    seq: Vec<i32>,
    answer: Vec<i32>,
    logprob_sum: f32,
    spos: usize,
    lpos: usize,
    /// Quality target driving the escalation policy
    /// ([`crate::policy::should_verify`]); unset requests default to 1.0
    /// (always verify — byte-identical to large-only greedy decoding).
    quality: f32,
    seed: u32,
}

/// One tier's engine-side state inside the hybrid worker: a private
/// block pool (no cross-request prefix trie — lanes always prefill into
/// fresh blocks; the pool is sized for `genb` full-context lanes, so
/// allocation cannot fail) plus the resident maps mirroring
/// [`WorkerCtx`]'s.
struct HybridEngine {
    engine: LmEngine,
    arts: PagedArtifacts,
    pool: PagedKvCache,
    alloc: BlockAllocator,
    /// Per-lane block tables `[genb][maxblk]`; entry 0 = unallocated.
    tables: Vec<Vec<u32>>,
    tables_t: Tensor,
    prefill: Arc<Exec>,
    /// Prefill admission bucket sizes (ascending, `<= genb`).
    buckets: Vec<usize>,
    prefill_resident: HashMap<usize, Arc<xla::PjRtBuffer>>,
    decode_resident: HashMap<usize, Arc<xla::PjRtBuffer>>,
}

impl HybridEngine {
    /// Back position `pos` of lane `idx` with a pool block before a
    /// kernel writes KV there (same growth rule as [`run_decode_paged`];
    /// fresh-blocks-only pool geometry makes exhaustion impossible).
    fn grow(&mut self, idx: usize, pos: usize) -> Result<()> {
        let j = pos / self.arts.block;
        if j < self.arts.maxblk && self.tables[idx][j] == 0 {
            self.tables[idx][j] = self
                .alloc
                .alloc()
                .context("hybrid pool exhausted growing a lane (pool undersized)")?;
        }
        Ok(())
    }

    /// Refill and return the `[genb, maxblk]` block-table tensor.
    fn fill_tables(&mut self) -> Result<&Tensor> {
        let maxblk = self.arts.maxblk;
        let tt = self.tables_t.as_i32_mut()?;
        for (i, table) in self.tables.iter().enumerate() {
            for (j, &b) in table.iter().enumerate() {
                tt[i * maxblk + j] = b as i32;
            }
        }
        Ok(&self.tables_t)
    }

    /// Release lane `idx`'s blocks back to the pool.
    fn release(&mut self, idx: usize) -> Result<()> {
        release_table(&mut self.tables[idx], &mut self.alloc)
    }
}

/// Everything the hybrid worker owns, on the supervisor's side of the
/// unwind boundary (mirrors [`WorkerCtx`]).
struct HybridCtx {
    /// Small (cheapest) tier: drafts tokens from its own KV state.
    draft: HybridEngine,
    /// Large (most expensive) tier: batch-verifies drafted blocks.
    verify: HybridEngine,
    varts: VerifyArtifacts,
    /// Verify bucket sizes (ascending) and the largest one.
    vbuckets: Vec<usize>,
    max_k: usize,
    lanes: Vec<Option<HybridLane>>,
    breaker: VerifyBreaker,
    ledger: hybrid::Ledger,
    /// Index of the most expensive tier — hybrid completions are
    /// attributed to it (the stream is pinned to its output).
    tier: usize,
    depth: Arc<AtomicU64>,
    health: Arc<FleetHealth>,
    // decode/verify-input scratch, refilled in place per call
    cur_t: Tensor,
    pos_t: Tensor,
    step_t: Tensor,
    seeds_t: Tensor,
    temp_t: Tensor,
}

impl HybridCtx {
    fn occupied(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Release lane `idx`'s blocks on **both** tiers.
    fn release_lane(&mut self, idx: usize) -> Result<()> {
        self.draft.release(idx)?;
        self.verify.release(idx)
    }
}

/// What one round does with one lane.
#[derive(Clone, Copy, PartialEq)]
enum LanePlan {
    /// Draft `gamma` fresh tokens, then verify the unverified tail plus
    /// the drafts in one `verify@k` call (`k = pending + 1 + gamma`).
    Verify { k: usize, gamma: usize },
    /// The unverified tail outgrew every verify bucket: feed `k` tail
    /// tokens through `verify@k` purely to advance the large KV
    /// (outputs ignored, nothing emitted).
    Sync { k: usize },
    /// Draft `gamma` tokens and stream them unverified (open breaker
    /// degradation; `degraded` distinguishes it from a policy skip).
    Local { gamma: usize, degraded: bool },
}

/// How [`lane_emit`] left the lane.
enum LaneEnd {
    Alive,
    /// Stop rule hit (EOS / token budget / context edge) — complete.
    Finished,
    /// The client dropped its handle — cancel.
    Dead,
}

/// Stream one token to a lane, enforcing exactly the routed decoder's
/// stop rules ([`decode_step`]): EOS and budget/context checks fire
/// *before* the token is appended, so a hybrid stream truncates at the
/// same point a routed large-tier stream would.
fn lane_emit(l: &mut HybridLane, t: i32, lp: f32, amax: usize, sctx: usize) -> LaneEnd {
    let n = l.answer.len();
    let plen = l.seq.len() - n;
    if t == tok::EOS || n >= l.work.req.token_limit(amax) || context_full(plen + n, sctx) {
        return LaneEnd::Finished;
    }
    if l.work.req.tx.send(Event::Token { token: t, logprob: lp }).is_err() {
        return LaneEnd::Dead;
    }
    l.answer.push(t);
    l.seq.push(t);
    l.logprob_sum += lp;
    LaneEnd::Alive
}

/// Terminal `Done` for a finished hybrid lane (mirrors [`complete`]).
fn hybrid_complete(ctx: &HybridCtx, lane: HybridLane, metrics: &Arc<ServerMetrics>) {
    let HybridLane { work, answer, logprob_sum, .. } = lane;
    let Work { req, score, routed } = work;
    let mean = logprob_sum / answer.len().max(1) as f32;
    let e2e = req.t0.elapsed();
    metrics.e2e_latency.record(e2e);
    metrics.tier_latency[ctx.tier].record(e2e);
    metrics.routing.complete(0.0);
    ctx.health.record_success(ctx.tier);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    let done = Event::Done(Completion {
        id: req.id,
        tokens: answer,
        tier: ctx.tier,
        router_score: score,
        mean_logprob: mean,
        e2e,
        routing: routed - req.t0,
    });
    finish(req, done);
}

/// Terminal `Cancelled` for a hybrid lane or backlog entry.
fn hybrid_cancel(ctx: &HybridCtx, w: Work, metrics: &Arc<ServerMetrics>) {
    metrics.routing.cancel(ctx.tier);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    finish(w.req, Event::Cancelled);
}

/// Terminal `Failed` for deadline-expired hybrid work (mirrors
/// [`shed_work`]): counted under `shed` and the request's priority
/// class so the brownout controller sees it.
fn hybrid_shed(ctx: &HybridCtx, w: Work, reason: &str, metrics: &Arc<ServerMetrics>) {
    metrics.routing.shed(ctx.tier);
    metrics.class_shed[w.req.priority.index()].fetch_add(1, Ordering::Relaxed);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    finish(w.req, Event::Failed { reason: reason.into() });
}

/// Retire cancelled / deadline-expired work queued for the hybrid
/// worker (mirrors [`sweep_backlog`]).
fn hybrid_sweep(backlog: &mut Vec<Work>, ctx: &HybridCtx, metrics: &Arc<ServerMetrics>) {
    let now = Instant::now();
    if !backlog
        .iter()
        .any(|w| w.req.cancelled() || w.req.expired_at(now))
    {
        return;
    }
    let mut kept: Vec<Work> = Vec::with_capacity(backlog.len());
    for w in backlog.drain(..) {
        if w.req.cancelled() {
            hybrid_cancel(ctx, w, metrics);
        } else if w.req.expired_at(now) {
            hybrid_shed(ctx, w, "deadline expired before decode", metrics);
        } else {
            kept.push(w);
        }
    }
    *backlog = kept;
}

/// The hybrid worker's supervisor thread: mirrors [`worker_thread`]'s
/// catch-unwind/respawn protocol, with one twist — requests orphaned by
/// a death are stripped of their hybrid flag before the requeue, so the
/// retry lands on the classic routed path instead of bouncing off the
/// same failure. The same contract holds past the respawn budget:
/// unlike a tier replica (whose exhausted supervisor terminally fails
/// arrivals — siblings still cover the tier), the hybrid worker has no
/// sibling, so its terminal state bounces arrivals back through
/// ingress as routed requests instead of failing them.
fn hybrid_thread(cfg: ServeConfig, links: WorkerLinks) -> Result<()> {
    let small = cfg.tiers[0].model.clone();
    let large = cfg.tiers[cfg.tiers.len() - 1].model.clone();
    let tier = cfg.tiers.len() - 1;
    // one PJRT client for both engines: unlike tier replicas (separate
    // address spaces by design), draft and verify are one worker's two
    // halves and share a runtime
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let g = rt.manifest.globals;
    let make_engine = |model: &str| -> Result<HybridEngine> {
        let engine =
            LmEngine::load(rt.clone(), model, &cfg.run_dir.join("params").join(model))?;
        let arts = engine
            .paged_artifacts()?
            .with_context(|| format!("{model}: hybrid decode needs the paged-KV artifacts"))?;
        let meta = *rt.manifest.model(model)?;
        let pool = PagedKvCache::zeros_on_device(
            &rt,
            meta.layers,
            arts.nblk,
            arts.block,
            meta.heads,
            meta.headdim,
        )?;
        let alloc = BlockAllocator::new(arts.nblk);
        let prefill = rt.exec(&format!("{model}.prefill"))?;
        let buckets: Vec<usize> = rt
            .manifest
            .prefill_buckets(model)
            .into_iter()
            .filter(|&b| b <= g.genb)
            .collect();
        let prefill_resident = engine.params.resident_map();
        let decode_resident = prefill_resident.clone();
        let maxblk = arts.maxblk;
        Ok(HybridEngine {
            engine,
            pool,
            alloc,
            tables: vec![vec![0u32; maxblk]; g.genb],
            tables_t: Tensor::i32(vec![g.genb, maxblk], vec![0; g.genb * maxblk]),
            prefill,
            buckets,
            prefill_resident,
            decode_resident,
            arts,
        })
    };
    let draft = make_engine(&small)?;
    let verify_eng = make_engine(&large)?;
    let varts = verify_eng
        .engine
        .verify_artifacts()?
        .with_context(|| format!("{large}: hybrid decode needs the verify@K artifacts"))?;
    let vbuckets: Vec<usize> = varts.execs.iter().map(|(k, _)| *k).collect();
    let max_k = varts.max_k();
    anyhow::ensure!(max_k >= 1, "{large}: empty verify@K family");
    // warm the largest verify bucket (the steady-state call)
    rt.exec(&format!("{large}.verify@{max_k}"))?;
    let mut ctx = HybridCtx {
        draft,
        verify: verify_eng,
        varts,
        vbuckets,
        max_k,
        lanes: (0..g.genb).map(|_| None).collect(),
        breaker: VerifyBreaker::new(),
        ledger: hybrid::Ledger::default(),
        tier,
        depth: links.depth.clone(),
        health: links.health.clone(),
        cur_t: Tensor::i32(vec![g.genb], vec![tok::PAD; g.genb]),
        pos_t: Tensor::i32(vec![g.genb], vec![0; g.genb]),
        step_t: Tensor::i32(vec![], vec![1]),
        seeds_t: Tensor::u32(vec![g.genb], vec![0; g.genb]),
        temp_t: Tensor::f32(vec![], vec![cfg.temp]),
    };
    let _ = links.ready.send(());
    let mut backlog: Vec<Work> = Vec::new();
    let mut shutdown = false;
    let mut deaths = 0u32;
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            hybrid_loop(&cfg, &mut ctx, &links, &mut backlog, &mut shutdown)
        }));
        let err = match run {
            Ok(Ok(())) => return Ok(()),
            Ok(Err(e)) => format!("error: {e:#}"),
            Err(p) => match p.downcast_ref::<&str>() {
                Some(s) => format!("panic: {s}"),
                None => match p.downcast_ref::<String>() {
                    Some(s) => format!("panic: {s}"),
                    None => "panic".into(),
                },
            },
        };
        deaths += 1;
        links.metrics.worker_deaths.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "[serve] hybrid worker ({small}+{large}) died ({err}); {}",
            if deaths < MAX_RESPAWNS { "respawning" } else { "respawn budget exhausted" }
        );
        // strip the hybrid flag before retiring: a requeued request
        // re-resolves onto the routed path (the flag is what steered it
        // here, and whatever killed the loop would kill the retry too)
        for i in 0..ctx.lanes.len() {
            if let Some(lane) = ctx.lanes[i].take() {
                let mut w = lane.work;
                w.req.hybrid = false;
                retire_orphan(&cfg, w, &links, tier, shutdown);
            }
        }
        for mut w in backlog.drain(..) {
            w.req.hybrid = false;
            retire_orphan(&cfg, w, &links, tier, shutdown);
        }
        // reset both pools' allocation state wholesale: every lane is
        // gone, and a reused block's stale contents are harmless (any
        // attended position is rewritten before it is read — the same
        // argument that makes normal block reuse sound)
        for eng in [&mut ctx.draft, &mut ctx.verify] {
            eng.alloc = BlockAllocator::new(eng.arts.nblk);
            for t in &mut eng.tables {
                t.iter_mut().for_each(|b| *b = 0);
            }
        }
        ctx.breaker = VerifyBreaker::new();
        // a death mid-round can leave the ledger between records —
        // restart its invariants from a clean slate with the lanes
        ctx.ledger = hybrid::Ledger::default();
        if deaths >= MAX_RESPAWNS {
            break;
        }
    }
    // respawn budget exhausted: the hybrid worker stays down, but the
    // routed fleet is still healthy — bounce arrivals back through
    // ingress with the hybrid flag stripped (the DecodeMode contract:
    // hybrid unavailability degrades to classic routing, it does not
    // fail requests) until shutdown drains the channel
    loop {
        let msg = if shutdown {
            match links.rx.try_recv() {
                Ok(m) => m,
                Err(_) => break,
            }
        } else {
            match links.rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            }
        };
        match msg {
            WorkMsg::Work(w) => {
                links.depth.fetch_sub(1, Ordering::Relaxed);
                let mut req = w.req;
                req.hybrid = false;
                if req.cancelled() {
                    links.metrics.routing.cancel(tier);
                    finish(req, Event::Cancelled);
                    continue;
                }
                // no retry-budget charge: the request was never decoded
                match links.ingress.send(RouterMsg::Req(req)) {
                    Ok(()) => {}
                    // the router is gone (shutdown raced the bounce):
                    // nothing left to serve the request
                    Err(mpsc::SendError(RouterMsg::Req(r))) => {
                        links.metrics.routing.fail(tier);
                        finish(
                            r,
                            Event::Failed {
                                reason: "hybrid worker: respawn budget exhausted".into(),
                            },
                        );
                    }
                    Err(_) => {}
                }
            }
            WorkMsg::Shutdown => shutdown = true,
        }
    }
    Err(anyhow::anyhow!(
        "hybrid worker died {deaths} times; respawn budget exhausted"
    ))
}

/// One supervised hybrid serve loop (mirrors [`serve_loop`]): pull
/// work, sweep, admit on both tiers, then run one draft–verify round
/// over the occupied lanes — until shutdown completes its drain. Owns
/// no request state (everything lives in `ctx`/`backlog` on the
/// supervisor's side of the unwind boundary).
fn hybrid_loop(
    cfg: &ServeConfig,
    ctx: &mut HybridCtx,
    links: &WorkerLinks,
    backlog: &mut Vec<Work>,
    shutdown: &mut bool,
) -> Result<()> {
    let metrics = &links.metrics;
    let genb = ctx.lanes.len();
    while !(*shutdown && ctx.occupied() == 0 && backlog.is_empty()) {
        links.heartbeat.fetch_add(1, Ordering::Relaxed);

        // 1. pull work (non-blocking while busy; blocking when idle)
        loop {
            let msg = if ctx.occupied() == 0 && backlog.is_empty() && !*shutdown {
                match links.rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        *shutdown = true;
                        break;
                    }
                }
            } else {
                match links.rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                WorkMsg::Work(w) => backlog.push(w),
                WorkMsg::Shutdown => *shutdown = true,
            }
        }

        // 2. retire cancelled / expired queued work before it costs two
        // prefills, and free cancelled / deadline-expired lanes on both
        // tiers (a lane past its deadline must not burn another
        // draft–verify round)
        hybrid_sweep(backlog, ctx, metrics);
        let now = Instant::now();
        for idx in 0..genb {
            let Some(l) = ctx.lanes[idx].as_ref() else { continue };
            if l.work.req.cancelled() {
                let lane = ctx.lanes[idx].take().expect("checked occupied");
                ctx.release_lane(idx)?;
                hybrid_cancel(ctx, lane.work, metrics);
            } else if l.work.req.expired_at(now) {
                let lane = ctx.lanes[idx].take().expect("checked occupied");
                ctx.release_lane(idx)?;
                hybrid_shed(ctx, lane.work, "deadline expired mid-decode", metrics);
            }
        }

        // 3. admission per batching mode (continuous: lanes join
        // mid-flight between rounds)
        let can_admit = match cfg.mode {
            BatchMode::Continuous => true,
            BatchMode::RunToCompletion => ctx.occupied() == 0,
        };
        if can_admit && !backlog.is_empty() && ctx.occupied() < genb {
            let free: Vec<usize> = (0..genb).filter(|&i| ctx.lanes[i].is_none()).collect();
            let n_new = backlog.len().min(free.len());
            let admitted: Vec<Work> = backlog.drain(..n_new).collect();
            hybrid_admit(ctx, &free[..n_new], admitted, metrics)?;
        }

        // 4. one draft–verify round over the occupied lanes
        if ctx.occupied() > 0 {
            hybrid_round(ctx, metrics)?;
            debug_assert_eq!(ctx.ledger.check(), Ok(()));
        }
    }
    Ok(())
}

/// One draft–verify round (DESIGN.md §12), five phases over the
/// occupied lanes:
///
/// 1. **plan** — per lane: how many tokens to draft and whether the
///    round ends in a `verify@k` call, a KV-sync call (tail catch-up,
///    nothing emitted), or an unverified local accept;
/// 2. **draft** — batched small-tier paged-decode steps; a lane whose
///    small KV lags the committed stream (`spos < seq.len() - 1`)
///    feeds committed tokens first, then feeds its own drafts;
/// 3. **escalation policy** — a lane with no unverified tail may skip
///    this round's verify call when every draft cleared the
///    quality-indexed confidence threshold
///    ([`crate::policy::should_verify`]);
/// 4. **verify** — one `verify@k` call per distinct bucket size over
///    the participating lanes (non-participating rows are masked into
///    the null block: zero table row, position 0, PAD tokens), then
///    longest-prefix acceptance plus the correction token
///    ([`hybrid::resolve_verify`]);
/// 5. **resolve** — stream accepted/local tokens under the routed stop
///    rules, advance the `spos`/`lpos` validity markers, retire
///    finished/dead lanes on both tiers.
fn hybrid_round(ctx: &mut HybridCtx, metrics: &Arc<ServerMetrics>) -> Result<()> {
    let rt = ctx.verify.engine.runtime().clone();
    let g = rt.manifest.globals;
    let genb = ctx.lanes.len();
    let amax = g.amax;
    let sctx = g.sctx;
    let degraded_round = !ctx.breaker.allow(Instant::now());
    // brownout L2: one level read per round — every lane in the round
    // sees the same actuator state (identity at levels 0 and 1)
    let level = metrics.brownout_level.load(Ordering::Relaxed) as u8;

    // --- phase 1: plan ---
    let mut plans: Vec<Option<LanePlan>> = vec![None; genb];
    let mut pend: Vec<usize> = vec![0; genb];
    for idx in 0..genb {
        let Some(lane) = ctx.lanes[idx].as_ref() else { continue };
        let len = lane.seq.len();
        let pending = len - 1 - lane.lpos;
        pend[idx] = pending;
        plans[idx] = if degraded_round {
            // open breaker: draft blocks locally; a lane out of draft
            // headroom idles until the half-open probe heals the path
            let gamma = (ctx.max_k - 1).min((sctx - 1).saturating_sub(len));
            (gamma > 0).then_some(LanePlan::Local { gamma, degraded: true })
        } else {
            let room = hybrid::context_room(lane.lpos, sctx);
            let full = room.min(ctx.max_k);
            // brownout L2: halve the verify-bucket bound (shrinking
            // both k and the draft-block γ = k - 1 - pending) so the
            // large tier's passes thin out under sustained pressure —
            // unless no smaller bucket can still make progress, in
            // which case the full bound keeps the lane moving
            let capped = crate::policy::brownout_gamma(level, full);
            let bound = match hybrid::largest_bucket_at_most(&ctx.vbuckets, capped) {
                Some(k) if k > pending => capped,
                _ => full,
            };
            match hybrid::largest_bucket_at_most(&ctx.vbuckets, bound) {
                // k covers the tail (pending), the newest token, and
                // k - 1 - pending fresh drafts
                Some(k) if k > pending => Some(LanePlan::Verify { k, gamma: k - 1 - pending }),
                // the unverified tail outgrew every verify bucket (only
                // possible after degraded local accepts): sync the
                // large KV forward over committed tokens instead
                _ => hybrid::largest_bucket_at_most(&ctx.vbuckets, pending.min(room))
                    .map(|k| LanePlan::Sync { k }),
            }
        };
    }

    // --- phase 2: draft (batched small-tier decode steps) ---
    let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); genb];
    let mut dlps: Vec<Vec<f32>> = vec![Vec::new(); genb];
    let mut fed: Vec<usize> = vec![0; genb];
    let mut want: Vec<usize> = vec![0; genb];
    for idx in 0..genb {
        if let Some(LanePlan::Verify { gamma, .. } | LanePlan::Local { gamma, .. }) = plans[idx] {
            want[idx] = gamma;
            fed[idx] = ctx.lanes[idx].as_ref().expect("planned lane").spos;
        }
    }
    let nd_params = ctx.draft.engine.params.len();
    loop {
        let active: Vec<usize> =
            (0..genb).filter(|&i| want[i] > 0 && drafts[i].len() < want[i]).collect();
        if active.is_empty() {
            break;
        }
        // back the written position with a pool block, per active lane
        for &idx in &active {
            ctx.draft.grow(idx, fed[idx])?;
        }
        {
            let cur = ctx.cur_t.as_i32_mut()?;
            let pos = ctx.pos_t.as_i32_mut()?;
            let seeds = ctx.seeds_t.as_u32_mut()?;
            for i in 0..genb {
                cur[i] = tok::PAD;
                pos[i] = 0;
                seeds[i] = 0;
            }
            for &idx in &active {
                let lane = ctx.lanes[idx].as_ref().expect("active lane");
                let f = fed[idx];
                let len = lane.seq.len();
                // catch-up feeds the committed stream; past the stream
                // end the lane stream-feeds its own drafts
                cur[idx] = if f < len { lane.seq[f] } else { drafts[idx][f - len] };
                pos[idx] = f as i32;
                seeds[idx] = lane.seed;
            }
        }
        {
            // unlike the routed worker (where every occupied slot steps
            // every iteration), an occupied-but-inactive lane here must
            // be masked into the null block or the step would overwrite
            // its committed KV at position 0
            let maxblk = ctx.draft.arts.maxblk;
            let tt = ctx.draft.tables_t.as_i32_mut()?;
            for v in tt.iter_mut() {
                *v = 0;
            }
            for &idx in &active {
                for j in 0..maxblk {
                    tt[idx * maxblk + j] = ctx.draft.tables[idx][j] as i32;
                }
            }
        }
        let host: Vec<(usize, &Tensor)> = vec![
            (nd_params + 2, &ctx.draft.tables_t),
            (nd_params + 3, &ctx.cur_t),
            (nd_params + 4, &ctx.pos_t),
            (nd_params + 5, &ctx.step_t),
            (nd_params + 6, &ctx.seeds_t),
            (nd_params + 7, &ctx.temp_t),
        ];
        ctx.draft.pool.bind(nd_params, nd_params + 1, &mut ctx.draft.decode_resident);
        let before = rt.transfers();
        let mut outs = ctx.draft.arts.decode.run_resident(&ctx.draft.decode_resident, &host)?;
        let moved = before.delta(rt.transfers());
        let vc = outs.pop().context("hybrid draft: vcache")?;
        let kc = outs.pop().context("hybrid draft: kcache")?;
        let logp = outs.pop().context("hybrid draft: logp")?.into_tensor()?;
        let next = outs.pop().context("hybrid draft: next")?.into_tensor()?;
        ctx.draft.pool.update(kc, vc)?;
        let next = next.as_i32()?;
        let logp = logp.as_f32()?;
        for &idx in &active {
            let len = ctx.lanes[idx].as_ref().expect("active lane").seq.len();
            // the step at position `fed` predicts position `fed + 1`:
            // a draft iff that lands past the committed stream
            if fed[idx] + 1 >= len {
                drafts[idx].push(next[idx]);
                dlps[idx].push(logp[idx]);
            }
            fed[idx] += 1;
        }
        metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
        metrics.decode_slot_steps.fetch_add(active.len() as u64, Ordering::Relaxed);
        metrics.decode_h2d_bytes.fetch_add(moved.h2d_bytes, Ordering::Relaxed);
        metrics.decode_d2h_bytes.fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    }

    // --- phase 3: escalation policy ---
    // Only a lane with no unverified tail may skip its verify call (a
    // tail means a previous round already deferred large-tier work),
    // and only when it actually drafted something to stream.
    for idx in 0..genb {
        if let Some(LanePlan::Verify { gamma, .. }) = plans[idx] {
            if gamma > 0 && pend[idx] == 0 {
                let lane = ctx.lanes[idx].as_ref().expect("planned lane");
                let conf = dlps[idx].iter().copied().fold(f32::INFINITY, f32::min);
                // brownout L2: judge escalation against the capped
                // quality target so verify passes are skipped more
                // aggressively under pressure (identity below level 2)
                let q = crate::policy::brownout_escalation_quality(level, lane.quality);
                if !crate::policy::should_verify(q, conf) {
                    plans[idx] = Some(LanePlan::Local { gamma, degraded: false });
                }
            }
        }
    }

    // --- phase 4: verify, one call per distinct bucket size ---
    let nv = ctx.verify.engine.params.len();
    let mut ks: Vec<usize> = plans
        .iter()
        .filter_map(|p| match p {
            Some(LanePlan::Verify { k, .. } | LanePlan::Sync { k }) => Some(*k),
            _ => None,
        })
        .collect();
    ks.sort_unstable();
    ks.dedup();
    for k in ks {
        let group: Vec<usize> = (0..genb)
            .filter(|&i| {
                matches!(
                    plans[i],
                    Some(LanePlan::Verify { k: kk, .. } | LanePlan::Sync { k: kk }) if kk == k
                )
            })
            .collect();
        let exec = ctx
            .varts
            .execs
            .iter()
            .find(|(kk, _)| *kk == k)
            .map(|(_, e)| e.clone())
            .expect("planned k comes from vbuckets");
        // back the k written positions with pool blocks, per lane
        for &idx in &group {
            let lpos = ctx.lanes[idx].as_ref().expect("participant").lpos;
            for p in lpos..lpos + k {
                ctx.verify.grow(idx, p)?;
            }
        }
        // masked inputs: non-participating rows aim at the null block
        let mut toks = vec![tok::PAD; genb * k];
        {
            let posv = ctx.pos_t.as_i32_mut()?;
            let seeds = ctx.seeds_t.as_u32_mut()?;
            for i in 0..genb {
                posv[i] = 0;
                seeds[i] = 0;
            }
            for &idx in &group {
                let lane = ctx.lanes[idx].as_ref().expect("participant");
                let row: Vec<i32> = match plans[idx] {
                    Some(LanePlan::Sync { .. }) => lane.seq[lane.lpos..lane.lpos + k].to_vec(),
                    _ => {
                        // unverified tail ++ this round's drafts
                        let mut r = lane.seq[lane.lpos..].to_vec();
                        r.extend_from_slice(&drafts[idx]);
                        r
                    }
                };
                debug_assert_eq!(row.len(), k, "verify row must fill the bucket exactly");
                toks[idx * k..(idx + 1) * k].copy_from_slice(&row);
                posv[idx] = lane.lpos as i32;
                seeds[idx] = lane.seed;
            }
        }
        {
            let maxblk = ctx.verify.arts.maxblk;
            let tt = ctx.verify.tables_t.as_i32_mut()?;
            for v in tt.iter_mut() {
                *v = 0;
            }
            for &idx in &group {
                for j in 0..maxblk {
                    tt[idx * maxblk + j] = ctx.verify.tables[idx][j] as i32;
                }
            }
        }
        let toks_t = Tensor::i32(vec![genb, k], toks);
        let host: Vec<(usize, &Tensor)> = vec![
            (nv + 2, &ctx.verify.tables_t),
            (nv + 3, &toks_t),
            (nv + 4, &ctx.pos_t),
            (nv + 5, &ctx.step_t),
            (nv + 6, &ctx.seeds_t),
            (nv + 7, &ctx.temp_t),
        ];
        ctx.verify.pool.bind(nv, nv + 1, &mut ctx.verify.decode_resident);
        let before = rt.transfers();
        let run = exec.run_resident(&ctx.verify.decode_resident, &host);
        let moved = before.delta(rt.transfers());
        metrics.decode_h2d_bytes.fetch_add(moved.h2d_bytes, Ordering::Relaxed);
        metrics.decode_d2h_bytes.fetch_add(moved.d2h_bytes, Ordering::Relaxed);
        let mut outs = match run {
            Ok(o) => o,
            Err(e) => {
                // large-tier failure: one breaker notch, and this
                // round's would-be-verified drafts degrade to an
                // unverified local accept (sync lanes retry next round)
                ctx.breaker.record_failure(Instant::now());
                eprintln!("[serve] hybrid verify@{k} failed ({e:#}); degrading to local accept");
                for &idx in &group {
                    plans[idx] = match plans[idx] {
                        Some(LanePlan::Verify { gamma, .. }) => {
                            Some(LanePlan::Local { gamma, degraded: true })
                        }
                        _ => None,
                    };
                }
                continue;
            }
        };
        ctx.breaker.record_success();
        let vc = outs.pop().context("hybrid verify: vcache")?;
        let kc = outs.pop().context("hybrid verify: kcache")?;
        let logp = outs.pop().context("hybrid verify: logp")?.into_tensor()?;
        let next = outs.pop().context("hybrid verify: next")?.into_tensor()?;
        ctx.verify.pool.update(kc, vc)?;
        let next = next.as_i32()?.to_vec();
        let lps = logp.as_f32()?.to_vec();
        for &idx in &group {
            let mut lane = ctx.lanes[idx].take().expect("participant");
            match plans[idx] {
                Some(LanePlan::Sync { .. }) => {
                    // outputs ignored: the call only advanced the large
                    // KV over k already-committed tail tokens
                    lane.lpos += k;
                    ctx.ledger.record_verify(0, 0, 0);
                    metrics.verify_calls.fetch_add(1, Ordering::Relaxed);
                    ctx.lanes[idx] = Some(lane);
                }
                Some(LanePlan::Verify { .. }) => {
                    let pending = pend[idx];
                    let nd = drafts[idx].len();
                    let old_len = lane.seq.len();
                    // row idx, positions past the tail: the large
                    // tier's verdict on the newest token + the drafts
                    let verified = &next[idx * k + pending..(idx + 1) * k];
                    let (a, emit) = hybrid::resolve_verify(&drafts[idx], verified);
                    let mut end = LaneEnd::Alive;
                    let mut streamed = 0usize;
                    for (j, &t) in emit.iter().enumerate() {
                        end = lane_emit(&mut lane, t, lps[idx * k + pending + j], amax, sctx);
                        match end {
                            LaneEnd::Alive => streamed += 1,
                            _ => break,
                        }
                    }
                    // `lane_emit` may truncate the accepted prefix
                    // (EOS / budget / context stop, dead client): only
                    // drafts actually streamed count as accepted, or
                    // `emitted >= accepted` in the ledger breaks
                    let accepted = a.min(streamed);
                    ctx.ledger.record_verify(nd, accepted, streamed);
                    metrics.draft_tokens.fetch_add(nd as u64, Ordering::Relaxed);
                    metrics.draft_accepted.fetch_add(accepted as u64, Ordering::Relaxed);
                    metrics.verify_calls.fetch_add(1, Ordering::Relaxed);
                    metrics.hybrid_emitted.fetch_add(streamed as u64, Ordering::Relaxed);
                    match end {
                        LaneEnd::Alive => {
                            // tail fully consumed: only the newest
                            // token awaits the next call
                            lane.lpos = old_len + a;
                            if nd > 0 {
                                // the small KV saw drafts, not the
                                // correction token: valid through the
                                // last *accepted* drafted-from position
                                lane.spos = old_len + a.min(nd - 1);
                            }
                            ctx.lanes[idx] = Some(lane);
                        }
                        LaneEnd::Finished => {
                            ctx.release_lane(idx)?;
                            hybrid_complete(ctx, lane, metrics);
                        }
                        LaneEnd::Dead => {
                            ctx.release_lane(idx)?;
                            hybrid_cancel(ctx, lane.work, metrics);
                        }
                    }
                }
                _ => unreachable!("verify group holds only Verify/Sync plans"),
            }
        }
    }

    // --- phase 5: local accepts (policy skips + degraded blocks) ---
    for idx in 0..genb {
        let Some(LanePlan::Local { degraded, .. }) = plans[idx] else { continue };
        let nd = drafts[idx].len();
        if nd == 0 {
            continue;
        }
        let mut lane = ctx.lanes[idx].take().expect("planned lane");
        let old_len = lane.seq.len();
        let mut end = LaneEnd::Alive;
        let mut streamed = 0usize;
        for j in 0..nd {
            end = lane_emit(&mut lane, drafts[idx][j], dlps[idx][j], amax, sctx);
            match end {
                LaneEnd::Alive => streamed += 1,
                _ => break,
            }
        }
        ctx.ledger.record_local(nd, streamed, degraded);
        metrics.draft_tokens.fetch_add(nd as u64, Ordering::Relaxed);
        metrics.draft_local_accepted.fetch_add(streamed as u64, Ordering::Relaxed);
        metrics.hybrid_emitted.fetch_add(streamed as u64, Ordering::Relaxed);
        if degraded {
            metrics.hybrid_degraded_blocks.fetch_add(1, Ordering::Relaxed);
        }
        match end {
            LaneEnd::Alive => {
                // every draft is committed stream now; the small KV is
                // valid through the last drafted-from position, and the
                // unverified tail (lpos unchanged) grew by `nd`
                lane.spos = old_len + nd - 1;
                ctx.lanes[idx] = Some(lane);
            }
            LaneEnd::Finished => {
                ctx.release_lane(idx)?;
                hybrid_complete(ctx, lane, metrics);
            }
            LaneEnd::Dead => {
                ctx.release_lane(idx)?;
                hybrid_cancel(ctx, lane.work, metrics);
            }
        }
    }
    Ok(())
}

/// Dual-tier admission: bucketed prefill on **both** engines into fresh
/// pool blocks, with the lane's first token (and its logprob) taken
/// from the **large** prefill only — the stream is pinned to the large
/// tier from token zero.
fn hybrid_admit(
    ctx: &mut HybridCtx,
    free: &[usize],
    work: Vec<Work>,
    metrics: &Arc<ServerMetrics>,
) -> Result<()> {
    let t0 = Instant::now();
    let rt = ctx.verify.engine.runtime().clone();
    let before = rt.transfers();
    let g = rt.manifest.globals;
    let n_req = work.len();
    debug_assert!(n_req <= free.len());

    // allocate fresh block tables for the prompt on both tiers
    for (w, &slot) in work.iter().zip(free) {
        let plen = w.req.prompt.len();
        anyhow::ensure!(
            plen <= g.sprompt,
            "admitted prompt of {plen} tokens exceeds the {}-token window",
            g.sprompt
        );
        for eng in [&mut ctx.draft, &mut ctx.verify] {
            let need = blocks_needed(plen, eng.arts.block).min(eng.arts.maxblk);
            let mut table = vec![0u32; eng.arts.maxblk];
            for entry in table.iter_mut().take(need) {
                *entry = eng
                    .alloc
                    .alloc()
                    .context("hybrid pool exhausted at admission (pool undersized)")?;
            }
            eng.tables[slot] = table;
        }
    }

    // shared prefill inputs (identical for both tiers)
    let bucket = |eng: &HybridEngine| eng.buckets.iter().find(|&&b| b >= n_req).copied();
    let mut firsts: Vec<(i32, f32)> = vec![(0, 0.0); n_req];
    for (ei, eng) in [&mut ctx.draft, &mut ctx.verify].into_iter().enumerate() {
        let (bsz, prefill) = match bucket(eng) {
            Some(b) if b < g.genb => {
                (b, rt.exec(&format!("{}.prefill@{b}", eng.engine.name))?)
            }
            _ => (g.genb, eng.prefill.clone()),
        };
        let (ib, install) = eng
            .arts
            .install_for(bsz)
            .with_context(|| format!("no kv_install_paged bucket covers {bsz}"))?;
        anyhow::ensure!(ib == bsz, "paged install bucket {ib} != prefill bucket {bsz}");
        let maxblk = eng.arts.maxblk;
        let mut ptoks = vec![tok::PAD; bsz * g.sprompt];
        let mut lens = vec![1i32; bsz];
        let mut seedv = vec![0u32; bsz];
        let mut dst = vec![0i32; bsz * maxblk];
        for (b, (w, &slot)) in work.iter().zip(free).enumerate() {
            let p = &w.req.prompt;
            ptoks[b * g.sprompt..b * g.sprompt + p.len()].copy_from_slice(p);
            lens[b] = p.len() as i32;
            seedv[b] = w.req.id as u32;
            let need = blocks_needed(p.len(), eng.arts.block).min(maxblk);
            for j in 0..need {
                dst[b * maxblk + j] = eng.tables[slot][j] as i32;
            }
        }
        let ptoks = Tensor::i32(vec![bsz, g.sprompt], ptoks);
        let lens_t = Tensor::i32(vec![bsz], lens);
        let seeds_t = Tensor::u32(vec![bsz], seedv);
        let host: Vec<(usize, &Tensor)> = vec![
            (eng.engine.params.len(), &ptoks),
            (eng.engine.params.len() + 1, &lens_t),
            (eng.engine.params.len() + 2, &seeds_t),
            (eng.engine.params.len() + 3, &ctx.temp_t),
        ];
        let mut outs = prefill.run_resident(&eng.prefill_resident, &host)?;
        let vc = outs.pop().context("hybrid prefill: vcache")?;
        let kc = outs.pop().context("hybrid prefill: kcache")?;
        let logp = outs.pop().context("hybrid prefill: logp")?.into_tensor()?;
        let first = outs.pop().context("hybrid prefill: next")?.into_tensor()?;
        let (Some(kb), Some(vb)) = (kc.device().cloned(), vc.device().cloned()) else {
            anyhow::bail!(
                "{}: hybrid admission needs device-resident prefill outputs",
                eng.engine.name
            );
        };
        let dst_t = Tensor::i32(vec![bsz, maxblk], dst);
        let mut resident: HashMap<usize, Arc<xla::PjRtBuffer>> = HashMap::with_capacity(4);
        eng.pool.bind(0, 1, &mut resident);
        resident.insert(2, kb);
        resident.insert(3, vb);
        let ihost: Vec<(usize, &Tensor)> = vec![(4, &dst_t)];
        let mut iouts = install.run_resident(&resident, &ihost)?;
        let pv = iouts.pop().context("hybrid install: vcache")?;
        let pk = iouts.pop().context("hybrid install: kcache")?;
        eng.pool.update(pk, pv)?;
        if ei == 1 {
            // the large tier's choices ARE the stream
            let first = first.as_i32()?;
            let logp = logp.as_f32()?;
            for b in 0..n_req {
                firsts[b] = (first[b], logp[b]);
            }
        }
    }

    // occupy lanes, streaming the large first token
    let mut prefilled = 0u64;
    for ((w, &slot), (ft, lp)) in work.into_iter().zip(free).zip(firsts) {
        let plen = w.req.prompt.len();
        prefilled += plen as u64;
        if ft == tok::EOS {
            // hybrid-served even though it never occupies a lane: its
            // completion/latency are attributed to the large tier below
            metrics.hybrid_requests.fetch_add(1, Ordering::Relaxed);
            ctx.release_lane(slot)?;
            hybrid_complete(ctx, HybridLane {
                seq: w.req.prompt.clone(),
                answer: vec![],
                logprob_sum: 0.0,
                spos: plen,
                lpos: plen,
                quality: 1.0,
                seed: w.req.id as u32,
                work: w,
            }, metrics);
            continue;
        }
        if w.req.tx.send(Event::Token { token: ft, logprob: lp }).is_err() {
            ctx.release_lane(slot)?;
            hybrid_cancel(ctx, w, metrics);
            continue;
        }
        let mut seq = w.req.prompt.clone();
        seq.push(ft);
        metrics.hybrid_requests.fetch_add(1, Ordering::Relaxed);
        ctx.lanes[slot] = Some(HybridLane {
            seq,
            answer: vec![ft],
            logprob_sum: lp,
            spos: plen,
            lpos: plen,
            quality: w.req.quality.unwrap_or(1.0),
            seed: w.req.id as u32,
            work: w,
        });
    }

    let moved = before.delta(rt.transfers());
    metrics
        .admit_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .admit_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    metrics.admissions.fetch_add(1, Ordering::Relaxed);
    metrics.admitted.fetch_add(n_req as u64, Ordering::Relaxed);
    // prefill work is counted once (the large tier's pass): the serving
    // invariant `prefill_tokens <= prompt tokens admitted` stays intact
    metrics.prefill_tokens.fetch_add(prefilled, Ordering::Relaxed);
    metrics.admit_latency.record(t0.elapsed());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tiers_defaults_and_overrides() {
        let t = parse_tiers("small:1,large:1").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].model, "small");
        assert_eq!(t[0].replicas, 1);
        assert_eq!(t[0].cost, 0.0);
        assert_eq!(t[1].cost, 1.0);

        let t = parse_tiers("nano:2:0.02, medium, large:1:1.0").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].replicas, 2);
        assert!((t[0].cost - 0.02).abs() < 1e-12);
        // omitted cost => even spacing over [0, 1]
        assert!((t[1].cost - 0.5).abs() < 1e-12);
        assert_eq!(t[1].replicas, 1);
        assert_eq!(t[2].cost, 1.0);

        // bare single tier
        let t = parse_tiers("large").unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].cost, 1.0);
    }

    #[test]
    fn parse_tiers_rejects_malformed_specs() {
        assert!(parse_tiers("").is_err());
        assert!(parse_tiers(" , ").is_err());
        assert!(parse_tiers("small:x").is_err());
        assert!(parse_tiers("small:0").is_err());
        assert!(parse_tiers("small:1:abc").is_err());
        assert!(parse_tiers("small:1:0.5:extra").is_err());
        assert!(parse_tiers("small:1:-1").is_err());
        assert!(parse_tiers("small:1:inf").is_err());
    }

    #[test]
    fn two_tier_matches_seed_semantics() {
        let t = two_tier("nano", "micro");
        assert_eq!(t[0].name, "nano");
        assert_eq!(t[0].cost, 0.0);
        assert_eq!(t[1].cost, 1.0);
        let cfg = ServeConfig::two_tier(
            PathBuf::from("a"),
            PathBuf::from("r"),
            "nano",
            "micro",
            String::new(),
            0.5,
        );
        assert_eq!(cfg.policy, TierPolicy::Ladder { thresholds: vec![0.5] });
        assert_eq!(cfg.policy.n_tiers(), Some(2));
        assert_eq!(cfg.tiers.len(), 2);
        assert_eq!(cfg.queue_cap, DEFAULT_QUEUE_CAP);
        assert!(cfg.quality_ladders.is_none());
    }

    #[test]
    fn request_builder_and_token_limits() {
        let r = Request::new(vec![1, 2, 3])
            .quality(0.7)
            .max_new_tokens(0) // recorded as-is; submit() rejects it
            .deadline(Duration::from_millis(5));
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.quality, Some(0.7));
        assert_eq!(r.max_new_tokens, Some(0), "builder must not silently promote 0 to 1");
        assert!(r.policy.is_none());

        let f = |max_new: Option<usize>| InFlight {
            id: 0,
            prompt: vec![],
            quality: None,
            policy: None,
            max_new,
            deadline: None,
            t0: Instant::now(),
            tx: mpsc::channel().0,
            cancel: Arc::new(AtomicBool::new(false)),
            retries: 0,
            hybrid: false,
            priority: Priority::Interactive,
            _admission: AdmissionGuard(Arc::new(AtomicU64::new(1))),
        };
        // default reproduces the seed's `len + 1 >= amax` stop rule
        assert_eq!(f(None).token_limit(32), 31);
        assert_eq!(f(Some(8)).token_limit(32), 8);
        // the artifact-wide cap still binds
        assert_eq!(f(Some(99)).token_limit(32), 31);
        assert_eq!(f(Some(3)).token_limit(1), 1);
    }

    #[test]
    fn context_full_reserves_the_eos_slot() {
        // sctx = 64: positions 0..=62 may hold sampled tokens; 63 is the
        // training layout's reserved EOS slot, so a slot whose *next*
        // write position is 63 must stop.
        assert!(!context_full(61, 64));
        assert!(!context_full(62, 64));
        assert!(context_full(63, 64));
        assert!(context_full(64, 64));
        // degenerate windows never underflow
        assert!(context_full(0, 1));
        assert!(context_full(0, 0));
        // a full-width prompt (pos starts at sprompt = sctx - amax) with
        // amax = 24 gets at most amax - 1 = 23 sampled tokens before the
        // stop fires: positions 40..=62 inclusive.
        let (sprompt, sctx) = (40usize, 64usize);
        let mut pos = sprompt;
        let mut sampled = 0;
        loop {
            pos += 1; // decode_step increments before the check
            if context_full(pos, sctx) {
                break;
            }
            sampled += 1;
        }
        assert_eq!(sampled, sctx - sprompt - 2); // == amax - 2 streamed after prefill's first
    }

    #[test]
    fn inflight_deadline_and_cancel_flags() {
        let cancel = Arc::new(AtomicBool::new(false));
        let req = InFlight {
            id: 0,
            prompt: vec![],
            quality: None,
            policy: None,
            max_new: None,
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            t0: Instant::now(),
            tx: mpsc::channel().0,
            cancel: cancel.clone(),
            retries: 0,
            hybrid: false,
            priority: Priority::Interactive,
            _admission: AdmissionGuard(Arc::new(AtomicU64::new(1))),
        };
        assert!(req.expired());
        assert!(!req.cancelled());
        cancel.store(true, Ordering::Relaxed);
        assert!(req.cancelled());
    }

    #[test]
    fn submit_and_request_errors_render() {
        assert_eq!(SubmitError::Busy.to_string(), "server busy: admission window full");
        assert!(SubmitError::Closed.to_string().contains("closed"));
        let e = SubmitError::PromptTooLong { len: 55, max: 40 };
        assert!(e.to_string().contains("55"));
        assert!(e.to_string().contains("40"));
        assert_ne!(e, SubmitError::Busy);
        assert!(SubmitError::ZeroTokenBudget.to_string().contains("max_new_tokens(0)"));
        let q = SubmitError::InvalidQuality { quality: f32::NAN };
        assert!(q.to_string().contains("invalid quality target"));
        assert!(q.to_string().contains("[0, 1]"));
        assert_ne!(q, SubmitError::Busy);
        assert!(RequestError::Failed("deadline".into()).to_string().contains("deadline"));
        assert_ne!(RequestError::Cancelled, RequestError::Timeout);
    }

    #[test]
    fn truncate_prompt_builder_flag() {
        let r = Request::new(vec![1; 100]);
        assert!(!r.truncate, "rejection is the default for oversized prompts");
        let r = r.truncate_prompt();
        assert!(r.truncate);
        // the builder only records the opt-in; clipping happens at
        // submit against the manifest's sprompt (integration-tested)
        assert_eq!(r.prompt.len(), 100);
    }

    #[test]
    fn expired_at_uses_the_callers_clock() {
        let mk = |deadline| InFlight {
            id: 0,
            prompt: vec![],
            quality: None,
            policy: None,
            max_new: None,
            deadline: Some(deadline),
            t0: Instant::now(),
            tx: mpsc::channel().0,
            cancel: Arc::new(AtomicBool::new(false)),
            retries: 0,
            hybrid: false,
            priority: Priority::Interactive,
            _admission: AdmissionGuard(Arc::new(AtomicU64::new(1))),
        };
        let now = Instant::now();
        let req = mk(now + Duration::from_secs(60));
        assert!(!req.expired_at(now));
        // the same request is expired when judged by a later clock —
        // sweep passes sharing one reading always agree
        assert!(req.expired_at(now + Duration::from_secs(61)));
        assert!(mk(now).expired_at(now));
    }

    #[test]
    fn admission_guard_releases_on_any_drop_path() {
        let counter = Arc::new(AtomicU64::new(1));
        let req = InFlight {
            id: 0,
            prompt: vec![],
            quality: None,
            policy: None,
            max_new: None,
            deadline: None,
            t0: Instant::now(),
            tx: mpsc::channel().0,
            cancel: Arc::new(AtomicBool::new(false)),
            retries: 0,
            hybrid: false,
            priority: Priority::Interactive,
            _admission: AdmissionGuard(counter.clone()),
        };
        // terminal path: finish() drops the request
        finish(req, Event::Cancelled);
        assert_eq!(counter.load(Ordering::Relaxed), 0);
        // error path: a plain drop (router/worker failure, shutdown with
        // pending work) must release the slot too
        counter.store(1, Ordering::Relaxed);
        let req = InFlight {
            id: 1,
            prompt: vec![],
            quality: None,
            policy: None,
            max_new: None,
            deadline: None,
            t0: Instant::now(),
            tx: mpsc::channel().0,
            cancel: Arc::new(AtomicBool::new(false)),
            retries: 0,
            hybrid: false,
            priority: Priority::Interactive,
            _admission: AdmissionGuard(counter.clone()),
        };
        drop(req);
        assert_eq!(counter.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn dropping_a_handle_sets_the_cancel_flag() {
        let (_tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let h = RequestHandle { id: 7, events: rx, cancel: cancel.clone() };
        assert_eq!(h.id(), 7);
        drop(h);
        assert!(cancel.load(Ordering::Relaxed));
    }
}
