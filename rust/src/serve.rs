//! The serving system (Fig. 2): a query-router front end dispatching to
//! two continuous-batching decode workers (edge/small and cloud/large).
//!
//! Threading model: the `xla` crate's PJRT client is `Rc`-based and
//! therefore `!Send`, so **each worker thread owns its own PJRT client,
//! runtime, and engine** (loaded from the shared artifacts + run
//! directories); channels carry only plain data. This mirrors a real
//! deployment more closely anyway — the edge device and the cloud
//! backend do not share an address space.
//!
//! * router thread — drains the ingress queue with a batching window,
//!   scores queries through the router encoder (single pass, §3), and
//!   dispatches on the threshold;
//! * decode workers — slot-based continuous batching ([`BatchMode`]),
//!   persistent KV caches, iteration-level admission.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::batching::{BatchMode, KvCache, Slot, SlotTable};
use crate::io::Tensor;
use crate::lm::LmEngine;
use crate::metrics::{LatencyRecorder, LatencySummary, RoutingCounters, RoutingSnapshot};
use crate::router::RouterEngine;
use crate::runtime::Runtime;
use crate::tokenizer as tok;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    /// Run directory holding trained params (`params/<model>/`,
    /// `routers/<router>/`).
    pub run_dir: PathBuf,
    pub small: String,
    pub large: String,
    /// Router params subdirectory under `run_dir/routers/` (empty =>
    /// random routing at `threshold` interpreted as p(large)).
    pub router: String,
    pub threshold: f32,
    pub temp: f32,
    pub mode: BatchMode,
    /// How long the router waits to fill a batch.
    pub batch_window: Duration,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub routed_small: bool,
    pub router_score: f32,
    pub mean_logprob: f32,
    /// Ingress → completion.
    pub e2e: Duration,
    /// Ingress → routed to a worker queue.
    pub routing: Duration,
}

struct Request {
    id: u64,
    prompt: Vec<i32>,
    t0: Instant,
    tx: Sender<Completion>,
}

enum RouterMsg {
    Req(Request),
    Shutdown,
}

struct Work {
    req: Request,
    score: f32,
    routed: Instant,
}

enum WorkMsg {
    Work(Work),
    Shutdown,
}

/// Shared (Send) metrics.
pub struct ServerMetrics {
    pub router_latency: LatencyRecorder,
    pub e2e_latency: LatencyRecorder,
    pub small_latency: LatencyRecorder,
    pub large_latency: LatencyRecorder,
    pub routing: RoutingCounters,
    pub decode_steps: AtomicU64,
    pub decode_slot_steps: AtomicU64,
}

/// Point-in-time server report.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub router_latency: LatencySummary,
    pub e2e_latency: LatencySummary,
    pub small_latency: LatencySummary,
    pub large_latency: LatencySummary,
    pub routing: RoutingSnapshot,
    pub decode_steps: u64,
    /// Occupied-slot decode steps (batching efficiency =
    /// `decode_slot_steps / (decode_steps * capacity)`).
    pub decode_slot_steps: u64,
}

/// Handle to a running server.
pub struct Server {
    ingress: Sender<RouterMsg>,
    small_tx: Sender<WorkMsg>,
    large_tx: Sender<WorkMsg>,
    handles: Vec<JoinHandle<Result<()>>>,
    metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
}

impl Server {
    /// Spawn router + two decode workers.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let metrics = Arc::new(ServerMetrics {
            router_latency: LatencyRecorder::new(),
            e2e_latency: LatencyRecorder::new(),
            small_latency: LatencyRecorder::new(),
            large_latency: LatencyRecorder::new(),
            routing: RoutingCounters::new(),
            decode_steps: AtomicU64::new(0),
            decode_slot_steps: AtomicU64::new(0),
        });
        let (ingress, router_rx) = mpsc::channel::<RouterMsg>();
        let (small_tx, small_rx) = mpsc::channel::<WorkMsg>();
        let (large_tx, large_rx) = mpsc::channel::<WorkMsg>();
        // readiness barrier: threads ack after compiling their executables
        // so `start` returns a warm server (PJRT compilation is seconds;
        // without this the first requests' latency measures the compiler)
        let (ready_tx, ready_rx) = mpsc::channel::<()>();

        let mut handles = Vec::new();
        {
            let cfg = cfg.clone();
            let m = metrics.clone();
            let (stx, ltx) = (small_tx.clone(), large_tx.clone());
            let rtx = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("router".into())
                    .spawn(move || router_thread(cfg, router_rx, stx, ltx, m, rtx))?,
            );
        }
        for (model, rx, is_small) in [
            (cfg.small.clone(), small_rx, true),
            (cfg.large.clone(), large_rx, false),
        ] {
            let cfg = cfg.clone();
            let m = metrics.clone();
            let rtx = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("worker-{model}"))
                    .spawn(move || worker_thread(cfg, model, rx, is_small, m, rtx))?,
            );
        }
        drop(ready_tx);
        for _ in 0..3 {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("server thread died during warm-up"))?;
        }
        Ok(Server {
            ingress,
            small_tx,
            large_tx,
            handles,
            metrics,
            next_id: AtomicU64::new(0),
        })
    }

    /// Submit a query; returns the receiver for its completion.
    pub fn submit(&self, prompt: Vec<i32>) -> Receiver<Completion> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.ingress.send(RouterMsg::Req(Request {
            id,
            prompt,
            t0: Instant::now(),
            tx,
        }));
        rx
    }

    pub fn stats(&self) -> ServerStats {
        ServerStats {
            router_latency: self.metrics.router_latency.snapshot(),
            e2e_latency: self.metrics.e2e_latency.snapshot(),
            small_latency: self.metrics.small_latency.snapshot(),
            large_latency: self.metrics.large_latency.snapshot(),
            routing: self.metrics.routing.snapshot(),
            decode_steps: self.metrics.decode_steps.load(Ordering::Relaxed),
            decode_slot_steps: self.metrics.decode_slot_steps.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown: drains in-flight work, joins all threads.
    pub fn shutdown(self) -> Result<ServerStats> {
        let _ = self.ingress.send(RouterMsg::Shutdown);
        let _ = self.small_tx.send(WorkMsg::Shutdown);
        let _ = self.large_tx.send(WorkMsg::Shutdown);
        let stats = self.stats();
        for h in self.handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => anyhow::bail!("server thread panicked"),
            }
        }
        Ok(stats)
    }
}

fn router_thread(
    cfg: ServeConfig,
    rx: Receiver<RouterMsg>,
    small_tx: Sender<WorkMsg>,
    large_tx: Sender<WorkMsg>,
    metrics: Arc<ServerMetrics>,
    ready: Sender<()>,
) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let router = if cfg.router.is_empty() {
        None
    } else {
        let eng = RouterEngine::load(
            rt.clone(),
            &cfg.run_dir.join("routers").join(&cfg.router),
        )?;
        rt.exec("router.fwd")?; // warm compile
        Some(eng)
    };
    let _ = ready.send(());
    let mut rng = crate::rng::Rng::new(0xA5);
    let max_batch = rt.manifest.globals.trainb;
    let mut pending: Vec<Request> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // batching window: collect until deadline or max batch
        let deadline = Instant::now() + cfg.batch_window;
        while pending.len() < max_batch {
            let now = Instant::now();
            let wait = if pending.is_empty() {
                Duration::from_millis(50)
            } else if now >= deadline {
                break;
            } else {
                deadline - now
            };
            match rx.recv_timeout(wait) {
                Ok(RouterMsg::Req(r)) => pending.push(r),
                Ok(RouterMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    if !pending.is_empty() {
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        let batch: Vec<Request> = pending.drain(..).collect();
        let t_score = Instant::now();
        let scores = match &router {
            Some(r) => {
                let prompts: Vec<&[i32]> = batch.iter().map(|r| r.prompt.as_slice()).collect();
                r.scores(&prompts)?
            }
            None => batch.iter().map(|_| rng.next_f32()).collect(),
        };
        let per_query = t_score.elapsed() / batch.len() as u32;
        for (req, score) in batch.into_iter().zip(scores) {
            metrics.router_latency.record(per_query);
            let routed = Instant::now();
            let routing = routed - req.t0;
            let to_small = score >= cfg.threshold;
            if to_small {
                metrics.routing.route_small();
            } else {
                metrics.routing.route_large();
            }
            let msg = WorkMsg::Work(Work { req, score, routed });
            let tx = if to_small { &small_tx } else { &large_tx };
            let _ = routing; // recorded at completion time
            tx.send(msg).ok().context("worker channel closed")?;
        }
    }
    Ok(())
}

struct WorkerCtx {
    engine: LmEngine,
    table: SlotTable<Work>,
    kv: KvCache,
    temp: f32,
}

fn worker_thread(
    cfg: ServeConfig,
    model: String,
    rx: Receiver<WorkMsg>,
    is_small: bool,
    metrics: Arc<ServerMetrics>,
    ready: Sender<()>,
) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let g = rt.manifest.globals;
    let meta = *rt.manifest.model(&model)?;
    let engine = LmEngine::load(rt.clone(), &model, &cfg.run_dir.join("params").join(&model))?;
    // warm compiles before accepting work (PJRT compile is seconds)
    rt.exec(&format!("{model}.prefill"))?;
    rt.exec(&format!("{model}.decode"))?;
    let _ = ready.send(());
    let mut ctx = WorkerCtx {
        engine,
        table: SlotTable::new(g.genb),
        kv: KvCache::zeros(meta.layers, g.genb, g.sctx, meta.heads, meta.headdim),
        temp: cfg.temp,
    };
    let mut backlog: Vec<Work> = Vec::new();
    let mut shutdown = false;

    while !(shutdown && ctx.table.is_empty() && backlog.is_empty()) {
        // 1. pull work (non-blocking while busy; blocking when idle)
        loop {
            let msg = if ctx.table.is_empty() && backlog.is_empty() && !shutdown {
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                WorkMsg::Work(w) => backlog.push(w),
                WorkMsg::Shutdown => shutdown = true,
            }
        }

        // 2. admission per batching mode
        let can_admit = match cfg.mode {
            BatchMode::Continuous => true,
            BatchMode::RunToCompletion => ctx.table.is_empty(),
        };
        if can_admit && !backlog.is_empty() && !ctx.table.free_indices().is_empty() {
            let free = ctx.table.free_indices();
            let n_new = free.len().min(backlog.len());
            let admitted: Vec<Work> = backlog.drain(..n_new).collect();
            admit(&mut ctx, &free[..n_new], admitted, &metrics, is_small)?;
        }

        // 3. one decode iteration over the occupied slots
        if !ctx.table.is_empty() {
            let t0 = Instant::now();
            decode_step(&mut ctx, &metrics, is_small)?;
            if std::env::var_os("HYBRID_SERVE_TRACE").is_some() {
                eprintln!(
                    "[trace {model}] decode iter {:.1} ms occ {}",
                    t0.elapsed().as_secs_f64() * 1e3,
                    ctx.table.occupied()
                );
            }
        }
    }
    Ok(())
}

/// Prefill newly-admitted requests and install them into slots.
fn admit(
    ctx: &mut WorkerCtx,
    slots: &[usize],
    work: Vec<Work>,
    metrics: &Arc<ServerMetrics>,
    is_small: bool,
) -> Result<()> {
    let rt = ctx.engine.runtime().clone();
    let g = rt.manifest.globals;
    let prompts: Vec<Vec<i32>> = work.iter().map(|w| w.req.prompt.clone()).collect();
    let seeds: Vec<u32> = work.iter().map(|w| w.req.id as u32).collect();

    // run prefill in waves of genb (slots are per worker, genb capacity)
    let prefill = rt.exec(&format!("{}.prefill", ctx.engine.name))?;
    let n = ctx.engine.params.len();
    let resident: std::collections::HashMap<usize, Arc<xla::PjRtBuffer>> =
        ctx.engine.params.device.iter().cloned().enumerate().collect();

    let bsz = g.genb;
    let mut ptoks = vec![tok::PAD; bsz * g.sprompt];
    let mut lens = vec![1i32; bsz];
    let mut seedv = vec![0u32; bsz];
    for (b, p) in prompts.iter().enumerate() {
        ptoks[b * g.sprompt..b * g.sprompt + p.len()].copy_from_slice(p);
        lens[b] = p.len() as i32;
        seedv[b] = seeds[b];
    }
    let ptoks = Tensor::i32(vec![bsz, g.sprompt], ptoks);
    let lens_t = Tensor::i32(vec![bsz], lens.clone());
    let seeds_t = Tensor::u32(vec![bsz], seedv);
    let temp_t = Tensor::f32(vec![], vec![ctx.temp]);
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &ptoks),
        (n + 1, &lens_t),
        (n + 2, &seeds_t),
        (n + 3, &temp_t),
    ];
    let mut outs = prefill.run_with_resident(&resident, &host)?;
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?;
    let first = outs.pop().context("next")?;
    let fresh = KvCache::from_tensors(kc, vc)?;
    let first = first.as_i32()?;
    let logp = logp.as_f32()?;

    for (b, (w, &slot_idx)) in work.into_iter().zip(slots).enumerate() {
        ctx.kv.copy_slot_from(&fresh, b, slot_idx)?;
        let prompt_len = ctx.table.capacity(); // placeholder, replaced below
        let _ = prompt_len;
        let plen = lens[b];
        if first[b] == tok::EOS {
            complete(ctx, w, vec![], 0.0, metrics, is_small);
            continue;
        }
        let slot = Slot {
            answer: vec![first[b]],
            logprob_sum: logp[b],
            cur: first[b],
            pos: plen,
            seed: w.req.id as u32,
            payload: w,
        };
        ctx.table.insert(slot_idx, slot)?;
    }
    Ok(())
}

/// One decode iteration for every occupied slot.
fn decode_step(ctx: &mut WorkerCtx, metrics: &Arc<ServerMetrics>, is_small: bool) -> Result<()> {
    let rt = ctx.engine.runtime().clone();
    let g = rt.manifest.globals;
    let decode = rt.exec(&format!("{}.decode", ctx.engine.name))?;
    let n = ctx.engine.params.len();
    let resident: std::collections::HashMap<usize, Arc<xla::PjRtBuffer>> =
        ctx.engine.params.device.iter().cloned().enumerate().collect();

    let (cur, pos, seeds) = ctx.table.decode_inputs();
    let bsz = ctx.table.capacity();
    let cur_t = Tensor::i32(vec![bsz], cur);
    let pos_t = Tensor::i32(vec![bsz], pos.clone());
    let step_t = Tensor::i32(vec![], vec![(pos.iter().max().copied().unwrap_or(0)) + 1]);
    let seeds_t = Tensor::u32(vec![bsz], seeds);
    let temp_t = Tensor::f32(vec![], vec![ctx.temp]);
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &ctx.kv.k),
        (n + 1, &ctx.kv.v),
        (n + 2, &cur_t),
        (n + 3, &pos_t),
        (n + 4, &step_t),
        (n + 5, &seeds_t),
        (n + 6, &temp_t),
    ];
    let mut outs = decode.run_with_resident(&resident, &host)?;
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?;
    let next = outs.pop().context("next")?;
    ctx.kv.replace(kc, vc)?;
    let next = next.as_i32()?;
    let logp = logp.as_f32()?;

    metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
    metrics
        .decode_slot_steps
        .fetch_add(ctx.table.occupied() as u64, Ordering::Relaxed);

    for idx in ctx.table.occupied_indices() {
        let (finished, answer, lpsum, nlen);
        {
            let slot = ctx.table.get_mut(idx).unwrap();
            slot.pos += 1;
            let nxt = next[idx];
            let full = slot.answer.len() + 1 >= g.amax || slot.pos as usize >= g.sctx - 1;
            if nxt == tok::EOS || full {
                finished = true;
            } else {
                slot.answer.push(nxt);
                slot.logprob_sum += logp[idx];
                slot.cur = nxt;
                finished = false;
            }
            answer = slot.answer.clone();
            lpsum = slot.logprob_sum;
            nlen = slot.answer.len().max(1);
        }
        if finished {
            let slot = ctx.table.take(idx).unwrap();
            complete(
                ctx,
                slot.payload,
                answer,
                lpsum / nlen as f32,
                metrics,
                is_small,
            );
        }
    }
    Ok(())
}

fn complete(
    _ctx: &mut WorkerCtx,
    w: Work,
    tokens: Vec<i32>,
    mean_logprob: f32,
    metrics: &Arc<ServerMetrics>,
    is_small: bool,
) {
    let e2e = w.req.t0.elapsed();
    metrics.e2e_latency.record(e2e);
    if is_small {
        metrics.small_latency.record(e2e);
    } else {
        metrics.large_latency.record(e2e);
    }
    metrics.routing.complete(0.0);
    let _ = w.req.tx.send(Completion {
        id: w.req.id,
        tokens,
        routed_small: is_small,
        router_score: w.score,
        mean_logprob,
        e2e,
        routing: w.routed - w.req.t0,
    });
}
