//! The serving system (Fig. 2), generalized from the paper's two-model
//! pair to an **N-tier model fleet**: a query-router front end
//! dispatching to per-tier continuous-batching decode workers. Each
//! [`TierSpec`] names a tier (e.g. `device` / `edge` / `cloud`), the
//! model it serves, a relative cost weight, and `1..N` replica worker
//! threads; the default [`two_tier`] fleet reproduces the paper's
//! small/large setup exactly.
//!
//! Threading model: the `xla` crate's PJRT client is `Rc`-based and
//! therefore `!Send`, so **each replica thread owns its own PJRT client,
//! runtime, and engine** (loaded from the shared artifacts + run
//! directories); channels carry only plain data. This mirrors a real
//! deployment more closely anyway — the device, edge, and cloud backends
//! do not share an address space.
//!
//! * router thread — drains the ingress queue with a batching window,
//!   scores queries through the router encoder (single pass, §3), maps
//!   scores to tiers via a [`TierPolicy`] (threshold ladder), and picks
//!   a replica by round-robin or shortest-queue;
//! * decode workers — slot-based continuous batching ([`BatchMode`]),
//!   persistent KV caches, iteration-level admission.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::batching::{BatchMode, KvCache, Slot, SlotTable};
use crate::io::Tensor;
use crate::lm::LmEngine;
use crate::metrics::{LatencyRecorder, LatencySummary, RoutingCounters, RoutingSnapshot};
use crate::policy::TierPolicy;
use crate::router::RouterEngine;
use crate::runtime::{Exec, Runtime};
use crate::tokenizer as tok;

/// One tier of the fleet: a named model backend with a relative cost
/// weight and a replica count (worker threads serving this tier).
#[derive(Debug, Clone)]
pub struct TierSpec {
    /// Display/metrics name (defaults to the model name).
    pub name: String,
    /// Roster model this tier serves.
    pub model: String,
    /// Worker threads for this tier (each owns its own PJRT client).
    pub replicas: usize,
    /// Relative per-query cost weight (most expensive tier defines the
    /// cost-advantage baseline).
    pub cost: f64,
}

impl TierSpec {
    pub fn new(model: impl Into<String>, replicas: usize, cost: f64) -> TierSpec {
        let model = model.into();
        TierSpec { name: model.clone(), model, replicas, cost }
    }

    pub fn named(name: impl Into<String>, model: impl Into<String>, replicas: usize, cost: f64) -> TierSpec {
        TierSpec { name: name.into(), model: model.into(), replicas, cost }
    }
}

/// The paper's two-model fleet: `small` (tier 0, cost 0) and `large`
/// (tier 1, cost 1), one replica each — cost advantage reduces to the
/// fraction routed small, as in §2.3.
pub fn two_tier(small: &str, large: &str) -> Vec<TierSpec> {
    vec![TierSpec::new(small, 1, 0.0), TierSpec::new(large, 1, 1.0)]
}

/// Parse a `--tiers` fleet spec: comma-separated `model[:replicas[:cost]]`
/// entries, cheapest tier first, e.g. `small:1,large:1` or
/// `nano:2:0.02,medium:1:0.45,large:1:1`. Omitted costs default to even
/// spacing over `[0, 1]` (two tiers → `0, 1`, matching the seed).
pub fn parse_tiers(spec: &str) -> Result<Vec<TierSpec>> {
    let mut parsed: Vec<(String, usize, Option<f64>)> = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let mut fields = part.split(':');
        let model = fields.next().unwrap_or("").trim().to_string();
        anyhow::ensure!(!model.is_empty(), "empty tier name in --tiers spec {spec:?}");
        let replicas = match fields.next() {
            None => 1,
            Some(r) => r
                .trim()
                .parse::<usize>()
                .with_context(|| format!("bad replica count in tier {part:?}"))?,
        };
        anyhow::ensure!(replicas >= 1, "tier {part:?} needs at least one replica");
        let cost = match fields.next() {
            None => None,
            Some(c) => {
                let c = c
                    .trim()
                    .parse::<f64>()
                    .with_context(|| format!("bad cost in tier {part:?}"))?;
                anyhow::ensure!(
                    c.is_finite() && c >= 0.0,
                    "tier {part:?} cost must be finite and >= 0"
                );
                Some(c)
            }
        };
        anyhow::ensure!(fields.next().is_none(), "too many `:` fields in tier {part:?}");
        parsed.push((model, replicas, cost));
    }
    anyhow::ensure!(!parsed.is_empty(), "--tiers spec {spec:?} names no tiers");
    let k = parsed.len();
    Ok(parsed
        .into_iter()
        .enumerate()
        .map(|(i, (model, replicas, cost))| {
            let cost =
                cost.unwrap_or(if k <= 1 { 1.0 } else { i as f64 / (k - 1) as f64 });
            TierSpec::new(model, replicas, cost)
        })
        .collect())
}

/// Replica selection within a tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaSelect {
    /// Rotate through replicas (fair under uniform work).
    RoundRobin,
    /// Send to the replica with the fewest in-flight requests.
    ShortestQueue,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    /// Run directory holding trained params (`params/<model>/`,
    /// `routers/<router>/`).
    pub run_dir: PathBuf,
    /// The fleet, cheapest tier first.
    pub tiers: Vec<TierSpec>,
    /// Router params subdirectory under `run_dir/routers/` (empty =>
    /// random scores fed through `policy`).
    pub router: String,
    /// Score → tier mapping (a threshold ladder in the paper's setup).
    pub policy: TierPolicy,
    /// Replica selection within a tier.
    pub select: ReplicaSelect,
    pub temp: f32,
    pub mode: BatchMode,
    /// How long the router waits to fill a batch.
    pub batch_window: Duration,
}

impl ServeConfig {
    /// Seed-compatible two-tier config: `score >= threshold` routes to
    /// `small`, one replica per tier. Adjust `temp`/`mode`/`batch_window`
    /// on the returned value as needed.
    pub fn two_tier(
        artifacts_dir: PathBuf,
        run_dir: PathBuf,
        small: &str,
        large: &str,
        router: String,
        threshold: f32,
    ) -> ServeConfig {
        ServeConfig {
            artifacts_dir,
            run_dir,
            tiers: two_tier(small, large),
            router,
            policy: TierPolicy::Ladder { thresholds: vec![threshold] },
            select: ReplicaSelect::RoundRobin,
            temp: 0.0,
            mode: BatchMode::Continuous,
            batch_window: Duration::from_millis(5),
        }
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Index of the tier that served the request (0 = cheapest).
    pub tier: usize,
    pub router_score: f32,
    pub mean_logprob: f32,
    /// Ingress → completion.
    pub e2e: Duration,
    /// Ingress → routed to a worker queue.
    pub routing: Duration,
}

struct Request {
    id: u64,
    prompt: Vec<i32>,
    t0: Instant,
    tx: Sender<Completion>,
}

enum RouterMsg {
    Req(Request),
    Shutdown,
}

struct Work {
    req: Request,
    score: f32,
    routed: Instant,
}

enum WorkMsg {
    Work(Work),
    Shutdown,
}

/// Dispatch state for one tier, owned by the router thread.
struct TierDispatch {
    txs: Vec<Sender<WorkMsg>>,
    /// Per-replica in-flight counts (incremented at dispatch,
    /// decremented at completion) for shortest-queue selection.
    depths: Vec<Arc<AtomicU64>>,
    rr: usize,
}

/// Shared (Send) metrics.
pub struct ServerMetrics {
    pub router_latency: LatencyRecorder,
    pub e2e_latency: LatencyRecorder,
    /// Per-tier e2e latency, indexed like `ServeConfig::tiers`.
    pub tier_latency: Vec<LatencyRecorder>,
    pub routing: RoutingCounters,
    pub decode_steps: AtomicU64,
    pub decode_slot_steps: AtomicU64,
    /// Host→device bytes moved by decode iterations (all workers). With
    /// device-resident KV caches this is the O(B) token/pos/seed upload
    /// per step; the seed paid the full KV pair both ways on every step.
    pub decode_h2d_bytes: AtomicU64,
    /// Device→host bytes moved by decode iterations (all workers).
    pub decode_d2h_bytes: AtomicU64,
    /// Host↔device bytes moved by admissions (prefill inputs + the KV
    /// slot-surgery round-trip), kept separate so the decode counters
    /// stay a pure per-iteration signal.
    pub admit_h2d_bytes: AtomicU64,
    pub admit_d2h_bytes: AtomicU64,
}

/// Point-in-time per-tier report.
#[derive(Debug, Clone)]
pub struct TierStats {
    pub name: String,
    pub latency: LatencySummary,
}

/// Point-in-time server report.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub router_latency: LatencySummary,
    pub e2e_latency: LatencySummary,
    /// Per-tier latency keyed by tier name, cheapest first (routing
    /// counts live in `routing.tiers`).
    pub tiers: Vec<TierStats>,
    pub routing: RoutingSnapshot,
    pub decode_steps: u64,
    /// Occupied-slot decode steps (batching efficiency =
    /// `decode_slot_steps / (decode_steps * capacity)`).
    pub decode_slot_steps: u64,
    /// Host↔device traffic attributable to decode iterations.
    pub decode_h2d_bytes: u64,
    pub decode_d2h_bytes: u64,
    /// Host↔device traffic attributable to admissions (prefill + KV
    /// slot surgery).
    pub admit_h2d_bytes: u64,
    pub admit_d2h_bytes: u64,
}

impl ServerStats {
    /// Mean device→host bytes per decode iteration — the residency
    /// headline number: O(B·token) when KV caches stay on device,
    /// O(L·B·S·H·Dh) when they round-trip.
    pub fn d2h_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_d2h_bytes as f64 / self.decode_steps as f64
        }
    }

    /// Mean host→device bytes per decode iteration.
    pub fn h2d_bytes_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_h2d_bytes as f64 / self.decode_steps as f64
        }
    }
}

/// Handle to a running server.
pub struct Server {
    ingress: Sender<RouterMsg>,
    tier_txs: Vec<Vec<Sender<WorkMsg>>>,
    tier_names: Vec<String>,
    router_handle: JoinHandle<Result<()>>,
    worker_handles: Vec<JoinHandle<Result<()>>>,
    metrics: Arc<ServerMetrics>,
    next_id: AtomicU64,
}

fn snapshot_stats(metrics: &ServerMetrics, tier_names: &[String]) -> ServerStats {
    ServerStats {
        router_latency: metrics.router_latency.snapshot(),
        e2e_latency: metrics.e2e_latency.snapshot(),
        tiers: tier_names
            .iter()
            .zip(&metrics.tier_latency)
            .map(|(name, rec)| TierStats { name: name.clone(), latency: rec.snapshot() })
            .collect(),
        routing: metrics.routing.snapshot(),
        decode_steps: metrics.decode_steps.load(Ordering::Relaxed),
        decode_slot_steps: metrics.decode_slot_steps.load(Ordering::Relaxed),
        decode_h2d_bytes: metrics.decode_h2d_bytes.load(Ordering::Relaxed),
        decode_d2h_bytes: metrics.decode_d2h_bytes.load(Ordering::Relaxed),
        admit_h2d_bytes: metrics.admit_h2d_bytes.load(Ordering::Relaxed),
        admit_d2h_bytes: metrics.admit_d2h_bytes.load(Ordering::Relaxed),
    }
}

impl Server {
    /// Spawn the router plus one decode worker per tier replica.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        anyhow::ensure!(!cfg.tiers.is_empty(), "fleet needs at least one tier");
        for t in &cfg.tiers {
            anyhow::ensure!(t.replicas >= 1, "tier {} needs at least one replica", t.name);
        }
        if let Some(k) = cfg.policy.n_tiers() {
            anyhow::ensure!(
                k == cfg.tiers.len(),
                "policy distinguishes {k} tiers but the fleet has {}",
                cfg.tiers.len()
            );
        }
        if let TierPolicy::Fixed { tier } = &cfg.policy {
            anyhow::ensure!(*tier < cfg.tiers.len(), "fixed tier {tier} out of range");
        }
        let tier_names: Vec<String> = cfg.tiers.iter().map(|t| t.name.clone()).collect();
        let costs: Vec<f64> = cfg.tiers.iter().map(|t| t.cost).collect();
        let metrics = Arc::new(ServerMetrics {
            router_latency: LatencyRecorder::new(),
            e2e_latency: LatencyRecorder::new(),
            tier_latency: cfg.tiers.iter().map(|_| LatencyRecorder::new()).collect(),
            routing: RoutingCounters::new(tier_names.clone(), costs),
            decode_steps: AtomicU64::new(0),
            decode_slot_steps: AtomicU64::new(0),
            decode_h2d_bytes: AtomicU64::new(0),
            decode_d2h_bytes: AtomicU64::new(0),
            admit_h2d_bytes: AtomicU64::new(0),
            admit_d2h_bytes: AtomicU64::new(0),
        });
        let (ingress, router_rx) = mpsc::channel::<RouterMsg>();
        // readiness barrier: threads ack after compiling their executables
        // so `start` returns a warm server (PJRT compilation is seconds;
        // without this the first requests' latency measures the compiler)
        let (ready_tx, ready_rx) = mpsc::channel::<()>();

        let mut worker_handles = Vec::new();
        let mut dispatch = Vec::new();
        let mut tier_txs = Vec::new();
        let mut n_workers = 0usize;
        for (ti, tier) in cfg.tiers.iter().enumerate() {
            let mut txs = Vec::new();
            let mut depths = Vec::new();
            for r in 0..tier.replicas {
                let (tx, rx) = mpsc::channel::<WorkMsg>();
                let depth = Arc::new(AtomicU64::new(0));
                let cfg = cfg.clone();
                let m = metrics.clone();
                let rtx = ready_tx.clone();
                let d = depth.clone();
                worker_handles.push(
                    std::thread::Builder::new()
                        .name(format!("worker-{}-{r}", tier.name))
                        .spawn(move || worker_thread(cfg, ti, rx, d, m, rtx))?,
                );
                txs.push(tx);
                depths.push(depth);
                n_workers += 1;
            }
            dispatch.push(TierDispatch { txs: txs.clone(), depths, rr: 0 });
            tier_txs.push(txs);
        }
        let router_handle = {
            let cfg = cfg.clone();
            let m = metrics.clone();
            let rtx = ready_tx.clone();
            std::thread::Builder::new()
                .name("router".into())
                .spawn(move || router_thread(cfg, router_rx, dispatch, m, rtx))?
        };
        drop(ready_tx);
        for _ in 0..n_workers + 1 {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("server thread died during warm-up"))?;
        }
        Ok(Server {
            ingress,
            tier_txs,
            tier_names,
            router_handle,
            worker_handles,
            metrics,
            next_id: AtomicU64::new(0),
        })
    }

    /// Submit a query; returns the receiver for its completion.
    pub fn submit(&self, prompt: Vec<i32>) -> Receiver<Completion> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let _ = self.ingress.send(RouterMsg::Req(Request {
            id,
            prompt,
            t0: Instant::now(),
            tx,
        }));
        rx
    }

    pub fn stats(&self) -> ServerStats {
        snapshot_stats(&self.metrics, &self.tier_names)
    }

    /// Graceful shutdown: drains in-flight work, joins all threads.
    ///
    /// Drain protocol: the router is joined *before* the workers are
    /// signalled. The router may still be dispatching when `Shutdown`
    /// arrives; signalling workers concurrently let a worker with an
    /// empty backlog exit while the router still held work for it,
    /// turning graceful shutdown into a "worker channel closed" error
    /// (and dropping the request). Joining the router first guarantees
    /// every routed request sits in a worker queue ahead of the worker's
    /// `Shutdown` message, and workers drain their queue before exiting.
    pub fn shutdown(self) -> Result<ServerStats> {
        let Server {
            ingress,
            tier_txs,
            tier_names,
            router_handle,
            worker_handles,
            metrics,
            ..
        } = self;
        let _ = ingress.send(RouterMsg::Shutdown);
        let router_res = match router_handle.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("router thread panicked")),
        };
        // all dispatches are now enqueued (or the router failed); workers
        // may stop once they drain
        for txs in &tier_txs {
            for tx in txs {
                let _ = tx.send(WorkMsg::Shutdown);
            }
        }
        let mut worker_err: Option<anyhow::Error> = None;
        for h in worker_handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => worker_err = Some(anyhow::anyhow!("worker thread panicked")),
            }
        }
        router_res?;
        if let Some(e) = worker_err {
            return Err(e);
        }
        // snapshot after the full drain so completions that raced the
        // shutdown call are included
        Ok(snapshot_stats(&metrics, &tier_names))
    }
}

fn router_thread(
    cfg: ServeConfig,
    rx: Receiver<RouterMsg>,
    mut tiers: Vec<TierDispatch>,
    metrics: Arc<ServerMetrics>,
    ready: Sender<()>,
) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let router = if cfg.router.is_empty() {
        None
    } else {
        let eng = RouterEngine::load(
            rt.clone(),
            &cfg.run_dir.join("routers").join(&cfg.router),
        )?;
        rt.exec("router.fwd")?; // warm compile
        Some(eng)
    };
    let _ = ready.send(());
    let mut rng = crate::rng::Rng::new(0xA5);
    let max_batch = rt.manifest.globals.trainb;
    let last_tier = tiers.len() - 1;
    let mut pending: Vec<Request> = Vec::new();
    let mut shutdown = false;

    while !shutdown {
        // batching window: collect until deadline or max batch
        let deadline = Instant::now() + cfg.batch_window;
        while pending.len() < max_batch {
            let now = Instant::now();
            let wait = if pending.is_empty() {
                Duration::from_millis(50)
            } else if now >= deadline {
                break;
            } else {
                deadline - now
            };
            match rx.recv_timeout(wait) {
                Ok(RouterMsg::Req(r)) => pending.push(r),
                Ok(RouterMsg::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    if !pending.is_empty() {
                        break;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        let batch: Vec<Request> = pending.drain(..).collect();
        let t_score = Instant::now();
        let scores = match &router {
            Some(r) => {
                let prompts: Vec<&[i32]> = batch.iter().map(|r| r.prompt.as_slice()).collect();
                r.scores(&prompts)?
            }
            None => batch.iter().map(|_| rng.next_f32()).collect(),
        };
        let per_query = t_score.elapsed() / batch.len() as u32;
        let assigns = cfg.policy.assign(&scores);
        for ((req, score), tier) in batch.into_iter().zip(scores).zip(assigns) {
            metrics.router_latency.record(per_query);
            let routed = Instant::now();
            let tier = tier.min(last_tier);
            metrics.routing.route(tier);
            let d = &mut tiers[tier];
            let rep = match cfg.select {
                ReplicaSelect::RoundRobin => {
                    let r = d.rr % d.txs.len();
                    d.rr = d.rr.wrapping_add(1);
                    r
                }
                ReplicaSelect::ShortestQueue => d
                    .depths
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, q)| q.load(Ordering::Relaxed))
                    .map(|(i, _)| i)
                    .unwrap_or(0),
            };
            d.depths[rep].fetch_add(1, Ordering::Relaxed);
            d.txs[rep]
                .send(WorkMsg::Work(Work { req, score, routed }))
                .ok()
                .context("worker channel closed")?;
        }
    }
    Ok(())
}

/// Per-worker state built **once** at thread start: compiled executables,
/// the resident-params maps, the trace flag, and the persistent KV cache.
/// The seed rebuilt the resident `HashMap` (and re-read `HYBRID_SERVE_TRACE`)
/// on every admit/decode call — pure per-token overhead.
struct WorkerCtx {
    engine: LmEngine,
    table: SlotTable<Work>,
    kv: KvCache,
    tier: usize,
    depth: Arc<AtomicU64>,
    /// Compiled prefill/decode artifacts (cached `Arc`s, no name lookups
    /// on the hot path).
    prefill: Arc<Exec>,
    decode: Arc<Exec>,
    /// Params-only resident map for prefill (input layout: params + data;
    /// never mutated).
    prefill_resident: HashMap<usize, Arc<xla::PjRtBuffer>>,
    /// Resident map for decode: params plus — while the cache is
    /// device-resident — the KV buffers at indices `n`/`n+1`, swapped in
    /// place each iteration by [`KvCache::bind`].
    decode_resident: HashMap<usize, Arc<xla::PjRtBuffer>>,
    /// Logical `[L, genb, sctx, H, Dh]` KV shape (for adopting prefill
    /// outputs).
    cache_dims: Vec<usize>,
    /// Reusable scalar temperature tensor.
    temp_t: Tensor,
    /// `HYBRID_SERVE_TRACE` read once at startup.
    trace: bool,
}

fn worker_thread(
    cfg: ServeConfig,
    tier: usize,
    rx: Receiver<WorkMsg>,
    depth: Arc<AtomicU64>,
    metrics: Arc<ServerMetrics>,
    ready: Sender<()>,
) -> Result<()> {
    let model = cfg.tiers[tier].model.clone();
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let g = rt.manifest.globals;
    let meta = *rt.manifest.model(&model)?;
    let engine = LmEngine::load(rt.clone(), &model, &cfg.run_dir.join("params").join(&model))?;
    // warm compiles before accepting work (PJRT compile is seconds)
    let prefill = rt.exec(&format!("{model}.prefill"))?;
    let decode = rt.exec(&format!("{model}.decode"))?;
    let _ = ready.send(());
    let prefill_resident = engine.params.resident_map();
    let decode_resident = prefill_resident.clone();
    let mut ctx = WorkerCtx {
        table: SlotTable::new(g.genb),
        kv: KvCache::zeros(meta.layers, g.genb, g.sctx, meta.heads, meta.headdim),
        tier,
        depth,
        prefill,
        decode,
        prefill_resident,
        decode_resident,
        cache_dims: vec![meta.layers, g.genb, g.sctx, meta.heads, meta.headdim],
        temp_t: Tensor::f32(vec![], vec![cfg.temp]),
        trace: std::env::var_os("HYBRID_SERVE_TRACE").is_some(),
        engine,
    };
    let mut backlog: Vec<Work> = Vec::new();
    let mut shutdown = false;

    while !(shutdown && ctx.table.is_empty() && backlog.is_empty()) {
        // 1. pull work (non-blocking while busy; blocking when idle)
        loop {
            let msg = if ctx.table.is_empty() && backlog.is_empty() && !shutdown {
                match rx.recv_timeout(Duration::from_millis(100)) {
                    Ok(m) => m,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                WorkMsg::Work(w) => backlog.push(w),
                WorkMsg::Shutdown => shutdown = true,
            }
        }

        // 2. admission per batching mode
        let can_admit = match cfg.mode {
            BatchMode::Continuous => true,
            BatchMode::RunToCompletion => ctx.table.is_empty(),
        };
        if can_admit && !backlog.is_empty() && ctx.table.has_free() {
            let n_new = backlog
                .len()
                .min(ctx.table.capacity() - ctx.table.occupied());
            let free: Vec<usize> = ctx.table.free_indices().take(n_new).collect();
            let admitted: Vec<Work> = backlog.drain(..n_new).collect();
            admit(&mut ctx, &free, admitted, &metrics)?;
        }

        // 3. one decode iteration over the occupied slots
        if !ctx.table.is_empty() {
            let t0 = Instant::now();
            decode_step(&mut ctx, &metrics)?;
            if ctx.trace {
                eprintln!(
                    "[trace {model}] decode iter {:.1} ms occ {} kv {}",
                    t0.elapsed().as_secs_f64() * 1e3,
                    ctx.table.occupied(),
                    if ctx.kv.is_device() { "device" } else { "host" },
                );
            }
        }
    }
    Ok(())
}

/// Prefill newly-admitted requests and install them into slots.
///
/// Slot surgery is a host-side operation, so admission is the one place
/// the persistent cache round-trips the device boundary (`to_host`,
/// surgery, `to_device`); the steady-state decode loop stays zero-copy.
/// Admission already pays a full prefill, so the KV hop is amortized
/// over every token the request will decode. All admission traffic is
/// metered into `admit_*_bytes`, separate from the decode counters.
fn admit(
    ctx: &mut WorkerCtx,
    slots: &[usize],
    work: Vec<Work>,
    metrics: &Arc<ServerMetrics>,
) -> Result<()> {
    let rt = ctx.engine.runtime().clone();
    let before = rt.transfers();
    let g = rt.manifest.globals;
    let prompts: Vec<Vec<i32>> = work.iter().map(|w| w.req.prompt.clone()).collect();
    let seeds: Vec<u32> = work.iter().map(|w| w.req.id as u32).collect();
    let n = ctx.engine.params.len();

    // run prefill in waves of genb (slots are per worker, genb capacity)
    let bsz = g.genb;
    let mut ptoks = vec![tok::PAD; bsz * g.sprompt];
    let mut lens = vec![1i32; bsz];
    let mut seedv = vec![0u32; bsz];
    for (b, p) in prompts.iter().enumerate() {
        ptoks[b * g.sprompt..b * g.sprompt + p.len()].copy_from_slice(p);
        lens[b] = p.len() as i32;
        seedv[b] = seeds[b];
    }
    let ptoks = Tensor::i32(vec![bsz, g.sprompt], ptoks);
    let lens_t = Tensor::i32(vec![bsz], lens.clone());
    let seeds_t = Tensor::u32(vec![bsz], seedv);
    let host: Vec<(usize, &Tensor)> = vec![
        (n, &ptoks),
        (n + 1, &lens_t),
        (n + 2, &seeds_t),
        (n + 3, &ctx.temp_t),
    ];
    let mut outs = ctx.prefill.run_resident(&ctx.prefill_resident, &host)?;
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?.into_tensor()?;
    let first = outs.pop().context("next")?.into_tensor()?;
    let mut fresh = KvCache::from_outputs(kc, vc, &ctx.cache_dims)?;
    fresh.to_host(&rt)?;
    ctx.kv.to_host(&rt)?;
    let first = first.as_i32()?;
    let logp = logp.as_f32()?;

    for (b, (w, &slot_idx)) in work.into_iter().zip(slots).enumerate() {
        ctx.kv.copy_slot_from(&fresh, b, slot_idx)?;
        let plen = lens[b];
        if first[b] == tok::EOS {
            complete(ctx, w, vec![], 0.0, metrics);
            continue;
        }
        let slot = Slot {
            answer: vec![first[b]],
            logprob_sum: logp[b],
            cur: first[b],
            pos: plen,
            seed: w.req.id as u32,
            payload: w,
        };
        ctx.table.insert(slot_idx, slot)?;
    }
    // hand the merged cache back to the device so steady-state decode
    // starts zero-copy immediately (a no-op gain on pre-v2 artifacts,
    // whose decode outputs pull it back to the host anyway)
    ctx.kv.to_device(&rt)?;
    let moved = before.delta(rt.transfers());
    metrics
        .admit_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .admit_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);
    Ok(())
}

/// One decode iteration for every occupied slot.
///
/// Steady state: the KV caches are device-resident, so the only
/// host↔device traffic is the O(B) token/pos/seed upload and the O(B)
/// next/logp download — per-token cost scales with model compute, not
/// KV-cache size (the seed moved the full `[L, B, S, H, Dh]` pair both
/// ways on every call).
fn decode_step(ctx: &mut WorkerCtx, metrics: &Arc<ServerMetrics>) -> Result<()> {
    let rt = ctx.engine.runtime().clone();
    let g = rt.manifest.globals;
    let n = ctx.engine.params.len();

    let (cur, pos, seeds) = ctx.table.decode_inputs();
    let bsz = ctx.table.capacity();
    let cur_t = Tensor::i32(vec![bsz], cur);
    let pos_t = Tensor::i32(vec![bsz], pos.clone());
    let step_t = Tensor::i32(vec![], vec![(pos.iter().max().copied().unwrap_or(0)) + 1]);
    let seeds_t = Tensor::u32(vec![bsz], seeds);
    let mut host: Vec<(usize, &Tensor)> = vec![
        (n + 2, &cur_t),
        (n + 3, &pos_t),
        (n + 4, &step_t),
        (n + 5, &seeds_t),
        (n + 6, &ctx.temp_t),
    ];
    ctx.kv.bind(n, n + 1, &mut ctx.decode_resident, &mut host);
    let before = rt.transfers();
    let mut outs = ctx.decode.run_resident(&ctx.decode_resident, &host)?;
    let moved = before.delta(rt.transfers());
    let vc = outs.pop().context("vcache")?;
    let kc = outs.pop().context("kcache")?;
    let logp = outs.pop().context("logp")?.into_tensor()?;
    let next = outs.pop().context("next")?.into_tensor()?;
    ctx.kv.update(kc, vc)?;
    let next = next.as_i32()?;
    let logp = logp.as_f32()?;

    metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
    metrics
        .decode_slot_steps
        .fetch_add(ctx.table.occupied() as u64, Ordering::Relaxed);
    metrics
        .decode_h2d_bytes
        .fetch_add(moved.h2d_bytes, Ordering::Relaxed);
    metrics
        .decode_d2h_bytes
        .fetch_add(moved.d2h_bytes, Ordering::Relaxed);

    for idx in 0..ctx.table.capacity() {
        if ctx.table.get(idx).is_none() {
            continue;
        }
        let (finished, answer, lpsum, nlen);
        {
            let slot = ctx.table.get_mut(idx).unwrap();
            slot.pos += 1;
            let nxt = next[idx];
            let full = slot.answer.len() + 1 >= g.amax || slot.pos as usize >= g.sctx - 1;
            if nxt == tok::EOS || full {
                finished = true;
            } else {
                slot.answer.push(nxt);
                slot.logprob_sum += logp[idx];
                slot.cur = nxt;
                finished = false;
            }
            answer = slot.answer.clone();
            lpsum = slot.logprob_sum;
            nlen = slot.answer.len().max(1);
        }
        if finished {
            let slot = ctx.table.take(idx).unwrap();
            complete(ctx, slot.payload, answer, lpsum / nlen as f32, metrics);
        }
    }
    Ok(())
}

fn complete(
    ctx: &mut WorkerCtx,
    w: Work,
    tokens: Vec<i32>,
    mean_logprob: f32,
    metrics: &Arc<ServerMetrics>,
) {
    let e2e = w.req.t0.elapsed();
    metrics.e2e_latency.record(e2e);
    metrics.tier_latency[ctx.tier].record(e2e);
    metrics.routing.complete(0.0);
    ctx.depth.fetch_sub(1, Ordering::Relaxed);
    let _ = w.req.tx.send(Completion {
        id: w.req.id,
        tokens,
        tier: ctx.tier,
        router_score: w.score,
        mean_logprob,
        e2e,
        routing: w.routed - w.req.t0,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tiers_defaults_and_overrides() {
        let t = parse_tiers("small:1,large:1").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].model, "small");
        assert_eq!(t[0].replicas, 1);
        assert_eq!(t[0].cost, 0.0);
        assert_eq!(t[1].cost, 1.0);

        let t = parse_tiers("nano:2:0.02, medium, large:1:1.0").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].replicas, 2);
        assert!((t[0].cost - 0.02).abs() < 1e-12);
        // omitted cost => even spacing over [0, 1]
        assert!((t[1].cost - 0.5).abs() < 1e-12);
        assert_eq!(t[1].replicas, 1);
        assert_eq!(t[2].cost, 1.0);

        // bare single tier
        let t = parse_tiers("large").unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].cost, 1.0);
    }

    #[test]
    fn parse_tiers_rejects_malformed_specs() {
        assert!(parse_tiers("").is_err());
        assert!(parse_tiers(" , ").is_err());
        assert!(parse_tiers("small:x").is_err());
        assert!(parse_tiers("small:0").is_err());
        assert!(parse_tiers("small:1:abc").is_err());
        assert!(parse_tiers("small:1:0.5:extra").is_err());
        assert!(parse_tiers("small:1:-1").is_err());
        assert!(parse_tiers("small:1:inf").is_err());
    }

    #[test]
    fn two_tier_matches_seed_semantics() {
        let t = two_tier("nano", "micro");
        assert_eq!(t[0].name, "nano");
        assert_eq!(t[0].cost, 0.0);
        assert_eq!(t[1].cost, 1.0);
        let cfg = ServeConfig::two_tier(
            PathBuf::from("a"),
            PathBuf::from("r"),
            "nano",
            "micro",
            String::new(),
            0.5,
        );
        assert_eq!(cfg.policy, TierPolicy::Ladder { thresholds: vec![0.5] });
        assert_eq!(cfg.policy.n_tiers(), Some(2));
        assert_eq!(cfg.tiers.len(), 2);
    }
}
