//! # hybrid-llm — Hybrid LLM query routing (ICLR 2024) reproduction
//!
//! A three-layer serving stack reproducing *"Hybrid LLM: Cost-Efficient and
//! Quality-Aware Query Routing"*:
//!
//! * **L3 (this crate)** — the serving coordinator: query-router service
//!   dispatching over an N-tier model fleet ([`serve::TierSpec`]),
//!   continuous-batching LLM workers (1..N replicas per tier), KV-cache
//!   slot management, the label pipeline (`y_det` / `y_prob` /
//!   `y_trans(t*)`), router training, threshold(-ladder) calibration,
//!   per-tier metrics, and one experiment driver per table and figure of
//!   the paper.
//! * **L2 (JAX, build time)** — transformer LMs / router encoder / scorer,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L1 (Pallas, build time)** — flash-style attention kernels on the
//!   serving hot path.
//!
//! Python never runs at request time: this crate loads `artifacts/*.hlo.txt`
//! through the PJRT C API (the `xla` crate) and drives everything —
//! including *training* the LMs and routers — from Rust.
//!
//! See `DESIGN.md` for the full system inventory, the tier-fleet serving
//! architecture, and the per-experiment index (§6); measured results are
//! rendered into `runs/<name>/results/` by the `eval` drivers.

pub mod batching;
pub mod bench;
pub mod calibrate;
pub mod cli;
pub mod corpus;
pub mod eval;
pub mod io;
pub mod labels;
pub mod lm;
pub mod metrics;
pub mod pipeline;
pub mod policy;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod scorer;
pub mod serve;
pub mod stats;
pub mod testing;
pub mod tokenizer;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
