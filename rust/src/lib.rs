//! # hybrid-llm — Hybrid LLM query routing (ICLR 2024) reproduction
//!
//! A three-layer serving stack reproducing *"Hybrid LLM: Cost-Efficient and
//! Quality-Aware Query Routing"*:
//!
//! * **L3 (this crate)** — the serving coordinator: query-router service
//!   dispatching over an N-tier model fleet ([`serve::TierSpec`]),
//!   continuous-batching LLM workers (1..N replicas per tier), KV-cache
//!   slot management, the label pipeline (`y_det` / `y_prob` /
//!   `y_trans(t*)`), router training, threshold(-ladder) calibration,
//!   per-tier metrics, and one experiment driver per table and figure of
//!   the paper.
//! * **L2 (JAX, build time)** — transformer LMs / router encoder / scorer,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **L1 (Pallas, build time)** — flash-style attention kernels on the
//!   serving hot path.
//!
//! Python never runs at request time: this crate loads `artifacts/*.hlo.txt`
//! through the PJRT C API (the `xla` crate) and drives everything —
//! including *training* the LMs and routers — from Rust.
//!
//! ## The request API
//!
//! The paper's quality/cost knob is a **request parameter**, not server
//! state. A [`serve::Request`] is built fluently — prompt, quality
//! target in `[0, 1]`, token budget, deadline, optional policy override
//! — and submitted through a bounded admission window:
//!
//! ```ignore
//! let server = serve::Server::start(cfg)?;
//! let handle = server.submit(
//!     serve::Request::new(prompt)
//!         .quality(0.9)
//!         .max_new_tokens(32)
//!         .deadline(Duration::from_secs(2)),
//! )?; // Err(Busy) = backpressure, Err(Closed) = server gone
//! for ev in handle.events().iter() {
//!     // Routed { tier, score }, Token { token, logprob } per decoded
//!     // token, then one terminal Done / Failed / Cancelled
//! }
//! ```
//!
//! Per-request quality targets resolve to tiers at routing time through
//! a calibrated quality-indexed ladder family
//! ([`policy::LadderFamily`], built by
//! [`calibrate::calibrate_quality_ladders`]), so requests in the same
//! batch window can trade quality for cost independently.
//! [`serve::RequestHandle::cancel`] frees an in-flight request's KV
//! slot within one decode step; [`serve::RequestHandle::wait`] is the
//! blocking convenience for callers that only want the final
//! [`serve::Completion`].
//!
//! Beyond per-request tier routing, the server offers a **token-level
//! hybrid decode mode** ([`serve::DecodeMode::Hybrid`], DESIGN.md §12):
//! the small tier drafts blocks of tokens from its own KV state and the
//! large tier verifies each block in one `verify@K` forward pass
//! (manifest v5), with longest-prefix acceptance plus a correction
//! token ([`hybrid::resolve_verify`]) keeping the stream byte-identical
//! to large-only greedy decoding whenever every block verifies. The
//! per-token escalation policy ([`policy::should_verify`]) trades
//! verification frequency against the request's quality target, and a
//! verify-path breaker ([`hybrid::VerifyBreaker`]) degrades a large-tier
//! outage to pure small-tier drafting instead of failing requests.
//!
//! Under sustained overload the server doesn't shed blindly: an
//! **overload brownout controller** ([`policy::BrownoutController`],
//! DESIGN.md §13) senses queue sojourn (EWMA of submit→dispatch delay
//! against a CoDel-style target), queue depth, and shed rate, and
//! actuates a small integer brownout level with AIMD ramp-up and
//! hysteretic recovery. Level 1 caps the *effective* quality target
//! resolved through the ladder family — the paper's dial, driven by
//! load; level 2 thins hybrid verification (escalation relaxes, draft
//! blocks shrink); level 3 sheds by request class, strictly
//! lowest-first via [`serve::Request::priority`]
//! ([`policy::Priority`]: `Interactive` / `Batch` / `BestEffort`). At
//! level 0 every actuator is the identity, so an unloaded server is
//! byte-identical to one built without the controller
//! (`ServeConfig::brownout_target: None`). Deadlines are enforced both
//! before dispatch and *mid-decode*: an expired in-flight request is
//! swept from the decode loop, freeing its KV slot for live work.
//!
//! The [`scenario`] module stress-tests this API with trace-driven
//! replays (Poisson bursts, diurnal swings, long-tail lengths, mixed
//! quality targets, overload, cancel storms) gated on serving
//! invariants — `repro kick-tires` runs the whole suite in one command.
//!
//! See `DESIGN.md` for the full system inventory, the tier-fleet serving
//! architecture (§3), the quality→ladder calibration table (§5), and the
//! per-experiment index (§6); measured results are rendered into
//! `runs/<name>/results/` by the `eval` drivers.

pub mod batching;
pub mod bench;
pub mod calibrate;
pub mod cli;
pub mod corpus;
pub mod eval;
pub mod hybrid;
pub mod io;
pub mod labels;
pub mod lm;
pub mod metrics;
pub mod paged;
pub mod pipeline;
pub mod policy;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod scenario;
pub mod scorer;
pub mod serve;
pub mod stats;
pub mod testing;
pub mod tokenizer;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
