//! Label pipeline — the heart of the paper's §3.
//!
//! Given, for every query `x`, `ns` sampled response qualities from the
//! small model (`qs`) and the large model (`ql`):
//!
//! * `y_det(x)  = 1[q(S(x)) >= q(L(x))]` on a single sample pair (§3.1),
//! * `y_prob(x) = Pr[H(x) >= 0]`, estimated over all `ns²` sample pairs
//!   (§3.2; the paper says "sample average of the indicator" — we use the
//!   full product estimator for the lowest variance),
//! * `y_trans(x; t) = Pr[H(x) >= -t]` (§3.3), with `t*` maximizing the
//!   average pairwise label difference (Eq. 3) — computed exactly in
//!   O(N log N) via the sorted-prefix identity rather than the naive
//!   O(N²) double sum.

use anyhow::{ensure, Result};

/// Per-pair quality samples: `q[i][k]` = quality of the k-th sampled
/// response of query i under the BART-analogue scorer.
#[derive(Debug, Clone)]
pub struct QualitySamples {
    pub q: Vec<Vec<f32>>,
}

impl QualitySamples {
    pub fn new(q: Vec<Vec<f32>>) -> Self {
        QualitySamples { q }
    }

    pub fn n_queries(&self) -> usize {
        self.q.len()
    }

    /// Mean quality per query.
    pub fn mean(&self) -> Vec<f64> {
        self.q
            .iter()
            .map(|s| s.iter().map(|&x| x as f64).sum::<f64>() / s.len().max(1) as f64)
            .collect()
    }
}

/// §3.1 deterministic labels from the first sample of each model.
pub fn y_det(qs: &QualitySamples, ql: &QualitySamples) -> Result<Vec<f32>> {
    ensure!(qs.n_queries() == ql.n_queries());
    Ok(qs
        .q
        .iter()
        .zip(&ql.q)
        .map(|(s, l)| {
            ensure_nonempty(s, l);
            f32::from(u8::from(s[0] >= l[0]))
        })
        .collect())
}

fn ensure_nonempty(s: &[f32], l: &[f32]) {
    debug_assert!(!s.is_empty() && !l.is_empty());
}

/// §3.2 probabilistic labels: `Pr[q(S) >= q(L) - t]` over all sample
/// pairs (t = 0 gives `y_prob`).
pub fn y_trans(qs: &QualitySamples, ql: &QualitySamples, t: f32) -> Result<Vec<f32>> {
    ensure!(qs.n_queries() == ql.n_queries());
    Ok(qs
        .q
        .iter()
        .zip(&ql.q)
        .map(|(s, l)| {
            let mut hits = 0usize;
            for &a in s {
                for &b in l {
                    if a >= b - t {
                        hits += 1;
                    }
                }
            }
            hits as f32 / (s.len() * l.len()).max(1) as f32
        })
        .collect())
}

/// §3.2 probabilistic labels (`t = 0`).
pub fn y_prob(qs: &QualitySamples, ql: &QualitySamples) -> Result<Vec<f32>> {
    y_trans(qs, ql, 0.0)
}

/// Mean quality gap `E[q(S(x))] - E[q(L(x))]` per query — used by the
/// router-validation (Fig 6) and generalization (Fig 8) experiments.
pub fn mean_gap(qs: &QualitySamples, ql: &QualitySamples) -> Result<Vec<f64>> {
    ensure!(qs.n_queries() == ql.n_queries());
    Ok(qs
        .mean()
        .iter()
        .zip(ql.mean())
        .map(|(a, b)| a - b)
        .collect())
}

/// Average pairwise absolute difference `1/N² Σ_{i,i'} |y_i - y_{i'}|`
/// (the Eq. 3 objective), exact, via the sorted identity:
/// `Σ_{i<j} (y_(j) - y_(i)) = Σ_j y_(j) (2j - N + 1)` (ascending order).
pub fn pairwise_mean_abs_diff(ys: &[f32]) -> f64 {
    let n = ys.len();
    if n < 2 {
        return 0.0;
    }
    let mut sorted: Vec<f64> = ys.iter().map(|&y| y as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut acc = 0.0;
    for (j, &y) in sorted.iter().enumerate() {
        acc += y * (2.0 * j as f64 - (n as f64 - 1.0));
    }
    2.0 * acc / (n as f64 * n as f64)
}

/// Naive O(N²) reference for the Eq. 3 objective (tests + tiny inputs).
pub fn pairwise_mean_abs_diff_naive(ys: &[f32]) -> f64 {
    let n = ys.len();
    if n == 0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for &a in ys {
        for &b in ys {
            acc += (a as f64 - b as f64).abs();
        }
    }
    acc / (n as f64 * n as f64)
}

/// Result of the Eq. 3 grid search.
#[derive(Debug, Clone)]
pub struct TStarSearch {
    pub tstar: f32,
    /// (t, J(t)) for the whole grid — the Fig. 4b curve.
    pub curve: Vec<(f32, f64)>,
}

/// Grid-search `t*` (Eq. 3). The grid spans `[0, t_max]`; `t_max`
/// defaults to the 95th percentile of observed |gap| so the search
/// brackets the label-spreading optimum at any scorer scale.
pub fn find_tstar(
    qs: &QualitySamples,
    ql: &QualitySamples,
    grid_points: usize,
) -> Result<TStarSearch> {
    ensure!(grid_points >= 2);
    let gaps = mean_gap(qs, ql)?;
    let mut mags: Vec<f64> = gaps.iter().map(|g| g.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t_max = (crate::stats::percentile_sorted(&mags, 95.0) * 2.0).max(1e-3);
    let mut curve = Vec::with_capacity(grid_points);
    let mut best = (0.0f32, f64::MIN);
    for i in 0..grid_points {
        let t = (t_max * i as f64 / (grid_points - 1) as f64) as f32;
        let ys = y_trans(qs, ql, t)?;
        let j = pairwise_mean_abs_diff(&ys);
        curve.push((t, j));
        if j > best.1 {
            best = (t, j);
        }
    }
    Ok(TStarSearch { tstar: best.0, curve })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn qsamples(v: Vec<Vec<f32>>) -> QualitySamples {
        QualitySamples::new(v)
    }

    #[test]
    fn det_uses_first_sample() {
        let qs = qsamples(vec![vec![-1.0, -9.0], vec![-3.0, 0.0]]);
        let ql = qsamples(vec![vec![-2.0, 0.0], vec![-2.0, -9.0]]);
        assert_eq!(y_det(&qs, &ql).unwrap(), vec![1.0, 0.0]);
    }

    #[test]
    fn prob_counts_all_pairs() {
        let qs = qsamples(vec![vec![-1.0, -3.0]]);
        let ql = qsamples(vec![vec![-2.0, -2.0]]);
        // pairs: (-1>=-2) yes, (-1>=-2) yes, (-3>=-2) no, no => 0.5
        assert_eq!(y_prob(&qs, &ql).unwrap(), vec![0.5]);
    }

    #[test]
    fn trans_relaxation_monotone_in_t() {
        let mut rng = Rng::new(3);
        let mk = |rng: &mut Rng| {
            (0..20)
                .map(|_| (0..5).map(|_| -(rng.next_f32() * 5.0)).collect())
                .collect::<Vec<Vec<f32>>>()
        };
        let qs = qsamples(mk(&mut rng));
        let ql = qsamples(mk(&mut rng));
        let y0 = y_trans(&qs, &ql, 0.0).unwrap();
        let y1 = y_trans(&qs, &ql, 0.5).unwrap();
        let y2 = y_trans(&qs, &ql, 2.0).unwrap();
        for i in 0..y0.len() {
            assert!(y1[i] >= y0[i]);
            assert!(y2[i] >= y1[i]);
        }
        // extreme relaxation saturates at 1
        let ybig = y_trans(&qs, &ql, 100.0).unwrap();
        assert!(ybig.iter().all(|&y| y == 1.0));
    }

    #[test]
    fn sorted_objective_matches_naive_property() {
        crate::testing::check("pairwise abs diff sorted == naive", 100, |rng| {
            let n = rng.range(1, 40);
            let ys: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let fast = pairwise_mean_abs_diff(&ys);
            let naive = pairwise_mean_abs_diff_naive(&ys);
            assert!((fast - naive).abs() < 1e-9, "{fast} vs {naive}");
        });
    }

    #[test]
    fn objective_prefers_balanced_labels() {
        // all-equal labels have zero spread; half/half has max spread
        assert_eq!(pairwise_mean_abs_diff(&[0.1; 10]), 0.0);
        let balanced = pairwise_mean_abs_diff(&[0.0, 0.0, 1.0, 1.0]);
        let skewed = pairwise_mean_abs_diff(&[0.0, 0.0, 0.0, 1.0]);
        assert!(balanced > skewed);
    }

    #[test]
    fn tstar_balances_imbalanced_labels() {
        // large model much better: gaps around -2; y_prob ~ 0 everywhere.
        // t* should move labels toward the spread-out regime.
        let mut rng = Rng::new(9);
        let n = 60;
        let qs = qsamples(
            (0..n)
                .map(|i| {
                    let base = -3.0 - (i as f32 / n as f32); // -3..-4
                    (0..5).map(|_| base + 0.2 * (rng.next_f32() - 0.5)).collect()
                })
                .collect(),
        );
        let ql = qsamples(
            (0..n)
                .map(|i| {
                    let base = -1.0 - 2.0 * (i as f32 / n as f32); // -1..-3
                    (0..5).map(|_| base + 0.2 * (rng.next_f32() - 0.5)).collect()
                })
                .collect(),
        );
        let y0 = y_prob(&qs, &ql).unwrap();
        let j0 = pairwise_mean_abs_diff(&y0);
        let search = find_tstar(&qs, &ql, 41).unwrap();
        assert!(search.tstar > 0.0);
        let jstar = pairwise_mean_abs_diff(&y_trans(&qs, &ql, search.tstar).unwrap());
        assert!(jstar >= j0, "{jstar} vs {j0}");
        // curve has the grid size and contains (0, j0)
        assert_eq!(search.curve.len(), 41);
        assert!((search.curve[0].1 - j0).abs() < 1e-9);
    }

    #[test]
    fn mean_gap_math() {
        let qs = qsamples(vec![vec![-1.0, -2.0]]);
        let ql = qsamples(vec![vec![-4.0, -4.0]]);
        assert_eq!(mean_gap(&qs, &ql).unwrap(), vec![2.5]);
    }
}
