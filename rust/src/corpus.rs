//! **MixSynth** — the synthetic instruction corpus standing in for
//! MixInstruct (paper §4.1, Table 5).
//!
//! Ten task families with algorithmic reference answers and graded
//! intrinsic difficulty; combined with the capacity-graded LM roster this
//! yields the paper's key structural property: larger models win on
//! average but the small model matches or beats them on an "easy" subset
//! of queries (Fig. 1b). Queries are grouped into four "sources" to
//! mirror MixInstruct's composition (Table 5).
//!
//! Prompt layout: `[BOS, TASK_KW, COLON, payload..., SEP]` (≤ `S_PROMPT`);
//! reference answer: task-defined tokens (EOS is appended by consumers).

use std::fmt;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::rng::Rng;
use crate::tokenizer as tok;

/// Maximum prompt length — must match the manifest's `sprompt`.
pub const S_PROMPT: usize = 40;
/// Maximum answer length including EOS — must match the manifest's `amax`.
pub const A_MAX: usize = 24;

/// The ten MixSynth task families (token = `TASK0 + Task as i32`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Copy = 0,
    Double = 1,
    Rev = 2,
    Sort = 3,
    Dedup = 4,
    Succ = 5,
    Add = 6,
    Count = 7,
    Extr = 8,
    Rot = 9,
}

pub const ALL_TASKS: [Task; 10] = [
    Task::Copy,
    Task::Double,
    Task::Rev,
    Task::Sort,
    Task::Dedup,
    Task::Succ,
    Task::Add,
    Task::Count,
    Task::Extr,
    Task::Rot,
];

impl Task {
    pub fn name(self) -> &'static str {
        tok::TASK_NAMES[self as usize]
    }

    pub fn from_name(name: &str) -> Option<Task> {
        tok::TASK_NAMES
            .iter()
            .position(|&n| n == name)
            .map(|i| ALL_TASKS[i])
    }

    pub fn keyword_token(self) -> i32 {
        tok::TASK0 + self as i32
    }

    /// Intrinsic difficulty grade in 1..=7 (corpus metadata; the *actual*
    /// hardness emerges from the trained models).
    pub fn difficulty(self) -> u8 {
        match self {
            Task::Copy => 1,
            Task::Double => 2,
            Task::Rev => 3,
            Task::Dedup => 3,
            Task::Extr => 3,
            Task::Succ => 4,
            Task::Rot => 5,
            Task::Sort => 6,
            Task::Count => 6,
            Task::Add => 7,
        }
    }

    /// "Source" grouping used to mirror MixInstruct's Table 5.
    pub fn source(self) -> &'static str {
        match self {
            Task::Copy | Task::Double | Task::Rev => "SynthAlpaca",
            Task::Dedup | Task::Extr => "SynthDolly",
            Task::Succ | Task::Rot | Task::Sort => "SynthGPT4All",
            Task::Count | Task::Add => "SynthShare",
        }
    }
}

/// Dataset split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

impl Split {
    pub fn name(self) -> &'static str {
        match self {
            Split::Train => "train",
            Split::Val => "val",
            Split::Test => "test",
        }
    }

    pub fn from_name(s: &str) -> Option<Split> {
        match s {
            "train" => Some(Split::Train),
            "val" => Some(Split::Val),
            "test" => Some(Split::Test),
            _ => None,
        }
    }
}

impl fmt::Display for Split {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One query: prompt tokens + algorithmic reference answer.
#[derive(Debug, Clone)]
pub struct Query {
    pub id: usize,
    pub split: Split,
    pub task: Task,
    /// Full prompt: `[BOS, KW, COLON, payload..., SEP]`.
    pub prompt: Vec<i32>,
    /// Reference answer tokens (no EOS).
    pub reference: Vec<i32>,
}

impl Query {
    /// Payload = prompt without frame tokens.
    pub fn payload(&self) -> &[i32] {
        &self.prompt[3..self.prompt.len() - 1]
    }
}

/// Compute the reference answer for `(task, payload)`.
pub fn reference(task: Task, payload: &[i32]) -> Vec<i32> {
    match task {
        Task::Copy => payload.to_vec(),
        Task::Double => payload.iter().flat_map(|&t| [t, t]).collect(),
        Task::Rev => payload.iter().rev().copied().collect(),
        Task::Sort => {
            let mut v = payload.to_vec();
            v.sort_unstable();
            v
        }
        Task::Dedup => {
            let mut out: Vec<i32> = Vec::new();
            for &t in payload {
                if out.last() != Some(&t) {
                    out.push(t);
                }
            }
            out
        }
        Task::Succ => payload
            .iter()
            .map(|&t| {
                debug_assert!(tok::is_digit(t));
                tok::digit((tok::digit_val(t) + 1) % 10)
            })
            .collect(),
        Task::Add => {
            debug_assert_eq!(payload.len(), 4);
            let num = |a: i32, b: i32| tok::digit_val(a) * 10 + tok::digit_val(b);
            let sum = num(payload[0], payload[1]) + num(payload[2], payload[3]);
            tok::encode_number(sum)
        }
        Task::Count => tok::encode_number(payload.len() as u32),
        Task::Extr => {
            let pos = payload
                .iter()
                .position(|&t| t == tok::COLON)
                .expect("EXTR payload must contain COLON");
            payload[pos + 1..].to_vec()
        }
        Task::Rot => payload
            .iter()
            .map(|&t| {
                debug_assert!(tok::is_letter(t));
                tok::LETTER0 + ((t - tok::LETTER0 + 1) % tok::N_LETTERS)
            })
            .collect(),
    }
}

fn gen_payload(task: Task, rng: &mut Rng) -> Vec<i32> {
    let rand_letters =
        |rng: &mut Rng, n: usize| (0..n).map(|_| tok::LETTER0 + rng.below(26) as i32).collect::<Vec<_>>();
    let rand_digits =
        |rng: &mut Rng, n: usize| (0..n).map(|_| tok::digit(rng.below(10) as u32)).collect::<Vec<_>>();
    match task {
        Task::Copy | Task::Rev | Task::Sort | Task::Rot => {
            let n = rng.range(3, 12);
            rand_letters(rng, n)
        }
        Task::Double => {
            let n = rng.range(3, 10);
            rand_letters(rng, n)
        }
        Task::Count => {
            let n = rng.range(3, 12);
            rand_letters(rng, n)
        }
        Task::Succ => {
            let n = rng.range(3, 10);
            rand_digits(rng, n)
        }
        Task::Add => rand_digits(rng, 4),
        Task::Dedup => {
            // draw from a small alphabet so consecutive repeats occur
            let n = rng.range(4, 12);
            let alpha: Vec<i32> = (0..4).map(|i| tok::LETTER0 + i).collect();
            let mut v = Vec::with_capacity(n);
            let mut cur = alpha[rng.below(alpha.len())];
            for _ in 0..n {
                if rng.next_f64() < 0.5 {
                    cur = alpha[rng.below(alpha.len())];
                }
                v.push(cur);
            }
            v
        }
        Task::Extr => {
            let n1 = rng.range(2, 6);
            let mut v = rand_letters(rng, n1);
            v.push(tok::COLON);
            let n2 = rng.range(2, 6);
            v.extend(rand_letters(rng, n2));
            v
        }
    }
}

/// Build one query with the standard prompt frame.
pub fn make_query(id: usize, split: Split, task: Task, rng: &mut Rng) -> Query {
    let payload = gen_payload(task, rng);
    let mut prompt = Vec::with_capacity(payload.len() + 4);
    prompt.push(tok::BOS);
    prompt.push(task.keyword_token());
    prompt.push(tok::COLON);
    prompt.extend_from_slice(&payload);
    prompt.push(tok::SEP);
    debug_assert!(prompt.len() <= S_PROMPT, "prompt too long: {}", prompt.len());
    let reference = reference(task, &payload);
    debug_assert!(reference.len() + 1 <= A_MAX, "answer too long");
    Query { id, split, task, prompt, reference }
}

/// Corpus scale presets (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized, minutes on CPU.
    Smoke,
    /// Between smoke and default: the single-CPU-hour reproduction.
    Mid,
    /// The default reproduction scale.
    Default,
    /// The paper's 10k/5k/5k.
    Paper,
}

impl Scale {
    pub fn from_name(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "mid" => Some(Scale::Mid),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// (n_train, n_val, n_test)
    pub fn sizes(self) -> (usize, usize, usize) {
        match self {
            Scale::Smoke => (256, 96, 96),
            Scale::Mid => (768, 512, 512),
            Scale::Default => (2000, 1000, 1000),
            Scale::Paper => (10_000, 5_000, 5_000),
        }
    }

    /// Number of sampled responses per (query, model) — paper uses 10.
    pub fn n_samples(self) -> usize {
        match self {
            Scale::Smoke => 4,
            Scale::Mid => 6,
            _ => 10,
        }
    }

    /// LM pre-training step multiplier.
    pub fn train_mult(self) -> f64 {
        match self {
            Scale::Smoke => 0.25,
            Scale::Mid => 0.6,
            _ => 1.0,
        }
    }
}

/// Generate the full corpus (train/val/test), uniformly over tasks, with
/// a deterministic seed. Queries get sequential ids: train, then val,
/// then test (the id is the row index everywhere downstream).
pub fn generate(seed: u64, scale: Scale) -> Vec<Query> {
    let (n_train, n_val, n_test) = scale.sizes();
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n_train + n_val + n_test);
    let mut id = 0;
    for (split, n) in [
        (Split::Train, n_train),
        (Split::Val, n_val),
        (Split::Test, n_test),
    ] {
        for _ in 0..n {
            let task = ALL_TASKS[rng.below(ALL_TASKS.len())];
            out.push(make_query(id, split, task, &mut rng));
            id += 1;
        }
    }
    out
}

/// Save the corpus as TSV (`split, task, prompt, reference` — rendered
/// with the tokenizer's reversible text form).
pub fn save(path: &Path, corpus: &[Query]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut s = String::with_capacity(corpus.len() * 48);
    for q in corpus {
        s.push_str(&format!(
            "{}\t{}\t{}\t{}\n",
            q.split.name(),
            q.task.name(),
            tok::detokenize(&q.prompt),
            tok::detokenize(&q.reference),
        ));
    }
    fs::write(path, s)?;
    Ok(())
}

/// Load a TSV corpus written by [`save`].
pub fn load(path: &Path) -> Result<Vec<Query>> {
    let text = fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let mut f = line.split('\t');
        let (Some(split), Some(task), Some(prompt), Some(reference)) =
            (f.next(), f.next(), f.next(), f.next())
        else {
            bail!("{path:?}:{}: bad corpus line", i + 1);
        };
        let split = Split::from_name(split).with_context(|| format!("bad split {split}"))?;
        let task = Task::from_name(task).with_context(|| format!("bad task {task}"))?;
        let prompt = tok::tokenize(prompt).context("bad prompt")?;
        let reference = tok::tokenize(reference).context("bad reference")?;
        out.push(Query { id: i, split, task, prompt, reference });
    }
    Ok(out)
}

/// Indices of a given split.
pub fn split_ids(corpus: &[Query], split: Split) -> Vec<usize> {
    corpus
        .iter()
        .enumerate()
        .filter(|(_, q)| q.split == split)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn references_are_correct() {
        use crate::tokenizer::{digit, letter};
        let p = [letter('c'), letter('a'), letter('b')];
        assert_eq!(reference(Task::Copy, &p), p.to_vec());
        assert_eq!(
            reference(Task::Rev, &p),
            vec![letter('b'), letter('a'), letter('c')]
        );
        assert_eq!(
            reference(Task::Sort, &p),
            vec![letter('a'), letter('b'), letter('c')]
        );
        assert_eq!(
            reference(Task::Double, &[letter('a'), letter('b')]),
            vec![letter('a'), letter('a'), letter('b'), letter('b')]
        );
        assert_eq!(
            reference(Task::Dedup, &[letter('a'), letter('a'), letter('b'), letter('a')]),
            vec![letter('a'), letter('b'), letter('a')]
        );
        assert_eq!(
            reference(Task::Succ, &[digit(0), digit(9), digit(4)]),
            vec![digit(1), digit(0), digit(5)]
        );
        // 17 + 25 = 42
        assert_eq!(
            reference(Task::Add, &[digit(1), digit(7), digit(2), digit(5)]),
            vec![digit(4), digit(2)]
        );
        assert_eq!(reference(Task::Count, &p), vec![digit(3)]);
        assert_eq!(
            reference(Task::Extr, &[letter('x'), tok::COLON, letter('p'), letter('q')]),
            vec![letter('p'), letter('q')]
        );
        assert_eq!(
            reference(Task::Rot, &[letter('a'), letter('z')]),
            vec![letter('b'), letter('a')]
        );
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(7, Scale::Smoke);
        let b = generate(7, Scale::Smoke);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.reference, y.reference);
        }
        let c = generate(8, Scale::Smoke);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn prompts_and_answers_fit_limits() {
        // property: every generated query satisfies the frame invariants
        for q in generate(3, Scale::Default) {
            assert!(q.prompt.len() <= S_PROMPT, "{:?}", q);
            assert!(q.reference.len() + 1 <= A_MAX, "{:?}", q);
            assert_eq!(q.prompt[0], tok::BOS);
            assert_eq!(q.prompt[1], q.task.keyword_token());
            assert_eq!(q.prompt[2], tok::COLON);
            assert_eq!(*q.prompt.last().unwrap(), tok::SEP);
            assert_eq!(reference(q.task, q.payload()), q.reference);
        }
    }

    #[test]
    fn splits_have_requested_sizes() {
        let c = generate(1, Scale::Smoke);
        let (nt, nv, ns) = Scale::Smoke.sizes();
        assert_eq!(split_ids(&c, Split::Train).len(), nt);
        assert_eq!(split_ids(&c, Split::Val).len(), nv);
        assert_eq!(split_ids(&c, Split::Test).len(), ns);
        // ids are the row index
        for (i, q) in c.iter().enumerate() {
            assert_eq!(q.id, i);
        }
    }

    #[test]
    fn tsv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("hybrid_corpus_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("corpus.tsv");
        let c = generate(11, Scale::Smoke);
        save(&p, &c).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), c.len());
        for (x, y) in c.iter().zip(&back) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.reference, y.reference);
            assert_eq!(x.task, y.task);
            assert_eq!(x.split, y.split);
            assert_eq!(x.id, y.id);
        }
    }

    #[test]
    fn all_tasks_appear() {
        let c = generate(5, Scale::Default);
        for t in ALL_TASKS {
            assert!(c.iter().any(|q| q.task == t), "{t:?} missing");
        }
    }

    #[test]
    fn extr_payload_always_has_colon() {
        let mut rng = Rng::new(2);
        for i in 0..200 {
            let q = make_query(i, Split::Train, Task::Extr, &mut rng);
            assert!(q.payload().contains(&tok::COLON));
        }
    }
}
