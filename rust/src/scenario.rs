//! Trace-driven scenario harness: replay recorded (or synthesized)
//! request traces against a running [`Server`] and gate the outcome on
//! serving invariants.
//!
//! The paper's headline claim — up to 40% fewer large-model calls with
//! no quality drop — is only credible under realistic traffic, and the
//! steady offered load the benches measure is the *easiest* regime for
//! a serving loop. This module supplies the hard ones: Poisson bursts,
//! diurnal rate swings, long-tail prompt/answer lengths, mixed
//! per-request quality targets, overload against a small admission
//! window, and mass mid-decode cancellation. Each scenario drives the
//! first-class request API ([`Request`]/[`RequestHandle`]) exactly the
//! way an external client would — live event draining, per-token
//! stream accounting, client-side cancels — and every replay is
//! checked against the invariants the API documents:
//!
//! * **exactly one terminal event** (`Done`/`Failed`/`Cancelled`) per
//!   accepted request, stream never silently dropped;
//! * **stream/completion agreement**: the concatenated `Token` events
//!   equal `Completion::tokens`;
//! * **counter balance at drain**: `completed + cancelled + shed +
//!   failed` equals accepted submits, and `in_flight` returns to zero;
//! * **bounded queue honored**: the sampled in-flight count never
//!   exceeds [`ServeConfig::queue_cap`];
//! * **O(B) transfer bounds preserved** (manifest-v3 artifacts):
//!   admission moves O(B·sprompt) host bytes per request and decode
//!   steps never approach the KV-pair round-trip.
//!
//! [`kick_tires`] is the one-command entry point (CLI subcommand
//! `repro kick-tires`, also run by the `serving_e2e` bench): it runs
//! every built-in scenario, renders a serving report, and merges
//! per-scenario metrics into the `BENCH_serving.json` perf trajectory.
//!
//! Traces are plain text (`# hybrid-trace v1` header, one
//! `key=value`-pair line per request) so real workloads can be
//! recorded, committed, and replayed deterministically; the synthetic
//! generators are seeded and reproduce bit-identically from a seed.

use std::path::{Path, PathBuf};
use std::sync::mpsc::TryRecvError;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::batching::BatchMode;
use crate::policy::{Priority, PRIORITY_CLASSES};
use crate::rng::Rng;
use crate::runtime::Manifest;
use crate::serve::{
    self, submit_with_retry, DecodeMode, Event, Fault, FaultKind, FaultPlan, Request,
    RequestHandle, ServeConfig, Server, ServerStats,
};
use crate::stats;
use crate::tokenizer as tok;

/// One request in a trace: when it arrives and what it asks for.
/// Prompts are described by length only — the replay engine fabricates
/// deterministic token content, so traces stay small and carry no
/// payload data.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Arrival offset from trace start.
    pub at: Duration,
    /// Prompt length in tokens (clamped to the artifacts' window at
    /// replay).
    pub prompt_len: usize,
    /// Per-request quality target ([`Request::quality`]).
    pub quality: Option<f32>,
    /// Token budget ([`Request::max_new_tokens`]).
    pub max_new: Option<usize>,
    /// Relative deadline ([`Request::deadline`]).
    pub deadline: Option<Duration>,
    /// Client-side cancel this long after the request is accepted.
    pub cancel_after: Option<Duration>,
    /// Prompt-content salt for [`synthetic_prompt`]. Events sharing a
    /// salt get positionally identical token content, so a longer
    /// prompt extends a shorter one exactly — how a trace expresses
    /// multi-turn sessions over a shared system prompt (the prefix-
    /// cache workload). `None` salts by event index: all prompts
    /// distinct.
    pub salt: Option<usize>,
    /// Priority class ([`Request::priority`]); `None` = the server
    /// default (`Interactive`).
    pub priority: Option<Priority>,
}

impl TraceEvent {
    pub fn new(at: Duration, prompt_len: usize) -> TraceEvent {
        TraceEvent {
            at,
            prompt_len,
            quality: None,
            max_new: None,
            deadline: None,
            cancel_after: None,
            salt: None,
            priority: None,
        }
    }
}

/// A named request trace, sorted by arrival time.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub name: String,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Total time span from first to last arrival.
    pub fn span(&self) -> Duration {
        self.events.last().map(|e| e.at).unwrap_or(Duration::ZERO)
    }

    /// Serialize to the `hybrid-trace v1` text format: a header line,
    /// then one `key=value` pair line per request (times in µs).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut s = format!("# hybrid-trace v1 {}\n", self.name);
        for e in &self.events {
            s.push_str(&format!("at_us={} plen={}", e.at.as_micros(), e.prompt_len));
            if let Some(q) = e.quality {
                s.push_str(&format!(" q={q}"));
            }
            if let Some(m) = e.max_new {
                s.push_str(&format!(" max={m}"));
            }
            if let Some(d) = e.deadline {
                s.push_str(&format!(" dl_us={}", d.as_micros()));
            }
            if let Some(c) = e.cancel_after {
                s.push_str(&format!(" cancel_us={}", c.as_micros()));
            }
            if let Some(sa) = e.salt {
                s.push_str(&format!(" salt={sa}"));
            }
            if let Some(p) = e.priority {
                s.push_str(&format!(" prio={}", p.name()));
            }
            s.push('\n');
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, s).with_context(|| format!("writing trace {path:?}"))
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
        Trace::parse(&text)
    }

    /// Parse the text format; rejects unknown versions and malformed
    /// pairs instead of guessing.
    pub fn parse(text: &str) -> Result<Trace> {
        let mut lines = text.lines();
        let header = lines.next().context("empty trace file")?;
        let name = header
            .strip_prefix("# hybrid-trace v1")
            .with_context(|| format!("bad trace header {header:?}"))?
            .trim()
            .to_string();
        let mut events = Vec::new();
        for (ln, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut at = None;
            let mut ev = TraceEvent::new(Duration::ZERO, 0);
            for pair in line.split_whitespace() {
                let (k, v) = pair
                    .split_once('=')
                    .with_context(|| format!("trace line {}: bad pair {pair:?}", ln + 2))?;
                let parse_u64 = || {
                    v.parse::<u64>()
                        .with_context(|| format!("trace line {}: bad {k}={v}", ln + 2))
                };
                match k {
                    "at_us" => at = Some(Duration::from_micros(parse_u64()?)),
                    "plen" => ev.prompt_len = parse_u64()? as usize,
                    "q" => {
                        ev.quality = Some(v.parse::<f32>().with_context(|| {
                            format!("trace line {}: bad q={v}", ln + 2)
                        })?)
                    }
                    "max" => ev.max_new = Some(parse_u64()? as usize),
                    "dl_us" => ev.deadline = Some(Duration::from_micros(parse_u64()?)),
                    "cancel_us" => ev.cancel_after = Some(Duration::from_micros(parse_u64()?)),
                    "salt" => ev.salt = Some(parse_u64()? as usize),
                    "prio" => {
                        ev.priority = Some(match v {
                            "interactive" => Priority::Interactive,
                            "batch" => Priority::Batch,
                            "best-effort" => Priority::BestEffort,
                            other => anyhow::bail!(
                                "trace line {}: unknown prio {other:?}",
                                ln + 2
                            ),
                        })
                    }
                    other => anyhow::bail!("trace line {}: unknown key {other:?}", ln + 2),
                }
            }
            ev.at = at.with_context(|| format!("trace line {}: missing at_us", ln + 2))?;
            anyhow::ensure!(ev.prompt_len > 0, "trace line {}: missing/zero plen", ln + 2);
            events.push(ev);
        }
        events.sort_by_key(|e| e.at);
        Ok(Trace { name, events })
    }
}

/// Artifact shape the generators target (from [`Manifest`] globals).
#[derive(Debug, Clone, Copy)]
pub struct GenShape {
    /// Prompt window (`sprompt`).
    pub sprompt: usize,
    /// Answer budget (`amax`).
    pub amax: usize,
}

fn exp_us(rng: &mut Rng, mean_us: f64) -> u64 {
    // inverse-CDF exponential draw; 1 - f64 in [0,1) keeps ln finite
    (-(1.0 - rng.next_f64()).ln() * mean_us).round() as u64
}

fn plen_uniform(rng: &mut Rng, shape: GenShape) -> usize {
    rng.range((shape.sprompt / 4).max(1), shape.sprompt.max(2))
}

/// Steady offered load: fixed inter-arrival gap, uniform mid-size
/// prompts — the regime the benches already measure, kept as the
/// control scenario.
pub fn gen_steady(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0x57EAD7);
    let mut events = Vec::with_capacity(n);
    for i in 0..n {
        events.push(TraceEvent::new(
            Duration::from_micros(i as u64 * 3_000),
            plen_uniform(&mut rng, shape),
        ));
    }
    Trace { name: "steady".into(), events }
}

/// Poisson arrivals with burst episodes: exponential inter-arrival gaps
/// at a base rate, with every third batch of arrivals compressed ~10×
/// — the bursty traffic ConsRoute-style cloud–edge deployments see.
pub fn gen_poisson_burst(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0xB0257);
    let mut events = Vec::with_capacity(n);
    let mut t_us = 0u64;
    for i in 0..n {
        let mean = if (i / 8) % 3 == 2 { 400.0 } else { 4_000.0 };
        t_us += exp_us(&mut rng, mean);
        events.push(TraceEvent::new(
            Duration::from_micros(t_us),
            plen_uniform(&mut rng, shape),
        ));
    }
    Trace { name: "poisson-burst".into(), events }
}

/// Diurnal arrivals: the instantaneous rate swings sinusoidally
/// (peak ≈ 9× trough) over the trace, compressing a day's load curve
/// into one replay.
pub fn gen_diurnal(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0xD1024A1);
    let mut events = Vec::with_capacity(n);
    let mut t_us = 0u64;
    let period_us = 120_000.0; // one "day"
    for _ in 0..n {
        let phase = (t_us as f64 / period_us) * std::f64::consts::TAU;
        let rate_scale = 1.0 + 0.8 * phase.sin(); // in [0.2, 1.8]
        t_us += exp_us(&mut rng, 3_000.0 / rate_scale);
        events.push(TraceEvent::new(
            Duration::from_micros(t_us),
            plen_uniform(&mut rng, shape),
        ));
    }
    Trace { name: "diurnal".into(), events }
}

/// Long-tail prompt and answer lengths: exponential draws clamped to
/// the artifact windows, so most requests are short and a few pin the
/// full prompt window or answer budget — the length skew that stresses
/// slot occupancy.
pub fn gen_long_tail(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0x107A11);
    let mut events = Vec::with_capacity(n);
    let mut t_us = 0u64;
    for _ in 0..n {
        t_us += exp_us(&mut rng, 3_000.0);
        let plen =
            (1 + exp_us(&mut rng, shape.sprompt as f64 / 4.0) as usize).min(shape.sprompt);
        let max_new =
            (1 + exp_us(&mut rng, shape.amax as f64 / 4.0) as usize).min(shape.amax);
        let mut ev = TraceEvent::new(Duration::from_micros(t_us), plen);
        ev.max_new = Some(max_new);
        events.push(ev);
    }
    Trace { name: "long-tail".into(), events }
}

/// Mixed per-request quality targets: each request carries its own
/// cost/quality knob, exercising the quality-indexed ladder family with
/// heterogeneous batches (the paper's knob as a *request* parameter).
pub fn gen_mixed_quality(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0x3B1A7);
    const LEVELS: [f32; 5] = [0.05, 0.25, 0.5, 0.75, 0.95];
    let mut events = Vec::with_capacity(n);
    for i in 0..n {
        let mut ev = TraceEvent::new(
            Duration::from_micros(i as u64 * 2_500),
            plen_uniform(&mut rng, shape),
        );
        ev.quality = Some(LEVELS[rng.below(LEVELS.len())]);
        events.push(ev);
    }
    Trace { name: "mixed-quality".into(), events }
}

/// Traffic for the hybrid draft–verify decode mode: mixed per-request
/// quality targets (exercising the escalation policy's verify/local
/// split) over varied token budgets, so verify blocks of every bucket
/// size and mid-block EOS/budget exhaustion all occur. Arrivals are
/// gently bursty to keep several lanes resident at once — the masking
/// rule for occupied-but-inactive lanes only matters under concurrency.
pub fn gen_hybrid_decode(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0x4B12D);
    const LEVELS: [f32; 5] = [0.05, 0.25, 0.5, 0.75, 1.0];
    let mut events = Vec::with_capacity(n);
    let mut t_us = 0u64;
    for i in 0..n {
        t_us += if i % 4 == 0 { exp_us(&mut rng, 6_000.0) } else { exp_us(&mut rng, 800.0) };
        let mut ev = TraceEvent::new(Duration::from_micros(t_us), plen_uniform(&mut rng, shape));
        ev.quality = Some(LEVELS[rng.below(LEVELS.len())]);
        ev.max_new = Some(rng.range(1, shape.amax));
        events.push(ev);
    }
    Trace { name: "hybrid-decode".into(), events }
}

/// Overload against a small admission window: arrivals far faster than
/// service with short deadlines. Run with a reduced `queue_cap` and no
/// Busy retries — the point is that backpressure (`Busy`) and deadline
/// shedding engage and the counters still balance.
pub fn gen_overload(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0x0E7105D);
    let mut events = Vec::with_capacity(n);
    let mut t_us = 0u64;
    for _ in 0..n {
        t_us += exp_us(&mut rng, 250.0);
        let mut ev = TraceEvent::new(Duration::from_micros(t_us), plen_uniform(&mut rng, shape));
        ev.deadline = Some(Duration::from_millis(rng.range(10, 60) as u64));
        events.push(ev);
    }
    Trace { name: "overload-shed".into(), events }
}

/// Mass mid-decode cancellation: every request asks for the full answer
/// budget and the client cancels most of them a few milliseconds after
/// acceptance, landing cancels on queued *and* decoding requests.
pub fn gen_cancel_storm(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0xCA4CE1);
    let mut events = Vec::with_capacity(n);
    for i in 0..n {
        let mut ev = TraceEvent::new(
            Duration::from_micros(i as u64 * 1_500),
            plen_uniform(&mut rng, shape),
        );
        ev.max_new = Some(shape.amax);
        if i % 4 != 3 {
            // 75% of requests cancel between ~1 ms and ~50 ms after
            // acceptance — spread across queued and mid-decode states
            ev.cancel_after = Some(Duration::from_micros(rng.range(1_000, 50_000) as u64));
        }
        events.push(ev);
    }
    Trace { name: "cancel-storm".into(), events }
}

/// Sustained ~3× overload with mixed priority classes, then a quiet
/// tail: the brownout workload. The burst phase offers interactive,
/// batch, and best-effort traffic round-robin at arrivals far faster
/// than service — every request carries a high quality target so the
/// L1 quality cap is observable as `effective_quality_delta` — and the
/// tail phase trickles sparse interactive requests long enough for the
/// controller's hysteretic recovery (ticks every ~10 ms, six calm
/// ticks per level) to walk the level back to 0 *before* the trace
/// ends, so the drained-stats `brownout_level == 0` invariant is
/// meaningful rather than racy.
pub fn gen_overload_brownout(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0xB40740);
    let mut events = Vec::with_capacity(n);
    let tail = 8.min(n.saturating_sub(1));
    let burst = n - tail;
    let mut t_us = 0u64;
    for i in 0..burst {
        t_us += exp_us(&mut rng, 300.0);
        let mut ev = TraceEvent::new(Duration::from_micros(t_us), plen_uniform(&mut rng, shape));
        ev.quality = Some(0.9);
        ev.max_new = Some(rng.range(4, shape.amax));
        ev.priority = Some(Priority::all()[i % crate::policy::PRIORITY_CLASSES]);
        events.push(ev);
    }
    // quiet tail: sparse interactive trickle while the server drains
    t_us += 250_000;
    for _ in 0..tail {
        let mut ev = TraceEvent::new(
            Duration::from_micros(t_us),
            (shape.sprompt / 4).max(1),
        );
        ev.quality = Some(0.9);
        ev.max_new = Some(2);
        ev.priority = Some(Priority::Interactive);
        events.push(ev);
        t_us += 120_000;
    }
    Trace { name: "overload-brownout".into(), events }
}

/// Multi-turn conversations over a shared seeded system prompt: every
/// request opens with the same system-prompt content (one shared
/// [`TraceEvent::salt`]), and each conversation's turns extend the
/// context a few tokens at a time — so consecutive turns re-send an
/// ever-longer prefix the server has already seen. The prefix-cache
/// workload: with cross-request sharing on, the hot system prompt is
/// prefilled once per worker and every later turn's shared blocks skip
/// prefill work.
pub fn gen_sessions(seed: u64, n: usize, shape: GenShape) -> Trace {
    let mut rng = Rng::new(seed ^ 0x5E5510);
    // the shared system prompt: half the window, identical content for
    // every request in the trace (the salt *is* its identity)
    let sys_len = (shape.sprompt / 2).max(1);
    let salt = 13 + (seed % 7) as usize;
    let mut events = Vec::with_capacity(n);
    let mut t_us = 0u64;
    let mut i = 0;
    while i < n {
        // one conversation: 2–4 turns, each extending the shared context
        let turns = rng.range(2, 4);
        let mut plen = sys_len;
        for _ in 0..turns {
            if i >= n {
                break;
            }
            t_us += exp_us(&mut rng, 2_000.0);
            let mut ev = TraceEvent::new(Duration::from_micros(t_us), plen.min(shape.sprompt));
            ev.salt = Some(salt);
            events.push(ev);
            plen += rng.range(1, (shape.sprompt / 8).max(2));
            i += 1;
        }
    }
    Trace { name: "sessions".into(), events }
}

/// Replay knobs.
#[derive(Debug, Clone)]
pub struct ReplayOpts {
    /// Retry `SubmitError::Busy` (with event draining between attempts)
    /// until `busy_retry_for` elapses; `false` counts the rejection and
    /// moves on — the right mode for overload scenarios where Busy *is*
    /// the expected behavior.
    pub retry_busy: bool,
    pub busy_retry_for: Duration,
    /// Hard cap on waiting for terminal events after the last submit.
    pub drain_timeout: Duration,
}

impl Default for ReplayOpts {
    fn default() -> Self {
        ReplayOpts {
            retry_busy: true,
            busy_retry_for: Duration::from_secs(10),
            drain_timeout: Duration::from_secs(120),
        }
    }
}

/// Client-side outcome of one trace replay: the request ledger reduced
/// to counts, plus client-observed end-to-end latencies. Invariant
/// violations are *detected* from this plus the server's
/// [`ServerStats`] by [`check_invariants`].
#[derive(Debug, Clone, Default)]
pub struct ReplayOutcome {
    pub name: String,
    pub wall: Duration,
    /// Requests accepted by `submit` (the invariant baseline).
    pub accepted: usize,
    /// `SubmitError::Busy` rejections (after retries, if enabled).
    pub busy_rejected: usize,
    /// Terminal `Done` events observed.
    pub done: usize,
    /// Terminal `Failed` events observed (deadline sheds, worker-death
    /// failures past the retry budget, whole-fleet outages).
    pub failed: usize,
    /// Terminal `Cancelled` events observed.
    pub cancelled: usize,
    /// Accepted requests whose stream closed with *no* terminal event.
    pub no_terminal: usize,
    /// Accepted requests that received *more than one* terminal event.
    pub multi_terminal: usize,
    /// `Done` completions whose streamed `Token` count diverged from
    /// `Completion::tokens`.
    pub stream_mismatch: usize,
    /// Total `Token` events observed.
    pub tokens_streamed: usize,
    /// Max of `Server::in_flight()` sampled after each accepted submit.
    pub max_in_flight: u64,
    /// Client-observed submit → terminal latencies, ms.
    pub e2e_ms: Vec<f64>,
    /// Trace events offered per priority class (accepted or not),
    /// indexed by [`Priority::index`].
    pub class_offered: [usize; PRIORITY_CLASSES],
    /// Accepted submits per priority class.
    pub class_accepted: [usize; PRIORITY_CLASSES],
    /// Terminal `Done` events per priority class — the per-class
    /// goodput numerator for the brownout gates.
    pub class_done: [usize; PRIORITY_CLASSES],
}

impl ReplayOutcome {
    /// Fraction of offered interactive requests that completed (`Done`);
    /// 1.0 when none were offered — the brownout goodput gate.
    pub fn interactive_goodput(&self) -> f64 {
        let i = Priority::Interactive.index();
        if self.class_offered[i] == 0 {
            return 1.0;
        }
        self.class_done[i] as f64 / self.class_offered[i] as f64
    }

    pub fn e2e_p50_ms(&self) -> f64 {
        stats::percentile(&self.e2e_ms, 50.0)
    }
    pub fn e2e_p95_ms(&self) -> f64 {
        stats::percentile(&self.e2e_ms, 95.0)
    }
    pub fn e2e_p99_ms(&self) -> f64 {
        stats::percentile(&self.e2e_ms, 99.0)
    }
}

/// Ledger entry for one accepted request during replay.
struct Tracked {
    handle: RequestHandle,
    submitted: Instant,
    cancel_at: Option<Instant>,
    cancel_sent: bool,
    streamed: usize,
    terminals: usize,
    done_tokens: Option<usize>,
    open: bool,
    priority: Priority,
}

/// Fabricate a deterministic prompt of `len` letter tokens (valid vocab,
/// no specials) — trace replays carry lengths, not payloads.
pub fn synthetic_prompt(len: usize, salt: usize) -> Vec<i32> {
    (0..len.max(1))
        .map(|i| tok::LETTER0 + ((i + salt) % tok::N_LETTERS as usize) as i32)
        .collect()
}

/// Drain every open handle's event stream without blocking; send due
/// client cancels. Returns `true` when all ledger entries are closed.
fn drain(tracked: &mut [Tracked], out: &mut ReplayOutcome, now: Instant) -> bool {
    let mut all_closed = true;
    for t in tracked.iter_mut() {
        if let Some(at) = t.cancel_at {
            if !t.cancel_sent && now >= at {
                t.handle.cancel();
                t.cancel_sent = true;
            }
        }
        if !t.open {
            continue;
        }
        loop {
            match t.handle.events().try_recv() {
                Ok(Event::Routed { .. }) => {}
                Ok(Event::Token { .. }) => {
                    t.streamed += 1;
                    out.tokens_streamed += 1;
                }
                Ok(ev @ (Event::Done(_) | Event::Failed { .. } | Event::Cancelled)) => {
                    t.terminals += 1;
                    if t.terminals == 1 {
                        out.e2e_ms
                            .push(t.submitted.elapsed().as_secs_f64() * 1e3);
                    }
                    match ev {
                        Event::Done(c) => {
                            out.done += 1;
                            out.class_done[t.priority.index()] += 1;
                            t.done_tokens = Some(c.tokens.len());
                        }
                        Event::Failed { .. } => out.failed += 1,
                        Event::Cancelled => out.cancelled += 1,
                        _ => unreachable!(),
                    }
                }
                Err(TryRecvError::Empty) => {
                    all_closed = false;
                    break;
                }
                Err(TryRecvError::Disconnected) => {
                    t.open = false;
                    break;
                }
            }
        }
    }
    all_closed
}

/// Replay `trace` against a running server, following arrival times in
/// real time, draining event streams live, and sending client cancels
/// on schedule. Returns the client-side ledger reduced to a
/// [`ReplayOutcome`]; pair it with the server's post-shutdown
/// [`ServerStats`] and [`check_invariants`] to gate the scenario.
pub fn replay(server: &Server, trace: &Trace, opts: &ReplayOpts) -> Result<ReplayOutcome> {
    let mut out = ReplayOutcome { name: trace.name.clone(), ..Default::default() };
    let mut tracked: Vec<Tracked> = Vec::with_capacity(trace.events.len());
    // seeded backoff jitter: replays stay deterministic per seed
    let mut rng = Rng::new(0x5EB0FF);
    let t0 = Instant::now();
    for (i, ev) in trace.events.iter().enumerate() {
        // wait out the arrival gap, draining streams while we wait
        loop {
            let now = Instant::now();
            if now.duration_since(t0) >= ev.at {
                break;
            }
            drain(&mut tracked, &mut out, now);
            let left = ev.at - now.duration_since(t0);
            std::thread::sleep(left.min(Duration::from_micros(200)));
        }
        let mut req =
            Request::new(synthetic_prompt(ev.prompt_len, ev.salt.unwrap_or(i))).truncate_prompt();
        if let Some(q) = ev.quality {
            req = req.quality(q);
        }
        if let Some(m) = ev.max_new {
            req = req.max_new_tokens(m);
        }
        if let Some(d) = ev.deadline {
            req = req.deadline(d);
        }
        let priority = ev.priority.unwrap_or_default();
        req = req.priority(priority);
        out.class_offered[priority.index()] += 1;
        // shared Busy-retry helper: jittered backoff, draining event
        // streams between attempts so the window can actually open
        let retry_for = if opts.retry_busy { opts.busy_retry_for } else { Duration::ZERO };
        let handle = submit_with_retry(server, &req, &mut rng, retry_for, || {
            drain(&mut tracked, &mut out, Instant::now());
        })
        .map_err(|e| anyhow::anyhow!(e))
        .context("trace replay submit")?;
        if handle.is_none() {
            out.busy_rejected += 1;
        }
        if let Some(handle) = handle {
            let now = Instant::now();
            out.accepted += 1;
            out.class_accepted[priority.index()] += 1;
            out.max_in_flight = out.max_in_flight.max(server.in_flight());
            tracked.push(Tracked {
                handle,
                submitted: now,
                cancel_at: ev.cancel_after.map(|d| now + d),
                cancel_sent: false,
                streamed: 0,
                terminals: 0,
                done_tokens: None,
                open: true,
                priority,
            });
        }
    }
    // drain until every accepted request's stream closes
    let deadline = Instant::now() + opts.drain_timeout;
    loop {
        let now = Instant::now();
        if drain(&mut tracked, &mut out, now) {
            break;
        }
        if now >= deadline {
            break; // missing terminals are counted below as violations
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    for t in &tracked {
        match t.terminals {
            0 => out.no_terminal += 1,
            1 => {}
            _ => out.multi_terminal += 1,
        }
        if let Some(n) = t.done_tokens {
            if n != t.streamed {
                out.stream_mismatch += 1;
            }
        }
    }
    out.wall = t0.elapsed();
    Ok(out)
}

/// Server-side bounds a scenario is gated on, derived once per run from
/// the manifest (see [`transfer_bounds`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct TransferBounds {
    /// O(B·sprompt) admission bound ([`serve::admission_byte_bound`]);
    /// `None` on pre-v3 artifacts (host surgery is their only path).
    pub admit_bytes_per_req: Option<f64>,
    /// Decode steps must stay far under the per-step KV-pair
    /// round-trip: `min_kv_pair_bytes / 4`.
    pub decode_bytes_per_step: Option<f64>,
}

/// Compute the transfer bounds for a model pair from the manifest;
/// empty bounds when the artifacts predate device-side admission.
pub fn transfer_bounds(manifest: &Manifest, models: &[&str]) -> Result<TransferBounds> {
    if manifest.version < 3 {
        return Ok(TransferBounds::default());
    }
    let kv_pair = serve::min_kv_pair_bytes(manifest, models)?;
    Ok(TransferBounds {
        admit_bytes_per_req: Some(serve::admission_byte_bound(&manifest.globals)),
        decode_bytes_per_step: Some(kv_pair / 4.0),
    })
}

/// Gate one replay against the declared invariants; returns the list of
/// violations (empty = scenario passed). `queue_cap` is the admission
/// bound the server ran with.
pub fn check_invariants(
    out: &ReplayOutcome,
    stats: &ServerStats,
    queue_cap: u64,
    bounds: &TransferBounds,
) -> Vec<String> {
    let mut v = Vec::new();
    if out.no_terminal > 0 {
        v.push(format!(
            "{} accepted request(s) never received a terminal event",
            out.no_terminal
        ));
    }
    if out.multi_terminal > 0 {
        v.push(format!(
            "{} request(s) received more than one terminal event",
            out.multi_terminal
        ));
    }
    if out.stream_mismatch > 0 {
        v.push(format!(
            "{} completion(s) diverged from their streamed tokens",
            out.stream_mismatch
        ));
    }
    let client_terminals = out.done + out.failed + out.cancelled;
    if client_terminals != out.accepted {
        v.push(format!(
            "client ledger unbalanced: {} accepted but {} terminal events \
             ({} done + {} failed + {} cancelled)",
            out.accepted, client_terminals, out.done, out.failed, out.cancelled
        ));
    }
    let server_terminals = stats.routing.completed
        + stats.routing.cancelled_total()
        + stats.routing.shed_total()
        + stats.routing.failed_total();
    if server_terminals != out.accepted as u64 {
        v.push(format!(
            "server counters unbalanced: {} accepted but completed {} + \
             cancelled {} + shed {} + failed {} = {}",
            out.accepted,
            stats.routing.completed,
            stats.routing.cancelled_total(),
            stats.routing.shed_total(),
            stats.routing.failed_total(),
            server_terminals
        ));
    }
    if stats.in_flight != 0 {
        v.push(format!("{} request(s) still in flight after drain", stats.in_flight));
    }
    if out.max_in_flight > queue_cap {
        v.push(format!(
            "bounded queue violated: saw {} in flight with queue_cap {}",
            out.max_in_flight, queue_cap
        ));
    }
    if let Some(bound) = bounds.admit_bytes_per_req {
        if stats.admitted > 0 {
            let per_req = stats.admit_bytes_per_req();
            if !(per_req > 0.0 && per_req < bound) {
                v.push(format!(
                    "admission moved {per_req:.0} B/request (O(B·sprompt) bound {bound:.0} B)"
                ));
            }
        }
    }
    if let Some(bound) = bounds.decode_bytes_per_step {
        if stats.decode_steps > 0 {
            let per_step = stats.d2h_bytes_per_step() + stats.h2d_bytes_per_step();
            if per_step >= bound {
                v.push(format!(
                    "decode moved {per_step:.0} B/step (KV round-trip bound {bound:.0} B)"
                ));
            }
        }
    }
    // hybrid draft/verify token ledger (all zero in routed mode, so
    // these gate every scenario for free)
    if stats.draft_accepted + stats.draft_local_accepted > stats.draft_tokens {
        v.push(format!(
            "hybrid ledger unbalanced: {} verify-accepted + {} local-accepted \
             draft tokens exceed {} drafted",
            stats.draft_accepted, stats.draft_local_accepted, stats.draft_tokens
        ));
    }
    if !(0.0..=1.0).contains(&stats.draft_accept_rate) {
        v.push(format!("draft_accept_rate {} outside [0, 1]", stats.draft_accept_rate));
    }
    if !stats.large_call_fraction.is_finite() || stats.large_call_fraction < 0.0 {
        v.push(format!("large_call_fraction {} not finite and non-negative", stats.large_call_fraction));
    }
    if stats.hybrid_emitted > 0 && stats.verify_calls == 0 && stats.hybrid_degraded_blocks == 0 {
        v.push(format!(
            "{} hybrid tokens emitted with zero verify calls and no degraded blocks",
            stats.hybrid_emitted
        ));
    }
    if stats.hybrid_requests > 0 && stats.decode_steps == 0 {
        v.push(format!(
            "{} hybrid requests admitted but no draft decode steps ran",
            stats.hybrid_requests
        ));
    }
    // brownout / priority accounting (holds for every scenario: with
    // the controller disabled the level is pinned to 0 and the class
    // counters still balance)
    if stats.brownout_level != 0 {
        v.push(format!(
            "brownout level {} nonzero after drain (monotone recovery violated)",
            stats.brownout_level
        ));
    }
    let class_admitted: u64 = stats.class_admitted.iter().sum();
    if class_admitted != out.accepted as u64 {
        v.push(format!(
            "priority ledger unbalanced: {} accepted but per-class admits sum to {}",
            out.accepted, class_admitted
        ));
    }
    v
}

/// Interactive-class goodput (`Done` / offered) the brownout scenario
/// must preserve under 3× overload — the CI gate floor.
pub const INTERACTIVE_GOODPUT_FLOOR: f64 = 0.9;

/// Extra gates for the `overload-brownout` scenario, on top of
/// [`check_invariants`]: interactive goodput holds the floor while the
/// lower classes absorb the shedding, and the controller actually
/// engaged (a brownout run that never trips is vacuous).
pub fn check_brownout_invariants(out: &ReplayOutcome, stats: &ServerStats) -> Vec<String> {
    let mut v = Vec::new();
    let goodput = out.interactive_goodput();
    if goodput < INTERACTIVE_GOODPUT_FLOOR {
        let i = Priority::Interactive.index();
        v.push(format!(
            "interactive goodput {goodput:.3} below the {INTERACTIVE_GOODPUT_FLOOR} floor \
             ({} done / {} offered)",
            out.class_done[i], out.class_offered[i]
        ));
    }
    // strict lowest-class-first shedding, aggregate form: the
    // interactive class never absorbs more shed events than best-effort
    let shed_i = stats.class_shed[Priority::Interactive.index()];
    let shed_b = stats.class_shed[Priority::BestEffort.index()];
    if shed_i > shed_b {
        v.push(format!(
            "priority inversion: interactive absorbed {shed_i} sheds vs best-effort {shed_b}"
        ));
    }
    if stats.effective_quality_delta <= 0.0 {
        v.push(
            "brownout never engaged: effective_quality_delta is zero under 3x overload".into(),
        );
    }
    v
}

/// One built-in scenario: a seeded generator plus the server/replay
/// configuration that makes it meaningful.
pub struct Scenario {
    pub name: &'static str,
    pub about: &'static str,
    /// Trace generator (seed, request count, artifact shape).
    pub make: fn(u64, usize, GenShape) -> Trace,
    /// Admission window for this scenario (`None` = server default).
    pub queue_cap: Option<usize>,
    /// Whether the replay retries `Busy` (off for overload, where Busy
    /// is the expected behavior under test).
    pub retry_busy: bool,
    /// Decode mode the server runs in ([`DecodeMode::Hybrid`] for the
    /// draft–verify scenario; the server falls back to routed decoding
    /// when the artifacts predate `verify@K`, so the scenario stays
    /// runnable — and its invariants meaningful — on any manifest).
    pub decode: DecodeMode,
    /// Arm the brownout controller with this target sojourn
    /// ([`ServeConfig::brownout_target`]); `None` (every scenario but
    /// `overload-brownout`) leaves the controller unbuilt, pinning the
    /// level to 0 — byte-identical to the pre-brownout server.
    pub brownout_target: Option<Duration>,
}

/// The built-in scenario suite, in run order.
pub fn builtin_suite() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "steady",
            about: "fixed-gap arrivals (control)",
            make: gen_steady,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "poisson-burst",
            about: "Poisson arrivals with 10x burst episodes",
            make: gen_poisson_burst,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "diurnal",
            about: "sinusoidal rate swing (compressed day)",
            make: gen_diurnal,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "long-tail",
            about: "exponential prompt/answer lengths",
            make: gen_long_tail,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "mixed-quality",
            about: "per-request quality targets across the ladder",
            make: gen_mixed_quality,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "overload-shed",
            about: "arrivals >> service, small window, short deadlines",
            make: gen_overload,
            queue_cap: Some(8),
            retry_busy: false,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "cancel-storm",
            about: "mass client cancels on queued and decoding requests",
            make: gen_cancel_storm,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "sessions",
            about: "multi-turn conversations over a shared system prompt",
            make: gen_sessions,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Routed,
            brownout_target: None,
        },
        Scenario {
            name: "hybrid-decode",
            about: "token-level draft–verify decoding under mixed quality targets",
            make: gen_hybrid_decode,
            queue_cap: None,
            retry_busy: true,
            decode: DecodeMode::Hybrid,
            brownout_target: None,
        },
    ]
}

/// The overload suite (run by `kick-tires --overload`): sustained ~3×
/// capacity with mixed priority classes against an armed brownout
/// controller, gated on [`check_invariants`] plus
/// [`check_brownout_invariants`] — zero lost requests, interactive
/// goodput above the floor while best-effort absorbs the shedding, and
/// the level back at 0 once the burst drains.
pub fn overload_suite() -> Vec<Scenario> {
    vec![Scenario {
        name: "overload-brownout",
        about: "3x sustained load, mixed priorities, brownout controller armed",
        make: gen_overload_brownout,
        queue_cap: Some(16),
        // Busy retries on: under brownout the point is graceful
        // degradation, not rejection — lower classes wait (absorbing
        // the shedding as repeated per-class shed counts) while
        // interactive traffic keeps its full admission window
        retry_busy: true,
        decode: DecodeMode::Routed,
        brownout_target: Some(Duration::from_millis(25)),
    }]
}

/// One chaos scenario: background traffic plus a deterministic
/// [`FaultPlan`] and the failure-handling knobs it exercises. Gated on
/// exactly the same invariants as the clean suite — the point is that
/// no injected schedule can make an accepted request go terminal-less.
pub struct ChaosSpec {
    pub name: &'static str,
    pub about: &'static str,
    /// Background traffic generator (seed, request count, shape).
    pub make: fn(u64, usize, GenShape) -> Trace,
    /// The deterministic fault schedule.
    pub plan: fn() -> FaultPlan,
    /// [`ServeConfig::decode_timeout`] for the run (stall detection).
    pub decode_timeout: Option<Duration>,
    /// [`ServeConfig::retry_budget`] for the run.
    pub retry_budget: u32,
}

/// The chaos suite (run by `kick-tires --chaos`): every spec injects
/// faults into the *large* tier (tier 1) of the two-tier fleet, so
/// recovery is visible as degradation onto the small tier.
pub fn chaos_suite() -> Vec<ChaosSpec> {
    vec![
        ChaosSpec {
            name: "chaos_crash",
            about: "replica crash mid-decode (+ one admission error), requeue + respawn",
            make: gen_steady,
            plan: || {
                FaultPlan::new(vec![
                    Fault { tier: 1, replica: 0, at_step: 3, kind: FaultKind::Crash },
                    Fault { tier: 1, replica: 0, at_step: 9, kind: FaultKind::AdmitError },
                ])
            },
            decode_timeout: None,
            retry_budget: 3,
        },
        ChaosSpec {
            name: "chaos_stall",
            about: "frozen replica trips the decode-timeout monitor; traffic routes around",
            make: gen_steady,
            plan: || {
                FaultPlan::new(vec![Fault {
                    tier: 1,
                    replica: 0,
                    at_step: 2,
                    kind: FaultKind::Stall { ms: 600 },
                }])
            },
            decode_timeout: Some(Duration::from_millis(150)),
            retry_budget: 2,
        },
        ChaosSpec {
            name: "chaos_tier_outage",
            about: "repeated large-tier crashes open the breaker; requests degrade, then recover",
            make: gen_steady,
            plan: || {
                FaultPlan::new(
                    (1..=5)
                        .map(|k| Fault {
                            tier: 1,
                            replica: 0,
                            at_step: k,
                            kind: FaultKind::Crash,
                        })
                        .collect(),
                )
            },
            decode_timeout: None,
            // every request survives all five deaths even if it is
            // unlucky enough to ride the doomed replica each time
            retry_budget: 6,
        },
    ]
}

/// `kick-tires` options: where the fleet lives and how hard to push.
#[derive(Debug, Clone)]
pub struct KickTiresOpts {
    pub artifacts_dir: PathBuf,
    pub run_dir: PathBuf,
    /// Cheap-tier model (cost 0).
    pub small: String,
    /// Expensive-tier model (cost 1).
    pub large: String,
    /// Downscaled sweep (fewer requests per scenario) for CI.
    pub smoke: bool,
    /// Also run the fault-injection suite ([`chaos_suite`]).
    pub chaos: bool,
    /// Also run the brownout overload suite ([`overload_suite`]).
    pub overload: bool,
    pub seed: u64,
    /// Run only scenarios whose name is in this list (all when `None`).
    pub only: Option<Vec<String>>,
    /// Merge per-scenario metrics into this flat-JSON trajectory file.
    pub bench_json: Option<PathBuf>,
    /// Override the post-submit drain cap ([`ReplayOpts::drain_timeout`]).
    pub drain_timeout: Option<Duration>,
}

impl KickTiresOpts {
    pub fn new(artifacts_dir: PathBuf, run_dir: PathBuf) -> KickTiresOpts {
        KickTiresOpts {
            artifacts_dir,
            run_dir,
            small: "small".into(),
            large: "medium".into(),
            smoke: false,
            chaos: false,
            overload: false,
            seed: 0x7EA5E7,
            only: None,
            bench_json: None,
            drain_timeout: None,
        }
    }
}

/// One scenario's full result: client ledger, server stats, violations.
pub struct ScenarioReport {
    pub scenario: &'static str,
    pub about: &'static str,
    pub outcome: ReplayOutcome,
    pub stats: ServerStats,
    pub violations: Vec<String>,
}

/// The whole sweep.
pub struct KickTiresReport {
    pub scenarios: Vec<ScenarioReport>,
}

impl KickTiresReport {
    pub fn total_violations(&self) -> usize {
        self.scenarios.iter().map(|s| s.violations.len()).sum()
    }

    /// Flat-JSON entries for the `BENCH_serving.json` trajectory:
    /// `scenario.<name>.<metric>` keys (no `"`/`,`/`:`, per the format).
    pub fn bench_entries(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for s in &self.scenarios {
            let k = |m: &str| format!("scenario.{}.{m}", s.scenario);
            out.push((k("accepted"), s.outcome.accepted as f64));
            out.push((k("e2e_p50_ms"), s.outcome.e2e_p50_ms()));
            out.push((k("e2e_p95_ms"), s.outcome.e2e_p95_ms()));
            out.push((k("e2e_p99_ms"), s.outcome.e2e_p99_ms()));
            out.push((k("done"), s.outcome.done as f64));
            out.push((k("failed"), s.outcome.failed as f64));
            out.push((k("cancelled"), s.outcome.cancelled as f64));
            out.push((k("busy"), s.outcome.busy_rejected as f64));
            out.push((k("shed"), s.stats.routing.shed_total() as f64));
            out.push((k("cost_advantage"), s.stats.routing.cost_advantage));
            out.push((k("admit_bytes_per_req"), s.stats.admit_bytes_per_req()));
            out.push((k("prefix_hit_rate"), s.stats.prefix_hit_rate));
            out.push((k("prefill_tokens"), s.stats.prefill_tokens as f64));
            out.push((k("kv_blocks_utilization"), s.stats.kv_blocks_utilization));
            out.push((k("pool_exhausted_requeues"), s.stats.pool_exhausted_requeues as f64));
            // hybrid draft–verify trajectory (all zero in routed mode)
            out.push((k("hybrid_requests"), s.stats.hybrid_requests as f64));
            out.push((k("draft_accept_rate"), s.stats.draft_accept_rate));
            out.push((k("large_call_fraction"), s.stats.large_call_fraction));
            // failure-handling trajectory (the chaos scenarios' gate:
            // CI fails the run unless every `lost` entry is zero)
            out.push((k("failovers"), s.stats.failovers as f64));
            out.push((k("degraded"), s.stats.degraded as f64));
            out.push((k("retries"), s.stats.retries as f64));
            // overload-brownout trajectory (level 0 / zero deltas in
            // every scenario that leaves the controller unarmed); the
            // CI gate greps brownout_level == 0, lost == 0, and
            // violations == 0 for the overload-brownout row
            out.push((k("queue_delay_p99_ms"), s.stats.queue_delay.p99_ms));
            out.push((k("brownout_level"), s.stats.brownout_level as f64));
            out.push((k("effective_quality_delta"), s.stats.effective_quality_delta));
            out.push((k("interactive_goodput"), s.outcome.interactive_goodput()));
            for p in Priority::all() {
                let i = p.index();
                out.push((k(&format!("{}_admitted", p.name())), s.stats.class_admitted[i] as f64));
                out.push((k(&format!("{}_shed", p.name())), s.stats.class_shed[i] as f64));
                out.push((k(&format!("{}_done", p.name())), s.outcome.class_done[i] as f64));
            }
            let terminals = s.outcome.done + s.outcome.failed + s.outcome.cancelled;
            out.push((k("lost"), s.outcome.accepted.saturating_sub(terminals) as f64));
            out.push((k("violations"), s.violations.len() as f64));
        }
        out
    }

    /// Serving report (markdown): one row per scenario plus violations.
    pub fn render(&self) -> String {
        let mut s = String::from("# Scenario sweep — serving report\n\n");
        s.push_str(
            "| scenario | accepted | done | failed | cancelled | busy | shed \
             | p50 ms | p95 ms | cost adv | violations |\n",
        );
        s.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.scenarios {
            let o = &r.outcome;
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {:.0} | {:.0} | {:.1}% | {} |\n",
                r.scenario,
                o.accepted,
                o.done,
                o.failed,
                o.cancelled,
                o.busy_rejected,
                r.stats.routing.shed_total(),
                o.e2e_p50_ms(),
                o.e2e_p95_ms(),
                r.stats.routing.cost_advantage * 100.0,
                r.violations.len(),
            ));
        }
        for r in &self.scenarios {
            if !r.violations.is_empty() {
                s.push_str(&format!("\n## {} — INVARIANT VIOLATIONS\n", r.scenario));
                for v in &r.violations {
                    s.push_str(&format!("- {v}\n"));
                }
            }
        }
        s
    }
}

/// Run every built-in scenario against a fresh two-tier server each
/// (fresh server ⇒ the final drained stats *are* the scenario's delta),
/// gate each on its invariants, write the serving report to
/// `<run_dir>/results/scenarios.md`, and merge per-scenario metrics
/// into the trajectory file. Violations are *reported*, not raised —
/// callers decide whether to fail (the CLI and the bench both do).
pub fn kick_tires(opts: &KickTiresOpts) -> Result<KickTiresReport> {
    let manifest = Manifest::load(&opts.artifacts_dir.join("manifest.txt"))?;
    let g = manifest.globals;
    let shape = GenShape { sprompt: g.sprompt, amax: g.amax };
    let bounds = transfer_bounds(&manifest, &[&opts.small, &opts.large])?;
    let n = if opts.smoke { 24 } else { 96 };
    let base_cfg = || {
        let mut cfg = ServeConfig::two_tier(
            opts.artifacts_dir.clone(),
            opts.run_dir.clone(),
            &opts.small,
            &opts.large,
            String::new(), // random routing: no trained router required
            0.5,
        );
        cfg.temp = 0.8;
        cfg.batch_window = Duration::from_millis(2);
        cfg.mode = BatchMode::Continuous;
        cfg
    };
    let skip = |name: &str| match &opts.only {
        Some(only) => !only.iter().any(|o| o == name),
        None => false,
    };
    let run_one = |cfg: ServeConfig, trace: &Trace, retry_busy: bool, name: &'static str| {
        let queue_cap = cfg.queue_cap as u64;
        let server = Server::start(cfg).with_context(|| format!("scenario {name}"))?;
        let mut replay_opts = ReplayOpts { retry_busy, ..Default::default() };
        if let Some(d) = opts.drain_timeout {
            replay_opts.drain_timeout = d;
        }
        let outcome =
            replay(&server, trace, &replay_opts).with_context(|| format!("scenario {name}"))?;
        let stats = server.shutdown().with_context(|| format!("scenario {name}"))?;
        let violations = check_invariants(&outcome, &stats, queue_cap, &bounds);
        Ok::<_, anyhow::Error>((outcome, stats, violations))
    };
    let mut scenarios = Vec::new();
    let mut suite = builtin_suite();
    if opts.overload {
        suite.extend(overload_suite());
    }
    for sc in suite {
        if skip(sc.name) {
            continue;
        }
        let mut cfg = base_cfg();
        if let Some(cap) = sc.queue_cap {
            cfg.queue_cap = cap;
        }
        cfg.decode = sc.decode;
        cfg.brownout_target = sc.brownout_target;
        let trace = (sc.make)(opts.seed, n, shape);
        let (outcome, stats, mut violations) = run_one(cfg, &trace, sc.retry_busy, sc.name)?;
        if sc.brownout_target.is_some() {
            violations.extend(check_brownout_invariants(&outcome, &stats));
        }
        scenarios.push(ScenarioReport {
            scenario: sc.name,
            about: sc.about,
            outcome,
            stats,
            violations,
        });
    }
    if opts.chaos {
        for sc in chaos_suite() {
            if skip(sc.name) {
                continue;
            }
            let mut cfg = base_cfg();
            cfg.fault_plan = Some((sc.plan)());
            cfg.decode_timeout = sc.decode_timeout;
            cfg.retry_budget = sc.retry_budget;
            let trace = (sc.make)(opts.seed, n, shape);
            let (outcome, stats, violations) = run_one(cfg, &trace, true, sc.name)?;
            scenarios.push(ScenarioReport {
                scenario: sc.name,
                about: sc.about,
                outcome,
                stats,
                violations,
            });
        }
    }
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios matched the filter");
    let report = KickTiresReport { scenarios };
    let results = opts.run_dir.join("results");
    std::fs::create_dir_all(&results)?;
    std::fs::write(results.join("scenarios.md"), report.render())?;
    if let Some(path) = &opts.bench_json {
        crate::bench::merge_bench_json(path, &report.bench_entries())?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: GenShape = GenShape { sprompt: 40, amax: 24 };

    #[test]
    fn generators_are_deterministic_and_sorted() {
        for (name, gen) in [
            ("steady", gen_steady as fn(u64, usize, GenShape) -> Trace),
            ("poisson-burst", gen_poisson_burst),
            ("diurnal", gen_diurnal),
            ("long-tail", gen_long_tail),
            ("mixed-quality", gen_mixed_quality),
            ("overload-shed", gen_overload),
            ("cancel-storm", gen_cancel_storm),
            ("sessions", gen_sessions),
            ("hybrid-decode", gen_hybrid_decode),
            ("overload-brownout", gen_overload_brownout),
        ] {
            let a = gen(7, 50, SHAPE);
            let b = gen(7, 50, SHAPE);
            assert_eq!(a, b, "{name} not deterministic");
            assert_eq!(a.name, name);
            assert_eq!(a.events.len(), 50);
            assert!(
                a.events.windows(2).all(|w| w[0].at <= w[1].at),
                "{name} arrivals not sorted"
            );
            for e in &a.events {
                assert!(
                    e.prompt_len >= 1 && e.prompt_len <= SHAPE.sprompt,
                    "{name} prompt_len {} outside [1, {}]",
                    e.prompt_len,
                    SHAPE.sprompt
                );
                if let Some(m) = e.max_new {
                    assert!(m >= 1, "{name} generated a zero token budget");
                }
            }
            // a different seed actually changes the trace
            assert_ne!(gen(8, 50, SHAPE), a, "{name} ignores its seed");
        }
    }

    #[test]
    fn cancel_storm_carries_cancels_and_overload_deadlines() {
        let storm = gen_cancel_storm(3, 40, SHAPE);
        let with_cancel = storm.events.iter().filter(|e| e.cancel_after.is_some()).count();
        assert!(with_cancel >= 40 / 2, "storm should cancel most requests");
        assert!(storm.events.iter().all(|e| e.max_new == Some(SHAPE.amax)));
        let over = gen_overload(3, 40, SHAPE);
        assert!(over.events.iter().all(|e| e.deadline.is_some()));
    }

    #[test]
    fn sessions_share_a_system_prompt_prefix() {
        let t = gen_sessions(9, 40, SHAPE);
        // one shared salt across the whole trace: every prompt extends
        // the same system-prompt content
        let salts: std::collections::BTreeSet<_> =
            t.events.iter().map(|e| e.salt.expect("sessions events carry a salt")).collect();
        assert_eq!(salts.len(), 1);
        let sys_len = SHAPE.sprompt / 2;
        assert!(t.events.iter().all(|e| e.prompt_len >= sys_len));
        // the fabricated prompts really are prefix-nested: a shorter
        // prompt is exactly the head of any longer one
        let salt = *salts.iter().next().unwrap();
        let long = synthetic_prompt(SHAPE.sprompt, salt);
        for e in &t.events {
            assert_eq!(synthetic_prompt(e.prompt_len, salt), long[..e.prompt_len]);
        }
        // and some requests re-send an identical full prompt (full hits)
        let lens: Vec<usize> = t.events.iter().map(|e| e.prompt_len).collect();
        let distinct: std::collections::BTreeSet<_> = lens.iter().collect();
        assert!(distinct.len() < lens.len(), "expected repeated turn lengths");
    }

    #[test]
    fn trace_text_roundtrip() {
        let trace = gen_cancel_storm(11, 12, SHAPE);
        let dir = std::env::temp_dir().join(format!("hybrid_trace_{}", std::process::id()));
        let path = dir.join("storm.trace");
        trace.save(&path).unwrap();
        let loaded = Trace::load(&path).unwrap();
        assert_eq!(trace, loaded);
        // salts survive the text format too
        let sess = gen_sessions(11, 12, SHAPE);
        let sess_path = dir.join("sessions.trace");
        sess.save(&sess_path).unwrap();
        assert_eq!(Trace::load(&sess_path).unwrap(), sess);
        // priority classes survive the text format too
        let brown = gen_overload_brownout(11, 12, SHAPE);
        assert!(brown.events.iter().all(|e| e.priority.is_some()));
        let brown_path = dir.join("brownout.trace");
        brown.save(&brown_path).unwrap();
        assert_eq!(Trace::load(&brown_path).unwrap(), brown);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_parse_rejects_garbage() {
        assert!(Trace::parse("").is_err());
        assert!(Trace::parse("# wrong-header\n").is_err());
        assert!(Trace::parse("# hybrid-trace v1 x\nat_us=5").is_err()); // no plen
        assert!(Trace::parse("# hybrid-trace v1 x\nplen=4").is_err()); // no at_us
        assert!(Trace::parse("# hybrid-trace v1 x\nat_us=5 plen=4 bogus=1").is_err());
        assert!(Trace::parse("# hybrid-trace v1 x\nat_us=zzz plen=4").is_err());
        assert!(Trace::parse("# hybrid-trace v1 x\nat_us=5 plen=4 prio=urgent").is_err());
        let t = Trace::parse("# hybrid-trace v1 x\nat_us=5 plen=4 prio=best-effort").unwrap();
        assert_eq!(t.events[0].priority, Some(Priority::BestEffort));
        // valid lines parse; comments and blanks are skipped, rows sort
        let t = Trace::parse(
            "# hybrid-trace v1 demo\n\n# a comment\nat_us=90 plen=4\nat_us=5 plen=2 q=0.5 max=3 dl_us=100 cancel_us=7\n",
        )
        .unwrap();
        assert_eq!(t.name, "demo");
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].at, Duration::from_micros(5));
        assert_eq!(t.events[0].quality, Some(0.5));
        assert_eq!(t.events[0].max_new, Some(3));
        assert_eq!(t.events[0].cancel_after, Some(Duration::from_micros(7)));
        assert_eq!(t.events[1].prompt_len, 4);
    }

    #[test]
    fn synthetic_prompts_stay_in_vocab() {
        for len in [0, 1, 5, 40] {
            let p = synthetic_prompt(len, 13);
            assert_eq!(p.len(), len.max(1));
            assert!(p
                .iter()
                .all(|&t| t >= tok::LETTER0 && t < tok::LETTER0 + tok::N_LETTERS));
        }
    }

    fn outcome(accepted: usize, done: usize, failed: usize, cancelled: usize) -> ReplayOutcome {
        ReplayOutcome {
            name: "x".into(),
            accepted,
            done,
            failed,
            cancelled,
            ..Default::default()
        }
    }

    fn stats_with(completed: u64, cancelled: u64, shed: u64) -> ServerStats {
        use crate::metrics::RoutingCounters;
        let c = RoutingCounters::two_tier();
        for _ in 0..completed {
            c.route(0);
            c.complete(0.0);
        }
        for _ in 0..cancelled {
            c.cancel(0);
        }
        for _ in 0..shed {
            c.shed(1);
        }
        ServerStats {
            in_flight: 0,
            router_latency: Default::default(),
            e2e_latency: Default::default(),
            tiers: Vec::new(),
            routing: c.snapshot(),
            decode_steps: 0,
            decode_slot_steps: 0,
            decode_h2d_bytes: 0,
            decode_d2h_bytes: 0,
            admit_h2d_bytes: 0,
            admit_d2h_bytes: 0,
            admissions: 0,
            admitted: 0,
            admit_latency: Default::default(),
            prefix_hit_rate: 0.0,
            prefix_shared_tokens: 0,
            prefill_tokens: 0,
            kv_blocks_utilization: 0.0,
            failovers: 0,
            degraded: 0,
            retries: 0,
            worker_deaths: 0,
            breaker_state: Vec::new(),
            hybrid_requests: 0,
            draft_tokens: 0,
            draft_accepted: 0,
            draft_local_accepted: 0,
            verify_calls: 0,
            hybrid_emitted: 0,
            hybrid_degraded_blocks: 0,
            draft_accept_rate: 0.0,
            large_call_fraction: 0.0,
            large_slot_steps: 0,
            pool_exhausted_requeues: 0,
            queue_delay: Default::default(),
            brownout_level: 0,
            // the helper's requests are all default-priority
            // (Interactive, index 2); summing to `accepted` keeps the
            // priority-ledger invariant balanced
            class_admitted: [0, 0, completed + cancelled + shed],
            class_shed: [0; PRIORITY_CLASSES],
            effective_quality_delta: 0.0,
        }
    }

    #[test]
    fn invariants_pass_on_balanced_ledger() {
        let out = outcome(10, 6, 1, 3);
        let st = stats_with(6, 3, 1);
        assert!(check_invariants(&out, &st, 256, &TransferBounds::default()).is_empty());
    }

    #[test]
    fn invariants_catch_missing_and_double_terminals() {
        let mut out = outcome(10, 6, 1, 3);
        out.no_terminal = 1;
        out.multi_terminal = 2;
        let st = stats_with(6, 3, 1);
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("never received a terminal")));
        assert!(v.iter().any(|m| m.contains("more than one terminal")));
    }

    #[test]
    fn invariants_catch_unbalanced_counters() {
        // client saw 10 terminals but the server only accounted for 9
        let out = outcome(10, 6, 1, 3);
        let st = stats_with(6, 2, 1);
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("server counters unbalanced")), "{v:?}");
        // and a client ledger that doesn't sum to accepted
        let out = outcome(10, 6, 1, 2);
        let st = stats_with(6, 2, 1);
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("client ledger unbalanced")), "{v:?}");
    }

    #[test]
    fn invariants_catch_queue_and_transfer_breaches() {
        let mut out = outcome(4, 4, 0, 0);
        out.max_in_flight = 300;
        let st = stats_with(4, 0, 0);
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("bounded queue violated")), "{v:?}");

        let out = outcome(4, 4, 0, 0);
        let mut st = stats_with(4, 0, 0);
        st.admitted = 4;
        st.admit_h2d_bytes = 1_000_000;
        st.decode_steps = 10;
        st.decode_h2d_bytes = 1_000_000;
        let bounds = TransferBounds {
            admit_bytes_per_req: Some(10_000.0),
            decode_bytes_per_step: Some(50_000.0),
        };
        let v = check_invariants(&out, &st, 256, &bounds);
        assert!(v.iter().any(|m| m.contains("admission moved")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("decode moved")), "{v:?}");
        // within bounds: no violations
        st.admit_h2d_bytes = 4_000;
        st.decode_h2d_bytes = 1_000;
        assert!(check_invariants(&out, &st, 256, &bounds).is_empty());
    }

    #[test]
    fn invariants_catch_leftover_in_flight() {
        let out = outcome(4, 4, 0, 0);
        let mut st = stats_with(4, 0, 0);
        st.in_flight = 2;
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("still in flight")), "{v:?}");
    }

    #[test]
    fn invariants_catch_hybrid_ledger_imbalance() {
        let out = outcome(4, 4, 0, 0);
        // accepted tokens exceed drafted tokens
        let mut st = stats_with(4, 0, 0);
        st.draft_tokens = 5;
        st.draft_accepted = 4;
        st.draft_local_accepted = 2;
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("hybrid ledger unbalanced")), "{v:?}");
        // emitted tokens without any verify call or degraded block
        let mut st = stats_with(4, 0, 0);
        st.hybrid_emitted = 3;
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("zero verify calls")), "{v:?}");
        // out-of-range derived rates
        let mut st = stats_with(4, 0, 0);
        st.draft_accept_rate = 1.5;
        st.large_call_fraction = f64::NAN;
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("draft_accept_rate")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("large_call_fraction")), "{v:?}");
        // a consistent hybrid ledger passes
        let mut st = stats_with(4, 0, 0);
        st.hybrid_requests = 4;
        st.decode_steps = 12;
        st.draft_tokens = 10;
        st.draft_accepted = 6;
        st.draft_local_accepted = 2;
        st.verify_calls = 3;
        st.hybrid_emitted = 11;
        st.draft_accept_rate = 0.6;
        st.large_call_fraction = 3.0 / 11.0;
        assert!(check_invariants(&out, &st, 256, &TransferBounds::default()).is_empty());
    }

    #[test]
    fn bench_entries_use_legal_flat_json_keys() {
        let report = KickTiresReport {
            scenarios: vec![ScenarioReport {
                scenario: "cancel-storm",
                about: "",
                outcome: outcome(10, 6, 1, 3),
                stats: stats_with(6, 3, 1),
                violations: vec!["boom".into()],
            }],
        };
        let entries = report.bench_entries();
        assert!(!entries.is_empty());
        for (k, v) in &entries {
            assert!(!k.contains(['"', ',', ':']), "illegal bench key {k}");
            assert!(v.is_finite() || *v == 0.0);
        }
        assert!(entries.iter().any(|(k, v)| k.ends_with(".violations") && *v == 1.0));
        // the chaos gate's keys are always present (zero on clean runs)
        for m in ["failovers", "degraded", "retries", "lost"] {
            assert!(entries.iter().any(|(k, _)| k.ends_with(&format!(".{m}"))), "missing {m}");
        }
        let text = report.render();
        assert!(text.contains("cancel-storm"));
        assert!(text.contains("INVARIANT VIOLATIONS"));
    }

    #[test]
    fn builtin_suite_names_are_unique_and_complete() {
        let suite = builtin_suite();
        let names: std::collections::BTreeSet<_> = suite.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), suite.len());
        for want in [
            "steady",
            "poisson-burst",
            "diurnal",
            "long-tail",
            "mixed-quality",
            "overload-shed",
            "cancel-storm",
            "sessions",
            "hybrid-decode",
        ] {
            assert!(names.contains(want), "missing scenario {want}");
        }
        // the overload scenario actually runs with a small window and
        // treats Busy as an outcome, not a retry
        let over = suite.iter().find(|s| s.name == "overload-shed").unwrap();
        assert_eq!(over.queue_cap, Some(8));
        assert!(!over.retry_busy);
        // exactly one scenario opts into the hybrid draft–verify mode
        let hybrid: Vec<_> =
            suite.iter().filter(|s| s.decode == DecodeMode::Hybrid).map(|s| s.name).collect();
        assert_eq!(hybrid, ["hybrid-decode"]);
        // its traffic mixes quality targets and token budgets so the
        // escalation policy's verify/local split actually engages
        let t = gen_hybrid_decode(5, 60, SHAPE);
        let qs: std::collections::BTreeSet<_> =
            t.events.iter().map(|e| (e.quality.unwrap() * 100.0) as u32).collect();
        assert!(qs.len() >= 4, "expected mixed quality targets, got {qs:?}");
        assert!(t.events.iter().all(|e| {
            let m = e.max_new.unwrap();
            (1..=SHAPE.amax).contains(&m)
        }));
    }

    #[test]
    fn chaos_suite_targets_the_large_tier_deterministically() {
        let suite = chaos_suite();
        let names: std::collections::BTreeSet<_> = suite.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), suite.len());
        for want in ["chaos_crash", "chaos_stall", "chaos_tier_outage"] {
            assert!(names.contains(want), "missing chaos scenario {want}");
            // underscore names keep the flat-JSON bench keys legal
            assert!(!want.contains(['"', ',', ':', ' ']));
        }
        for sc in &suite {
            let plan = (sc.plan)();
            assert!(!plan.faults.is_empty(), "{} has an empty fault plan", sc.name);
            assert!(
                plan.faults.iter().all(|f| f.tier == 1),
                "{} must fault the large tier so degradation is observable",
                sc.name
            );
            // plans are pure: the same schedule on every call
            assert_eq!(plan.faults.len(), (sc.plan)().faults.len());
        }
        // the outage spec crashes often enough to trip the breaker (3
        // consecutive failures) and budgets a retry per death
        let outage = suite.iter().find(|s| s.name == "chaos_tier_outage").unwrap();
        let plan = (outage.plan)();
        assert!(plan.faults.len() >= 4);
        assert!(outage.retry_budget as usize >= plan.faults.len());
    }

    #[test]
    fn overload_suite_arms_the_brownout_controller() {
        let suite = overload_suite();
        assert_eq!(suite.len(), 1);
        let sc = &suite[0];
        assert_eq!(sc.name, "overload-brownout");
        assert!(sc.brownout_target.is_some(), "controller must be armed");
        assert_eq!(sc.queue_cap, Some(16));
        assert!(sc.retry_busy, "lower classes wait rather than reject");
        // no clean-suite scenario arms the controller: their replays
        // must stay byte-identical to the pre-brownout server
        assert!(builtin_suite().iter().all(|s| s.brownout_target.is_none()));
        // the trace mixes all three classes in the burst and trickles
        // interactive-only traffic through the recovery tail
        let t = gen_overload_brownout(5, 60, SHAPE);
        for p in Priority::all() {
            assert!(
                t.events.iter().any(|e| e.priority == Some(p)),
                "burst must offer {} traffic",
                p.name()
            );
        }
        let tail: Vec<_> = t.events.iter().rev().take(8).collect();
        assert!(tail.iter().all(|e| e.priority == Some(Priority::Interactive)));
        // the tail spans enough wall time for hysteretic recovery
        // (>= 18 calm ticks at the 10 ms cadence, with margin)
        let span = t.events.last().unwrap().at - t.events[t.events.len() - 8].at;
        assert!(span >= Duration::from_millis(500), "recovery tail too short: {span:?}");
        // every burst request carries a quality target above the L1
        // cap, so an engaged controller is visible as a quality delta
        assert!(t.events.iter().all(|e| e.quality == Some(0.9)));
    }

    #[test]
    fn brownout_invariants_gate_goodput_ordering_and_engagement() {
        let i = Priority::Interactive.index();
        let mk_out = |offered: usize, done: usize| {
            let mut o = ReplayOutcome { name: "brownout".into(), ..Default::default() };
            o.class_offered[i] = offered;
            o.class_done[i] = done;
            o
        };
        let mut st = stats_with(0, 0, 0);
        st.effective_quality_delta = 0.05;
        // healthy run: goodput at 1.0, shedding on best-effort only
        st.class_shed = [7, 2, 0];
        assert!(check_brownout_invariants(&mk_out(10, 10), &st).is_empty());
        // goodput below the floor is a violation
        let v = check_brownout_invariants(&mk_out(10, 5), &st);
        assert!(v.iter().any(|m| m.contains("interactive goodput")), "{v:?}");
        // priority inversion: interactive shed more than best-effort
        let mut st_inv = stats_with(0, 0, 0);
        st_inv.effective_quality_delta = 0.05;
        st_inv.class_shed = [1, 0, 4];
        let v = check_brownout_invariants(&mk_out(10, 10), &st_inv);
        assert!(v.iter().any(|m| m.contains("priority inversion")), "{v:?}");
        // a run where the controller never engaged is vacuous
        let mut st_idle = stats_with(0, 0, 0);
        st_idle.class_shed = [5, 0, 0];
        let v = check_brownout_invariants(&mk_out(10, 10), &st_idle);
        assert!(v.iter().any(|m| m.contains("never engaged")), "{v:?}");
        // no interactive traffic offered => goodput is vacuously 1.0
        assert_eq!(ReplayOutcome::default().interactive_goodput(), 1.0);
    }

    #[test]
    fn invariants_catch_nonzero_drained_brownout_level() {
        let out = outcome(4, 4, 0, 0);
        let mut st = stats_with(4, 0, 0);
        st.brownout_level = 2;
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("brownout level")), "{v:?}");
        // and an unbalanced per-class admit ledger
        let mut st = stats_with(4, 0, 0);
        st.class_admitted = [0, 0, 3];
        let v = check_invariants(&out, &st, 256, &TransferBounds::default());
        assert!(v.iter().any(|m| m.contains("priority ledger unbalanced")), "{v:?}");
    }

    #[test]
    fn replay_outcome_percentiles_are_nan_free_when_empty() {
        let out = ReplayOutcome::default();
        assert_eq!(out.e2e_p50_ms(), 0.0);
        assert_eq!(out.e2e_p95_ms(), 0.0);
        assert_eq!(out.e2e_p99_ms(), 0.0);
    }
}
