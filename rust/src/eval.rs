//! Experiment drivers — one per table/figure of the paper (DESIGN.md §6).
//!
//! Every driver reads pipeline outputs (`runs/<name>/…`), computes the
//! paper's quantity, renders a markdown report (tables + TSV series +
//! ASCII histograms), writes it to `runs/<name>/results/<id>.md`, and
//! returns it for stdout. Quality of a (query, model) is the mean
//! BART-analogue score over the sampled responses unless stated
//! otherwise.

use std::fs;


use anyhow::{ensure, Result};

use crate::corpus::{Query, Split, ALL_TASKS};
use crate::labels::{self, QualitySamples};
use crate::pipeline::{pair_id, subset, Pipeline, MAIN_PAIRS, ROSTER};
use crate::policy::{self, random_curve, tradeoff_at, tradeoff_curve};
use crate::router::{RouterKind, ALL_ROUTERS};
use crate::stats::{self, Histogram};

/// Markdown table renderer.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

/// Driver context.
pub struct Eval<'a> {
    pub pl: &'a Pipeline,
    pub corpus: &'a [Query],
}

impl<'a> Eval<'a> {
    pub fn new(pl: &'a Pipeline, corpus: &'a [Query]) -> Self {
        Eval { pl, corpus }
    }

    fn ids(&self, split: Split) -> Vec<usize> {
        crate::corpus::split_ids(self.corpus, split)
    }

    /// Per-query mean qualities of a pair over a split: (q_small, q_large).
    fn pair_quality(&self, small: &str, large: &str, ids: &[usize]) -> Result<(Vec<f64>, Vec<f64>)> {
        let qs = self.pl.load_quality(small, self.corpus)?;
        let ql = self.pl.load_quality(large, self.corpus)?;
        Ok((
            subset(&qs, ids).mean(),
            subset(&ql, ids).mean(),
        ))
    }

    fn router_scores_on(&self, pair: &str, kind: RouterKind, ids: &[usize]) -> Result<Vec<f32>> {
        let all = self.pl.load_router_scores(pair, kind)?;
        Ok(ids.iter().map(|&i| all[i]).collect())
    }

    fn write(&self, id: &str, body: &str) -> Result<String> {
        let dir = self.pl.paths.results();
        fs::create_dir_all(&dir)?;
        fs::write(dir.join(format!("{id}.md")), body)?;
        Ok(body.to_string())
    }

    /// Dispatch by experiment id.
    pub fn run(&self, id: &str) -> Result<String> {
        match id {
            "fig1" => self.fig1(),
            "fig3" => self.fig3(),
            "fig4" => self.fig4(),
            "fig5" => self.fig5(&MAIN_PAIRS),
            "fig6" => self.gapdiff("fig6", &MAIN_PAIRS),
            "fig7" => self.fig7(),
            "fig8" => self.fig8(),
            "fig9" => self.fig5_named("fig9", &crate::pipeline::APPENDIX_PAIRS),
            "fig10" => self.gapdiff("fig10", &crate::pipeline::APPENDIX_PAIRS),
            "table1" => self.table1(&MAIN_PAIRS, "table1"),
            "table3" => self.table3(),
            "table4" => self.table1(&crate::pipeline::APPENDIX_PAIRS, "table4"),
            "table5" => self.table5(),
            "nmodel" => self.nmodel(),
            "ladder" => self.ladder(),
            other => anyhow::bail!("unknown experiment id {other} (see DESIGN.md §6)"),
        }
    }

    /// All experiment ids runnable without live engines (Table 2 is the
    /// exception — it measures real latency and lives in `main.rs`).
    pub fn all_ids() -> &'static [&'static str] {
        &[
            "table5", "fig1", "fig3", "fig4", "fig5", "fig6", "table1", "table3", "fig7",
            "fig8", "fig9", "fig10", "table4", "nmodel", "ladder",
        ]
    }

    // ------------------------------------------------------------------
    // Fig 1 — (a) quality per model, (b) gap tail, (c) headline tradeoff
    // ------------------------------------------------------------------
    pub fn fig1(&self) -> Result<String> {
        let test = self.ids(Split::Test);
        let mut body = String::from("# Fig 1 — motivation\n\n## (a) response quality by model (test split)\n\n");
        let mut rows = Vec::new();
        for model in ROSTER {
            let q = subset(&self.pl.load_quality(model, self.corpus)?, &test).mean();
            rows.push(vec![
                model.to_string(),
                format!("{:.3}", stats::mean(&q)),
                format!("{:.3}", stats::percentile(&q, 25.0)),
                format!("{:.3}", stats::percentile(&q, 50.0)),
                format!("{:.3}", stats::percentile(&q, 75.0)),
            ]);
        }
        body.push_str(&md_table(&["model", "mean q", "p25", "p50", "p75"], &rows));

        // (b) tail of the quality gap for the medium-gap pair
        let (small, large) = ("medium", "large");
        let (qs, ql) = self.pair_quality(small, large, &test)?;
        let mut gaps: Vec<f64> = qs.iter().zip(&ql).map(|(a, b)| a - b).collect();
        gaps.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let frac_nonneg = gaps.iter().filter(|&&g| g >= 0.0).count() as f64 / gaps.len() as f64;
        body.push_str(&format!(
            "\n## (b) quality-gap tail: {small} vs {large}\n\nPr[H(x) >= 0] = {:.3} \
             (paper: ~0.20 for Llama-2-13b vs GPT-3.5)\n\n",
            frac_nonneg
        ));
        body.push_str("top-of-tail gap values (sorted desc, every 5th pctile):\n\n```\n");
        for k in 0..=20 {
            let idx = (k as f64 / 20.0 * (gaps.len() - 1) as f64) as usize;
            body.push_str(&format!("pct {:>3}: {:+.3}\n", k * 5, gaps[idx]));
        }
        body.push_str("```\n");

        // (c) headline: trans router on medium/large
        let pair = pair_id(small, large);
        let scores = self.router_scores_on(&pair, RouterKind::Trans, &test)?;
        let curve = tradeoff_curve(&scores, &qs, &ql, 20);
        body.push_str("\n## (c) error–cost tradeoff (r_trans, medium/large)\n\n```\ncost_adv\tdrop_pct\n");
        for p in &curve {
            body.push_str(&format!(
                "{:.2}\t{:+.2}\n",
                p.achieved_cost_advantage, p.drop_pct
            ));
        }
        body.push_str("```\n");
        // headline number: best cost advantage with <=1% drop
        let best = curve
            .iter()
            .filter(|p| p.drop_pct <= 1.0)
            .map(|p| p.achieved_cost_advantage)
            .fold(0.0, f64::max);
        body.push_str(&format!(
            "\nheadline: {:.0}% cost advantage with <=1% quality drop \
             (paper Fig 1c: 22% with <1%)\n",
            best * 100.0
        ));
        self.write("fig1", &body)
    }

    // ------------------------------------------------------------------
    // Fig 3 — per-query response-quality distributions + shift
    // ------------------------------------------------------------------
    pub fn fig3(&self) -> Result<String> {
        let (small, large) = ("nano", "medium");
        let pair = pair_id(small, large);
        let tstar = self.pl.load_tstar(&pair)?;
        let qs = self.pl.load_quality(small, self.corpus)?;
        let ql = self.pl.load_quality(large, self.corpus)?;
        // pick the test query whose distributions overlap the most after
        // the shift (illustrative, like the paper's hand-picked example)
        let test = self.ids(Split::Test);
        let qi = *test
            .iter()
            .find(|&&i| self.corpus[i].task == crate::corpus::Task::Extr)
            .unwrap_or(&test[0]);
        let q = &self.corpus[qi];
        let mut body = format!(
            "# Fig 3 — response quality distribution for one query\n\nquery: `{}`\n\
             pair: {small} vs {large}, t* = {tstar:.3}\n\n",
            crate::tokenizer::detokenize(&q.prompt)
        );
        let all: Vec<f64> = qs.q[qi]
            .iter()
            .chain(ql.q[qi].iter())
            .map(|&x| x as f64)
            .collect();
        let lo = all.iter().cloned().fold(f64::MAX, f64::min) - 0.2;
        let hi = all.iter().cloned().fold(f64::MIN, f64::max) + 0.2;
        for (name, samples, shift) in [
            (format!("{small} (small)"), &qs.q[qi], 0.0f32),
            (format!("{large} (large)"), &ql.q[qi], 0.0),
            (format!("{large} shifted by -t*"), &ql.q[qi], tstar),
        ] {
            let vals: Vec<f64> = samples.iter().map(|&x| (x - shift) as f64).collect();
            let h = Histogram::build(&vals, lo, hi, 12);
            body.push_str(&format!("\n### {name}\n\n```\n{}```\n", h.ascii(30)));
        }
        body.push_str(&format!(
            "\nPr[q(S) >= q(L)] = {:.2}, Pr[q(S) >= q(L) - t*] = {:.2}\n",
            labels::y_prob(&pick(&qs, qi), &pick(&ql, qi))?[0],
            labels::y_trans(&pick(&qs, qi), &pick(&ql, qi), tstar)?[0],
        ));
        self.write("fig3", &body)
    }

    // ------------------------------------------------------------------
    // Fig 4 — label distributions before/after the transformation
    // ------------------------------------------------------------------
    pub fn fig4(&self) -> Result<String> {
        let (small, large) = ("nano", "medium");
        let pair = pair_id(small, large);
        let tstar = self.pl.load_tstar(&pair)?;
        let train = self.ids(Split::Train);
        let mut body = format!(
            "# Fig 4 — data transformation ({small}/{large}, t* = {tstar:.3})\n"
        );
        for (tag, kind) in [("(a) y_prob", RouterKind::Prob), ("(c) y_trans(t*)", RouterKind::Trans)] {
            let y = crate::io::Tensor::load(&self.pl.paths.labels_tz(&pair, kind))?;
            let y = y.as_f32()?;
            let vals: Vec<f64> = train.iter().map(|&i| y[i] as f64).collect();
            let h = Histogram::build(&vals, 0.0, 1.0001, 10);
            body.push_str(&format!("\n## {tag} label distribution (train)\n\n```\n{}```\n", h.ascii(40)));
            let frac_zero = vals.iter().filter(|&&v| v < 0.05).count() as f64 / vals.len() as f64;
            body.push_str(&format!("fraction of labels < 0.05: {:.2}\n", frac_zero));
        }
        // (b) the Eq. 3 objective curve
        let curve = crate::io::Tensor::load(&self.pl.paths.tstar_curve(&pair))?;
        let c = curve.as_f32()?;
        body.push_str("\n## (b) objective J(t) (Eq. 3)\n\n```\nt\tJ(t)\n");
        for row in c.chunks(2) {
            body.push_str(&format!("{:.3}\t{:.4}\n", row[0], row[1]));
        }
        body.push_str("```\n");
        self.write("fig4", &body)
    }

    // ------------------------------------------------------------------
    // Fig 5 / Fig 9 — error-cost tradeoff curves
    // ------------------------------------------------------------------
    pub fn fig5(&self, pairs: &[(&str, &str, &str)]) -> Result<String> {
        self.fig5_named("fig5", pairs)
    }

    pub fn fig5_named(&self, id: &str, pairs: &[(&str, &str, &str)]) -> Result<String> {
        let test = self.ids(Split::Test);
        let mut body = format!("# {id} — error–cost tradeoffs\n");
        for (small, large, regime) in pairs {
            let pair = pair_id(small, large);
            let (qs, ql) = self.pair_quality(small, large, &test)?;
            body.push_str(&format!(
                "\n## {small} vs {large} ({regime})\n\n```\ncost_adv\trandom\tr_det\tr_prob\tr_trans\n"
            ));
            let rnd = random_curve(test.len(), &qs, &ql, 20, 99);
            let mut curves = Vec::new();
            for kind in ALL_ROUTERS {
                let scores = self.router_scores_on(&pair, kind, &test)?;
                curves.push(tradeoff_curve(&scores, &qs, &ql, 20));
            }
            for k in 0..=20 {
                body.push_str(&format!(
                    "{:.2}\t{:+.2}\t{:+.2}\t{:+.2}\t{:+.2}\n",
                    k as f64 / 20.0,
                    rnd[k].drop_pct,
                    curves[0][k].drop_pct,
                    curves[1][k].drop_pct,
                    curves[2][k].drop_pct,
                ));
            }
            body.push_str("```\n");
        }
        self.write(id, &body)
    }

    // ------------------------------------------------------------------
    // Table 1 / Table 4 — drops at fixed cost advantages
    // ------------------------------------------------------------------
    pub fn table1(&self, pairs: &[(&str, &str, &str)], id: &str) -> Result<String> {
        let test = self.ids(Split::Test);
        let mut body = format!(
            "# {id} — quality drop (%) vs all-at-large at fixed cost advantage\n\n"
        );
        let mut rows = Vec::new();
        for ca in [0.10, 0.20, 0.40] {
            let mut row = vec![format!("{:.0}", ca * 100.0)];
            for (small, large, _) in pairs {
                let pair = pair_id(small, large);
                let (qs, ql) = self.pair_quality(small, large, &test)?;
                for kind in ALL_ROUTERS {
                    let scores = self.router_scores_on(&pair, kind, &test)?;
                    let p = tradeoff_at(&scores, &qs, &ql, ca);
                    row.push(format!("{:+.1}", p.drop_pct));
                }
            }
            rows.push(row);
        }
        let mut headers = vec!["cost adv %".to_string()];
        for (small, large, _) in pairs {
            for kind in ALL_ROUTERS {
                headers.push(format!("{small}/{large} r_{}", kind.name()));
            }
        }
        let headers_ref: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        body.push_str(&md_table(&headers_ref, &rows));
        self.write(id, &body)
    }

    // ------------------------------------------------------------------
    // Fig 6 / Fig 10 — router validation via quality-gap difference
    // ------------------------------------------------------------------
    pub fn gapdiff(&self, id: &str, pairs: &[(&str, &str, &str)]) -> Result<String> {
        let test = self.ids(Split::Test);
        let mut body = format!(
            "# {id} — avg quality-gap difference (small-routed minus large-routed)\n\n\
             Positive = easy queries go to the small model (router works).\n"
        );
        for (small, large, regime) in pairs {
            let pair = pair_id(small, large);
            let (qs, ql) = self.pair_quality(small, large, &test)?;
            let gap: Vec<f64> = qs.iter().zip(&ql).map(|(a, b)| a - b).collect();
            let scores = self.router_scores_on(&pair, RouterKind::Trans, &test)?;
            body.push_str(&format!(
                "\n## {small} vs {large} ({regime})\n\n```\ncost_adv\trouter\trandom\n"
            ));
            for k in 1..10 {
                let target = k as f64 / 10.0;
                let diff_router = gap_diff(&scores, &gap, target);
                let rnd_scores: Vec<f32> = {
                    let mut rng = crate::rng::Rng::new(1234 + k as u64);
                    (0..gap.len()).map(|_| rng.next_f32()).collect()
                };
                let diff_rnd = gap_diff(&rnd_scores, &gap, target);
                body.push_str(&format!("{target:.1}\t{diff_router:+.3}\t{diff_rnd:+.3}\n"));
            }
            body.push_str("```\n");
        }
        self.write(id, &body)
    }

    // ------------------------------------------------------------------
    // Table 3 — threshold calibration (§4.5)
    // ------------------------------------------------------------------
    pub fn table3(&self) -> Result<String> {
        let val = self.ids(Split::Val);
        let test = self.ids(Split::Test);
        let nval = val.len().min(500);
        let mut body = String::from(
            "# Table 3 — thresholds from 500 validation samples (<=1% drop)\n\n",
        );
        let mut rows = Vec::new();
        for kind in ALL_ROUTERS {
            for (small, large, _) in &MAIN_PAIRS {
                let pair = pair_id(small, large);
                let sub = crate::calibrate::subsample(val.len(), nval, 0xCAFE);
                let val_ids: Vec<usize> = sub.iter().map(|&i| val[i]).collect();
                let (qs_v, ql_v) = self.pair_quality(small, large, &val_ids)?;
                let scores_v = self.router_scores_on(&pair, kind, &val_ids)?;
                let cal = crate::calibrate::calibrate(&scores_v, &qs_v, &ql_v, 1.0);
                let (qs_t, ql_t) = self.pair_quality(small, large, &test)?;
                let scores_t = self.router_scores_on(&pair, kind, &test)?;
                let on_test =
                    crate::calibrate::evaluate_threshold(cal.threshold, &scores_t, &qs_t, &ql_t);
                rows.push(vec![
                    format!("r_{}", kind.name()),
                    format!("{small}/{large}"),
                    format!("{:.2}", cal.drop_pct),
                    format!("{:.1}", cal.cost_advantage * 100.0),
                    format!("{:.2}", on_test.drop_pct),
                    format!("{:.1}", on_test.cost_advantage * 100.0),
                ]);
            }
        }
        body.push_str(&md_table(
            &["router", "pair", "val drop %", "val cost adv %", "test drop %", "test cost adv %"],
            &rows,
        ));
        self.write("table3", &body)
    }

    // ------------------------------------------------------------------
    // Fig 7 — alternate (oracle) metric evaluation
    // ------------------------------------------------------------------
    pub fn fig7(&self) -> Result<String> {
        let test = self.ids(Split::Test);
        let mut body = String::from(
            "# Fig 7 — routing evaluated under the oracle rating (GPT-4-judge analogue)\n",
        );
        for (small, large, regime) in &MAIN_PAIRS {
            let pair = pair_id(small, large);
            // correlations between BART-analogue gap and oracle gap
            let (qs_b, ql_b) = self.pair_quality(small, large, &test)?;
            let gap_bart: Vec<f64> = qs_b.iter().zip(&ql_b).map(|(a, b)| a - b).collect();
            let qs_o = subset(&self.pl.load_oracle_quality(small, self.corpus)?, &test).mean();
            let ql_o = subset(&self.pl.load_oracle_quality(large, self.corpus)?, &test).mean();
            let gap_orc: Vec<f64> = qs_o.iter().zip(&ql_o).map(|(a, b)| a - b).collect();
            let r = stats::pearson(&gap_bart, &gap_orc);
            let rho = stats::spearman(&gap_bart, &gap_orc);
            body.push_str(&format!(
                "\n## {small} vs {large} ({regime}) — r = {r:.2}, rho = {rho:.2}\n\n\
                 drop % under oracle rating:\n\n"
            ));
            let mut rows = Vec::new();
            for ca in [0.10, 0.20, 0.40] {
                let mut row = vec![format!("{:.0}", ca * 100.0)];
                for kind in ALL_ROUTERS {
                    let scores = self.router_scores_on(&pair, kind, &test)?;
                    let p = tradeoff_at(&scores, &qs_o, &ql_o, ca);
                    row.push(format!("{:+.1}", p.drop_pct));
                }
                rows.push(row);
            }
            body.push_str(&md_table(&["cost adv %", "r_det", "r_prob", "r_trans"], &rows));
        }
        self.write("fig7", &body)
    }

    // ------------------------------------------------------------------
    // Fig 8 — generalization across model pairs
    // ------------------------------------------------------------------
    pub fn fig8(&self) -> Result<String> {
        let test = self.ids(Split::Test);
        let mut body = String::from("# Fig 8 — routers applied to pairs they were not trained on\n");
        // train-pair -> test-pair combos spanning correlation regimes
        let combos = [
            ("small", "medium", "medium", "large"),
            ("medium", "large", "small", "large"),
            ("nano", "medium", "small", "medium"),
            ("small", "medium", "nano", "large"),
        ];
        for (tr_s, tr_l, te_s, te_l) in combos {
            let tr_pair = pair_id(tr_s, tr_l);
            // gap correlation between train pair and test pair (test split)
            let (qs_tr, ql_tr) = self.pair_quality(tr_s, tr_l, &test)?;
            let gap_tr: Vec<f64> = qs_tr.iter().zip(&ql_tr).map(|(a, b)| a - b).collect();
            let (qs_te, ql_te) = self.pair_quality(te_s, te_l, &test)?;
            let gap_te: Vec<f64> = qs_te.iter().zip(&ql_te).map(|(a, b)| a - b).collect();
            let r = stats::pearson(&gap_tr, &gap_te);
            let rho = stats::spearman(&gap_tr, &gap_te);
            body.push_str(&format!(
                "\n## trained on {tr_s}/{tr_l}, tested on {te_s}/{te_l} — r = {r:.2}, rho = {rho:.2}\n\n"
            ));
            let mut rows = Vec::new();
            for ca in [0.10, 0.20, 0.40] {
                let mut row = vec![format!("{:.0}", ca * 100.0)];
                for kind in ALL_ROUTERS {
                    let scores = self.router_scores_on(&tr_pair, kind, &test)?;
                    let p = tradeoff_at(&scores, &qs_te, &ql_te, ca);
                    row.push(format!("{:+.1}", p.drop_pct));
                }
                rows.push(row);
            }
            body.push_str(&md_table(&["cost adv %", "r_det", "r_prob", "r_trans"], &rows));
        }
        self.write("fig8", &body)
    }

    // ------------------------------------------------------------------
    // Table 5 — dataset statistics
    // ------------------------------------------------------------------
    pub fn table5(&self) -> Result<String> {
        let mut body = String::from("# Table 5 — MixSynth dataset statistics\n\n");
        let mut by_source: std::collections::BTreeMap<&str, usize> = Default::default();
        for q in self.corpus {
            *by_source.entry(q.task.source()).or_default() += 1;
        }
        let rows: Vec<Vec<String>> = by_source
            .iter()
            .map(|(s, n)| vec![s.to_string(), n.to_string()])
            .collect();
        body.push_str(&md_table(&["source", "#examples"], &rows));
        body.push_str(&format!("\ntotal: {}\n\n", self.corpus.len()));

        let mut rows = Vec::new();
        for t in ALL_TASKS {
            let n = self.corpus.iter().filter(|q| q.task == t).count();
            let (ntr, nv, nte) = (
                self.corpus.iter().filter(|q| q.task == t && q.split == Split::Train).count(),
                self.corpus.iter().filter(|q| q.task == t && q.split == Split::Val).count(),
                self.corpus.iter().filter(|q| q.task == t && q.split == Split::Test).count(),
            );
            rows.push(vec![
                t.name().to_string(),
                t.difficulty().to_string(),
                n.to_string(),
                ntr.to_string(),
                nv.to_string(),
                nte.to_string(),
            ]);
        }
        body.push_str(&md_table(
            &["task", "difficulty", "total", "train", "val", "test"],
            &rows,
        ));
        self.write("table5", &body)
    }

    // ------------------------------------------------------------------
    // §5 extension — N-model routing
    // ------------------------------------------------------------------
    pub fn nmodel(&self) -> Result<String> {
        let test = self.ids(Split::Test);
        // roster ladder nano -> medium -> large with the two trained
        // adjacent pair-routers
        let ladder = ["nano", "medium", "large"];
        let pairs = [pair_id("nano", "medium"), pair_id("medium", "large")];
        let mut pair_scores = Vec::new();
        for p in &pairs {
            pair_scores.push(self.router_scores_on(p, RouterKind::Trans, &test)?);
        }
        let mut quals = Vec::new();
        for m in ladder {
            quals.push(subset(&self.pl.load_quality(m, self.corpus)?, &test).mean());
        }
        let base = stats::mean(&quals[2]);
        let mut body = String::from(
            "# N-model routing (§5 extension 2): nano -> medium -> large ladder\n\n\
             Thresholds swept jointly; quality drop vs all-at-largest.\n\n```\n\
             thr\tfrac_nano\tfrac_medium\tfrac_large\tdrop_pct\n",
        );
        for k in 0..=10 {
            let thr = k as f32 / 10.0;
            let assign = policy::nmodel_assign(&pair_scores, &[thr, thr], test.len());
            let frac = policy::tier_fractions(&assign, ladder.len());
            let q = policy::achieved_quality_tiers(&assign, &quals);
            body.push_str(&format!(
                "{thr:.1}\t{:.2}\t{:.2}\t{:.2}\t{:+.2}\n",
                frac[0],
                frac[1],
                frac[2],
                crate::metrics::quality_drop_pct(base, q)
            ));
        }
        body.push_str("```\n");
        self.write("nmodel", &body)
    }

    // ------------------------------------------------------------------
    // Fleet extension — N-tier ladder routing over a single router score
    // ------------------------------------------------------------------

    /// 3-tier ladder: one router score (medium/large r_trans) partitioned
    /// into bands over a nano → medium → large fleet, with [`model_cost`]
    /// weights. Reports per-tier fractions, cost-weighted cost advantage,
    /// and drop vs all-at-large as the proportional-ladder pivot sweeps.
    pub fn ladder(&self) -> Result<String> {
        let test = self.ids(Split::Test);
        let val = self.ids(Split::Val);
        let fleet = ["nano", "medium", "large"];
        let costs: Vec<f64> = fleet.iter().map(|m| crate::pipeline::model_cost(m)).collect();
        let scores =
            self.router_scores_on(&pair_id("medium", "large"), RouterKind::Trans, &test)?;
        // one tensor load per model, subset for both splits
        let mut quals = Vec::new();
        let mut quals_v = Vec::new();
        for m in fleet {
            let q = self.pl.load_quality(m, self.corpus)?;
            quals.push(subset(&q, &test).mean());
            quals_v.push(subset(&q, &val).mean());
        }
        let mut body = String::from(
            "# ladder — 3-tier fleet (nano / medium / large), single-score bands\n\n\
             Proportional ladder `t_i = pivot * (K-1-i)/(K-1)`; cost advantage is\n\
             cost-weighted spend saved vs all-at-large.\n\n```\n\
             pivot\tfrac_nano\tfrac_medium\tfrac_large\tcost_adv\tdrop_pct\n",
        );
        for k in 0..=10 {
            let pivot = k as f32 / 10.0;
            let thresholds = crate::calibrate::ladder_from_pivot(pivot, fleet.len());
            let assign =
                policy::TierPolicy::Ladder { thresholds }.assign(&scores);
            let frac = policy::tier_fractions(&assign, fleet.len());
            let q = policy::achieved_quality_tiers(&assign, &quals);
            let ca = policy::cost_advantage_tiers(&assign, &costs);
            body.push_str(&format!(
                "{pivot:.1}\t{:.2}\t{:.2}\t{:.2}\t{:.2}\t{:+.2}\n",
                frac[0],
                frac[1],
                frac[2],
                ca,
                crate::metrics::quality_drop_pct(stats::mean(&quals[2]), q)
            ));
        }
        body.push_str("```\n");
        // §4.5-style ladder operating point on the validation split
        let scores_v =
            self.router_scores_on(&pair_id("medium", "large"), RouterKind::Trans, &val)?;
        let cal = crate::calibrate::calibrate_ladder(&scores_v, &quals_v, &costs, 1.0);
        let on_test = crate::calibrate::evaluate_ladder(&cal.thresholds, &scores, &quals, &costs);
        body.push_str(&format!(
            "\ncalibrated ladder {:?} (<=1% drop on val): test cost advantage {:.1}% at {:+.2}% drop\n",
            cal.thresholds,
            on_test.cost_advantage * 100.0,
            on_test.drop_pct
        ));
        self.write("ladder", &body)
    }
}

/// Difference between average quality gaps of small-routed vs
/// large-routed queries at a target cost advantage (Fig. 6 quantity).
pub fn gap_diff(scores: &[f32], gap: &[f64], target: f64) -> f64 {
    let n = scores.len();
    let k = ((target * n as f64).round() as usize).clamp(1, n.saturating_sub(1));
    let mut idx: Vec<usize> = (0..n).collect();
    // total_cmp: NaN router scores must not panic the eval driver
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    let small: Vec<f64> = idx[..k].iter().map(|&i| gap[i]).collect();
    let large: Vec<f64> = idx[k..].iter().map(|&i| gap[i]).collect();
    stats::mean(&small) - stats::mean(&large)
}

fn pick(q: &QualitySamples, i: usize) -> QualitySamples {
    QualitySamples::new(vec![q.q[i].clone()])
}

/// Ensure result invariants used by integration tests.
pub fn sanity_check_report(report: &str) -> Result<()> {
    ensure!(!report.is_empty());
    ensure!(report.starts_with('#'), "report must start with a title");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn md_table_renders() {
        let t = md_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(t.contains("| a | b |"));
        assert!(t.contains("| 1 | 2 |"));
        assert!(t.contains("|---|---|"));
    }

    #[test]
    fn gap_diff_positive_for_informative_scores() {
        // scores aligned with gap: top-scored queries have the biggest gap
        let gap: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let scores: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let d = gap_diff(&scores, &gap, 0.3);
        assert!(d > 0.4, "{d}");
        // uninformative scores: near zero (use a shuffled permutation)
        let mut rng = crate::rng::Rng::new(5);
        let mut perm: Vec<f32> = scores.clone();
        rng.shuffle(&mut perm);
        let d0 = gap_diff(&perm, &gap, 0.3);
        assert!(d0.abs() < 0.25, "{d0}");
    }

    #[test]
    fn sanity_check_works() {
        assert!(sanity_check_report("# title\nbody").is_ok());
        assert!(sanity_check_report("").is_err());
        assert!(sanity_check_report("no title").is_err());
    }
}
