//! Integration: the full label→router chain on a micro workload with
//! real artifacts (train a tiny LM a few steps, sample, score, label,
//! train a router, calibrate). Complements the smoke-scale pipeline run
//! recorded in EXPERIMENTS.md — this is the fast CI-sized version.

use std::path::{Path, PathBuf};

use hybrid_llm::corpus::{make_query, Split, Task};
use hybrid_llm::labels::{self, QualitySamples};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::rng::Rng;
use hybrid_llm::router::{RouterEngine, TrainCfg};
use hybrid_llm::runtime::Runtime;
use hybrid_llm::scorer::ScorerEngine;

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.txt").exists().then_some(p)
}

#[test]
fn micro_pipeline_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(42);

    // tiny corpus: 48 queries over two tasks of different difficulty
    let mut corpus = Vec::new();
    for i in 0..48 {
        let task = if i % 2 == 0 { Task::Copy } else { Task::Sort };
        let split = if i < 32 { Split::Train } else { Split::Val };
        corpus.push(make_query(i, split, task, &mut rng));
    }
    let train_refs: Vec<&hybrid_llm::corpus::Query> =
        corpus.iter().filter(|q| q.split == Split::Train).collect();

    // 1. train nano briefly — loss must drop
    let mut eng = LmEngine::init(rt.clone(), "nano", 7).unwrap();
    let losses = eng.train(&train_refs, 30, 1e-2, 1, |_, _| {}).unwrap();
    assert_eq!(losses.len(), 30);
    assert!(losses.iter().all(|l| l.is_finite()));
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[25..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss did not drop: {head} -> {tail}");

    // 2. save + reload round-trips
    let tmp = std::env::temp_dir().join(format!("hybrid_pi_{}", std::process::id()));
    eng.save(&tmp.join("nano")).unwrap();
    let eng2 = LmEngine::load(rt.clone(), "nano", &tmp.join("nano")).unwrap();
    assert_eq!(eng2.params.host[0], eng.params.host[0]);

    // 3. sample 2 responses per query from nano and an un-trained micro
    let eng_big = LmEngine::init(rt.clone(), "micro", 9).unwrap();
    let prompts: Vec<&[i32]> = corpus.iter().map(|q| q.prompt.as_slice()).collect();
    let seeds1: Vec<u32> = (0..corpus.len() as u32).collect();
    let seeds2: Vec<u32> = (100..100 + corpus.len() as u32).collect();
    let rs1 = eng.generate(&prompts, &seeds1, 0.8).unwrap();
    let rs2 = eng.generate(&prompts, &seeds2, 0.8).unwrap();
    let rb1 = eng_big.generate(&prompts, &seeds1, 0.8).unwrap();
    let rb2 = eng_big.generate(&prompts, &seeds2, 0.8).unwrap();
    assert_eq!(rs1.len(), corpus.len());
    // answers respect the budget and never contain EOS
    for r in rs1.iter().chain(&rb1) {
        assert!(r.tokens.len() < hybrid_llm::corpus::A_MAX);
        assert!(!r.tokens.contains(&hybrid_llm::tokenizer::EOS));
    }

    // 4. score with a fresh scorer (values finite, log-prob scale)
    let scorer = ScorerEngine::init(rt.clone(), 3).unwrap();
    let score_of = |resp: &[hybrid_llm::lm::Response]| -> Vec<f32> {
        let flat: Vec<(&[i32], &[i32])> = corpus
            .iter()
            .zip(resp)
            .map(|(q, r)| (q.prompt.as_slice(), r.tokens.as_slice()))
            .collect();
        scorer.score(&flat).unwrap()
    };
    let sc = score_of(&rs1);
    assert_eq!(sc.len(), corpus.len());
    assert!(sc.iter().all(|s| s.is_finite() && *s < 1.0));
    let sc2 = score_of(&rs2);
    let scb = score_of(&rb1);
    let scb2 = score_of(&rb2);

    // 5. labels from 2-sample quality matrices
    let mk = |a: &[f32], b: &[f32]| -> QualitySamples {
        QualitySamples::new(a.iter().zip(b).map(|(&x, &y)| vec![x, y]).collect())
    };
    let qs = mk(&sc, &sc2);
    let ql = mk(&scb, &scb2);
    let y_prob = labels::y_prob(&qs, &ql).unwrap();
    assert!(y_prob.iter().all(|&y| (0.0..=1.0).contains(&y)));
    let search = labels::find_tstar(&qs, &ql, 11).unwrap();
    let y_trans = labels::y_trans(&qs, &ql, search.tstar).unwrap();
    // relaxation can only raise labels
    for (a, b) in y_prob.iter().zip(&y_trans) {
        assert!(b >= a);
    }

    // 6. router trains on these labels without blowing up
    let mut router = RouterEngine::init(rt.clone(), 5).unwrap();
    let (rl, best) = router
        .train(
            &prompts[..32],
            &y_trans[..32],
            &prompts[32..],
            &y_trans[32..],
            TrainCfg { epochs: 2, base_lr: 1e-3, seed: 3 },
            |_, _, _| {},
        )
        .unwrap();
    assert!(!rl.is_empty());
    assert!(rl.iter().all(|l| l.is_finite()));
    assert!(best.is_finite());
    let scores = router.scores(&prompts).unwrap();
    assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));

    // 7. calibration respects the drop budget on this data
    let qsm = qs.mean();
    let qlm = ql.mean();
    let cal = hybrid_llm::calibrate::calibrate(&scores, &qsm, &qlm, 1.0);
    assert!(cal.drop_pct <= 1.0 + 1e-9);

    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn generation_is_reproducible_per_seed() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let eng = LmEngine::init(rt, "nano", 7).unwrap();
    let mut rng = Rng::new(1);
    let q = make_query(0, Split::Test, Task::Copy, &mut rng);
    let prompts = vec![q.prompt.as_slice(); 4];
    let seeds = vec![5u32, 5, 9, 9];
    let r = eng.generate(&prompts, &seeds, 0.9).unwrap();
    // same seed → same sample
    assert_eq!(r[0].tokens, r[1].tokens);
    assert_eq!(r[2].tokens, r[3].tokens);
    let r2 = eng.generate(&prompts, &seeds, 0.9).unwrap();
    assert_eq!(r[0].tokens, r2[0].tokens);
}

#[test]
fn greedy_generation_is_temp_invariant_at_zero() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let eng = LmEngine::init(rt, "nano", 7).unwrap();
    let mut rng = Rng::new(2);
    let q = make_query(0, Split::Test, Task::Rev, &mut rng);
    let prompts = vec![q.prompt.as_slice(); 2];
    let r = eng.generate(&prompts, &[1, 999], 0.0).unwrap();
    assert_eq!(r[0].tokens, r[1].tokens, "greedy must ignore seeds");
    // single-request path agrees with the batched path under greedy
    let (one, _steps) = eng.generate_one(&q.prompt, 7, 0.0).unwrap();
    assert_eq!(one.tokens, r[0].tokens);
}
