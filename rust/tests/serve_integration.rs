//! Integration: the threaded serving system against real artifacts —
//! request lifecycle, continuous batching, both scheduling modes, clean
//! shutdown under load, N-tier fleets with replicated workers, and the
//! first-class request API (per-request quality targets, streaming
//! events, cancellation, backpressure).

use std::path::{Path, PathBuf};
use std::time::Duration;

use hybrid_llm::batching::BatchMode;
use hybrid_llm::corpus::{generate, Scale, Split};
use hybrid_llm::lm::LmEngine;
use hybrid_llm::policy::{LadderFamily, TierPolicy};
use hybrid_llm::runtime::Runtime;
use hybrid_llm::serve::{
    admission_byte_bound, min_kv_pair_bytes, DecodeMode, Event, ReplicaSelect, Request,
    RequestError, ServeConfig, Server, SubmitError, TierSpec,
};

fn artifacts_dir() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.txt").exists().then_some(p)
}

fn seed_run_dir(artifacts: &Path, tag: &str) -> PathBuf {
    let run = std::env::temp_dir().join(format!("hybrid_serve_{}_{tag}", std::process::id()));
    let rt = Runtime::load(artifacts).unwrap();
    for model in ["nano", "micro"] {
        let dir = run.join("params").join(model);
        if !dir.join("p.emb.tz").exists() {
            let eng = LmEngine::init(rt.clone(), model, 3).unwrap();
            eng.save(&dir).unwrap();
        }
    }
    run
}

fn base_cfg(artifacts: PathBuf, run_dir: PathBuf, mode: BatchMode) -> ServeConfig {
    // random routing (no trained router needed) over the seed pair
    let mut cfg = ServeConfig::two_tier(artifacts, run_dir, "nano", "micro", String::new(), 0.5);
    cfg.temp = 0.8;
    cfg.mode = mode;
    cfg.batch_window = Duration::from_millis(2);
    cfg
}

#[test]
fn serves_all_requests_continuous() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "cont");
    let server =
        Server::start(base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(3, Scale::Smoke);
    let reqs: Vec<_> = corpus
        .iter()
        .filter(|q| q.split == Split::Test)
        .take(24)
        .collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    let mut ids = std::collections::HashSet::new();
    let mut small = 0;
    for h in handles {
        let c = h.wait_timeout(Duration::from_secs(120)).expect("completion");
        assert!(ids.insert(c.id), "duplicate completion id");
        assert!(c.tokens.len() < hybrid_llm::corpus::A_MAX);
        assert!((0.0..=1.0).contains(&c.router_score));
        if c.tier == 0 {
            small += 1;
        }
    }
    assert_eq!(ids.len(), 24, "every request completed exactly once");
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 24);
    assert_eq!(stats.routing.to_small() as usize, small);
    assert!(stats.decode_steps > 0);
    assert_eq!(stats.e2e_latency.n, 24);
    // per-tier latency counts partition the e2e count
    assert_eq!(stats.tiers.len(), 2);
    assert_eq!(stats.tiers.iter().map(|t| t.latency.n).sum::<usize>(), 24);

    // residency acceptance: with v2 (untupled) artifacts the steady-state
    // decode downloads O(B) bytes per step — the sampled tokens and
    // logprobs — never the O(L·B·S·H·Dh) KV pair the seed round-tripped.
    let rt = Runtime::load(&artifacts).unwrap();
    if rt.manifest.version >= 2 {
        let kv_pair_bytes = min_kv_pair_bytes(&rt.manifest, &["nano", "micro"]).unwrap();
        assert!(
            stats.d2h_bytes_per_step() < kv_pair_bytes / 4.0,
            "decode downloads {:.0} B/step — KV caches are round-tripping \
             (smallest pair = {kv_pair_bytes:.0} B)",
            stats.d2h_bytes_per_step()
        );
        // uploads are O(B) too: the post-surgery KV re-upload is part of
        // the admission window, not the decode loop
        assert!(
            stats.h2d_bytes_per_step() < kv_pair_bytes / 4.0,
            "decode uploads {:.0} B/step — KV caches are round-tripping",
            stats.h2d_bytes_per_step()
        );
    }
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn shutdown_under_load_drains_every_request() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "drain");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(13, Scale::Smoke);
    // submit a burst and shut down immediately, while the router is still
    // dispatching and the workers still decoding: the drain protocol
    // (join router before signalling workers) must deliver every
    // completion instead of erroring with "worker channel closed"
    let handles: Vec<_> = corpus
        .iter()
        .take(30)
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    let stats = server.shutdown().expect("graceful shutdown under load");
    assert_eq!(stats.e2e_latency.n, 30, "all in-flight requests completed");
    assert_eq!(stats.in_flight, 0, "admission window fully drained");
    let mut ids = std::collections::HashSet::new();
    for h in handles {
        // terminal events were delivered before shutdown returned
        let c = h
            .wait_timeout(Duration::from_millis(200))
            .expect("completion delivered before shutdown returned");
        assert!(ids.insert(c.id));
    }
    assert_eq!(ids.len(), 30);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn device_and_host_kv_decode_identical_tokens() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&artifacts).unwrap();
    let eng = LmEngine::init(rt.clone(), "nano", 3).unwrap();
    let corpus = generate(17, Scale::Smoke);
    let g = rt.manifest.globals;
    let prompts: Vec<&[i32]> = corpus
        .iter()
        .take(g.genb)
        .map(|q| q.prompt.as_slice())
        .collect();
    let seeds: Vec<u32> = (0..prompts.len() as u32).collect();
    // sampled (temp > 0) so any divergence in the KV contents would
    // surface as different tokens almost immediately
    let dev = eng.generate_with(&prompts, &seeds, 0.8, false).unwrap();
    let host = eng.generate_with(&prompts, &seeds, 0.8, true).unwrap();
    assert_eq!(dev.len(), host.len());
    for (b, (d, h)) in dev.iter().zip(&host).enumerate() {
        assert_eq!(d.tokens, h.tokens, "slot {b}: residency changed the decode");
        assert!(
            (d.mean_logprob - h.mean_logprob).abs() < 1e-6,
            "slot {b}: logprobs diverged"
        );
    }
}

/// Acceptance (manifest v3): a steady-load run admits without any
/// `[L, genb, sctx, H, Dh]` host↔device transfer — per admission the
/// host moves O(B·sprompt) prompt bytes, asserted through the
/// `TransferCounters`-backed admission byte counters.
#[test]
fn admission_moves_o_b_sprompt_bytes_on_v3() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&artifacts).unwrap();
    if rt.manifest.version < 3 {
        eprintln!("pre-v3 artifacts: admission is host surgery by design");
        return;
    }
    let run_dir = seed_run_dir(&artifacts, "admitbytes");
    let server =
        Server::start(base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(43, Scale::Smoke);
    let handles: Vec<_> = corpus
        .iter()
        .take(24)
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    for h in handles {
        h.wait_timeout(Duration::from_secs(120)).expect("completion");
    }
    let stats = server.shutdown().unwrap();
    assert!(stats.admissions > 0, "no admission waves recorded");
    assert_eq!(stats.admitted, 24, "every request admitted exactly once");
    let per_admission =
        (stats.admit_h2d_bytes + stats.admit_d2h_bytes) as f64 / stats.admissions as f64;
    // O(B·sprompt) vs O(L·genb·sctx·H·Dh): the same bound definitions
    // the serving_e2e CI gate enforces
    let o_b_sprompt = admission_byte_bound(&rt.manifest.globals);
    let kv_pair_bytes = min_kv_pair_bytes(&rt.manifest, &["nano", "micro"]).unwrap();
    assert!(
        per_admission < o_b_sprompt,
        "admission moved {per_admission:.0} B/wave — over the O(B·sprompt) bound \
         ({o_b_sprompt:.0} B); the KV cache is round-tripping (pair = {kv_pair_bytes:.0} B)"
    );
    assert!(per_admission < kv_pair_bytes / 4.0);
    assert!(stats.admit_bytes_per_req() > 0.0);
    assert_eq!(stats.admit_latency.n, stats.admissions as usize);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Device-install vs host-surgery admission must decode byte-identical
/// tokens. Requests are submitted one at a time (each waits for its
/// completion) so both servers see identical admission groups — the
/// only variable is the install mechanism.
#[test]
fn device_and_host_admission_identical_tokens() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let corpus = generate(47, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(6).map(|q| q.prompt.clone()).collect();
    let run = |tag: &str, force_host: bool| -> Vec<Vec<i32>> {
        let run_dir = seed_run_dir(&artifacts, tag);
        let mut cfg = base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous);
        cfg.temp = 0.0; // greedy: tokens depend only on the KV contents
        cfg.force_host_admission = force_host;
        let server = Server::start(cfg).unwrap();
        let out = prompts
            .iter()
            .map(|p| {
                server
                    .submit(Request::new(p.clone()))
                    .expect("submit")
                    .wait_timeout(Duration::from_secs(120))
                    .expect("completion")
                    .tokens
            })
            .collect();
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.admitted, prompts.len() as u64);
        let _ = std::fs::remove_dir_all(&run_dir);
        out
    };
    let device = run("admitdev", false);
    let host = run("admithost", true);
    for (i, (d, h)) in device.iter().zip(&host).enumerate() {
        assert_eq!(d, h, "request {i}: install mechanism changed the decode");
    }
}

/// Acceptance (manifest v4): the block-paged KV path must decode
/// byte-identical greedy tokens to the dense slab. Every prompt is
/// submitted twice so the second pass also exercises the prefix-cache
/// full-hit replay (cached first token + copy-on-extend tail) — which
/// must be indistinguishable from a fresh prefill. Requests are
/// submitted one at a time so both servers see identical admission
/// groups; the only variable is the KV layout.
#[test]
fn paged_and_dense_kv_decode_identical_tokens() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&artifacts).unwrap();
    if rt.manifest.version < 4 {
        eprintln!("pre-v4 artifacts: no paged decode to compare");
        return;
    }
    let corpus = generate(59, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(6).map(|q| q.prompt.clone()).collect();
    let run = |tag: &str, force_dense: bool| -> (Vec<Vec<i32>>, f64) {
        let run_dir = seed_run_dir(&artifacts, tag);
        let mut cfg = base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous);
        cfg.temp = 0.0; // greedy: tokens depend only on the KV contents
        cfg.force_dense_kv = force_dense;
        let server = Server::start(cfg).unwrap();
        let out = prompts
            .iter()
            .chain(prompts.iter()) // second pass: exact re-sends
            .map(|p| {
                server
                    .submit(Request::new(p.clone()))
                    .expect("submit")
                    .wait_timeout(Duration::from_secs(120))
                    .expect("completion")
                    .tokens
            })
            .collect();
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.admitted, 2 * prompts.len() as u64);
        let _ = std::fs::remove_dir_all(&run_dir);
        (out, stats.prefix_hit_rate)
    };
    let (paged, hit_rate) = run("kvpaged", false);
    let (dense, _) = run("kvdense", true);
    for (i, (p, d)) in paged.iter().zip(&dense).enumerate() {
        assert_eq!(p, d, "request {i}: KV layout changed the decode");
    }
    // the re-sent prompts must actually have hit the prefix cache
    assert!(
        hit_rate > 0.0,
        "exact prompt re-sends never hit the prefix cache (rate {hit_rate})"
    );
    // and within the paged run, a replayed prompt reproduces its first
    // serving exactly
    for i in 0..prompts.len() {
        assert_eq!(
            paged[i],
            paged[i + prompts.len()],
            "request {i}: the prefix-cache replay diverged from the original decode"
        );
    }
}

#[test]
fn oversized_prompts_rejected_or_truncated() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let sprompt = Runtime::load(&artifacts).unwrap().manifest.globals.sprompt;
    let run_dir = seed_run_dir(&artifacts, "toolong");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(53, Scale::Smoke);
    // extend a real prompt past the window with letter tokens
    let mut long = corpus[0].prompt.clone();
    while long.len() <= sprompt + 4 {
        long.push(4); // 'a'
    }
    // default: rejected at submit, before any admission-window slot or
    // prefill is spent on it
    match server.submit(Request::new(long.clone())) {
        Err(SubmitError::PromptTooLong { len, max }) => {
            assert_eq!(len, long.len());
            assert_eq!(max, sprompt);
        }
        other => panic!("expected PromptTooLong, got {:?}", other.map(|h| h.id())),
    }
    // opt-in truncation: clipped to the window and served normally
    let h = server
        .submit(Request::new(long).truncate_prompt())
        .expect("truncating submit");
    h.wait_timeout(Duration::from_secs(120)).expect("truncated request completes");
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 1, "the rejected prompt never reached routing");
    assert_eq!(stats.in_flight, 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn serves_all_requests_run_to_completion() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "rtc");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::RunToCompletion)).unwrap();
    let corpus = generate(5, Scale::Smoke);
    let handles: Vec<_> = corpus
        .iter()
        .take(20)
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    for h in handles {
        h.wait_timeout(Duration::from_secs(120)).expect("completion");
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.e2e_latency.n, 20);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn shutdown_with_no_traffic_is_clean() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "idle");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn threshold_extremes_route_everything_one_way() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "thr");
    // threshold 0.0 => every score >= 0 => all small
    let mut cfg = base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous);
    cfg.policy = TierPolicy::Ladder { thresholds: vec![0.0] };
    let server = Server::start(cfg).unwrap();
    let corpus = generate(7, Scale::Smoke);
    let handles: Vec<_> = corpus
        .iter()
        .take(8)
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    for h in handles {
        let c = h.wait_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(c.tier, 0, "everything must route to the small tier");
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.to_large(), 0);
    assert!((stats.routing.cost_advantage - 1.0).abs() < 1e-9);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn three_tier_fleet_with_replicas_serves() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "fleet");
    // device/edge/cloud fleet over the two seeded models, with a
    // replicated bottom tier and shortest-queue replica selection
    let mut cfg = base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous);
    cfg.tiers = vec![
        TierSpec::named("device", "nano", 2, 0.0),
        TierSpec::named("edge", "nano", 1, 0.4),
        TierSpec::named("cloud", "micro", 1, 1.0),
    ];
    cfg.policy = TierPolicy::even_ladder(3);
    cfg.select = ReplicaSelect::ShortestQueue;
    let server = Server::start(cfg).unwrap();
    let corpus = generate(9, Scale::Smoke);
    let handles: Vec<_> = corpus
        .iter()
        .take(18)
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    let mut by_tier = [0usize; 3];
    for h in handles {
        let c = h.wait_timeout(Duration::from_secs(180)).expect("completion");
        assert!(c.tier < 3);
        by_tier[c.tier] += 1;
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 18);
    assert_eq!(stats.tiers.len(), 3);
    assert_eq!(stats.routing.tiers.len(), 3);
    for (i, tr) in stats.routing.tiers.iter().enumerate() {
        assert_eq!(tr.routed as usize, by_tier[i], "tier {} count mismatch", tr.name);
    }
    assert_eq!(stats.routing.tiers[0].name, "device");
    assert_eq!(stats.routing.tiers[2].name, "cloud");
    // per-tier latencies partition e2e completions
    assert_eq!(stats.tiers.iter().map(|t| t.latency.n).sum::<usize>(), 18);
    assert_eq!(stats.e2e_latency.n, 18);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn quality_targets_route_differently_in_one_batch_window() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "quality");
    let mut cfg = base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous);
    // ladder family whose 0.1-rung routes everything cheap and whose
    // 0.9-rung routes everything capable: with random router scores the
    // tier split is then decided purely by the per-request target
    cfg.quality_ladders = Some(
        LadderFamily::new(vec![
            (0.1, vec![f32::NEG_INFINITY]),
            (0.9, vec![f32::INFINITY]),
        ])
        .unwrap(),
    );
    let server = Server::start(cfg).unwrap();
    let corpus = generate(21, Scale::Smoke);
    // all submitted before the 2ms batch window closes: the router sees
    // both targets inside the same scoring batch
    let handles: Vec<_> = corpus
        .iter()
        .take(8)
        .enumerate()
        .map(|(i, q)| {
            let quality = if i % 2 == 0 { 0.1 } else { 0.9 };
            server
                .submit(Request::new(q.prompt.clone()).quality(quality))
                .expect("submit")
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        // the Routed event carries the decision; the completion pins it
        let tier = match h.events().recv_timeout(Duration::from_secs(120)).unwrap() {
            Event::Routed { tier, .. } => tier,
            ev => panic!("expected Routed first, got {ev:?}"),
        };
        let c = h.wait_timeout(Duration::from_secs(120)).expect("completion");
        assert_eq!(c.tier, tier, "completion disagrees with the Routed event");
        if i % 2 == 0 {
            assert_eq!(c.tier, 0, "quality 0.1 must route to the cheap tier");
        } else {
            assert_eq!(c.tier, 1, "quality 0.9 must route to the capable tier");
        }
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.to_small(), 4);
    assert_eq!(stats.routing.to_large(), 4);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn streamed_tokens_equal_blocking_completion() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "stream");
    let server =
        Server::start(base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous))
            .unwrap();
    let corpus = generate(25, Scale::Smoke);
    let handles: Vec<_> = corpus
        .iter()
        .take(6)
        .map(|q| server.submit(Request::new(q.prompt.clone())).expect("submit"))
        .collect();
    for h in handles {
        let mut streamed: Vec<i32> = Vec::new();
        let mut routed_seen = false;
        let c = loop {
            match h.events().recv_timeout(Duration::from_secs(120)).expect("event") {
                Event::Routed { .. } => {
                    assert!(streamed.is_empty(), "Routed must precede all tokens");
                    routed_seen = true;
                }
                Event::Token { token, logprob } => {
                    assert!(logprob.is_finite());
                    streamed.push(token);
                }
                Event::Done(c) => break c,
                ev => panic!("unexpected terminal: {ev:?}"),
            }
        };
        assert!(routed_seen, "no routing event before completion");
        assert_eq!(streamed, c.tokens, "concatenated Event::Tokens != Completion::tokens");
    }
    server.shutdown().unwrap();

    // the engine-level streaming path agrees with the blocking path too
    let rt = Runtime::load(&artifacts).unwrap();
    let eng = LmEngine::init(rt.clone(), "nano", 3).unwrap();
    let g = rt.manifest.globals;
    let prompts: Vec<&[i32]> = corpus
        .iter()
        .take(g.genb + 1) // force a second wave to cover the offset math
        .map(|q| q.prompt.as_slice())
        .collect();
    let seeds: Vec<u32> = (0..prompts.len() as u32).collect();
    let mut streams: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
    let streamed_resp = eng
        .generate_streaming(&prompts, &seeds, 0.8, &mut |i, t, _| streams[i].push(t))
        .unwrap();
    let blocking = eng.generate_with(&prompts, &seeds, 0.8, false).unwrap();
    for ((s, r), b) in streams.iter().zip(&streamed_resp).zip(&blocking) {
        assert_eq!(s, &r.tokens, "callback stream != returned response");
        assert_eq!(&r.tokens, &b.tokens, "streaming changed the decode");
    }
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn cancellation_frees_slot_without_touching_others() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "cancel");
    let corpus = generate(31, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(6).map(|q| q.prompt.clone()).collect();
    // greedy decode (temp 0): tokens depend only on each slot's own
    // prompt, so run B must reproduce run A's survivors exactly
    let greedy_cfg = |tag: &str| {
        let mut cfg = base_cfg(artifacts.clone(), seed_run_dir(&artifacts, tag), BatchMode::Continuous);
        cfg.temp = 0.0;
        cfg
    };

    // run A: no cancellation — the reference tokens
    let server = Server::start(greedy_cfg("cancel")).unwrap();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(Request::new(p.clone())).expect("submit"))
        .collect();
    let reference: Vec<Vec<i32>> = handles
        .into_iter()
        .map(|h| h.wait_timeout(Duration::from_secs(120)).expect("completion").tokens)
        .collect();
    server.shutdown().unwrap();

    // run B: same prompts, same order, but cancel the victim once it is
    // in flight (after its first streamed token)
    let server = Server::start(greedy_cfg("cancel")).unwrap();
    let victim = 2usize;
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(Request::new(p.clone())).expect("submit"))
        .collect();
    let mut cancelled = false;
    let mut victim_done_early = false;
    for (i, h) in handles.iter().enumerate() {
        if i != victim {
            continue;
        }
        // wait for evidence the victim occupies a KV slot, then cancel
        loop {
            match h.events().recv_timeout(Duration::from_secs(120)).expect("event") {
                Event::Token { .. } => {
                    h.cancel();
                    break;
                }
                Event::Done(_) => {
                    // answered before the cancel could land
                    victim_done_early = true;
                    break;
                }
                Event::Routed { .. } => {}
                ev => panic!("unexpected event {ev:?}"),
            }
        }
    }
    for (i, h) in handles.into_iter().enumerate() {
        if i == victim {
            if victim_done_early {
                continue; // terminal event already consumed above
            }
            match h.wait_timeout(Duration::from_secs(120)) {
                Err(RequestError::Cancelled) => cancelled = true,
                Ok(_) => {} // completed before the cancel landed
                Err(e) => panic!("victim: {e}"),
            }
            continue;
        }
        let c = h.wait_timeout(Duration::from_secs(120)).expect("completion");
        assert_eq!(
            c.tokens, reference[i],
            "request {i}: cancelling the victim changed another slot's tokens"
        );
    }
    let stats = server.shutdown().unwrap();
    if cancelled {
        assert_eq!(stats.routing.cancelled_total(), 1, "cancellation must be counted");
        assert_eq!(stats.e2e_latency.n, prompts.len() - 1);
    }
    assert_eq!(stats.in_flight, 0, "cancelled request retired from the window");
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn full_admission_window_returns_busy() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "busy");
    let mut cfg = base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous);
    cfg.queue_cap = 2;
    let server = Server::start(cfg).unwrap();
    let corpus = generate(37, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(6).map(|q| q.prompt.clone()).collect();
    let mut accepted = Vec::new();
    let mut busy = 0usize;
    for p in &prompts {
        match server.submit(Request::new(p.clone())) {
            Ok(h) => accepted.push(h),
            Err(SubmitError::Busy) => busy += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    // six instant submissions against a window of two: decode takes
    // milliseconds, so at least one must have been pushed back
    assert!(busy >= 1, "no backpressure despite a full window");
    assert!(accepted.len() >= 2);
    for h in accepted {
        h.wait_timeout(Duration::from_secs(120)).expect("accepted requests complete");
    }
    // the window drains: new submissions are accepted again
    let h = server
        .submit(Request::new(prompts[0].clone()))
        .expect("window must reopen after completions");
    h.wait_timeout(Duration::from_secs(120)).expect("completion");
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.in_flight, 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

#[test]
fn deadline_expired_requests_are_shed() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "shed");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(41, Scale::Smoke);
    // a deadline that is already expired at submit time must be shed at
    // dispatch with Event::Failed, never decoded
    let h = server
        .submit(
            Request::new(corpus[0].prompt.clone()).deadline(Duration::from_nanos(1)),
        )
        .expect("submit");
    match h.wait_timeout(Duration::from_secs(60)) {
        Err(RequestError::Failed(reason)) => {
            assert!(reason.contains("deadline"), "unexpected reason: {reason}");
        }
        other => panic!("expected a deadline failure, got {other:?}"),
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.shed_total(), 1);
    assert_eq!(stats.routing.total(), 0, "shed requests are not counted as routed");
    assert_eq!(stats.in_flight, 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Regression (PR 10): a deadline that expires *mid-decode* must shed the
/// request from inside the decode loop — terminal `Event::Failed` with the
/// distinct "deadline expired mid-decode" reason, KV slot released — instead
/// of burning decode steps to completion the caller will never read. Decode
/// and dispatch latency are hardware-dependent, so the test scans deadlines
/// from tight to loose: pre-dispatch sheds (dispatch outran the deadline)
/// step to the next rung; the first rung that clears dispatch but not the
/// full `A_MAX`-token decode is the regression case.
#[test]
fn mid_decode_deadline_expiry_sheds_and_frees_the_slot() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "middecode");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(61, Scale::Smoke);
    // warm the compile caches so dispatch latency is milliseconds, not the
    // first-request PJRT load
    server
        .submit(Request::new(corpus[0].prompt.clone()))
        .expect("warm-up submit")
        .wait_timeout(Duration::from_secs(120))
        .expect("warm-up completion");

    let mut saw_mid_decode = false;
    let mut deadline_ms = 2u64;
    for _ in 0..12 {
        let h = server
            .submit(
                Request::new(corpus[1].prompt.clone())
                    .max_new_tokens(hybrid_llm::corpus::A_MAX)
                    .deadline(Duration::from_millis(deadline_ms)),
            )
            .expect("submit");
        match h.wait_timeout(Duration::from_secs(120)) {
            Err(RequestError::Failed(reason)) if reason.contains("mid-decode") => {
                assert!(
                    reason.contains("deadline expired mid-decode"),
                    "unexpected mid-decode reason: {reason}"
                );
                saw_mid_decode = true;
                break;
            }
            Err(RequestError::Failed(reason)) => {
                // shed before decode: dispatch was slower than this rung
                assert!(reason.contains("deadline"), "unexpected failure: {reason}");
                deadline_ms = deadline_ms * 3 / 2 + 1;
            }
            Ok(_) => {
                // the full decode beat the deadline — the window between
                // dispatch and completion was jumped; keep scanning
                deadline_ms = deadline_ms * 3 / 2 + 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(
        saw_mid_decode,
        "no deadline rung shed mid-decode — the in-flight sweep is not running"
    );
    // the swept request's KV slot is free again: a normal request completes
    server
        .submit(Request::new(corpus[2].prompt.clone()))
        .expect("post-shed submit")
        .wait_timeout(Duration::from_secs(120))
        .expect("post-shed completion");
    let stats = server.shutdown().unwrap();
    assert!(stats.routing.shed_total() >= 1, "mid-decode expiry must count under shed");
    assert_eq!(stats.in_flight, 0, "swept request retired from the admission window");
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// Satellite (PR 10): NaN and out-of-`[0, 1]` quality targets are rejected
/// at submit with the typed `SubmitError::InvalidQuality`, before any
/// admission-window slot is spent; the boundary values 0.0 and 1.0 are
/// legal and serve normally.
#[test]
fn invalid_quality_targets_rejected_at_submit() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let run_dir = seed_run_dir(&artifacts, "badq");
    let server =
        Server::start(base_cfg(artifacts, run_dir.clone(), BatchMode::Continuous)).unwrap();
    let corpus = generate(67, Scale::Smoke);
    let prompt = corpus[0].prompt.clone();
    for bad in [f32::NAN, -0.5, 1.5, f32::INFINITY] {
        match server.submit(Request::new(prompt.clone()).quality(bad)) {
            Err(SubmitError::InvalidQuality { quality }) => {
                if bad.is_nan() {
                    assert!(quality.is_nan());
                } else {
                    assert_eq!(quality, bad);
                }
            }
            other => panic!(
                "quality {bad}: expected InvalidQuality, got {:?}",
                other.map(|h| h.id())
            ),
        }
    }
    for ok in [0.0f32, 1.0] {
        server
            .submit(Request::new(prompt.clone()).quality(ok))
            .expect("boundary quality accepted")
            .wait_timeout(Duration::from_secs(120))
            .expect("boundary-quality request completes");
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.routing.total(), 2, "rejected qualities never reached routing");
    assert_eq!(stats.in_flight, 0);
    let _ = std::fs::remove_dir_all(&run_dir);
}

/// The brownout A/B pin (DESIGN.md §13): at brownout level 0 every actuator
/// is the identity, so a server whose controller is armed (but never
/// tripped — the target sojourn is far above what one-at-a-time traffic can
/// reach) must make byte-identical routing decisions and greedy tokens to a
/// server built without the controller (`brownout_target: None`).
#[test]
fn disarmed_and_level0_brownout_decode_identical_tokens() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let corpus = generate(71, Scale::Smoke);
    let prompts: Vec<Vec<i32>> = corpus.iter().take(6).map(|q| q.prompt.clone()).collect();
    let run = |tag: &str, target: Option<Duration>| -> (Vec<(usize, Vec<i32>)>, u64) {
        let run_dir = seed_run_dir(&artifacts, tag);
        let mut cfg = base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous);
        cfg.temp = 0.0; // the byte-identity claim is greedy-only
        cfg.brownout_target = target;
        let server = Server::start(cfg).unwrap();
        let out = prompts
            .iter()
            .map(|p| {
                let c = server
                    .submit(Request::new(p.clone()).quality(0.9))
                    .expect("submit")
                    .wait_timeout(Duration::from_secs(120))
                    .expect("completion");
                (c.tier, c.tokens)
            })
            .collect();
        let stats = server.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&run_dir);
        (out, stats.brownout_level)
    };
    let (armed, level) = run("bo_armed", Some(Duration::from_secs(5)));
    let (disarmed, _) = run("bo_off", None);
    assert_eq!(level, 0, "one-at-a-time traffic must never trip the controller");
    for (i, (a, d)) in armed.iter().zip(&disarmed).enumerate() {
        assert_eq!(a.0, d.0, "request {i}: level-0 brownout changed the routing decision");
        assert_eq!(a.1, d.1, "request {i}: level-0 brownout changed the greedy decode");
    }
}

/// The hybrid draft–verify pin (DESIGN.md §12): at temperature 0 with an
/// always-verify quality target, token-level hybrid decoding must be
/// **byte-identical** to routing every request to the large tier —
/// longest-prefix acceptance plus the correction token re-derives
/// exactly the large model's greedy stream, whatever the small tier
/// drafts. Budgets are varied so draft blocks of every length occur
/// (including budget 1, which finishes at prefill with no drafting).
#[test]
fn hybrid_decode_matches_large_only_greedy() {
    let Some(artifacts) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&artifacts).unwrap();
    if !(rt.manifest.has_verify("micro") && rt.manifest.has_paged_kv("nano")) {
        eprintln!("skipping: artifacts predate verify@K");
        return;
    }
    let run_dir = seed_run_dir(&artifacts, "hybeq");
    let mut cfg = base_cfg(artifacts.clone(), run_dir.clone(), BatchMode::Continuous);
    cfg.temp = 0.0; // the byte-identity claim is greedy-only
    let server = Server::start(cfg).unwrap();
    let corpus = generate(53, Scale::Smoke);
    let budgets = [1usize, 2, 5, rt.manifest.globals.amax];
    let prompts: Vec<(Vec<i32>, usize)> = corpus
        .iter()
        .filter(|q| q.split == Split::Test)
        .take(8)
        .enumerate()
        .map(|(i, q)| (q.prompt.clone(), budgets[i % budgets.len()]))
        .collect();

    // reference: every request pinned to the large tier, routed decode
    let handles: Vec<_> = prompts
        .iter()
        .map(|(p, m)| {
            server
                .submit(
                    Request::new(p.clone())
                        .max_new_tokens(*m)
                        .policy(TierPolicy::Fixed { tier: 1 }),
                )
                .expect("submit routed reference")
        })
        .collect();
    let reference: Vec<Vec<i32>> = handles
        .into_iter()
        .map(|h| h.wait_timeout(Duration::from_secs(120)).expect("reference completion").tokens)
        .collect();

    // hybrid: same prompts and budgets, quality 1.0 => always verify
    let handles: Vec<_> = prompts
        .iter()
        .map(|(p, m)| {
            server
                .submit(
                    Request::new(p.clone())
                        .max_new_tokens(*m)
                        .quality(1.0)
                        .decode(DecodeMode::Hybrid),
                )
                .expect("submit hybrid")
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let c = h.wait_timeout(Duration::from_secs(120)).expect("hybrid completion");
        assert_eq!(
            c.tokens, reference[i],
            "request {i} (budget {}): hybrid stream diverged from large-only greedy",
            prompts[i].1
        );
    }
    let stats = server.shutdown().unwrap();
    // EOS-at-prefill completions bypass lane occupation, so <= not ==
    assert!(stats.hybrid_requests >= 1 && stats.hybrid_requests <= prompts.len() as u64);
    assert!(stats.verify_calls > 0, "always-verify hybrid decode made no verify calls");
    assert_eq!(stats.hybrid_degraded_blocks, 0, "no outage was injected");
    assert_eq!(stats.draft_local_accepted, 0, "quality 1.0 must never accept locally");
    assert!(
        stats.draft_accepted <= stats.draft_tokens,
        "ledger: accepted {} > drafted {}",
        stats.draft_accepted,
        stats.draft_tokens
    );
    let _ = std::fs::remove_dir_all(&run_dir);
}
